//! Evaluation harness: min-perplexity option scoring (lm-eval-harness
//! style), greedy/sampled generation, and continual-learning metrics.

pub mod generate;
pub mod ppl;
pub mod transfer;

pub use generate::{generate_accuracy, pass_at_k};
pub use ppl::{ppl_accuracy, ppl_accuracy_by_category};
pub use transfer::{backward_transfer, forward_transfer, average_performance};
