//! Generation-based evaluation: greedy decoding for exact-match
//! accuracy (GSM8K-style) and temperature sampling for Pass@k
//! (MBPP-style). Decoding re-runs the full forward per emitted token —
//! fine at these sequence lengths and keeps one artifact for
//! everything.

use anyhow::Result;

use crate::coordinator::state::ModelState;
use crate::data::vocab::{BOS, EOS, PAD};
use crate::data::EvalItem;
use crate::runtime::{ExecPlan, Runtime};
use crate::tensor::select::{argmax, softmax};
use crate::util::rng::Rng;

/// Decode up to `max_new` tokens after the prompt for a batch of
/// prompts. temperature = 0 → greedy. Parameters are bound statically
/// per `generate` call; only the token grid re-uploads per emitted
/// token.
pub struct Generator<'rt> {
    rt: &'rt Runtime,
    exe: std::sync::Arc<crate::runtime::Executable>,
}

impl<'rt> Generator<'rt> {
    pub fn new(rt: &'rt Runtime) -> Result<Self> {
        Ok(Generator {
            rt,
            exe: rt.load("fwd_logits")?,
        })
    }

    /// Generate continuations for up to `batch` prompts at once.
    pub fn generate(
        &self,
        state: &ModelState,
        prompts: &[Vec<u32>],
        max_new: usize,
        temperature: f32,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<u32>>> {
        let b = self.rt.cfg.batch;
        let s = self.rt.cfg.seq_len;
        let v = self.rt.cfg.vocab;
        assert!(prompts.len() <= b, "at most {b} prompts per call");
        // rows: BOS + prompt, padded
        let mut seqs: Vec<Vec<u32>> = prompts
            .iter()
            .map(|p| {
                let mut row = vec![BOS];
                row.extend_from_slice(p);
                assert!(row.len() + max_new <= s, "prompt too long");
                row
            })
            .collect();
        let mut done = vec![false; prompts.len()];
        let mut outs: Vec<Vec<u32>> =
            vec![Vec::new(); prompts.len()];

        // fwd_logits wants only params + tokens; params upload once
        let param_names: Vec<&str> = self
            .rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut plan = ExecPlan::new(
            std::sync::Arc::clone(&self.exe),
            &param_names,
        )?;
        plan.bind_params(state)?;

        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            // pack current sequences
            let mut tokens = vec![PAD as i32; b * s];
            for (i, seq) in seqs.iter().enumerate() {
                for (t, &tok) in seq.iter().enumerate() {
                    tokens[i * s + t] = tok as i32;
                }
            }
            plan.bind_i32("tokens", &[b, s], &tokens)?;
            let out = plan.run()?;
            let logits = &out[0]; // [B, S, V]
            for i in 0..prompts.len() {
                if done[i] {
                    continue;
                }
                let pos = seqs[i].len() - 1;
                let row =
                    &logits.data[(i * s + pos) * v..(i * s + pos + 1) * v];
                let next = if temperature <= 0.0 {
                    argmax(row) as u32
                } else {
                    let scaled: Vec<f32> =
                        row.iter().map(|x| x / temperature).collect();
                    let probs = softmax(&scaled);
                    sample(&probs, rng) as u32
                };
                if next == EOS {
                    done[i] = true;
                } else {
                    outs[i].push(next);
                    seqs[i].push(next);
                    if seqs[i].len() >= s {
                        done[i] = true;
                    }
                }
            }
        }
        Ok(outs)
    }
}

fn sample(probs: &[f32], rng: &mut Rng) -> usize {
    let u = rng.uniform();
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i;
        }
    }
    probs.len() - 1
}

/// Greedy exact-match accuracy over eval items (the correct option is
/// the reference answer).
pub fn generate_accuracy(
    rt: &Runtime,
    state: &ModelState,
    items: &[EvalItem],
) -> Result<f64> {
    let gen = Generator::new(rt)?;
    let mut rng = Rng::new(0);
    let b = rt.cfg.batch;
    let mut correct = 0usize;
    for chunk in items.chunks(b) {
        let prompts: Vec<Vec<u32>> =
            chunk.iter().map(|i| i.prompt.clone()).collect();
        let max_new = chunk
            .iter()
            .map(|i| i.options[i.correct].len())
            .max()
            .unwrap()
            + 1;
        let outs =
            gen.generate(state, &prompts, max_new, 0.0, &mut rng)?;
        for (item, out) in chunk.iter().zip(&outs) {
            let want = &item.options[item.correct];
            if out.len() >= want.len() && &out[..want.len()] == &want[..]
            {
                correct += 1;
            }
        }
    }
    Ok(100.0 * correct as f64 / items.len().max(1) as f64)
}

/// Pass@k via k temperature samples per item (MBPP protocol analogue).
pub fn pass_at_k(
    rt: &Runtime,
    state: &ModelState,
    items: &[EvalItem],
    k: usize,
    temperature: f32,
    seed: u64,
) -> Result<f64> {
    let gen = Generator::new(rt)?;
    let mut rng = Rng::new(seed);
    let b = rt.cfg.batch;
    let mut passed = 0usize;
    for item in items {
        let want = &item.options[item.correct];
        let mut hit = false;
        for _round in 0..k.div_ceil(b) {
            let n = b.min(k);
            let prompts = vec![item.prompt.clone(); n];
            let outs = gen.generate(
                state,
                &prompts,
                want.len() + 1,
                temperature,
                &mut rng,
            )?;
            if outs.iter().any(|o| {
                o.len() >= want.len() && o[..want.len()] == want[..]
            }) {
                hit = true;
                break;
            }
        }
        if hit {
            passed += 1;
        }
    }
    Ok(100.0 * passed as f64 / items.len().max(1) as f64)
}
