//! Generation-based evaluation: greedy decoding for exact-match
//! accuracy (GSM8K-style) and temperature sampling for Pass@k
//! (MBPP-style). When the config carries the `fwd_decode` artifact
//! (every builtin does), decoding runs KV-cached: the prompt prefills
//! once and each emitted token costs one incremental step instead of a
//! full-grid forward. Lowered manifests without `fwd_decode` fall back
//! to the historical full re-run per token — both paths produce
//! bitwise-identical logits (pinned by `tests/serve_parity.rs`), so
//! scores don't depend on which engine served them.

use anyhow::Result;

use crate::coordinator::state::ModelState;
use crate::data::vocab::{BOS, EOS, PAD};
use crate::data::EvalItem;
use crate::runtime::{ExecPlan, Runtime};
use crate::serve::{AdapterBinding, Decoder};
use crate::tensor::select::{argmax, sample_multinomial, softmax};
use crate::util::rng::Rng;
use crate::util::warn::warn;

/// Which forward serves the decode loop.
enum Engine<'rt> {
    /// KV-cached incremental decode (`fwd_decode`), backbone static,
    /// plain (no-adapter) binding per step.
    Decode {
        dec: Decoder<'rt>,
        plain: AdapterBinding,
    },
    /// Full-grid `fwd_logits` re-run per emitted token — the fallback
    /// when a lowered manifest predates the decode artifact.
    Grid { plan: ExecPlan },
}

/// Decode up to `max_new` tokens after the prompt for a batch of
/// prompts. temperature = 0 → greedy. A `Generator` is one decoding
/// pass over one model state: parameters are bound (and uploaded)
/// once at construction, so across every `generate` call of the pass
/// only the per-step token controls re-upload.
pub struct Generator<'rt> {
    rt: &'rt Runtime,
    engine: Engine<'rt>,
}

impl<'rt> Generator<'rt> {
    pub fn new(rt: &'rt Runtime, state: &ModelState) -> Result<Self> {
        let engine = if rt.cfg.has_artifact("fwd_decode") {
            Engine::Decode {
                dec: Decoder::new(rt, state)?,
                plain: AdapterBinding::plain(&rt.cfg),
            }
        } else {
            let exe = rt.load("fwd_logits")?;
            // fwd_logits wants only params + tokens; params upload once
            let param_names: Vec<&str> = rt
                .cfg
                .params
                .iter()
                .map(|(n, _)| n.as_str())
                .collect();
            let mut plan = ExecPlan::new(exe, &param_names)?;
            plan.bind_params(state)?;
            Engine::Grid { plan }
        };
        Ok(Generator { rt, engine })
    }

    /// Generate continuations for up to `batch` prompts at once.
    /// Errors are typed (`Result`), never panics: a malformed request
    /// fails this call only, so callers can keep scoring their other
    /// prompts.
    pub fn generate(
        &mut self,
        prompts: &[Vec<u32>],
        max_new: usize,
        temperature: f32,
        rng: &mut Rng,
    ) -> Result<Vec<Vec<u32>>> {
        let b = self.rt.cfg.batch;
        let s = self.rt.cfg.seq_len;
        let v = self.rt.cfg.vocab;
        anyhow::ensure!(
            prompts.len() <= b,
            "{} prompts in one call, artifact batch is {b}",
            prompts.len()
        );
        // rows: BOS + prompt, padded. Rows must fit the token grid;
        // generation length is additionally capped by seq_len below,
        // so an ambitious max_new truncates instead of erroring.
        let mut seqs = Vec::with_capacity(prompts.len());
        for p in prompts {
            let mut row = vec![BOS];
            row.extend_from_slice(p);
            anyhow::ensure!(
                row.len() <= s,
                "prompt of {} tokens (with BOS) exceeds seq_len {s}",
                row.len()
            );
            seqs.push(row);
        }
        let mut done: Vec<bool> = seqs
            .iter()
            .map(|row| row.len() >= s) // no room to emit anything
            .collect();
        let mut outs: Vec<Vec<u32>> =
            vec![Vec::new(); prompts.len()];
        if let Engine::Decode { dec, .. } = &mut self.engine {
            // each generate() call is a fresh pass over fresh prompts
            dec.clear_cache();
        }
        let mut primed = vec![false; prompts.len()];

        for _ in 0..max_new {
            if done.iter().all(|&d| d) {
                break;
            }
            // pack the still-active rows; finished rows stay idle
            // (lens 0 / PAD) so they cost nothing and can't perturb
            // their neighbours (rows are independent in the batch dim)
            let logits = match &mut self.engine {
                Engine::Decode { dec, plain } => {
                    let mut tokens = vec![PAD as i32; b * s];
                    let mut lens = vec![0i32; b];
                    let mut reset = vec![0i32; b];
                    for (i, seq) in seqs.iter().enumerate() {
                        if done[i] {
                            continue;
                        }
                        if primed[i] {
                            tokens[i * s] =
                                *seq.last().unwrap() as i32;
                            lens[i] = 1;
                        } else {
                            for (t, &tok) in seq.iter().enumerate()
                            {
                                tokens[i * s + t] = tok as i32;
                            }
                            lens[i] = seq.len() as i32;
                            reset[i] = 1;
                        }
                    }
                    dec.step(plain, &tokens, &lens, &reset)? // [B, V]
                }
                Engine::Grid { plan } => {
                    let mut tokens = vec![PAD as i32; b * s];
                    for (i, seq) in seqs.iter().enumerate() {
                        if done[i] {
                            continue;
                        }
                        for (t, &tok) in seq.iter().enumerate() {
                            tokens[i * s + t] = tok as i32;
                        }
                    }
                    plan.bind_i32("tokens", &[b, s], &tokens)?;
                    plan.run()?
                        .into_iter()
                        .next()
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "fwd_logits emitted no outputs"
                            )
                        })?
                        .into_host()? // [B, S, V]
                }
            };
            for i in 0..prompts.len() {
                if done[i] {
                    continue;
                }
                primed[i] = true;
                let row = match &self.engine {
                    // decode output is already last-position-only
                    Engine::Decode { .. } => {
                        &logits.data[i * v..(i + 1) * v]
                    }
                    Engine::Grid { .. } => {
                        let pos = seqs[i].len() - 1;
                        &logits.data
                            [(i * s + pos) * v..(i * s + pos + 1) * v]
                    }
                };
                let next = if temperature <= 0.0 {
                    argmax(row) as u32
                } else {
                    let scaled: Vec<f32> =
                        row.iter().map(|x| x / temperature).collect();
                    let probs = softmax(&scaled);
                    sample(&probs, rng) as u32
                };
                if next == EOS {
                    done[i] = true;
                } else {
                    outs[i].push(next);
                    seqs[i].push(next);
                    if seqs[i].len() >= s {
                        done[i] = true;
                    }
                }
            }
        }
        Ok(outs)
    }
}

fn sample(probs: &[f32], rng: &mut Rng) -> usize {
    sample_multinomial(probs, rng.uniform())
}

/// The reference answer of an eval item, as a typed error instead of
/// the `item.options[item.correct]` index panic: a single malformed
/// item used to take down a whole eval pass (the crash family PR 3
/// fixed in `ppl.rs`).
fn reference_option(item: &EvalItem) -> Result<&Vec<u32>> {
    item.options.get(item.correct).ok_or_else(|| {
        anyhow::anyhow!(
            "eval item: correct-option index {} out of range \
             ({} options)",
            item.correct,
            item.options.len()
        )
    })
}

/// Greedy exact-match accuracy over eval items (the correct option is
/// the reference answer). Malformed items — a correct index past the
/// option list, or a prompt that cannot fit the token grid — score as
/// incorrect (with a warning) while every other prompt keeps scoring.
pub fn generate_accuracy(
    rt: &Runtime,
    state: &ModelState,
    items: &[EvalItem],
) -> Result<f64> {
    let mut gen = Generator::new(rt, state)?;
    let mut rng = Rng::new(0);
    let b = rt.cfg.batch;
    let s = rt.cfg.seq_len;
    let mut scorable: Vec<(&EvalItem, &Vec<u32>)> = Vec::new();
    for item in items {
        match reference_option(item) {
            // BOS + prompt + at least one generated token must fit
            Ok(_) if 1 + item.prompt.len() >= s => warn(format!(
                "[eval] prompt of {} tokens cannot fit seq_len {s}; \
                 scored incorrect",
                item.prompt.len()
            )),
            Ok(want) => scorable.push((item, want)),
            Err(e) => warn(format!(
                "[eval] skipping item (scored incorrect): {e}"
            )),
        }
    }
    let mut correct = 0usize;
    for chunk in scorable.chunks(b) {
        let prompts: Vec<Vec<u32>> =
            chunk.iter().map(|(i, _)| i.prompt.clone()).collect();
        let max_new = chunk
            .iter()
            .map(|(_, w)| w.len())
            .max()
            .unwrap_or(0)
            + 1;
        let outs = gen.generate(&prompts, max_new, 0.0, &mut rng)?;
        for ((_, want), out) in chunk.iter().zip(&outs) {
            if out.len() >= want.len() && out[..want.len()] == want[..]
            {
                correct += 1;
            }
        }
    }
    Ok(100.0 * correct as f64 / items.len().max(1) as f64)
}

/// Per-round batch sizes for drawing exactly `k` samples with batch
/// capacity `b`: every round draws what's left, capped at `b`. The
/// historical loop drew `b.min(k)` every round, over-sampling whenever
/// `b < k` and `b ∤ k` (k=6, b=4 → 8 samples instead of 6) — inflating
/// Pass@k beyond its budget.
fn round_sizes(k: usize, b: usize) -> Vec<usize> {
    let mut sizes = Vec::new();
    let mut drawn = 0;
    while drawn < k {
        let n = b.min(k - drawn);
        sizes.push(n);
        drawn += n;
    }
    sizes
}

/// Pass@k via k temperature samples per item (MBPP protocol analogue).
/// Malformed items score as failed instead of panicking the pass.
pub fn pass_at_k(
    rt: &Runtime,
    state: &ModelState,
    items: &[EvalItem],
    k: usize,
    temperature: f32,
    seed: u64,
) -> Result<f64> {
    let mut gen = Generator::new(rt, state)?;
    let mut rng = Rng::new(seed);
    let b = rt.cfg.batch;
    let s = rt.cfg.seq_len;
    let mut passed = 0usize;
    for item in items {
        let want = match reference_option(item) {
            Ok(w) if 1 + item.prompt.len() < s => w,
            Ok(_) => {
                warn(format!(
                    "[eval] prompt of {} tokens cannot fit seq_len \
                     {s}; scored failed",
                    item.prompt.len()
                ));
                continue;
            }
            Err(e) => {
                warn(format!(
                    "[eval] skipping item (scored failed): {e}"
                ));
                continue;
            }
        };
        let mut hit = false;
        for n in round_sizes(k, b) {
            let prompts = vec![item.prompt.clone(); n];
            let outs = gen.generate(
                &prompts,
                want.len() + 1,
                temperature,
                &mut rng,
            )?;
            if outs.iter().any(|o| {
                o.len() >= want.len() && o[..want.len()] == want[..]
            }) {
                hit = true;
                break;
            }
        }
        if hit {
            passed += 1;
        }
    }
    Ok(100.0 * passed as f64 / items.len().max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_option_is_a_typed_error_not_a_panic() {
        let bad = EvalItem {
            prompt: vec![1, 2],
            options: vec![vec![3], vec![4]],
            correct: 7,
            category: "t",
        };
        let err = reference_option(&bad).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains('7'), "{msg}");
        assert!(msg.contains("2 options"), "{msg}");
        let ok = EvalItem { correct: 1, ..bad };
        assert_eq!(reference_option(&ok).unwrap(), &vec![4]);
    }

    #[test]
    fn round_sizes_draw_exactly_k() {
        // the regression: k=6, b=4 used to draw 4+4=8 samples
        assert_eq!(round_sizes(6, 4), vec![4, 2]);
        assert_eq!(round_sizes(4, 4), vec![4]);
        assert_eq!(round_sizes(3, 8), vec![3]);
        assert_eq!(round_sizes(9, 4), vec![4, 4, 1]);
        assert_eq!(round_sizes(0, 4), Vec::<usize>::new());
        for (k, b) in [(1, 1), (5, 2), (16, 4), (7, 3)] {
            assert_eq!(
                round_sizes(k, b).iter().sum::<usize>(),
                k,
                "k={k} b={b}"
            );
        }
    }

    #[test]
    fn malformed_items_score_incorrect_without_killing_the_pass() {
        let rt = crate::runtime::Runtime::with_backend(
            crate::config::resolve_config(
                &crate::runtime::artifacts_dir(),
                "tiny",
            )
            .unwrap(),
            Box::new(crate::runtime::RefBackend),
        );
        let mut rng = crate::util::rng::Rng::new(3);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let sane = EvalItem {
            prompt: vec![1, 2],
            options: vec![vec![3], vec![4]],
            correct: 0,
            category: "t",
        };
        let bad_index = EvalItem {
            correct: 9,
            ..sane.clone()
        };
        let long_prompt = EvalItem {
            prompt: vec![1; rt.cfg.seq_len + 4],
            ..sane.clone()
        };
        let items = vec![sane, bad_index, long_prompt];
        // previously: index-out-of-bounds / assert panic. Now: the
        // pass completes, malformed items count against accuracy.
        let acc = generate_accuracy(&rt, &state, &items).unwrap();
        assert!((0.0..=34.0).contains(&acc), "acc {acc}");
        let p = pass_at_k(&rt, &state, &items, 1, 0.5, 1).unwrap();
        assert!((0.0..=34.0).contains(&p), "pass@1 {p}");
    }
}
