//! Continual-learning metrics (paper §4.4, Table 5).
//!
//! With `perf[i][j]` = accuracy on task j after training through task
//! i (1-based rows; row 0 = single-task reference `p0`):
//!
//! * AP  = mean_j perf[N][j]
//! * FWT = mean_i (perf[i][i] − p0[i])
//! * BWT = mean_{i<N} (perf[N][i] − perf[i][i])

/// Average Performance after the full sequence.
pub fn average_performance(perf: &[Vec<f64>]) -> f64 {
    let last = perf.last().expect("empty matrix");
    last.iter().sum::<f64>() / last.len() as f64
}

/// Forward Transfer against single-task baselines `p0`.
pub fn forward_transfer(perf: &[Vec<f64>], p0: &[f64]) -> f64 {
    let n = perf.len();
    assert_eq!(p0.len(), n);
    (0..n)
        .map(|i| perf[i][i] - p0[i])
        .sum::<f64>()
        / n as f64
}

/// Backward Transfer (forgetting; more negative = worse).
pub fn backward_transfer(perf: &[Vec<f64>]) -> f64 {
    let n = perf.len();
    assert!(n >= 2, "BWT needs at least two tasks");
    (0..n - 1)
        .map(|i| perf[n - 1][i] - perf[i][i])
        .sum::<f64>()
        / (n - 1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix() -> Vec<Vec<f64>> {
        // 3 tasks; diagonal = just-trained accuracy
        vec![
            vec![80.0, 50.0, 50.0],
            vec![70.0, 90.0, 55.0],
            vec![60.0, 85.0, 95.0],
        ]
    }

    #[test]
    fn ap_is_last_row_mean() {
        assert!((average_performance(&matrix()) - 80.0).abs() < 1e-9);
    }

    #[test]
    fn fwt_against_single_task() {
        let p0 = vec![75.0, 88.0, 97.0];
        // (80-75)+(90-88)+(95-97) = 5  → /3
        assert!(
            (forward_transfer(&matrix(), &p0) - 5.0 / 3.0).abs() < 1e-9
        );
    }

    #[test]
    fn bwt_measures_forgetting() {
        // (60-80)+(85-90) = -25 → /2
        assert!((backward_transfer(&matrix()) + 12.5).abs() < 1e-9);
    }

    #[test]
    fn no_forgetting_gives_zero_bwt() {
        let perf = vec![vec![80.0, 0.0], vec![80.0, 90.0]];
        assert_eq!(backward_transfer(&perf), 0.0);
    }
}
