//! Continual-learning metrics (paper §4.4, Table 5).
//!
//! `perf` is the N×N accuracy matrix of an N-task sequence, 0-based:
//! `perf[i][j]` = accuracy on task j after training through task i
//! (so `perf[i][i]` is the just-trained accuracy and `perf[N-1]` the
//! final row). Single-task reference accuracies `p0` are passed
//! separately to [`forward_transfer`] — there is no reference row
//! inside the matrix.
//!
//! * AP  = mean_j perf[N−1][j]
//! * FWT = mean_i (perf[i][i] − p0[i])
//! * BWT = mean_{i<N−1} (perf[N−1][i] − perf[i][i])
//!
//! Every metric validates the matrix shape and returns a typed error
//! on ragged or empty input instead of panicking mid-report.

use anyhow::{ensure, Result};

/// Check `perf` is a non-empty N×N matrix; returns N.
fn validate_matrix(perf: &[Vec<f64>]) -> Result<usize> {
    let n = perf.len();
    ensure!(n > 0, "continual metrics: empty performance matrix");
    for (i, row) in perf.iter().enumerate() {
        ensure!(
            row.len() == n,
            "continual metrics: ragged performance matrix — row {i} \
             has {} entries, expected {n} (one per task)",
            row.len()
        );
    }
    Ok(n)
}

/// Average Performance over the final stage's row.
pub fn average_performance(perf: &[Vec<f64>]) -> Result<f64> {
    let n = validate_matrix(perf)?;
    let last = &perf[n - 1];
    Ok(last.iter().sum::<f64>() / n as f64)
}

/// Forward Transfer against single-task baselines `p0` (one per task).
pub fn forward_transfer(perf: &[Vec<f64>], p0: &[f64]) -> Result<f64> {
    let n = validate_matrix(perf)?;
    ensure!(
        p0.len() == n,
        "continual metrics: {} single-task baselines for {n} tasks",
        p0.len()
    );
    Ok((0..n).map(|i| perf[i][i] - p0[i]).sum::<f64>() / n as f64)
}

/// Backward Transfer (forgetting; more negative = worse).
pub fn backward_transfer(perf: &[Vec<f64>]) -> Result<f64> {
    let n = validate_matrix(perf)?;
    ensure!(n >= 2, "continual metrics: BWT needs at least two tasks");
    Ok((0..n - 1)
        .map(|i| perf[n - 1][i] - perf[i][i])
        .sum::<f64>()
        / (n - 1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn matrix() -> Vec<Vec<f64>> {
        // 3 tasks; diagonal = just-trained accuracy
        vec![
            vec![80.0, 50.0, 50.0],
            vec![70.0, 90.0, 55.0],
            vec![60.0, 85.0, 95.0],
        ]
    }

    #[test]
    fn ap_is_last_row_mean() {
        assert!(
            (average_performance(&matrix()).unwrap() - 80.0).abs()
                < 1e-9
        );
    }

    #[test]
    fn fwt_against_single_task() {
        let p0 = vec![75.0, 88.0, 97.0];
        // (80-75)+(90-88)+(95-97) = 5  → /3
        assert!(
            (forward_transfer(&matrix(), &p0).unwrap() - 5.0 / 3.0)
                .abs()
                < 1e-9
        );
    }

    #[test]
    fn bwt_measures_forgetting() {
        // (60-80)+(85-90) = -25 → /2
        assert!(
            (backward_transfer(&matrix()).unwrap() + 12.5).abs() < 1e-9
        );
    }

    #[test]
    fn no_forgetting_gives_zero_bwt() {
        let perf = vec![vec![80.0, 0.0], vec![80.0, 90.0]];
        assert_eq!(backward_transfer(&perf).unwrap(), 0.0);
    }

    #[test]
    fn ragged_matrix_is_a_typed_error_not_a_panic() {
        // row 1 is short — indexing perf[i][i] used to go out of
        // bounds here
        let ragged = vec![vec![80.0, 50.0], vec![70.0]];
        for err in [
            average_performance(&ragged).unwrap_err(),
            forward_transfer(&ragged, &[75.0, 88.0]).unwrap_err(),
            backward_transfer(&ragged).unwrap_err(),
        ] {
            let msg = err.to_string();
            assert!(msg.contains("ragged"), "{msg}");
            assert!(msg.contains("row 1"), "{msg}");
        }
    }

    #[test]
    fn empty_and_undersized_inputs_are_typed_errors() {
        assert!(average_performance(&[]).is_err());
        let one = vec![vec![50.0]];
        assert!(backward_transfer(&one).is_err());
        // baseline length mismatch
        assert!(forward_transfer(&matrix(), &[1.0]).is_err());
    }
}
