//! Min-perplexity option scoring: for each eval item, score every
//! option's summed answer NLL through the `fwd_loss` artifact and pick
//! the minimum (the protocol behind the paper's Table 2 / MMLU-PPL).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::coordinator::state::ModelState;
use crate::data::batcher::pack_example;
use crate::data::{EvalItem, Example};
use crate::runtime::{ExecPlan, Runtime};

/// Scored candidate streams are packed batch-first; the artifact has a
/// fixed batch size so candidates are chunked and padded. Parameters
/// are bound statically (uploaded once per scoring pass); only the
/// packed batch crosses the host boundary per chunk.
struct NllScorer<'rt> {
    rt: &'rt Runtime,
    exe: std::sync::Arc<crate::runtime::Executable>,
}

impl<'rt> NllScorer<'rt> {
    fn new(rt: &'rt Runtime) -> Result<Self> {
        Ok(NllScorer {
            rt,
            exe: rt.load("fwd_loss")?,
        })
    }

    /// Summed answer NLL for each (prompt, answer) pair.
    fn score(
        &self,
        state: &ModelState,
        pairs: &[Example],
    ) -> Result<Vec<f64>> {
        let b = self.rt.cfg.batch;
        let s = self.rt.cfg.seq_len;
        let param_names: Vec<&str> = self
            .rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut plan = ExecPlan::new(
            std::sync::Arc::clone(&self.exe),
            &param_names,
        )?;
        plan.bind_params(state)?;
        let mut out = Vec::with_capacity(pairs.len());
        for chunk in pairs.chunks(b) {
            let mut tokens = Vec::with_capacity(b * s);
            let mut targets = Vec::with_capacity(b * s);
            let mut mask = Vec::with_capacity(b * s);
            for i in 0..b {
                let ex = chunk.get(i).unwrap_or(&chunk[0]);
                let (t, y, m) = pack_example(ex, s);
                tokens.extend(t);
                targets.extend(y);
                mask.extend(m);
            }
            let batch = crate::data::Batch {
                tokens,
                targets,
                mask,
                batch: b,
                seq: s,
            };
            plan.bind_batch(&batch)?;
            // fwd_loss emits (nll, cnt); scoring only reads nll, so
            // the cnt handle is dropped device-side undownloaded
            let mut res = plan.run()?;
            let nll_idx = res
                .iter()
                .position(|h| h.name() == "nll")
                .ok_or_else(|| {
                    anyhow::anyhow!("fwd_loss emitted no nll output")
                })?;
            let nll = res.swap_remove(nll_idx).into_host()?; // [B]
            for i in 0..chunk.len() {
                out.push(nll.data[i] as f64);
            }
        }
        Ok(out)
    }
}

/// Accuracy of min-PPL option choice over eval items.
pub fn ppl_accuracy(
    rt: &Runtime,
    state: &ModelState,
    items: &[EvalItem],
) -> Result<f64> {
    Ok(ppl_accuracy_by_category(rt, state, items)?
        .remove("__all__")
        .unwrap_or(0.0))
}

/// Accuracy overall (key `"__all__"`) and per category (the MMLU-style
/// breakdown of paper Table 12).
pub fn ppl_accuracy_by_category(
    rt: &Runtime,
    state: &ModelState,
    items: &[EvalItem],
) -> Result<BTreeMap<String, f64>> {
    let scorer = NllScorer::new(rt)?;
    // flatten all (item, option) pairs into one scoring stream
    let mut pairs = Vec::new();
    for item in items {
        for opt in &item.options {
            pairs.push(Example {
                prompt: item.prompt.clone(),
                answer: opt.clone(),
            });
        }
    }
    let scores = scorer.score(state, &pairs)?;
    let mut cursor = 0usize;
    let mut hits: BTreeMap<String, (usize, usize)> = BTreeMap::new();
    for item in items {
        let k = item.options.len();
        let s = &scores[cursor..cursor + k];
        cursor += k;
        let correct = best_option(s) == Some(item.correct);
        for key in ["__all__", item.category] {
            let e = hits.entry(key.to_string()).or_insert((0, 0));
            e.1 += 1;
            if correct {
                e.0 += 1;
            }
        }
    }
    Ok(hits
        .into_iter()
        .map(|(k, (c, n))| (k, 100.0 * c as f64 / n.max(1) as f64))
        .collect())
}

/// Index of the minimum-NLL option, ignoring NaN scores.
///
/// A divergent run can turn an option's NLL into NaN; a
/// `partial_cmp().unwrap()` there used to panic the whole eval pass.
/// NaN options simply cannot win, and an all-NaN (or empty) option
/// set returns `None` so the item scores as incorrect instead of
/// crashing.
fn best_option(scores: &[f64]) -> Option<usize> {
    scores
        .iter()
        .enumerate()
        .filter(|(_, s)| !s.is_nan())
        .min_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_option_picks_min() {
        assert_eq!(best_option(&[3.0, 1.0, 2.0]), Some(1));
    }

    #[test]
    fn best_option_ignores_nan_scores() {
        assert_eq!(
            best_option(&[f64::NAN, 2.0, 1.0, f64::NAN]),
            Some(2)
        );
        // -inf is still an orderable value, NaN is not
        assert_eq!(
            best_option(&[f64::NAN, f64::NEG_INFINITY]),
            Some(1)
        );
    }

    #[test]
    fn all_nan_options_score_as_incorrect_not_panic() {
        assert_eq!(best_option(&[f64::NAN, f64::NAN]), None);
        assert_eq!(best_option(&[]), None);
    }
}
