//! Structured run reports.
//!
//! Every session — the `losia` CLI, the benches, and multi-task
//! continual-learning sequences — summarises a run in the same
//! [`RunReport`] shape: method, losses, accuracies, latency,
//! trainable-parameter count, and subnet-selection stats. Reports
//! serialize to JSON through [`crate::util::json`] and round-trip
//! losslessly, so downstream tooling can diff runs without scraping
//! stdout tables.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Per-artifact executor stats for one stage: how often an artifact
/// ran, how long it took, and how much host→device parameter traffic
/// it generated (static re-binds vs per-step uploads). Fed by the
/// stock [`crate::session::observer::ExecProfileObserver`]; the BENCH
/// trajectory tracks executor overhead PR-over-PR through these.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ExecProfile {
    pub artifact: String,
    pub calls: u64,
    /// execute-phase wall time (transfers are the two fields below)
    pub total_secs: f64,
    pub mean_secs: f64,
    /// host→device bind-phase wall time on the training thread (the
    /// *exposed* share of upload time)
    pub upload_secs: f64,
    /// device→host download-phase wall time
    pub download_secs: f64,
    /// staged-upload wall time performed off-thread by the step
    /// pipeline — overlapped with execution, 0 for synchronous runs
    pub overlap_secs: f64,
    /// re-uploads of static bindings (frozen params/indices); 0
    /// between LoSiA relocalizations by design
    pub static_uploads: u64,
    /// per-step uploads (batch tensors, subnet deltas, …)
    pub step_uploads: u64,
    /// outputs materialised host-side (lazy `OutputHandle` downloads)
    pub downloads: u64,
    /// device→host bytes those downloads moved
    pub download_bytes: u64,
}

impl ExecProfile {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("artifact".into(), Json::Str(self.artifact.clone()));
        m.insert("calls".into(), Json::Num(self.calls as f64));
        m.insert("total_secs".into(), Json::Num(self.total_secs));
        m.insert("mean_secs".into(), Json::Num(self.mean_secs));
        m.insert("upload_secs".into(), Json::Num(self.upload_secs));
        m.insert(
            "download_secs".into(),
            Json::Num(self.download_secs),
        );
        m.insert("overlap_secs".into(), Json::Num(self.overlap_secs));
        m.insert(
            "static_uploads".into(),
            Json::Num(self.static_uploads as f64),
        );
        m.insert(
            "step_uploads".into(),
            Json::Num(self.step_uploads as f64),
        );
        m.insert("downloads".into(), Json::Num(self.downloads as f64));
        m.insert(
            "download_bytes".into(),
            Json::Num(self.download_bytes as f64),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(ExecProfile {
            artifact: get_str(j, "artifact")?,
            calls: get_u64(j, "calls")?,
            total_secs: get_num(j, "total_secs")?,
            mean_secs: get_num(j, "mean_secs")?,
            // reports written before the phase-timing split (PR 5)
            // lack the wall-time keys — they read as zero, like the
            // PR 4 download-split precedent below
            upload_secs: get_num_or_zero(j, "upload_secs")?,
            download_secs: get_num_or_zero(j, "download_secs")?,
            // reports written before the step pipeline (PR 9) lack
            // the overlap key — synchronous runs have zero overlap
            overlap_secs: get_num_or_zero(j, "overlap_secs")?,
            static_uploads: get_u64(j, "static_uploads")?,
            step_uploads: get_u64(j, "step_uploads")?,
            // reports written before the download split lack the keys
            downloads: get_u64_or_zero(j, "downloads")?,
            download_bytes: get_u64_or_zero(j, "download_bytes")?,
        })
    }

    /// One-line human summary (`losia info --report` / table16).
    pub fn summary_line(&self) -> String {
        format!(
            "{}: {} calls, {:.3} ms/call ({:.3}s exec, {:.3}s upl, \
             {:.3}s dl, {:.3}s ovl), uploads static {} / per-step {}, \
             downloads {} ({:.1} KB)",
            self.artifact,
            self.calls,
            self.mean_secs * 1e3,
            self.total_secs,
            self.upload_secs,
            self.download_secs,
            self.overlap_secs,
            self.static_uploads,
            self.step_uploads,
            self.downloads,
            self.download_bytes as f64 / 1024.0,
        )
    }
}

/// Data-parallel stats for one stage. Present only when the sharded
/// loop ran (`DpConfig::enabled()`); fed by the stock
/// [`crate::session::observer::DpProfileObserver`].
#[derive(Debug, Clone, PartialEq, Default)]
pub struct DpReport {
    /// plan-replica (worker) count
    pub workers: usize,
    /// logical shard count (the numerics knob)
    pub shards: usize,
    /// bytes one shard contributed to the reduction per step —
    /// subnet-delta-sized for LoSiA-Pro (pinned by
    /// `tests/dp_parity.rs`), trainable-set-sized otherwise
    pub frame_bytes: u64,
    /// total wall seconds inside the fixed-order tree reduction
    pub reduce_secs: f64,
    /// total busy seconds summed across all workers
    pub worker_busy_secs: f64,
}

impl DpReport {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert("shards".into(), Json::Num(self.shards as f64));
        m.insert(
            "frame_bytes".into(),
            Json::Num(self.frame_bytes as f64),
        );
        m.insert("reduce_secs".into(), Json::Num(self.reduce_secs));
        m.insert(
            "worker_busy_secs".into(),
            Json::Num(self.worker_busy_secs),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(DpReport {
            workers: get_usize(j, "workers")?,
            shards: get_usize(j, "shards")?,
            frame_bytes: get_u64(j, "frame_bytes")?,
            reduce_secs: get_num(j, "reduce_secs")?,
            worker_busy_secs: get_num(j, "worker_busy_secs")?,
        })
    }
}

/// Step-pipeline stats for one stage. Present only when the pipelined
/// loop ran (`PipelineConfig::enabled`); fed by the stock
/// [`crate::session::observer::PipelineProfileObserver`]. Mirrors the
/// [`DpReport`] JSON contract: absent/null for synchronous runs.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct PipelineReport {
    /// staging sets in rotation (the prefetch queue bound)
    pub queue_depth: usize,
    /// worker threads the pipeline ran (pack + stage)
    pub prefetch_threads: usize,
    /// total wall seconds the training thread spent blocked waiting
    /// for a staged group — the *exposed* share of prefetch + staging
    pub stall_secs: f64,
    /// total bytes uploaded off-thread across the stage
    pub staged_bytes: u64,
}

impl PipelineReport {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "queue_depth".into(),
            Json::Num(self.queue_depth as f64),
        );
        m.insert(
            "prefetch_threads".into(),
            Json::Num(self.prefetch_threads as f64),
        );
        m.insert("stall_secs".into(), Json::Num(self.stall_secs));
        m.insert(
            "staged_bytes".into(),
            Json::Num(self.staged_bytes as f64),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(PipelineReport {
            queue_depth: get_usize(j, "queue_depth")?,
            prefetch_threads: get_usize(j, "prefetch_threads")?,
            stall_secs: get_num(j, "stall_secs")?,
            staged_bytes: get_u64(j, "staged_bytes")?,
        })
    }
}

/// Durable-checkpoint stats for one stage. Present only when the
/// stage wrote checkpoints or resumed from one; fed by the stock
/// [`crate::session::observer::CheckpointProfileObserver`]. Mirrors
/// the [`DpReport`] JSON contract: absent/null otherwise.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct CheckpointReport {
    /// durable checkpoints written during the stage
    pub writes: usize,
    /// total bytes those writes moved
    pub bytes: u64,
    /// path of the newest checkpoint written (`None` when the stage
    /// only resumed and never reached another write)
    pub last_path: Option<String>,
    /// completed-step count the stage resumed from (`None` for fresh
    /// starts)
    pub resume_step: Option<usize>,
}

impl CheckpointReport {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("writes".into(), Json::Num(self.writes as f64));
        m.insert("bytes".into(), Json::Num(self.bytes as f64));
        m.insert(
            "last_path".into(),
            match &self.last_path {
                Some(p) => Json::Str(p.clone()),
                None => Json::Null,
            },
        );
        m.insert(
            "resume_step".into(),
            opt_num(self.resume_step.map(|x| x as f64)),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        Ok(CheckpointReport {
            writes: get_usize(j, "writes")?,
            bytes: get_u64(j, "bytes")?,
            last_path: match j.get("last_path") {
                None | Some(Json::Null) => None,
                Some(Json::Str(s)) => Some(s.clone()),
                Some(other) => bail!(
                    "report field \"last_path\": expected string or \
                     null, got {other:?}"
                ),
            },
            resume_step: get_opt_usize(j, "resume_step")?,
        })
    }
}

/// Summary of one training (or evaluation-only) stage.
#[derive(Debug, Clone, PartialEq)]
pub struct RunReport {
    pub config: String,
    pub method: String,
    pub task: String,
    /// steps executed (0 for evaluation-only reports)
    pub steps: usize,
    pub seed: u64,
    pub first_loss: Option<f64>,
    /// mean loss over the last 10 steps
    pub final_loss: Option<f64>,
    /// full (step, loss) curve
    pub loss_curve: Vec<(usize, f64)>,
    pub ppl_acc_pre: Option<f64>,
    pub ppl_acc_post: Option<f64>,
    pub gen_acc: Option<f64>,
    pub us_per_token: Option<f64>,
    pub wall_secs: f64,
    pub trainable_params: Option<usize>,
    pub total_params: usize,
    /// analytic memory estimate (paper Table 14), GB-equivalent
    pub memory_gb: f64,
    /// subnet re-localizations performed (0 for non-subnet methods)
    pub reselections: usize,
    /// mean % selection turnover between consecutive reselections
    pub selection_drift: Option<f64>,
    /// per-artifact executor stats (empty for evaluation-only runs)
    pub exec: Vec<ExecProfile>,
    /// data-parallel stats (`None` when the sharded loop never ran —
    /// including every report written before dp existed)
    pub dp: Option<DpReport>,
    /// step-pipeline stats (`None` when the pipelined loop never ran —
    /// including every report written before the pipeline existed)
    pub pipeline: Option<PipelineReport>,
    /// durable-checkpoint stats (`None` when the stage neither wrote
    /// nor resumed from a checkpoint — including every report written
    /// before checkpointing existed)
    pub checkpoint: Option<CheckpointReport>,
}

impl Default for RunReport {
    fn default() -> Self {
        RunReport {
            config: String::new(),
            method: String::new(),
            task: String::new(),
            steps: 0,
            seed: 0,
            first_loss: None,
            final_loss: None,
            loss_curve: Vec::new(),
            ppl_acc_pre: None,
            ppl_acc_post: None,
            gen_acc: None,
            us_per_token: None,
            wall_secs: 0.0,
            trainable_params: None,
            total_params: 0,
            memory_gb: 0.0,
            reselections: 0,
            selection_drift: None,
            exec: Vec::new(),
            dp: None,
            pipeline: None,
            checkpoint: None,
        }
    }
}

fn opt_num(x: Option<f64>) -> Json {
    match x {
        Some(v) if v.is_finite() => Json::Num(v),
        _ => Json::Null,
    }
}

fn get_opt_num(j: &Json, key: &str) -> Option<f64> {
    match j.get(key) {
        Some(Json::Num(n)) => Some(*n),
        _ => None,
    }
}

fn get_num(j: &Json, key: &str) -> Result<f64> {
    match j.get(key) {
        Some(Json::Num(n)) => Ok(*n),
        other => bail!("report field {key:?}: expected number, got {other:?}"),
    }
}

/// A JSON number destined for a count field. A bare `as usize` cast
/// silently wraps negative or non-finite values into huge counts on
/// round-trip; this errors on anything that is not a non-negative
/// finite number instead.
fn count_value(key: &str, v: f64) -> Result<f64> {
    anyhow::ensure!(
        v.is_finite() && v >= 0.0,
        "report field {key:?}: expected a non-negative count, got {v}"
    );
    Ok(v)
}

fn get_usize(j: &Json, key: &str) -> Result<usize> {
    Ok(count_value(key, get_num(j, key)?)? as usize)
}

fn get_u64(j: &Json, key: &str) -> Result<u64> {
    Ok(count_value(key, get_num(j, key)?)? as u64)
}

/// Like [`get_u64`] but a missing/null key reads as 0 (fields newer
/// than the report being parsed). A *present* malformed value still
/// errors.
fn get_u64_or_zero(j: &Json, key: &str) -> Result<u64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(0),
        Some(_) => get_u64(j, key),
    }
}

/// [`get_u64_or_zero`]'s float twin, for wall-time fields newer than
/// the report being parsed (the phase-timing split).
fn get_num_or_zero(j: &Json, key: &str) -> Result<f64> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(0.0),
        Some(_) => get_num(j, key),
    }
}

/// Optional count: absent/null → `None`; present but malformed
/// (wrong type, negative, or non-finite) → a typed error, not a
/// silent `None` or a wrapped huge value.
fn get_opt_usize(j: &Json, key: &str) -> Result<Option<usize>> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(Json::Num(n)) => {
            Ok(Some(count_value(key, *n)? as usize))
        }
        Some(other) => bail!(
            "report field {key:?}: expected number or null, got \
             {other:?}"
        ),
    }
}

fn get_str(j: &Json, key: &str) -> Result<String> {
    match j.get(key) {
        Some(Json::Str(s)) => Ok(s.clone()),
        other => bail!("report field {key:?}: expected string, got {other:?}"),
    }
}

impl RunReport {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("config".into(), Json::Str(self.config.clone()));
        m.insert("method".into(), Json::Str(self.method.clone()));
        m.insert("task".into(), Json::Str(self.task.clone()));
        m.insert("steps".into(), Json::Num(self.steps as f64));
        m.insert("seed".into(), Json::Num(self.seed as f64));
        m.insert("first_loss".into(), opt_num(self.first_loss));
        m.insert("final_loss".into(), opt_num(self.final_loss));
        m.insert(
            "loss_curve".into(),
            Json::Arr(
                self.loss_curve
                    .iter()
                    .map(|(t, l)| {
                        Json::Arr(vec![
                            Json::Num(*t as f64),
                            Json::Num(*l),
                        ])
                    })
                    .collect(),
            ),
        );
        m.insert("ppl_acc_pre".into(), opt_num(self.ppl_acc_pre));
        m.insert("ppl_acc_post".into(), opt_num(self.ppl_acc_post));
        m.insert("gen_acc".into(), opt_num(self.gen_acc));
        m.insert("us_per_token".into(), opt_num(self.us_per_token));
        m.insert("wall_secs".into(), Json::Num(self.wall_secs));
        m.insert(
            "trainable_params".into(),
            opt_num(self.trainable_params.map(|x| x as f64)),
        );
        m.insert(
            "total_params".into(),
            Json::Num(self.total_params as f64),
        );
        m.insert("memory_gb".into(), Json::Num(self.memory_gb));
        m.insert(
            "reselections".into(),
            Json::Num(self.reselections as f64),
        );
        m.insert(
            "selection_drift".into(),
            opt_num(self.selection_drift),
        );
        m.insert(
            "exec".into(),
            Json::Arr(self.exec.iter().map(|p| p.to_json()).collect()),
        );
        m.insert(
            "dp".into(),
            match &self.dp {
                Some(d) => d.to_json(),
                None => Json::Null,
            },
        );
        m.insert(
            "pipeline".into(),
            match &self.pipeline {
                Some(p) => p.to_json(),
                None => Json::Null,
            },
        );
        m.insert(
            "checkpoint".into(),
            match &self.checkpoint {
                Some(c) => c.to_json(),
                None => Json::Null,
            },
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut curve = Vec::new();
        if let Some(Json::Arr(rows)) = j.get("loss_curve") {
            for row in rows {
                let Json::Arr(pair) = row else {
                    bail!("loss_curve rows must be [step, loss] pairs");
                };
                let [Json::Num(t), Json::Num(l)] = pair.as_slice()
                else {
                    bail!("loss_curve rows must be [step, loss] pairs");
                };
                curve.push((
                    count_value("loss_curve step", *t)? as usize,
                    *l,
                ));
            }
        }
        Ok(RunReport {
            config: get_str(j, "config")?,
            method: get_str(j, "method")?,
            task: get_str(j, "task")?,
            steps: get_usize(j, "steps")?,
            seed: get_u64(j, "seed")?,
            first_loss: get_opt_num(j, "first_loss"),
            final_loss: get_opt_num(j, "final_loss"),
            loss_curve: curve,
            ppl_acc_pre: get_opt_num(j, "ppl_acc_pre"),
            ppl_acc_post: get_opt_num(j, "ppl_acc_post"),
            gen_acc: get_opt_num(j, "gen_acc"),
            us_per_token: get_opt_num(j, "us_per_token"),
            wall_secs: get_num(j, "wall_secs")?,
            trainable_params: get_opt_usize(j, "trainable_params")?,
            total_params: get_usize(j, "total_params")?,
            memory_gb: get_num(j, "memory_gb")?,
            reselections: get_usize(j, "reselections")?,
            selection_drift: get_opt_num(j, "selection_drift"),
            exec: match j.get("exec") {
                Some(Json::Arr(rows)) => rows
                    .iter()
                    .map(ExecProfile::from_json)
                    .collect::<Result<_>>()?,
                // older reports predate executor profiling
                _ => Vec::new(),
            },
            dp: match j.get("dp") {
                // older reports predate data-parallel training
                None | Some(Json::Null) => None,
                Some(d) => Some(DpReport::from_json(d)?),
            },
            pipeline: match j.get("pipeline") {
                // older reports predate the step pipeline
                None | Some(Json::Null) => None,
                Some(p) => Some(PipelineReport::from_json(p)?),
            },
            checkpoint: match j.get("checkpoint") {
                // older reports predate durable checkpoints
                None | Some(Json::Null) => None,
                Some(c) => Some(CheckpointReport::from_json(c)?),
            },
        })
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    pub fn from_json_str(s: &str) -> Result<Self> {
        let j = json::parse(s)
            .map_err(|e| anyhow::anyhow!("report parse error: {e}"))?;
        Self::from_json(&j)
    }

    /// Write the report to an explicit path.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        std::fs::write(path, self.to_json_string())
            .with_context(|| format!("writing {}", path.display()))
    }

    /// Write to `results/<stem>.json` (the bench convention) and
    /// return the path.
    pub fn save_results(&self, stem: &str) -> Result<PathBuf> {
        let path = Path::new("results").join(format!("{stem}.json"));
        self.save(&path)?;
        Ok(path)
    }

    /// Executor stats for one artifact, if it ran this stage.
    pub fn exec_profile(&self, artifact: &str) -> Option<&ExecProfile> {
        self.exec.iter().find(|p| p.artifact == artifact)
    }

    /// One-line human summary for CLI output.
    pub fn summary_line(&self) -> String {
        let fmt = |x: Option<f64>| match x {
            Some(v) => format!("{v:.3}"),
            None => "-".to_string(),
        };
        format!(
            "method={} task={} steps={} final_loss={} ppl_acc={}% \
             gen_acc={}% us_per_token={} trainable={} reselections={}",
            self.method,
            self.task,
            self.steps,
            fmt(self.final_loss),
            fmt(self.ppl_acc_post),
            fmt(self.gen_acc),
            fmt(self.us_per_token),
            self.trainable_params
                .map(|x| x.to_string())
                .unwrap_or_else(|| "-".into()),
            self.reselections,
        )
    }
}

/// Report for a multi-task sequence (`Session::train_sequence`).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct SequenceReport {
    /// one per stage, in training order
    pub stages: Vec<RunReport>,
    /// `perf[i][j]` = PPL accuracy on task j's eval set after stage i
    /// (empty when the sequence ran without eval sets)
    pub perf: Vec<Vec<f64>>,
}

impl SequenceReport {
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert(
            "stages".into(),
            Json::Arr(self.stages.iter().map(|s| s.to_json()).collect()),
        );
        m.insert(
            "perf".into(),
            Json::Arr(
                self.perf
                    .iter()
                    .map(|row| {
                        Json::Arr(
                            row.iter().map(|&v| Json::Num(v)).collect(),
                        )
                    })
                    .collect(),
            ),
        );
        Json::Obj(m)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let mut stages = Vec::new();
        if let Some(Json::Arr(ss)) = j.get("stages") {
            for s in ss {
                stages.push(RunReport::from_json(s)?);
            }
        }
        let mut perf = Vec::new();
        if let Some(Json::Arr(rows)) = j.get("perf") {
            for row in rows {
                let Json::Arr(cells) = row else {
                    bail!("perf rows must be arrays of numbers");
                };
                let mut out_row = Vec::with_capacity(cells.len());
                for v in cells {
                    let Json::Num(n) = v else {
                        bail!("perf rows must be arrays of numbers");
                    };
                    out_row.push(*n);
                }
                perf.push(out_row);
            }
        }
        Ok(SequenceReport { stages, perf })
    }

    pub fn to_json_string(&self) -> String {
        self.to_json().to_string()
    }

    /// Average performance over the final stage's row (paper AP),
    /// `None` without eval data. A *malformed* (ragged) matrix is
    /// also `None`, but warns — silently printing NaN is the failure
    /// mode the typed validation exists to kill.
    pub fn average_performance(&self) -> Option<f64> {
        self.metric(crate::eval::average_performance(&self.perf))
    }

    /// Backward transfer (paper BWT), `None` below two stages (the
    /// expected case, not warned) or on a malformed matrix (warned).
    pub fn backward_transfer(&self) -> Option<f64> {
        if self.perf.len() < 2 {
            return None;
        }
        self.metric(crate::eval::backward_transfer(&self.perf))
    }

    fn metric(&self, r: anyhow::Result<f64>) -> Option<f64> {
        match r {
            Ok(v) => Some(v),
            Err(e) => {
                if !self.perf.is_empty() {
                    eprintln!("[report] continual metric skipped: {e}");
                }
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> RunReport {
        RunReport {
            config: "tiny".into(),
            method: "LoSiA-Pro".into(),
            task: "modmath".into(),
            steps: 3,
            seed: 42,
            first_loss: Some(4.5),
            final_loss: Some(2.25),
            loss_curve: vec![(0, 4.5), (1, 3.0), (2, 2.25)],
            ppl_acc_pre: Some(9.5),
            ppl_acc_post: Some(61.0),
            gen_acc: None,
            us_per_token: Some(123.75),
            wall_secs: 1.5,
            trainable_params: Some(4096),
            total_params: 120_000,
            memory_gb: 0.0015,
            reselections: 7,
            selection_drift: Some(37.5),
            exec: vec![ExecProfile {
                artifact: "grads_losia".into(),
                calls: 3,
                total_secs: 0.75,
                mean_secs: 0.25,
                upload_secs: 0.125,
                download_secs: 0.0625,
                overlap_secs: 0.03125,
                static_uploads: 27,
                step_uploads: 36,
                downloads: 21,
                download_bytes: 5376,
            }],
            dp: None,
            pipeline: None,
            checkpoint: None,
        }
    }

    #[test]
    fn checkpoint_block_round_trips_and_tolerates_old_reports() {
        // None serializes as null and survives the round trip
        let r = sample();
        let s = r.to_json_string();
        assert!(s.contains("\"checkpoint\":null"), "{s}");
        let back = RunReport::from_json_str(&s).unwrap();
        assert_eq!(back.checkpoint, None);
        // a populated block round-trips field-for-field, including
        // the resume-only shape (no writes, no last path)
        for ck in [
            CheckpointReport {
                writes: 3,
                bytes: 98304,
                last_path: Some("ckpt/step-000012.losia-ckpt".into()),
                resume_step: None,
            },
            CheckpointReport {
                writes: 0,
                bytes: 0,
                last_path: None,
                resume_step: Some(8),
            },
        ] {
            let mut r = sample();
            r.checkpoint = Some(ck);
            let back =
                RunReport::from_json_str(&r.to_json_string()).unwrap();
            assert_eq!(back, r);
        }
        // reports written before checkpointing lack the key entirely
        let mut j = sample().to_json();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("checkpoint");
        }
        let old = RunReport::from_json_str(&j.to_string()).unwrap();
        assert_eq!(old.checkpoint, None);
    }

    #[test]
    fn dp_block_round_trips_and_tolerates_old_reports() {
        // None serializes as null and survives the round trip
        let r = sample();
        let s = r.to_json_string();
        assert!(s.contains("\"dp\":null"), "{s}");
        let back = RunReport::from_json_str(&s).unwrap();
        assert_eq!(back.dp, None);
        // a populated block round-trips field-for-field
        let mut r = sample();
        r.dp = Some(DpReport {
            workers: 4,
            shards: 4,
            frame_bytes: 5376,
            reduce_secs: 0.125,
            worker_busy_secs: 1.5,
        });
        let back =
            RunReport::from_json_str(&r.to_json_string()).unwrap();
        assert_eq!(back, r);
        // reports written before dp existed lack the key entirely
        let mut j = sample().to_json();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("dp");
        }
        let old = RunReport::from_json_str(&j.to_string()).unwrap();
        assert_eq!(old.dp, None);
    }

    #[test]
    fn pre_phase_timing_reports_read_zero_wall_times() {
        // Reports serialized before the upload/execute/download phase
        // split (and before the PR 4 download split) must still
        // deserialize, with the missing fields defaulting to 0 — the
        // bench-trajectory tooling diffs reports across PRs.
        let mut r = sample();
        let s = r.to_json_string();
        // keys serialize alphabetically: upload_secs is last in the
        // exec object (leading comma), the others carry trailing ones
        let stripped = s
            .replace(",\"upload_secs\":0.125", "")
            .replace("\"download_secs\":0.0625,", "")
            .replace("\"downloads\":21,", "")
            .replace("\"download_bytes\":5376,", "");
        assert!(
            !stripped.contains("upload_secs"),
            "old-report fixture still has the new key: {stripped}"
        );
        let back = RunReport::from_json_str(&stripped).unwrap();
        r.exec[0].upload_secs = 0.0;
        r.exec[0].download_secs = 0.0;
        r.exec[0].downloads = 0;
        r.exec[0].download_bytes = 0;
        assert_eq!(r, back);
        // and the zero-filled form round-trips stably from here on
        let again =
            RunReport::from_json_str(&back.to_json_string()).unwrap();
        assert_eq!(back, again);
    }

    #[test]
    fn pipeline_block_round_trips_and_tolerates_old_reports() {
        // None serializes as null and survives the round trip — so a
        // mid-run `--pipeline off` report and an `on` report diff
        // cleanly instead of one failing to parse
        let r = sample();
        let s = r.to_json_string();
        assert!(s.contains("\"pipeline\":null"), "{s}");
        let back = RunReport::from_json_str(&s).unwrap();
        assert_eq!(back.pipeline, None);
        // a populated block round-trips field-for-field
        let mut r = sample();
        r.pipeline = Some(PipelineReport {
            queue_depth: 2,
            prefetch_threads: 2,
            stall_secs: 0.25,
            staged_bytes: 98304,
        });
        let back =
            RunReport::from_json_str(&r.to_json_string()).unwrap();
        assert_eq!(back, r);
        // reports written before the pipeline lack the key entirely,
        // and their exec profiles lack overlap_secs — both must read
        // as the synchronous defaults
        let mut j = sample().to_json();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("pipeline");
        }
        let s = j
            .to_string()
            .replace("\"overlap_secs\":0.03125,", "");
        assert!(!s.contains("overlap_secs"), "{s}");
        let old = RunReport::from_json_str(&s).unwrap();
        assert_eq!(old.pipeline, None);
        assert_eq!(old.exec[0].overlap_secs, 0.0);
    }

    #[test]
    fn run_report_json_round_trips() {
        let r = sample();
        let s = r.to_json_string();
        let back = RunReport::from_json_str(&s).unwrap();
        assert_eq!(r, back);
        // and the serialized form itself is stable valid JSON
        let back2 =
            RunReport::from_json_str(&back.to_json_string()).unwrap();
        assert_eq!(back, back2);
    }

    #[test]
    fn missing_optionals_round_trip_as_null() {
        let mut r = sample();
        r.gen_acc = None;
        r.us_per_token = None;
        r.trainable_params = None;
        r.selection_drift = None;
        let s = r.to_json_string();
        assert!(s.contains("\"gen_acc\":null"), "{s}");
        let back = RunReport::from_json_str(&s).unwrap();
        assert_eq!(r, back);
    }

    #[test]
    fn non_finite_values_serialize_as_null() {
        let mut r = sample();
        r.us_per_token = Some(f64::NAN);
        let s = r.to_json_string();
        assert!(s.contains("\"us_per_token\":null"), "{s}");
        // still parseable; NaN collapses to None
        let back = RunReport::from_json_str(&s).unwrap();
        assert_eq!(back.us_per_token, None);
    }

    #[test]
    fn malformed_report_is_a_typed_error() {
        let err = RunReport::from_json_str("{\"config\":1}")
            .unwrap_err()
            .to_string();
        assert!(err.contains("config"), "{err}");
        assert!(RunReport::from_json_str("not json").is_err());
        // malformed nested structures error instead of panicking
        let mut bad = sample().to_json_string();
        bad = bad.replace("[0,4.5]", "[\"x\",4.5]");
        let err = RunReport::from_json_str(&bad).unwrap_err();
        assert!(err.to_string().contains("loss_curve"), "{err}");
        let bad_perf = r#"{"stages":[],"perf":[[1,"y"]]}"#;
        let j = crate::util::json::parse(bad_perf).unwrap();
        assert!(SequenceReport::from_json(&j).is_err());
    }

    #[test]
    fn exec_profiles_round_trip_and_tolerate_old_reports() {
        let r = sample();
        let s = r.to_json_string();
        assert!(s.contains("\"static_uploads\":27"), "{s}");
        assert!(s.contains("\"download_bytes\":5376"), "{s}");
        let back = RunReport::from_json_str(&s).unwrap();
        assert_eq!(back.exec, r.exec);
        assert_eq!(
            back.exec_profile("grads_losia").unwrap().calls,
            3
        );
        assert!(back.exec_profile("missing").is_none());
        // reports written before executor profiling lack the key
        let mut j = r.to_json();
        if let crate::util::json::Json::Obj(m) = &mut j {
            m.remove("exec");
        }
        let old =
            RunReport::from_json_str(&j.to_string()).unwrap();
        assert!(old.exec.is_empty());
        // reports written before the download split lack those keys:
        // they parse with zero downloads, not an error
        let s = r.to_json_string()
            .replace(",\"downloads\":21", "")
            .replace(",\"download_bytes\":5376", "");
        let old = RunReport::from_json_str(&s).unwrap();
        let p = old.exec_profile("grads_losia").unwrap();
        assert_eq!(p.downloads, 0);
        assert_eq!(p.download_bytes, 0);
    }

    #[test]
    fn negative_counts_error_instead_of_wrapping() {
        // `steps: -3` used to cast through `as usize` into ~2^64
        let s = sample().to_json_string().replace(
            "\"steps\":3",
            "\"steps\":-3",
        );
        let err = RunReport::from_json_str(&s).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("steps"), "{msg}");
        assert!(msg.contains("non-negative"), "{msg}");

        // the same guard covers every count field, nested ones too
        let s = sample().to_json_string().replace(
            "\"calls\":3",
            "\"calls\":-1",
        );
        let err = RunReport::from_json_str(&s).unwrap_err();
        assert!(err.to_string().contains("calls"), "{}", err);

        // a negative loss_curve step is a malformed row
        let s = sample()
            .to_json_string()
            .replace("[1,3]", "[-1,3]");
        assert!(RunReport::from_json_str(&s).is_err());

        // present-but-negative optional counts error rather than
        // silently becoming huge
        let s = sample().to_json_string().replace(
            "\"trainable_params\":4096",
            "\"trainable_params\":-4096",
        );
        let err = RunReport::from_json_str(&s).unwrap_err();
        assert!(
            err.to_string().contains("trainable_params"),
            "{}",
            err
        );

        // present-but-wrong-type optional counts are an error too,
        // not a silent None
        let s = sample().to_json_string().replace(
            "\"trainable_params\":4096",
            "\"trainable_params\":\"4096\"",
        );
        let err = RunReport::from_json_str(&s).unwrap_err();
        assert!(
            err.to_string().contains("trainable_params"),
            "{}",
            err
        );
    }

    #[test]
    fn sequence_report_round_trips() {
        let seq = SequenceReport {
            stages: vec![sample(), sample()],
            perf: vec![vec![80.0, 50.0], vec![70.0, 90.0]],
        };
        let j = seq.to_json();
        let back = SequenceReport::from_json(&j).unwrap();
        assert_eq!(seq, back);
        assert!(
            (back.average_performance().unwrap() - 80.0).abs() < 1e-9
        );
        assert!((back.backward_transfer().unwrap() + 10.0).abs() < 1e-9);
    }
}
