//! The session layer: one typed entry point for every run.
//!
//! Historically each entrypoint (CLI, 16 benches, 4 examples, the
//! integration tests) hand-wired `Runtime` + `TrainConfig` + `Batcher`
//! + `Trainer` with copy-pasted glue. [`SessionBuilder`] owns that
//! assembly — runtime loading, task construction via the
//! [`registry::TaskRegistry`], seeding, driver assembly — and returns
//! `anyhow` errors instead of scattered panics:
//!
//! ```no_run
//! use losia::config::Method;
//! use losia::session::Session;
//!
//! let mut session = Session::builder()
//!     .config("tiny")
//!     .method(Method::LosiaPro)
//!     .task("modmath")
//!     .steps(200)
//!     .lr(1e-3)
//!     .build()?;
//! let report = session.train()?;
//! println!("{}", report.to_json_string());
//! # anyhow::Ok(())
//! ```
//!
//! Telemetry (loss curves, µs/token, memory, subnet selection) flows
//! through the [`observer::Observer`] event stream rather than trainer
//! fields, every run is summarised as a serializable
//! [`report::RunReport`], and multi-task continual learning is a
//! first-class [`Session::train_sequence`] over [`TaskSpec`]s instead
//! of ad-hoc loops.

pub mod observer;
pub mod registry;
pub mod report;

pub use observer::{
    CheckpointEvent, DpEvent, ExecEvent, Observer, ObserverSet,
    PipelineEvent, SelectionEvent,
};
pub use registry::TaskRegistry;
pub use report::{
    CheckpointReport, DpReport, ExecProfile, PipelineReport, RunReport,
    SequenceReport,
};

use std::path::PathBuf;
use std::time::Instant;

use anyhow::{bail, ensure, Context, Result};

use crate::config::{Ablation, Method, ModelCfg, TrainConfig};
use crate::coordinator::state::ModelState;
use crate::coordinator::trainer::Trainer;
use crate::data::{gen_eval_set, gen_train_set, Batcher, EvalItem, Example, Task};
use crate::eval::{generate_accuracy, ppl_accuracy};
use crate::runtime::Runtime;
use crate::util::rng::Rng;

use observer::{RunStartEvent, TaskBoundaryEvent};

/// Runtime ownership: sessions either load their own runtime (CLI,
/// examples) or borrow one so repeated sessions share the compiled
/// artifact cache (benches).
enum RuntimeRef<'a> {
    Owned(Box<Runtime>),
    Shared(&'a Runtime),
}

impl<'a> RuntimeRef<'a> {
    fn get(&self) -> &Runtime {
        match self {
            RuntimeRef::Owned(rt) => rt,
            RuntimeRef::Shared(rt) => rt,
        }
    }
}

/// Task ownership inside a built session.
enum SessionTask<'a> {
    Owned(Box<dyn Task>),
    Shared(&'a dyn Task),
}

impl<'a> SessionTask<'a> {
    fn as_dyn(&self) -> &dyn Task {
        match self {
            SessionTask::Owned(t) => t.as_ref(),
            SessionTask::Shared(t) => *t,
        }
    }
}

/// One stage of a continual-learning sequence. Unset fields inherit
/// the session defaults.
#[derive(Debug, Clone, Default)]
pub struct TaskSpec {
    pub task: String,
    pub steps: Option<usize>,
    pub train_n: Option<usize>,
    pub data_seed: Option<u64>,
    pub batcher_seed: Option<u64>,
    pub eval_n: Option<usize>,
    pub eval_seed: Option<u64>,
}

impl TaskSpec {
    pub fn new(task: &str) -> Self {
        TaskSpec {
            task: task.to_string(),
            ..Self::default()
        }
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = Some(steps);
        self
    }

    pub fn train_n(mut self, n: usize) -> Self {
        self.train_n = Some(n);
        self
    }

    pub fn data_seed(mut self, seed: u64) -> Self {
        self.data_seed = Some(seed);
        self
    }

    pub fn batcher_seed(mut self, seed: u64) -> Self {
        self.batcher_seed = Some(seed);
        self
    }

    pub fn eval_n(mut self, n: usize) -> Self {
        self.eval_n = Some(n);
        self
    }

    pub fn eval_seed(mut self, seed: u64) -> Self {
        self.eval_seed = Some(seed);
        self
    }
}

enum TaskChoice<'a> {
    None,
    Named(String),
    Borrowed(&'a dyn Task),
}

/// Fluent, typed configuration for a [`Session`]. See the module docs
/// for the canonical five-line usage.
pub struct SessionBuilder<'a> {
    config_name: String,
    runtime: Option<&'a Runtime>,
    base_tc: Option<TrainConfig>,
    method: Option<Method>,
    steps: Option<usize>,
    lr: Option<f64>,
    time_slot: Option<usize>,
    log_every: Option<usize>,
    seed: Option<u64>,
    use_remat: Option<bool>,
    galore_rank: Option<usize>,
    ablation: Option<Ablation>,
    rank_factor_override: Option<f64>,
    workers: Option<usize>,
    dp_shards: Option<usize>,
    pipeline: Option<bool>,
    checkpoint_every: Option<usize>,
    checkpoint_dir: Option<PathBuf>,
    checkpoint_keep: Option<usize>,
    resume: Option<bool>,
    task: TaskChoice<'a>,
    registry: TaskRegistry,
    model_seed: Option<u64>,
    data_seed: Option<u64>,
    batcher_seed: Option<u64>,
    train_n: usize,
    eval_n: usize,
    eval_seed: Option<u64>,
    measure_gen: bool,
    initial_state: Option<PathBuf>,
    observers: Vec<Box<dyn Observer>>,
}

impl<'a> SessionBuilder<'a> {
    pub fn new() -> Self {
        SessionBuilder {
            config_name: "tiny".to_string(),
            runtime: None,
            base_tc: None,
            method: None,
            steps: None,
            lr: None,
            time_slot: None,
            log_every: None,
            seed: None,
            use_remat: None,
            galore_rank: None,
            ablation: None,
            rank_factor_override: None,
            workers: None,
            dp_shards: None,
            pipeline: None,
            checkpoint_every: None,
            checkpoint_dir: None,
            checkpoint_keep: None,
            resume: None,
            task: TaskChoice::None,
            registry: TaskRegistry::with_builtins(),
            model_seed: None,
            data_seed: None,
            batcher_seed: None,
            train_n: 2000,
            eval_n: 0,
            eval_seed: None,
            measure_gen: false,
            initial_state: None,
            observers: Vec::new(),
        }
    }

    /// Model config name from the artifact manifest (default `tiny`).
    /// Ignored when [`Self::runtime`] supplies a loaded runtime.
    pub fn config(mut self, name: &str) -> Self {
        self.config_name = name.to_string();
        self
    }

    /// Reuse an already-loaded runtime (shares the compiled-artifact
    /// cache across sessions — the bench pattern).
    pub fn runtime(mut self, rt: &'a Runtime) -> Self {
        self.runtime = Some(rt);
        self
    }

    /// Start from a fully-specified [`TrainConfig`] instead of the
    /// defaults; the individual setters below still override it.
    pub fn train_config(mut self, tc: TrainConfig) -> Self {
        self.base_tc = Some(tc);
        self
    }

    pub fn method(mut self, method: Method) -> Self {
        self.method = Some(method);
        self
    }

    /// Parse a method name (`losia-pro`, `lora`, …) with a typed
    /// error instead of panicking at the call site.
    pub fn method_str(self, name: &str) -> Result<Self> {
        let m = Method::parse(name)
            .with_context(|| format!("session method {name:?}"))?;
        Ok(self.method(m))
    }

    /// Select the workload by registry name (`modmath`, `stack`,
    /// `kvfacts`, or any commonsense-suite name).
    pub fn task(mut self, name: &str) -> Self {
        self.task = TaskChoice::Named(name.to_string());
        self
    }

    /// Use a caller-constructed task instance (e.g. a `KvFacts` with
    /// swept parameters); datasets are generated from it at run time.
    pub fn task_ref(mut self, task: &'a dyn Task) -> Self {
        self.task = TaskChoice::Borrowed(task);
        self
    }

    /// Replace the task registry (after registering custom tasks).
    pub fn registry(mut self, registry: TaskRegistry) -> Self {
        self.registry = registry;
        self
    }

    pub fn steps(mut self, steps: usize) -> Self {
        self.steps = Some(steps);
        self
    }

    pub fn lr(mut self, lr: f64) -> Self {
        self.lr = Some(lr);
        self
    }

    pub fn time_slot(mut self, t: usize) -> Self {
        self.time_slot = Some(t);
        self
    }

    pub fn log_every(mut self, n: usize) -> Self {
        self.log_every = Some(n);
        self
    }

    /// Base seed: defaults the model/data/batcher seeds unless those
    /// are set individually.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = Some(seed);
        self
    }

    pub fn model_seed(mut self, seed: u64) -> Self {
        self.model_seed = Some(seed);
        self
    }

    pub fn data_seed(mut self, seed: u64) -> Self {
        self.data_seed = Some(seed);
        self
    }

    pub fn batcher_seed(mut self, seed: u64) -> Self {
        self.batcher_seed = Some(seed);
        self
    }

    pub fn use_remat(mut self, remat: bool) -> Self {
        self.use_remat = Some(remat);
        self
    }

    pub fn galore_rank(mut self, rank: usize) -> Self {
        self.galore_rank = Some(rank);
        self
    }

    pub fn ablation(mut self, ablation: Ablation) -> Self {
        self.ablation = Some(ablation);
        self
    }

    pub fn rank_factor_override(mut self, p: f64) -> Self {
        self.rank_factor_override = Some(p);
        self
    }

    /// Data-parallel worker count: N plan replicas executing disjoint
    /// shard blocks concurrently. Defaults the shard count to the same
    /// N unless [`Self::dp_shards`] is set. Workers never affect
    /// numerics — the result is a function of `(seed, shards)` only.
    /// Overrides `LOSIA_DP_WORKERS`.
    pub fn workers(mut self, n: usize) -> Self {
        self.workers = Some(n);
        self
    }

    /// Logical shards per step — the data-parallel *numerics* knob:
    /// the batcher splits into this many seed-stable sub-streams and
    /// each step reduces that many gradient frames in fixed order.
    /// Overrides `LOSIA_DP_SHARDS`.
    pub fn dp_shards(mut self, n: usize) -> Self {
        self.dp_shards = Some(n);
        self
    }

    /// Pipelined step loop: double-buffered per-step uploads plus
    /// bounded batch prefetch. Never affects numerics — the pipelined
    /// run is bitwise identical to the synchronous one (pinned by
    /// `tests/pipeline_parity.rs`). Overrides `LOSIA_PIPELINE`.
    pub fn pipeline(mut self, on: bool) -> Self {
        self.pipeline = Some(on);
        self
    }

    /// Write a durable training checkpoint every `n` steps (0
    /// disables). Overrides `LOSIA_CKPT_EVERY`.
    pub fn checkpoint_every(mut self, n: usize) -> Self {
        self.checkpoint_every = Some(n);
        self
    }

    /// Directory for durable checkpoints (default `checkpoints/`).
    /// Overrides `LOSIA_CKPT_DIR`.
    pub fn checkpoint_dir(mut self, dir: impl Into<PathBuf>) -> Self {
        self.checkpoint_dir = Some(dir.into());
        self
    }

    /// Newest checkpoints retained after each write (min 1).
    /// Overrides `LOSIA_CKPT_KEEP`.
    pub fn checkpoint_keep(mut self, keep: usize) -> Self {
        self.checkpoint_keep = Some(keep);
        self
    }

    /// Resume from the newest loadable checkpoint before training —
    /// bitwise identical to the uninterrupted run (pinned by
    /// `tests/checkpoint_parity.rs`). Overrides `LOSIA_CKPT_RESUME`.
    pub fn resume(mut self, on: bool) -> Self {
        self.resume = Some(on);
        self
    }

    /// Training examples to generate per stage (default 2000).
    pub fn train_n(mut self, n: usize) -> Self {
        self.train_n = n;
        self
    }

    /// Held-out eval items per stage; 0 (the default) disables the
    /// pre/post PPL evaluation.
    pub fn eval_n(mut self, n: usize) -> Self {
        self.eval_n = n;
        self
    }

    pub fn eval_seed(mut self, seed: u64) -> Self {
        self.eval_seed = Some(seed);
        self
    }

    /// Also measure exact-answer generation accuracy after training.
    pub fn measure_gen(mut self, on: bool) -> Self {
        self.measure_gen = on;
        self
    }

    /// Load initial parameters from a state file saved with
    /// [`Session::save_state`] instead of random initialization.
    pub fn initial_state(mut self, path: impl Into<PathBuf>) -> Self {
        self.initial_state = Some(path.into());
        self
    }

    /// Attach a user observer to the event stream.
    pub fn observer(mut self, obs: Box<dyn Observer>) -> Self {
        self.observers.push(obs);
        self
    }

    /// Validate the configuration, load the runtime, resolve the
    /// task, and initialize model state.
    pub fn build(self) -> Result<Session<'a>> {
        let mut tc = self.base_tc.clone().unwrap_or_default();
        let had_base = self.base_tc.is_some();
        if let Some(m) = self.method {
            tc.method = m;
        }
        if let Some(s) = self.steps {
            tc.steps = s;
        }
        if let Some(lr) = self.lr {
            tc.lr = lr;
        }
        if let Some(t) = self.time_slot {
            tc.time_slot = t;
        }
        if let Some(n) = self.log_every {
            tc.log_every = n;
        }
        if let Some(s) = self.seed {
            tc.seed = s;
        }
        if let Some(r) = self.use_remat {
            tc.use_remat = r;
        }
        if let Some(a) = self.ablation {
            tc.ablation = a;
        }
        if let Some(p) = self.rank_factor_override {
            tc.rank_factor_override = Some(p);
        }
        if let Some(w) = self.workers {
            ensure!(
                w >= 1,
                "session misuse: workers must be ≥ 1 (got {w})"
            );
            tc.dp_workers = w;
        }
        if let Some(s) = self.dp_shards {
            ensure!(
                s >= 1,
                "session misuse: dp_shards must be ≥ 1 (got {s})"
            );
            tc.dp_shards = s;
        }
        if let Some(n) = self.checkpoint_every {
            tc.checkpoint_every = Some(n);
        }
        if let Some(dir) = self.checkpoint_dir {
            tc.checkpoint_dir = Some(dir);
        }
        if let Some(k) = self.checkpoint_keep {
            ensure!(
                k >= 1,
                "session misuse: checkpoint_keep must be ≥ 1 (got {k})"
            );
            tc.checkpoint_keep = Some(k);
        }
        if let Some(r) = self.resume {
            tc.resume = Some(r);
        }
        ensure!(
            tc.steps >= 1,
            "session misuse: steps must be ≥ 1 (got {})",
            tc.steps
        );
        ensure!(
            self.train_n >= 1,
            "session misuse: train_n must be ≥ 1"
        );

        // Resolve the task before touching the runtime so misuse
        // errors (unknown task, zero steps) don't require artifacts.
        let (task, task_name) = match self.task {
            TaskChoice::None => (None, String::new()),
            TaskChoice::Named(name) => {
                let t = self
                    .registry
                    .create(&name)
                    .context("building session")?;
                (Some(SessionTask::Owned(t)), name)
            }
            TaskChoice::Borrowed(t) => {
                let name = t.name().to_string();
                (Some(SessionTask::Shared(t)), name)
            }
        };

        let rt = match self.runtime {
            Some(rt) => RuntimeRef::Shared(rt),
            None => RuntimeRef::Owned(Box::new(
                Runtime::from_config_name(&self.config_name)
                    .context("building session runtime")?,
            )),
        };

        if let Some(r) = self.galore_rank {
            tc.galore_rank = r;
        } else if !had_base {
            // sensible scale-aware default (the manifest default of 32
            // fits no config in particular)
            tc.galore_rank = (rt.get().cfg.d_model / 4).max(1);
        }

        let model_seed = self.model_seed.unwrap_or(tc.seed);
        let state = match &self.initial_state {
            Some(path) => ModelState::load(path, &rt.get().cfg)
                .with_context(|| {
                    format!("loading initial state {}", path.display())
                })?,
            None => {
                let mut rng = Rng::new(model_seed);
                ModelState::init(&rt.get().cfg, &mut rng)
            }
        };

        Ok(Session {
            rt,
            tc: tc.clone(),
            state,
            obs: ObserverSet::with_extra(self.observers),
            registry: self.registry,
            task,
            task_name,
            data_seed: self.data_seed.unwrap_or(tc.seed),
            batcher_seed: self.batcher_seed.unwrap_or(tc.seed),
            train_n: self.train_n,
            eval_n: self.eval_n,
            eval_seed: self.eval_seed.unwrap_or(tc.seed),
            measure_gen: self.measure_gen,
        })
    }
}

impl<'a> Default for SessionBuilder<'a> {
    fn default() -> Self {
        Self::new()
    }
}

/// A configured run: runtime + model state + observers. Create via
/// [`Session::builder`]; drive with [`Session::train`],
/// [`Session::train_sequence`], or [`Session::evaluate`].
pub struct Session<'a> {
    rt: RuntimeRef<'a>,
    tc: TrainConfig,
    state: ModelState,
    obs: ObserverSet,
    registry: TaskRegistry,
    task: Option<SessionTask<'a>>,
    task_name: String,
    data_seed: u64,
    batcher_seed: u64,
    train_n: usize,
    eval_n: usize,
    eval_seed: u64,
    measure_gen: bool,
}

impl<'a> Session<'a> {
    pub fn builder() -> SessionBuilder<'a> {
        SessionBuilder::new()
    }

    pub fn runtime(&self) -> &Runtime {
        self.rt.get()
    }

    pub fn model_cfg(&self) -> &ModelCfg {
        &self.rt.get().cfg
    }

    pub fn train_cfg(&self) -> &TrainConfig {
        &self.tc
    }

    pub fn state(&self) -> &ModelState {
        &self.state
    }

    pub fn state_mut(&mut self) -> &mut ModelState {
        &mut self.state
    }

    pub fn into_state(self) -> ModelState {
        self.state
    }

    /// Subnet selection events recorded during the most recent stage.
    pub fn selection_events(&self) -> &[SelectionEvent] {
        &self.obs.selection.history
    }

    /// Current subnet snapshot `(group, kind, rho, gamma)`.
    pub fn selection_snapshot(
        &self,
    ) -> Vec<(usize, String, Vec<usize>, Vec<usize>)> {
        self.obs.selection.snapshot()
    }

    /// Save the model parameters (reloadable via
    /// `SessionBuilder::initial_state`).
    pub fn save_state(&self, path: impl AsRef<std::path::Path>) -> Result<()> {
        self.state.save(path.as_ref())
    }

    /// Train the configured task once and report.
    pub fn train(&mut self) -> Result<RunReport> {
        let task = match self.task.take() {
            Some(t) => t,
            None => bail!(
                "session misuse: no task configured — call \
                 SessionBuilder::task(...) or use train_sequence"
            ),
        };
        let train_set =
            gen_train_set(task.as_dyn(), self.train_n, self.data_seed);
        let eval = if self.eval_n > 0 {
            gen_eval_set(task.as_dyn(), self.eval_n, self.eval_seed)
        } else {
            Vec::new()
        };
        let name = self.task_name.clone();
        let result = self.run_stage(
            0,
            &name,
            train_set,
            &eval,
            self.tc.steps,
            self.batcher_seed,
        );
        self.task = Some(task);
        result
    }

    /// Sequentially fine-tune through `specs` on the evolving model
    /// (paper §4.4). Fires `on_task_boundary` between stages. When
    /// every spec carries an eval set, the report includes the full
    /// stage × task accuracy matrix (Tables 5/13).
    pub fn train_sequence(
        &mut self,
        specs: &[TaskSpec],
    ) -> Result<SequenceReport> {
        ensure!(
            !specs.is_empty(),
            "session misuse: train_sequence needs ≥ 1 task"
        );
        // Resolve everything up front so a typo or zero-step spec
        // fails before stage 0 burns any compute.
        for (i, s) in specs.iter().enumerate() {
            ensure!(
                s.steps.unwrap_or(self.tc.steps) >= 1,
                "session misuse: stage {i} ({:?}) has 0 steps",
                s.task
            );
        }
        let tasks: Vec<Box<dyn Task>> = specs
            .iter()
            .map(|s| {
                self.registry
                    .create(&s.task)
                    .context("building task sequence")
            })
            .collect::<Result<_>>()?;
        let evals: Vec<Vec<EvalItem>> = specs
            .iter()
            .zip(&tasks)
            .enumerate()
            .map(|(i, (s, t))| {
                let n = s.eval_n.unwrap_or(self.eval_n);
                if n > 0 {
                    gen_eval_set(
                        t.as_ref(),
                        n,
                        s.eval_seed.unwrap_or(self.eval_seed + i as u64),
                    )
                } else {
                    Vec::new()
                }
            })
            .collect();
        let all_eval = evals.iter().all(|e| !e.is_empty());

        let mut out = SequenceReport::default();
        for (i, (spec, task)) in specs.iter().zip(&tasks).enumerate() {
            if i > 0 {
                let ev = TaskBoundaryEvent {
                    from_index: i - 1,
                    from_task: specs[i - 1].task.clone(),
                    to_index: i,
                    to_task: spec.task.clone(),
                };
                self.obs.emit_task_boundary(&ev);
            }
            let train_set = gen_train_set(
                task.as_ref(),
                spec.train_n.unwrap_or(self.train_n),
                spec.data_seed.unwrap_or(self.data_seed + i as u64),
            );
            // When the full perf matrix is being collected, the
            // post-stage row already scores this stage's eval set —
            // skip the per-stage pre/post evals instead of running
            // them a second time inside run_stage.
            let stage_eval: &[EvalItem] =
                if all_eval { &[] } else { &evals[i] };
            let mut report = self.run_stage(
                i,
                &spec.task,
                train_set,
                stage_eval,
                spec.steps.unwrap_or(self.tc.steps),
                spec.batcher_seed.unwrap_or(self.batcher_seed),
            )?;
            if all_eval {
                let rt = self.rt.get();
                let row: Vec<f64> = evals
                    .iter()
                    .map(|e| ppl_accuracy(rt, &self.state, e))
                    .collect::<Result<_>>()?;
                report.ppl_acc_post = Some(row[i]);
                out.perf.push(row);
            }
            out.stages.push(report);
        }
        Ok(out)
    }

    /// Evaluate the current state on the configured task without
    /// training (the `losia eval` path). Uses the session eval set
    /// size (defaulting to 200 when unset).
    pub fn evaluate(&mut self) -> Result<RunReport> {
        let task = match self.task.take() {
            Some(t) => t,
            None => bail!(
                "session misuse: no task configured for evaluation"
            ),
        };
        let n = if self.eval_n > 0 { self.eval_n } else { 200 };
        let eval = gen_eval_set(task.as_dyn(), n, self.eval_seed);
        let name = self.task_name.clone();
        self.task = Some(task);

        let rt = self.rt.get();
        let t0 = Instant::now();
        let ppl = ppl_accuracy(rt, &self.state, &eval)?;
        let gen = if self.measure_gen {
            Some(generate_accuracy(rt, &self.state, &eval)?)
        } else {
            None
        };
        Ok(RunReport {
            config: rt.cfg.name.clone(),
            method: self.tc.method.name().to_string(),
            task: name,
            steps: 0,
            seed: self.tc.seed,
            ppl_acc_post: Some(ppl),
            gen_acc: gen,
            wall_secs: t0.elapsed().as_secs_f64(),
            total_params: self.state.total_params(),
            ..RunReport::default()
        })
    }

    /// Run one training stage on the session state.
    fn run_stage(
        &mut self,
        index: usize,
        task_label: &str,
        train_set: Vec<Example>,
        eval: &[EvalItem],
        steps: usize,
        batcher_seed: u64,
    ) -> Result<RunReport> {
        ensure!(
            steps >= 1,
            "session misuse: stage {index} ({task_label:?}) has 0 steps"
        );
        let rt = self.rt.get();
        let mut tc = self.tc.clone();
        tc.steps = steps;
        let batcher = Batcher::new(
            train_set,
            rt.cfg.batch,
            rt.cfg.seq_len,
            batcher_seed,
        )
        .with_context(|| {
            format!(
                "stage {index} ({task_label:?}): batching the \
                 training set"
            )
        })?;
        let mut trainer = Trainer::new(rt, tc.clone())
            .with_context(|| {
                format!("assembling {} driver", tc.method.name())
            })?;
        let trainable = trainer.driver.trainable_params();
        self.obs.begin_task(&RunStartEvent {
            task_index: index,
            task: task_label,
            method: tc.method,
            cfg: &rt.cfg,
            tc: &tc,
            trainable_params: trainable,
        });

        let pre = if eval.is_empty() {
            None
        } else {
            Some(ppl_accuracy(rt, &self.state, eval)?)
        };
        let t0 = Instant::now();
        trainer.train(&mut self.state, batcher, &mut self.obs)?;
        let wall = t0.elapsed().as_secs_f64();
        let post = if eval.is_empty() {
            None
        } else {
            Some(ppl_accuracy(rt, &self.state, eval)?)
        };
        let gen = if self.measure_gen && !eval.is_empty() {
            Some(generate_accuracy(rt, &self.state, eval)?)
        } else {
            None
        };

        Ok(RunReport {
            config: rt.cfg.name.clone(),
            method: tc.method.name().to_string(),
            task: task_label.to_string(),
            steps,
            seed: tc.seed,
            first_loss: self.obs.loss.first(),
            final_loss: self.obs.loss.tail_mean(10),
            loss_curve: self.obs.loss.log.clone(),
            ppl_acc_pre: pre,
            ppl_acc_post: post,
            gen_acc: gen,
            us_per_token: self.obs.latency.us_per_token(),
            wall_secs: wall,
            trainable_params: Some(trainable),
            total_params: self.state.total_params(),
            memory_gb: self.obs.memory.gb,
            reselections: self.obs.selection.reselections(),
            selection_drift: self.obs.selection.mean_turnover(),
            exec: self.obs.exec.profiles(),
            dp: (self.obs.dp.steps > 0).then(|| DpReport {
                workers: self.obs.dp.workers,
                shards: self.obs.dp.shards,
                frame_bytes: self.obs.dp.frame_bytes,
                reduce_secs: self.obs.dp.reduce_secs,
                worker_busy_secs: self.obs.dp.worker_busy_secs,
            }),
            pipeline: (self.obs.pipeline.steps > 0).then(|| {
                PipelineReport {
                    queue_depth: self.obs.pipeline.queue_depth,
                    prefetch_threads: self
                        .obs
                        .pipeline
                        .prefetch_threads,
                    stall_secs: self.obs.pipeline.stall_secs,
                    staged_bytes: self.obs.pipeline.staged_bytes,
                }
            }),
            checkpoint: (self.obs.checkpoint.writes > 0
                || self.obs.checkpoint.resume_step.is_some())
            .then(|| CheckpointReport {
                writes: self.obs.checkpoint.writes,
                bytes: self.obs.checkpoint.bytes,
                last_path: self.obs.checkpoint.last_path.clone(),
                resume_step: self.obs.checkpoint.resume_step,
            }),
        })
    }
}
