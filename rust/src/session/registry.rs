//! Task registry: named constructors for every built-in workload.
//!
//! Replaces the `task_by_name` panic that used to live in `main.rs`
//! (and its three copy-pasted siblings in the examples) with a typed
//! lookup whose error lists the known tasks. Examples, benches, and
//! the CLI all resolve tasks through one registry, and callers can
//! [`TaskRegistry::register`] their own constructors — e.g. a
//! parameter-swept `KvFacts` — without forking the session layer.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::data::commonsense::{suite_task, SUITE_NAMES};
use crate::data::domain::{KvFacts, ModMath, StackEval};
use crate::data::Task;

/// Constructors may fail (the registry's typed error surfaces instead
/// of a panic); [`TaskRegistry::register`] wraps infallible closures.
type TaskCtor = Box<dyn Fn() -> Result<Box<dyn Task>>>;

/// Named task constructors.
pub struct TaskRegistry {
    ctors: BTreeMap<String, TaskCtor>,
}

impl TaskRegistry {
    /// An empty registry (no built-ins).
    pub fn new() -> Self {
        TaskRegistry {
            ctors: BTreeMap::new(),
        }
    }

    /// The standard roster: the three domain tasks (`modmath`,
    /// `stack`, `kvfacts`) plus the eight commonsense-suite tasks
    /// under their `SUITE_NAMES` (`parity-5`, `copy`, `boolfact`, …).
    /// Suite tasks construct directly by index (`suite_task`) — no
    /// per-lookup rebuild of the whole suite, and an out-of-range
    /// index is the registry's typed error rather than a panic.
    pub fn with_builtins() -> Self {
        let mut r = Self::new();
        r.register("modmath", || Box::new(ModMath));
        r.register("stack", || Box::new(StackEval));
        r.register("kvfacts", || Box::new(KvFacts::new(64, 4, 7)));
        for (i, name) in SUITE_NAMES.iter().enumerate() {
            r.ctors.insert(
                name.to_string(),
                Box::new(move || {
                    suite_task(i).ok_or_else(|| {
                        anyhow!(
                            "suite task index {i} out of range \
                             ({} suite tasks)",
                            SUITE_NAMES.len()
                        )
                    })
                }),
            );
        }
        r
    }

    /// Register (or replace) an infallible constructor under `name`.
    pub fn register<F>(&mut self, name: &str, ctor: F)
    where
        F: Fn() -> Box<dyn Task> + 'static,
    {
        self.ctors
            .insert(name.to_string(), Box::new(move || Ok(ctor())));
    }

    /// Instantiate the task registered under `name`.
    pub fn create(&self, name: &str) -> Result<Box<dyn Task>> {
        match self.ctors.get(name) {
            Some(c) => c(),
            None => Err(anyhow!(
                "unknown task {name:?} (known tasks: {})",
                self.known().join(", ")
            )),
        }
    }

    pub fn contains(&self, name: &str) -> bool {
        self.ctors.contains_key(name)
    }

    /// Sorted registered names.
    pub fn known(&self) -> Vec<&str> {
        self.ctors.keys().map(|s| s.as_str()).collect()
    }
}

impl Default for TaskRegistry {
    fn default() -> Self {
        Self::with_builtins()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn builtins_cover_domain_and_commonsense() {
        let r = TaskRegistry::with_builtins();
        assert_eq!(r.known().len(), 3 + SUITE_NAMES.len());
        for name in ["modmath", "stack", "kvfacts", "copy", "boolfact"]
        {
            assert!(r.contains(name), "missing {name}");
            let task = r.create(name).unwrap();
            let mut rng = Rng::new(1);
            let ex = task.gen_train(&mut rng);
            assert!(!ex.prompt.is_empty());
            assert!(!ex.answer.is_empty());
        }
    }

    #[test]
    fn every_suite_name_constructs_without_panicking() {
        // regression: the suite ctors used to `.expect("suite index")`
        // and rebuild the full suite per lookup
        let r = TaskRegistry::with_builtins();
        for name in SUITE_NAMES {
            let task = r.create(name).unwrap();
            let mut rng = Rng::new(3);
            let ex = task.gen_train(&mut rng);
            assert!(!ex.prompt.is_empty(), "{name}");
        }
    }

    #[test]
    fn unknown_task_error_lists_known_names() {
        let r = TaskRegistry::with_builtins();
        let err = r.create("nope").unwrap_err().to_string();
        assert!(err.contains("unknown task"), "{err}");
        assert!(err.contains("known tasks"), "{err}");
        assert!(err.contains("modmath"), "{err}");
    }

    #[test]
    fn custom_registration_overrides() {
        let mut r = TaskRegistry::with_builtins();
        r.register("kvfacts", || Box::new(KvFacts::new(8, 2, 3)));
        let t = r.create("kvfacts").unwrap();
        let mut rng = Rng::new(2);
        let _ = t.gen_eval(&mut rng);
    }
}
