//! Telemetry observers for training sessions.
//!
//! The trainer emits a typed event stream instead of accumulating
//! telemetry in its own fields: one [`StepEvent`] per optimization
//! step, a [`SelectionEvent`] (re-exported from [`crate::methods`])
//! whenever a driver installs a subnet selection, a
//! [`TaskBoundaryEvent`] between stages of a continual-learning
//! sequence, and a [`FinalizeEvent`] when a stage's adapters have been
//! merged. Anything that wants loss curves, µs/token latency, memory
//! estimates, or selection dynamics implements [`Observer`] and
//! composes — benches no longer fork the training loop to add a
//! metric.
//!
//! The stock observers ([`LossObserver`], [`LatencyObserver`],
//! [`MemoryObserver`], [`SelectionObserver`]) are always installed by
//! a [`crate::session::Session`] and feed its
//! [`crate::session::RunReport`]; user observers registered through
//! `SessionBuilder::observer` see the same stream.

pub use crate::methods::SelectionEvent;

use crate::config::{Method, ModelCfg, TrainConfig};
use crate::metrics::memory::method_memory_gb;

/// One optimization step, after the driver applied its update.
#[derive(Debug, Clone, PartialEq)]
pub struct StepEvent {
    /// stage index within a task sequence (0 for single-task runs)
    pub task_index: usize,
    /// 0-based step within the stage
    pub step: usize,
    pub loss: f64,
    /// effective base learning rate at this step
    pub lr: f64,
    /// wall-clock seconds spent in `Driver::step`
    pub secs: f64,
    /// tokens processed this step (batch × seq_len)
    pub tokens: usize,
}

/// Fired once per stage before the first step.
#[derive(Debug)]
pub struct RunStartEvent<'a> {
    pub task_index: usize,
    pub task: &'a str,
    pub method: Method,
    pub cfg: &'a ModelCfg,
    pub tc: &'a TrainConfig,
    pub trainable_params: usize,
}

/// Executor activity attributed to one step: per-artifact deltas of
/// call count, wall time, and host→device upload counts (split into
/// static re-binds vs per-step traffic). Emitted by the trainer from
/// runtime counter snapshots; prepare/finalize activity is attributed
/// to the boundary steps.
#[derive(Debug, Clone, PartialEq)]
pub struct ExecEvent {
    /// step the delta is attributed to
    pub step: usize,
    /// artifact manifest name (e.g. `grads_losia`)
    pub artifact: String,
    /// executions during this step
    pub calls: u64,
    /// wall-clock seconds spent inside the executor's execute phase
    pub secs: f64,
    /// wall-clock seconds spent binding inputs (host→device) **on the
    /// training thread** — the exposed share of upload time
    pub upload_secs: f64,
    /// wall-clock seconds spent materialising outputs (device→host)
    pub download_secs: f64,
    /// wall-clock seconds of staged uploads performed off-thread by
    /// the pipeline — overlapped with execution, so *not* part of the
    /// step's critical path (0 whenever the pipeline is off)
    pub overlap_secs: f64,
    /// re-uploads of static bindings (0 on a healthy hot path)
    pub static_uploads: u64,
    /// per-step uploads (batch tensors, subnet deltas, …)
    pub step_uploads: u64,
    /// outputs materialised host-side (lazy handle downloads)
    pub downloads: u64,
    /// device→host bytes those downloads moved — subnet-delta-sized
    /// for the LoSiA-Pro hot path, full-gradient-sized for FFT/GaLore
    pub download_bytes: u64,
}

/// One data-parallel step: reduction cost and per-worker busy time.
/// Emitted by the trainer only when the sharded loop is active
/// (`DpConfig::enabled()`), so single-plan runs carry no dp stream.
#[derive(Debug, Clone, PartialEq)]
pub struct DpEvent {
    /// step the reduction belongs to
    pub step: usize,
    /// worker (plan replica) count actually used this step
    pub workers: usize,
    /// logical shard count (the numerics knob)
    pub shards: usize,
    /// wall nanos spent inside the fixed-order tree reduction
    pub reduce_nanos: u64,
    /// bytes one shard contributed to the reduction this step —
    /// subnet-delta-sized for LoSiA-Pro, trainable-set-sized otherwise
    pub frame_bytes: u64,
    /// wall nanos each worker spent on its shard block
    pub worker_nanos: Vec<u64>,
}

/// One pipelined step: how far ahead the prefetch/staging workers ran
/// and how much of their work the training thread still had to wait
/// for. Emitted only when the step pipeline is active (mirroring how
/// [`DpEvent`] is emitted only under `DpConfig::enabled()`), so
/// synchronous runs carry no pipeline stream.
#[derive(Debug, Clone, PartialEq)]
pub struct PipelineEvent {
    /// step the staged group fed
    pub step: usize,
    /// staging sets in rotation (the queue bound)
    pub queue_depth: usize,
    /// worker threads the pipeline runs (pack + stage)
    pub prefetch_threads: usize,
    /// wall nanos the training thread spent blocked waiting for the
    /// staged group — the *exposed* share of prefetch + staging
    pub stall_nanos: u64,
    /// bytes the staged group uploaded off-thread
    pub staged_bytes: u64,
}

/// One durable-checkpoint interaction: a `LOSIACK1` record written
/// after a step (`resume == false`), or a resume from one before the
/// first step (`resume == true`). Emitted only when checkpointing is
/// configured, so ordinary runs carry no checkpoint stream.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointEvent {
    /// steps completed when the record was written / resumed from
    /// (a checkpoint after 0-based step t carries `step == t + 1`)
    pub step: usize,
    /// bytes of the durable record (0 on resume events)
    pub bytes: u64,
    /// path of the checkpoint file
    pub path: String,
    /// true when this event reports a resume, not a write
    pub resume: bool,
}

/// Fired between two stages of `Session::train_sequence`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TaskBoundaryEvent {
    pub from_index: usize,
    pub from_task: String,
    pub to_index: usize,
    pub to_task: String,
}

/// Fired after `Driver::finalize` (adapter merge) ends a stage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FinalizeEvent {
    pub task_index: usize,
    /// steps actually executed in this stage
    pub steps: usize,
}

/// A training-telemetry sink. All hooks default to no-ops so an
/// observer implements only what it cares about.
pub trait Observer {
    fn on_run_start(&mut self, _ev: &RunStartEvent<'_>) {}
    fn on_step(&mut self, _ev: &StepEvent) {}
    fn on_relocalize(&mut self, _ev: &SelectionEvent) {}
    fn on_exec(&mut self, _ev: &ExecEvent) {}
    fn on_dp(&mut self, _ev: &DpEvent) {}
    fn on_pipeline(&mut self, _ev: &PipelineEvent) {}
    fn on_checkpoint(&mut self, _ev: &CheckpointEvent) {}
    fn on_task_boundary(&mut self, _ev: &TaskBoundaryEvent) {}
    fn on_finalize(&mut self, _ev: &FinalizeEvent) {}
}

// ---------------------------------------------------------------- stock

/// Records the (step, loss) curve of the current stage.
#[derive(Debug, Default, Clone)]
pub struct LossObserver {
    pub log: Vec<(usize, f64)>,
}

impl LossObserver {
    pub fn first(&self) -> Option<f64> {
        self.log.first().map(|x| x.1)
    }

    /// Mean loss over the last `k` recorded steps. `None` when the log
    /// is empty or `k == 0` (the old `Trainer::tail_loss` sliced past
    /// the start of an empty log).
    pub fn tail_mean(&self, k: usize) -> Option<f64> {
        if self.log.is_empty() || k == 0 {
            return None;
        }
        let k = k.min(self.log.len());
        let sum: f64 =
            self.log[self.log.len() - k..].iter().map(|(_, l)| l).sum();
        Some(sum / k as f64)
    }
}

impl Observer for LossObserver {
    fn on_run_start(&mut self, _ev: &RunStartEvent<'_>) {
        self.log.clear();
    }

    fn on_step(&mut self, ev: &StepEvent) {
        self.log.push((ev.step, ev.loss));
    }
}

/// Records per-step wall time and reports mean µs/token.
#[derive(Debug, Default, Clone)]
pub struct LatencyObserver {
    pub step_secs: Vec<f64>,
    tokens_per_step: usize,
}

impl LatencyObserver {
    /// Mean µs/token, skipping the first step (compile/warmup cost)
    /// when at least two samples exist. `None` with no samples; a
    /// single sample is reported as-is (the old `Trainer::us_per_token`
    /// returned NaN for both).
    pub fn us_per_token(&self) -> Option<f64> {
        if self.tokens_per_step == 0 || self.step_secs.is_empty() {
            return None;
        }
        let kept: &[f64] = if self.step_secs.len() > 1 {
            &self.step_secs[1..]
        } else {
            &self.step_secs
        };
        let mean = kept.iter().sum::<f64>() / kept.len() as f64;
        Some(mean * 1e6 / self.tokens_per_step as f64)
    }

    pub fn total_secs(&self) -> f64 {
        self.step_secs.iter().sum()
    }
}

impl Observer for LatencyObserver {
    fn on_run_start(&mut self, ev: &RunStartEvent<'_>) {
        self.step_secs.clear();
        self.tokens_per_step = ev.cfg.tokens_per_step();
    }

    fn on_step(&mut self, ev: &StepEvent) {
        self.step_secs.push(ev.secs);
    }
}

/// Analytic memory estimate (paper Table 14) for the running method.
#[derive(Debug, Default, Clone)]
pub struct MemoryObserver {
    pub gb: f64,
}

impl Observer for MemoryObserver {
    fn on_run_start(&mut self, ev: &RunStartEvent<'_>) {
        self.gb = method_memory_gb(ev.cfg, ev.tc);
    }
}

/// Tracks subnet selections: full history plus the current snapshot.
#[derive(Debug, Default, Clone)]
pub struct SelectionObserver {
    pub history: Vec<SelectionEvent>,
}

impl SelectionObserver {
    /// Number of genuine re-localizations (initial random selections
    /// excluded).
    pub fn reselections(&self) -> usize {
        self.history.iter().filter(|e| !e.initial).count()
    }

    /// Latest `(group, kind, rho, gamma)` per matrix — the current
    /// subnet, in (group, kind) order.
    pub fn snapshot(
        &self,
    ) -> Vec<(usize, String, Vec<usize>, Vec<usize>)> {
        let mut last: std::collections::BTreeMap<
            (usize, String),
            (Vec<usize>, Vec<usize>),
        > = std::collections::BTreeMap::new();
        for e in &self.history {
            last.insert(
                (e.group, e.kind.clone()),
                (e.rho.clone(), e.gamma.clone()),
            );
        }
        last.into_iter()
            .map(|((g, k), (r, c))| (g, k, r, c))
            .collect()
    }

    /// Mean % of indices replaced between consecutive selections of
    /// the same matrix (`None` until a matrix reselects once).
    pub fn mean_turnover(&self) -> Option<f64> {
        let mut prev: std::collections::BTreeMap<
            (usize, String),
            &SelectionEvent,
        > = std::collections::BTreeMap::new();
        let mut total = 0.0;
        let mut n = 0usize;
        for e in &self.history {
            let key = (e.group, e.kind.clone());
            if let Some(p) = prev.get(&key) {
                let (new, old) = if e.rho.is_empty() {
                    (&e.gamma, &p.gamma)
                } else {
                    (&e.rho, &p.rho)
                };
                if !new.is_empty() {
                    let kept =
                        new.iter().filter(|i| old.contains(i)).count();
                    total +=
                        100.0 * (1.0 - kept as f64 / new.len() as f64);
                    n += 1;
                }
            }
            prev.insert(key, e);
        }
        (n > 0).then(|| total / n as f64)
    }
}

impl Observer for SelectionObserver {
    fn on_run_start(&mut self, _ev: &RunStartEvent<'_>) {
        self.history.clear();
    }

    fn on_relocalize(&mut self, ev: &SelectionEvent) {
        self.history.push(ev.clone());
    }
}

/// Accumulates per-artifact executor stats for the current stage and
/// feeds `RunReport::exec` — the PR-over-PR view of executor overhead
/// (calls, mean/total secs, and the static/per-step upload split).
#[derive(Debug, Default, Clone)]
pub struct ExecProfileObserver {
    pub by_artifact:
        std::collections::BTreeMap<String, crate::session::report::ExecProfile>,
}

impl ExecProfileObserver {
    /// Per-artifact profiles in name order.
    pub fn profiles(&self) -> Vec<crate::session::report::ExecProfile> {
        self.by_artifact.values().cloned().collect()
    }
}

impl Observer for ExecProfileObserver {
    fn on_run_start(&mut self, _ev: &RunStartEvent<'_>) {
        self.by_artifact.clear();
    }

    fn on_exec(&mut self, ev: &ExecEvent) {
        let p = self
            .by_artifact
            .entry(ev.artifact.clone())
            .or_insert_with(|| crate::session::report::ExecProfile {
                artifact: ev.artifact.clone(),
                ..Default::default()
            });
        p.calls += ev.calls;
        p.total_secs += ev.secs;
        p.upload_secs += ev.upload_secs;
        p.download_secs += ev.download_secs;
        p.overlap_secs += ev.overlap_secs;
        p.static_uploads += ev.static_uploads;
        p.step_uploads += ev.step_uploads;
        p.downloads += ev.downloads;
        p.download_bytes += ev.download_bytes;
        p.mean_secs = p.total_secs / p.calls.max(1) as f64;
    }
}

/// Accumulates data-parallel stats for the current stage and feeds
/// `RunReport::dp`: the worker/shard layout, total reduction time, and
/// the per-step cross-shard traffic (which `tests/dp_parity.rs` pins
/// against the analytic reduce-set size for LoSiA-Pro).
#[derive(Debug, Default, Clone)]
pub struct DpProfileObserver {
    /// dp steps observed (0 ⇒ the sharded loop never ran)
    pub steps: usize,
    pub workers: usize,
    pub shards: usize,
    /// bytes one shard contributes per step (constant over a stage)
    pub frame_bytes: u64,
    pub reduce_secs: f64,
    /// total busy seconds across all workers
    pub worker_busy_secs: f64,
}

impl Observer for DpProfileObserver {
    fn on_run_start(&mut self, _ev: &RunStartEvent<'_>) {
        *self = Self::default();
    }

    fn on_dp(&mut self, ev: &DpEvent) {
        self.steps += 1;
        self.workers = ev.workers;
        self.shards = ev.shards;
        self.frame_bytes = ev.frame_bytes;
        self.reduce_secs += ev.reduce_nanos as f64 * 1e-9;
        self.worker_busy_secs += ev
            .worker_nanos
            .iter()
            .map(|&n| n as f64 * 1e-9)
            .sum::<f64>();
    }
}

/// Accumulates step-pipeline stats for the current stage and feeds
/// `RunReport::pipeline`: the queue layout, total exposed stall, and
/// the off-thread upload volume (which `tests/pipeline_parity.rs` pins
/// against the synchronous run's per-step upload counts).
#[derive(Debug, Default, Clone)]
pub struct PipelineProfileObserver {
    /// pipelined steps observed (0 ⇒ the pipeline never ran)
    pub steps: usize,
    pub queue_depth: usize,
    pub prefetch_threads: usize,
    /// total seconds the training thread spent blocked on the queue
    pub stall_secs: f64,
    /// total bytes uploaded off-thread
    pub staged_bytes: u64,
}

impl Observer for PipelineProfileObserver {
    fn on_run_start(&mut self, _ev: &RunStartEvent<'_>) {
        *self = Self::default();
    }

    fn on_pipeline(&mut self, ev: &PipelineEvent) {
        self.steps += 1;
        self.queue_depth = ev.queue_depth;
        self.prefetch_threads = ev.prefetch_threads;
        self.stall_secs += ev.stall_nanos as f64 * 1e-9;
        self.staged_bytes += ev.staged_bytes;
    }
}

/// Accumulates durable-checkpoint stats for the current stage and
/// feeds `RunReport::checkpoint`: how many `LOSIACK1` records were
/// written, the bytes they moved, the newest on-disk path, and the
/// step a resumed stage restarted from.
#[derive(Debug, Default, Clone)]
pub struct CheckpointProfileObserver {
    /// checkpoint records written (0 ⇒ checkpointing never ran)
    pub writes: usize,
    /// total bytes across the written records
    pub bytes: u64,
    /// newest checkpoint written this stage
    pub last_path: Option<String>,
    /// steps already completed when the stage resumed (None for a
    /// fresh start)
    pub resume_step: Option<usize>,
}

impl Observer for CheckpointProfileObserver {
    fn on_run_start(&mut self, _ev: &RunStartEvent<'_>) {
        *self = Self::default();
    }

    fn on_checkpoint(&mut self, ev: &CheckpointEvent) {
        if ev.resume {
            self.resume_step = Some(ev.step);
        } else {
            self.writes += 1;
            self.bytes += ev.bytes;
            self.last_path = Some(ev.path.clone());
        }
    }
}

// ------------------------------------------------------------ dispatch

/// The observer bundle a trainer reports into: the four stock
/// observers (read back by `Session` to build its `RunReport`) plus
/// any user observers.
#[derive(Default)]
pub struct ObserverSet {
    pub task_index: usize,
    pub loss: LossObserver,
    pub latency: LatencyObserver,
    pub memory: MemoryObserver,
    pub selection: SelectionObserver,
    pub exec: ExecProfileObserver,
    pub dp: DpProfileObserver,
    pub pipeline: PipelineProfileObserver,
    pub checkpoint: CheckpointProfileObserver,
    pub extra: Vec<Box<dyn Observer>>,
}

impl ObserverSet {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_extra(extra: Vec<Box<dyn Observer>>) -> Self {
        ObserverSet {
            extra,
            ..Self::default()
        }
    }

    /// Start a stage: stock observers reset, everyone sees
    /// `on_run_start`.
    pub fn begin_task(&mut self, ev: &RunStartEvent<'_>) {
        self.task_index = ev.task_index;
        self.loss.on_run_start(ev);
        self.latency.on_run_start(ev);
        self.memory.on_run_start(ev);
        self.selection.on_run_start(ev);
        self.exec.on_run_start(ev);
        self.dp.on_run_start(ev);
        self.pipeline.on_run_start(ev);
        self.checkpoint.on_run_start(ev);
        for o in &mut self.extra {
            o.on_run_start(ev);
        }
    }

    pub fn emit_exec(&mut self, ev: &ExecEvent) {
        self.loss.on_exec(ev);
        self.latency.on_exec(ev);
        self.memory.on_exec(ev);
        self.selection.on_exec(ev);
        self.exec.on_exec(ev);
        self.dp.on_exec(ev);
        self.pipeline.on_exec(ev);
        self.checkpoint.on_exec(ev);
        for o in &mut self.extra {
            o.on_exec(ev);
        }
    }

    pub fn emit_dp(&mut self, ev: &DpEvent) {
        self.loss.on_dp(ev);
        self.latency.on_dp(ev);
        self.memory.on_dp(ev);
        self.selection.on_dp(ev);
        self.exec.on_dp(ev);
        self.dp.on_dp(ev);
        self.pipeline.on_dp(ev);
        self.checkpoint.on_dp(ev);
        for o in &mut self.extra {
            o.on_dp(ev);
        }
    }

    pub fn emit_pipeline(&mut self, ev: &PipelineEvent) {
        self.loss.on_pipeline(ev);
        self.latency.on_pipeline(ev);
        self.memory.on_pipeline(ev);
        self.selection.on_pipeline(ev);
        self.exec.on_pipeline(ev);
        self.dp.on_pipeline(ev);
        self.pipeline.on_pipeline(ev);
        self.checkpoint.on_pipeline(ev);
        for o in &mut self.extra {
            o.on_pipeline(ev);
        }
    }

    pub fn emit_checkpoint(&mut self, ev: &CheckpointEvent) {
        self.loss.on_checkpoint(ev);
        self.latency.on_checkpoint(ev);
        self.memory.on_checkpoint(ev);
        self.selection.on_checkpoint(ev);
        self.exec.on_checkpoint(ev);
        self.dp.on_checkpoint(ev);
        self.pipeline.on_checkpoint(ev);
        self.checkpoint.on_checkpoint(ev);
        for o in &mut self.extra {
            o.on_checkpoint(ev);
        }
    }

    pub fn emit_step(
        &mut self,
        step: usize,
        loss: f64,
        lr: f64,
        secs: f64,
        tokens: usize,
    ) {
        let ev = StepEvent {
            task_index: self.task_index,
            step,
            loss,
            lr,
            secs,
            tokens,
        };
        self.loss.on_step(&ev);
        self.latency.on_step(&ev);
        self.memory.on_step(&ev);
        self.selection.on_step(&ev);
        self.exec.on_step(&ev);
        self.dp.on_step(&ev);
        self.pipeline.on_step(&ev);
        self.checkpoint.on_step(&ev);
        for o in &mut self.extra {
            o.on_step(&ev);
        }
    }

    pub fn emit_relocalize(&mut self, ev: &SelectionEvent) {
        self.loss.on_relocalize(ev);
        self.latency.on_relocalize(ev);
        self.memory.on_relocalize(ev);
        self.selection.on_relocalize(ev);
        self.exec.on_relocalize(ev);
        self.dp.on_relocalize(ev);
        self.pipeline.on_relocalize(ev);
        self.checkpoint.on_relocalize(ev);
        for o in &mut self.extra {
            o.on_relocalize(ev);
        }
    }

    pub fn emit_task_boundary(&mut self, ev: &TaskBoundaryEvent) {
        self.loss.on_task_boundary(ev);
        self.latency.on_task_boundary(ev);
        self.memory.on_task_boundary(ev);
        self.selection.on_task_boundary(ev);
        self.exec.on_task_boundary(ev);
        self.dp.on_task_boundary(ev);
        self.pipeline.on_task_boundary(ev);
        self.checkpoint.on_task_boundary(ev);
        for o in &mut self.extra {
            o.on_task_boundary(ev);
        }
    }

    pub fn emit_finalize(&mut self, steps: usize) {
        let ev = FinalizeEvent {
            task_index: self.task_index,
            steps,
        };
        self.loss.on_finalize(&ev);
        self.latency.on_finalize(&ev);
        self.memory.on_finalize(&ev);
        self.selection.on_finalize(&ev);
        self.exec.on_finalize(&ev);
        self.dp.on_finalize(&ev);
        self.pipeline.on_finalize(&ev);
        self.checkpoint.on_finalize(&ev);
        for o in &mut self.extra {
            o.on_finalize(&ev);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sev(
        step: usize,
        group: usize,
        kind: &str,
        rho: Vec<usize>,
        initial: bool,
    ) -> SelectionEvent {
        SelectionEvent {
            step,
            group,
            kind: kind.to_string(),
            rho,
            gamma: vec![0, 1],
            initial,
        }
    }

    #[test]
    fn loss_observer_handles_empty_and_single_logs() {
        let mut o = LossObserver::default();
        // empty: the old Trainer::tail_loss panicked here
        assert_eq!(o.first(), None);
        assert_eq!(o.tail_mean(10), None);
        o.log.push((0, 2.0));
        assert_eq!(o.first(), Some(2.0));
        assert_eq!(o.tail_mean(10), Some(2.0));
        assert_eq!(o.tail_mean(0), None);
        o.log.push((1, 4.0));
        assert_eq!(o.tail_mean(1), Some(4.0));
        assert_eq!(o.tail_mean(2), Some(3.0));
    }

    #[test]
    fn latency_observer_handles_empty_and_single_logs() {
        let mut o = LatencyObserver::default();
        // no samples: the old Trainer::us_per_token returned NaN
        assert_eq!(o.us_per_token(), None);
        o.tokens_per_step = 100;
        o.step_secs.push(1e-3);
        // one sample: report it instead of NaN
        let one = o.us_per_token().unwrap();
        assert!((one - 10.0).abs() < 1e-9, "{one}");
        // ≥ 2 samples: skip the first (warmup)
        o.step_secs.push(3e-3);
        o.step_secs.push(5e-3);
        let us = o.us_per_token().unwrap();
        assert!((us - 40.0).abs() < 1e-9, "{us}");
    }

    #[test]
    fn latency_observer_without_token_count_is_none() {
        let mut o = LatencyObserver::default();
        o.step_secs.push(1.0);
        assert_eq!(o.us_per_token(), None);
    }

    #[test]
    fn selection_observer_snapshot_keeps_latest() {
        let mut o = SelectionObserver::default();
        o.on_relocalize(&sev(0, 0, "wq", vec![1, 2], true));
        o.on_relocalize(&sev(0, 1, "wq", vec![5, 6], true));
        o.on_relocalize(&sev(8, 0, "wq", vec![2, 3], false));
        assert_eq!(o.reselections(), 1);
        let snap = o.snapshot();
        assert_eq!(snap.len(), 2);
        assert_eq!(snap[0], (0, "wq".into(), vec![2, 3], vec![0, 1]));
        assert_eq!(snap[1], (1, "wq".into(), vec![5, 6], vec![0, 1]));
    }

    #[test]
    fn selection_turnover_measures_replacement() {
        let mut o = SelectionObserver::default();
        assert_eq!(o.mean_turnover(), None);
        o.on_relocalize(&sev(0, 0, "wq", vec![1, 2], true));
        assert_eq!(o.mean_turnover(), None);
        // one of two indices kept → 50% turnover
        o.on_relocalize(&sev(8, 0, "wq", vec![2, 3], false));
        assert!((o.mean_turnover().unwrap() - 50.0).abs() < 1e-9);
    }

    #[test]
    fn checkpoint_observer_splits_writes_from_resume() {
        let mut o = CheckpointProfileObserver::default();
        o.on_checkpoint(&CheckpointEvent {
            step: 2,
            bytes: 0,
            path: "ckpt-00000002.losia".into(),
            resume: true,
        });
        o.on_checkpoint(&CheckpointEvent {
            step: 4,
            bytes: 100,
            path: "a".into(),
            resume: false,
        });
        o.on_checkpoint(&CheckpointEvent {
            step: 6,
            bytes: 150,
            path: "b".into(),
            resume: false,
        });
        assert_eq!(o.resume_step, Some(2));
        assert_eq!(o.writes, 2);
        assert_eq!(o.bytes, 250);
        assert_eq!(o.last_path.as_deref(), Some("b"));
    }

    #[test]
    fn observer_set_dispatches_to_extras() {
        #[derive(Default)]
        struct Counter(std::rc::Rc<std::cell::Cell<usize>>);
        impl Observer for Counter {
            fn on_step(&mut self, _ev: &StepEvent) {
                self.0.set(self.0.get() + 1);
            }
        }
        let n = std::rc::Rc::new(std::cell::Cell::new(0));
        let mut obs = ObserverSet::with_extra(vec![Box::new(Counter(
            n.clone(),
        ))]);
        obs.emit_step(0, 1.0, 1e-3, 0.1, 64);
        obs.emit_step(1, 0.9, 1e-3, 0.1, 64);
        assert_eq!(n.get(), 2);
        assert_eq!(obs.loss.log.len(), 2);
        assert_eq!(obs.latency.step_secs.len(), 2);
    }
}
