//! Training configuration: model manifest (produced by `aot.py`) plus
//! run hyperparameters (method, rank factor, time slot, LR schedule).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Per-kind linear-layer dimensions (n = in, m = out) and subnet dims.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KindDims {
    pub n: usize,
    pub m: usize,
    pub np: usize,
    pub mp: usize,
}

/// Tensor spec from the artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered artifact: HLO file + typed I/O signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Static model configuration mirrored from `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub rank_factor: f64,
    pub out_factor: f64,
    pub vocab_sub: usize,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub param_count: usize,
    pub linear_kinds: Vec<String>,
    pub kinds: BTreeMap<String, KindDims>,
    /// canonical parameter ABI order: (name, shape)
    pub params: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ModelCfg {
    pub fn kind(&self, kind: &str) -> KindDims {
        *self
            .kinds
            .get(kind)
            .unwrap_or_else(|| panic!("unknown linear kind {kind:?}"))
    }

    /// Typed artifact lookup: the error names the config and lists
    /// every available artifact so a missing-artifact failure is
    /// actionable (re-run `python -m compile.aot`).
    pub fn try_artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {name:?} not in manifest for config {:?} \
                 (available: {:?}); re-run `make artifacts`",
                self.name,
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Infallible lookup for contexts that already validated the
    /// manifest; panics with the same actionable message otherwise.
    pub fn artifact(&self, name: &str) -> &ArtifactSpec {
        self.try_artifact(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    pub fn param_shape(&self, name: &str) -> &[usize] {
        &self
            .params
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("unknown param {name:?}"))
            .1
    }

    /// Tokens per training step (batch × seq), for µs/token metrics.
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq_len
    }
}

/// Load the manifest and return the named model config.
pub fn load_manifest(artifacts_dir: &Path, config: &str) -> Result<ModelCfg> {
    let mpath = artifacts_dir.join("manifest.json");
    let text = std::fs::read_to_string(&mpath)
        .with_context(|| format!("reading {}", mpath.display()))?;
    let root = json::parse(&text)
        .map_err(|e| anyhow::anyhow!("manifest parse error: {e}"))?;
    let cfgs = root.at("configs");
    let Some(c) = cfgs.get(config) else {
        bail!(
            "config {config:?} not in manifest (have {:?}); \
             run `make artifacts`",
            cfgs.as_obj().keys().collect::<Vec<_>>()
        );
    };
    parse_config(c, artifacts_dir)
}

fn parse_spec(j: &Json) -> TensorSpec {
    TensorSpec {
        name: j.at("name").as_str().to_string(),
        shape: j.at("shape").as_arr().iter().map(|v| v.as_usize()).collect(),
        dtype: match j.at("dtype").as_str() {
            "i32" => Dtype::I32,
            _ => Dtype::F32,
        },
    }
}

fn parse_config(c: &Json, artifacts_dir: &Path) -> Result<ModelCfg> {
    let mut kinds = BTreeMap::new();
    for (k, v) in c.at("kinds").as_obj() {
        kinds.insert(
            k.clone(),
            KindDims {
                n: v.at("n").as_usize(),
                m: v.at("m").as_usize(),
                np: v.at("np").as_usize(),
                mp: v.at("mp").as_usize(),
            },
        );
    }
    let mut artifacts = BTreeMap::new();
    for (k, v) in c.at("artifacts").as_obj() {
        artifacts.insert(
            k.clone(),
            ArtifactSpec {
                name: k.clone(),
                file: artifacts_dir.join(v.at("file").as_str()),
                inputs: v.at("inputs").as_arr().iter().map(parse_spec).collect(),
                outputs: v
                    .at("outputs")
                    .as_arr()
                    .iter()
                    .map(parse_spec)
                    .collect(),
            },
        );
    }
    Ok(ModelCfg {
        name: c.at("name").as_str().to_string(),
        vocab: c.at("vocab").as_usize(),
        d_model: c.at("d_model").as_usize(),
        n_heads: c.at("n_heads").as_usize(),
        d_ff: c.at("d_ff").as_usize(),
        n_layers: c.at("n_layers").as_usize(),
        seq_len: c.at("seq_len").as_usize(),
        batch: c.at("batch").as_usize(),
        rank_factor: c.at("rank_factor").as_f64(),
        out_factor: c.at("out_factor").as_f64(),
        vocab_sub: c.at("vocab_sub").as_usize(),
        lora_rank: c.at("lora_rank").as_usize(),
        lora_alpha: c.at("lora_alpha").as_f64(),
        param_count: c.at("param_count").as_usize(),
        linear_kinds: c
            .at("linear_kinds")
            .as_arr()
            .iter()
            .map(|v| v.as_str().to_string())
            .collect(),
        kinds,
        params: c
            .at("params")
            .as_arr()
            .iter()
            .map(|p| {
                (
                    p.at("name").as_str().to_string(),
                    p.at("shape")
                        .as_arr()
                        .iter()
                        .map(|v| v.as_usize())
                        .collect(),
                )
            })
            .collect(),
        artifacts,
    })
}

/// Fine-tuning method selector (paper Table 1 row set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// full-parameter fine-tuning
    Fft,
    /// LoRA (Hu et al. 2022)
    Lora,
    /// PiSSA: LoRA with principal-singular-vector init
    Pissa,
    /// DoRA: magnitude/direction decomposition
    Dora,
    /// GaLore: low-rank gradient projection
    Galore,
    /// LoSiA: subnet localization, full-grad backward (gather on host)
    Losia,
    /// LoSiA-Pro: factorized subnet gradients via the Pallas kernel
    LosiaPro,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fft" | "full" => Method::Fft,
            "lora" => Method::Lora,
            "pissa" => Method::Pissa,
            "dora" => Method::Dora,
            "galore" => Method::Galore,
            "losia" => Method::Losia,
            "losia-pro" | "losiapro" | "losia_pro" => Method::LosiaPro,
            other => bail!("unknown method {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Fft => "FFT",
            Method::Lora => "LoRA",
            Method::Pissa => "PiSSA",
            Method::Dora => "DoRA",
            Method::Galore => "GaLore",
            Method::Losia => "LoSiA",
            Method::LosiaPro => "LoSiA-Pro",
        }
    }
}

/// Ablation switches from paper Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Ablation {
    /// SL: synchronous localization — every layer reselects at the same
    /// step instead of the staggered async timeline.
    pub synchronous: bool,
    /// GL: gradient-magnitude importance instead of sensitivity EMA.
    pub gradient_importance: bool,
    /// WDS: disable learning-rate rewarming after reselection.
    pub no_rewarm: bool,
    /// FFTO: fully fine-tune lm_head instead of the p_o subnet.
    pub fft_output: bool,
    /// ReLO: never re-localize (freeze the initial subnet).
    pub no_relocalize: bool,
}

/// Full run configuration for the trainer.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub method: Method,
    pub steps: usize,
    pub lr: f64,
    /// warmup fraction of total steps (paper: 0.1)
    pub warmup_ratio: f64,
    /// LoSiA time slot T (steps per layer-profiling window)
    pub time_slot: usize,
    /// EMA factors β1 = β2 for sensitivity importance (paper: 0.85)
    pub ema_beta: f64,
    /// Adam moment decay rates
    pub adam_beta1: f64,
    pub adam_beta2: f64,
    pub adam_eps: f64,
    /// GaLore projection rank R and projector refresh period
    pub galore_rank: usize,
    pub galore_period: usize,
    pub ablation: Ablation,
    pub seed: u64,
    /// log loss every N steps (0 = never)
    pub log_every: usize,
    /// use the gradient-checkpointed (remat) artifact variants
    pub use_remat: bool,
    /// Override the manifest rank factor p (Table 11 sweep). Only the
    /// host-gather LoSiA path supports this — the Pro artifact's
    /// subnet shapes are baked at AOT time.
    pub rank_factor_override: Option<f64>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::LosiaPro,
            steps: 100,
            lr: 6e-5,
            warmup_ratio: 0.1,
            time_slot: 20,
            ema_beta: 0.85,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            adam_eps: 1e-8,
            galore_rank: 32,
            galore_period: 40,
            ablation: Ablation::default(),
            seed: 42,
            log_every: 0,
            use_remat: false,
            rank_factor_override: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::Fft,
            Method::Lora,
            Method::Pissa,
            Method::Dora,
            Method::Galore,
            Method::Losia,
            Method::LosiaPro,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn manifest_loads_tiny() {
        let dir = crate::runtime::artifacts_dir();
        let cfg = load_manifest(&dir, "tiny").expect("tiny manifest");
        assert_eq!(cfg.n_layers, 2);
        assert_eq!(cfg.linear_kinds.len(), 7);
        let kd = cfg.kind("wq");
        assert_eq!(kd.n, cfg.d_model);
        assert_eq!(kd.np, (cfg.d_model as f64 * cfg.rank_factor) as usize);
        assert!(cfg.has_artifact("grads_losia"));
        let a = cfg.artifact("fwd_logits");
        assert_eq!(a.outputs[0].shape, vec![cfg.batch, cfg.seq_len, cfg.vocab]);
    }
}
