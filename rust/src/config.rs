//! Training configuration: model manifest (produced by `aot.py`) plus
//! run hyperparameters (method, rank factor, time slot, LR schedule).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{self, Json};

/// Per-kind linear-layer dimensions (n = in, m = out) and subnet dims.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KindDims {
    pub n: usize,
    pub m: usize,
    pub np: usize,
    pub mp: usize,
}

/// Tensor spec from the artifact manifest.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One lowered artifact: HLO file + typed I/O signature.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Render a tensor-spec list as `name: dtype[d0,d1], …` (shared by
/// [`ArtifactSpec::signature`] and `losia info`).
pub fn fmt_specs(specs: &[TensorSpec]) -> String {
    specs
        .iter()
        .map(|s| {
            let dt = match s.dtype {
                Dtype::F32 => "f32",
                Dtype::I32 => "i32",
            };
            let dims = s
                .shape
                .iter()
                .map(|d| d.to_string())
                .collect::<Vec<_>>()
                .join(",");
            format!("{}: {dt}[{dims}]", s.name)
        })
        .collect::<Vec<_>>()
        .join(", ")
}

impl ArtifactSpec {
    /// Human-readable manifest signature for error messages, e.g.
    /// `inputs: [embed: f32[64,32], …] -> outputs: [loss: f32[]]`.
    pub fn signature(&self) -> String {
        format!(
            "inputs: [{}] -> outputs: [{}]",
            fmt_specs(&self.inputs),
            fmt_specs(&self.outputs)
        )
    }
}

/// Static model configuration mirrored from `python/compile/aot.py`.
#[derive(Debug, Clone)]
pub struct ModelCfg {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub n_layers: usize,
    pub seq_len: usize,
    pub batch: usize,
    pub rank_factor: f64,
    pub out_factor: f64,
    pub vocab_sub: usize,
    pub lora_rank: usize,
    pub lora_alpha: f64,
    pub param_count: usize,
    pub linear_kinds: Vec<String>,
    pub kinds: BTreeMap<String, KindDims>,
    /// canonical parameter ABI order: (name, shape)
    pub params: Vec<(String, Vec<usize>)>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
}

impl ModelCfg {
    pub fn kind(&self, kind: &str) -> KindDims {
        *self
            .kinds
            .get(kind)
            .unwrap_or_else(|| panic!("unknown linear kind {kind:?}"))
    }

    /// Typed artifact lookup: the error names the config and lists
    /// every available artifact so a missing-artifact failure is
    /// actionable (re-run `python -m compile.aot`).
    pub fn try_artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {name:?} not in manifest for config {:?} \
                 (available: {:?}); re-run `make artifacts`",
                self.name,
                self.artifacts.keys().collect::<Vec<_>>()
            )
        })
    }

    /// Infallible lookup for contexts that already validated the
    /// manifest; panics with the same actionable message otherwise.
    pub fn artifact(&self, name: &str) -> &ArtifactSpec {
        self.try_artifact(name).unwrap_or_else(|e| panic!("{e}"))
    }

    pub fn has_artifact(&self, name: &str) -> bool {
        self.artifacts.contains_key(name)
    }

    pub fn param_shape(&self, name: &str) -> &[usize] {
        &self
            .params
            .iter()
            .find(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("unknown param {name:?}"))
            .1
    }

    /// Tokens per training step (batch × seq), for µs/token metrics.
    pub fn tokens_per_step(&self) -> usize {
        self.batch * self.seq_len
    }
}

/// Load the manifest and return the named model config.
pub fn load_manifest(artifacts_dir: &Path, config: &str) -> Result<ModelCfg> {
    let mpath = artifacts_dir.join("manifest.json");
    let text = std::fs::read_to_string(&mpath)
        .with_context(|| format!("reading {}", mpath.display()))?;
    let root = json::parse(&text)
        .map_err(|e| anyhow::anyhow!("manifest parse error: {e}"))?;
    let cfgs = root.at("configs");
    let Some(c) = cfgs.get(config) else {
        bail!(
            "config {config:?} not in manifest (have {:?}); \
             run `make artifacts`",
            cfgs.as_obj().keys().collect::<Vec<_>>()
        );
    };
    parse_config(c, artifacts_dir)
}

fn parse_spec(j: &Json) -> TensorSpec {
    TensorSpec {
        name: j.at("name").as_str().to_string(),
        shape: j.at("shape").as_arr().iter().map(|v| v.as_usize()).collect(),
        dtype: match j.at("dtype").as_str() {
            "i32" => Dtype::I32,
            _ => Dtype::F32,
        },
    }
}

fn parse_config(c: &Json, artifacts_dir: &Path) -> Result<ModelCfg> {
    let mut kinds = BTreeMap::new();
    for (k, v) in c.at("kinds").as_obj() {
        kinds.insert(
            k.clone(),
            KindDims {
                n: v.at("n").as_usize(),
                m: v.at("m").as_usize(),
                np: v.at("np").as_usize(),
                mp: v.at("mp").as_usize(),
            },
        );
    }
    let mut artifacts = BTreeMap::new();
    for (k, v) in c.at("artifacts").as_obj() {
        artifacts.insert(
            k.clone(),
            ArtifactSpec {
                name: k.clone(),
                file: artifacts_dir.join(v.at("file").as_str()),
                inputs: v.at("inputs").as_arr().iter().map(parse_spec).collect(),
                outputs: v
                    .at("outputs")
                    .as_arr()
                    .iter()
                    .map(parse_spec)
                    .collect(),
            },
        );
    }
    Ok(ModelCfg {
        name: c.at("name").as_str().to_string(),
        vocab: c.at("vocab").as_usize(),
        d_model: c.at("d_model").as_usize(),
        n_heads: c.at("n_heads").as_usize(),
        d_ff: c.at("d_ff").as_usize(),
        n_layers: c.at("n_layers").as_usize(),
        seq_len: c.at("seq_len").as_usize(),
        batch: c.at("batch").as_usize(),
        rank_factor: c.at("rank_factor").as_f64(),
        out_factor: c.at("out_factor").as_f64(),
        vocab_sub: c.at("vocab_sub").as_usize(),
        lora_rank: c.at("lora_rank").as_usize(),
        lora_alpha: c.at("lora_alpha").as_f64(),
        param_count: c.at("param_count").as_usize(),
        linear_kinds: c
            .at("linear_kinds")
            .as_arr()
            .iter()
            .map(|v| v.as_str().to_string())
            .collect(),
        kinds,
        params: c
            .at("params")
            .as_arr()
            .iter()
            .map(|p| {
                (
                    p.at("name").as_str().to_string(),
                    p.at("shape")
                        .as_arr()
                        .iter()
                        .map(|v| v.as_usize())
                        .collect(),
                )
            })
            .collect(),
        artifacts,
    })
}

/// Resolve a config: from `manifest.json` when the artifacts have been
/// lowered, else from the [`builtin_config`] zoo (identical shapes) so
/// the reference backend runs from a bare checkout.
pub fn resolve_config(
    artifacts_dir: &Path,
    name: &str,
) -> Result<ModelCfg> {
    if artifacts_dir.join("manifest.json").exists() {
        load_manifest(artifacts_dir, name)
    } else {
        builtin_config(name, artifacts_dir)
    }
}

// ----------------------------------------------------- builtin configs

const LINEAR_KINDS: [&str; 7] =
    ["wq", "wk", "wv", "wo", "wgate", "wup", "wdown"];

/// Mirror of the config zoo in `python/compile/aot.py::CONFIGS`,
/// including per-artifact I/O signatures, so the reference backend
/// needs no generated manifest. Must stay bit-identical to the Python
/// side — `config::tests::builtin_matches_manifest` pins that whenever
/// a lowered manifest is present.
#[allow(clippy::type_complexity)]
pub fn builtin_config(name: &str, artifacts_dir: &Path) -> Result<ModelCfg> {
    // (vocab, d_model, n_heads, d_ff, n_layers, seq, batch, p, p_o, r)
    let (vocab, d_model, n_heads, d_ff, n_layers, seq_len, batch,
         rank_factor, out_factor, lora_rank): (
        usize, usize, usize, usize, usize, usize, usize, f64, f64, usize,
    ) = match name {
        "tiny" => (64, 32, 2, 64, 2, 32, 4, 0.125, 0.25, 4),
        "small" => (256, 128, 4, 256, 4, 64, 4, 0.125, 0.125, 16),
        "medium" => (512, 256, 8, 512, 6, 128, 4, 0.125, 0.125, 32),
        "gpt90m" => {
            (4096, 768, 12, 2048, 12, 128, 4, 0.125, 0.0625, 64)
        }
        other => bail!(
            "config {other:?} is neither in a lowered manifest nor a \
             builtin (builtins: tiny, small, medium, gpt90m); run \
             `make artifacts` for manifest-defined configs"
        ),
    };
    let lora_alpha = 16.0;
    let sub = |n: usize, p: f64| ((n as f64 * p) as usize).max(1);
    let vocab_sub = sub(vocab, out_factor);

    let mut kinds = BTreeMap::new();
    for kind in LINEAR_KINDS {
        let (n, m) = match kind {
            "wgate" | "wup" => (d_model, d_ff),
            "wdown" => (d_ff, d_model),
            _ => (d_model, d_model),
        };
        kinds.insert(
            kind.to_string(),
            KindDims {
                n,
                m,
                np: sub(n, rank_factor),
                mp: sub(m, rank_factor),
            },
        );
    }

    // canonical parameter ABI order (model.py::param_specs)
    let (d, f, v, l) = (d_model, d_ff, vocab, n_layers);
    let params: Vec<(String, Vec<usize>)> = vec![
        ("embed".into(), vec![v, d]),
        ("wq".into(), vec![l, d, d]),
        ("wk".into(), vec![l, d, d]),
        ("wv".into(), vec![l, d, d]),
        ("wo".into(), vec![l, d, d]),
        ("wgate".into(), vec![l, d, f]),
        ("wup".into(), vec![l, d, f]),
        ("wdown".into(), vec![l, f, d]),
        ("norm1".into(), vec![l, d]),
        ("norm2".into(), vec![l, d]),
        ("norm_f".into(), vec![d]),
        ("lm_head".into(), vec![d, v]),
    ];
    let param_count = v * d
        + l * (4 * d * d + 3 * d * f + 2 * d)
        + d
        + d * v;

    let f32s = |n: &str, s: &[usize]| TensorSpec {
        name: n.to_string(),
        shape: s.to_vec(),
        dtype: Dtype::F32,
    };
    let i32s = |n: &str, s: &[usize]| TensorSpec {
        name: n.to_string(),
        shape: s.to_vec(),
        dtype: Dtype::I32,
    };
    let pio: Vec<TensorSpec> =
        params.iter().map(|(n, s)| f32s(n, s)).collect();
    let bio = vec![
        i32s("tokens", &[batch, seq_len]),
        i32s("targets", &[batch, seq_len]),
        f32s("mask", &[batch, seq_len]),
    ];
    let mut dio = Vec::new();
    let mut iio = Vec::new();
    for kind in LINEAR_KINDS {
        let kd = kinds[kind];
        dio.push(f32s(&format!("dws_{kind}"), &[l, kd.np, kd.mp]));
        iio.push(i32s(&format!("rho_{kind}"), &[l, kd.np]));
        iio.push(i32s(&format!("gamma_{kind}"), &[l, kd.mp]));
    }
    dio.push(f32s("dws_out", &[d, vocab_sub]));
    iio.push(i32s("gamma_out", &[vocab_sub]));
    let lora_io = |dora: bool| {
        let mut io = Vec::new();
        for kind in LINEAR_KINDS {
            let kd = kinds[kind];
            io.push(f32s(&format!("la_{kind}"), &[l, kd.n, lora_rank]));
            io.push(f32s(&format!("lb_{kind}"), &[l, lora_rank, kd.m]));
            if dora {
                io.push(f32s(&format!("mag_{kind}"), &[l, kd.m]));
            }
        }
        io
    };

    let mut artifacts = BTreeMap::new();
    let full_set = [
        "fwd_logits",
        "fwd_loss",
        "fwd_decode",
        "grads_full",
        "grads_losia",
        "grads_probe",
        "grads_lora",
        "grads_dora",
        "grads_full_remat",
        "grads_losia_remat",
        "grads_lora_remat",
        "grads_dora_remat",
    ];
    let big_set = [
        "fwd_logits",
        "fwd_loss",
        "fwd_decode",
        "grads_losia_remat",
        "grads_probe",
        "grads_lora_remat",
    ];
    let set: &[&str] =
        if name == "gpt90m" { &big_set } else { &full_set };
    for art in set {
        let base = art.strip_suffix("_remat").unwrap_or(art);
        let (inputs, outputs): (Vec<TensorSpec>, Vec<TensorSpec>) =
            match base {
                "fwd_logits" => (
                    pio.iter()
                        .cloned()
                        .chain([i32s("tokens", &[batch, seq_len])])
                        .collect(),
                    vec![f32s("logits", &[batch, seq_len, v])],
                ),
                "fwd_loss" => (
                    pio.iter().cloned().chain(bio.clone()).collect(),
                    vec![f32s("nll", &[batch]), f32s("cnt", &[batch])],
                ),
                // KV-cached incremental decode step (serving path).
                // Backbone params are the only static-eligible inputs;
                // every adapter tensor is a per-step binding so tenant
                // hot-swaps never re-upload the frozen backbone.
                // `tokens` packs each row's new tokens at the row head,
                // `lens` counts them (0 = row inactive this step) and
                // `reset` clears a row's cache before appending.
                "fwd_decode" => (
                    pio.iter()
                        .cloned()
                        .chain(dio.clone())
                        .chain(iio.clone())
                        .chain(lora_io(false))
                        .chain([
                            i32s("adapter_mode", &[]),
                            i32s("tokens", &[batch, seq_len]),
                            i32s("lens", &[batch]),
                            i32s("reset", &[batch]),
                        ])
                        .collect(),
                    vec![f32s("logits", &[batch, v])],
                ),
                "grads_full" => (
                    pio.iter().cloned().chain(bio.clone()).collect(),
                    [f32s("loss", &[])]
                        .into_iter()
                        .chain(params.iter().map(|(n, s)| {
                            f32s(&format!("g_{n}"), s)
                        }))
                        .collect(),
                ),
                "grads_losia" => (
                    pio.iter()
                        .cloned()
                        .chain(dio.clone())
                        .chain(iio.clone())
                        .chain([i32s("probe", &[])])
                        .chain(bio.clone())
                        .collect(),
                    [f32s("loss", &[])]
                        .into_iter()
                        .chain(dio.iter().map(|s| {
                            f32s(&format!("g_{}", s.name), &s.shape)
                        }))
                        .chain(LINEAR_KINDS.iter().map(|k| {
                            let kd = kinds[*k];
                            f32s(
                                &format!("probe_{k}"),
                                &[kd.n, kd.m],
                            )
                        }))
                        .chain([f32s("probe_lm_head", &[d, v])])
                        .collect(),
                ),
                "grads_probe" => (
                    pio.iter()
                        .cloned()
                        .chain([i32s("probe", &[])])
                        .chain(bio.clone())
                        .collect(),
                    [f32s("loss", &[])]
                        .into_iter()
                        .chain(LINEAR_KINDS.iter().map(|k| {
                            let kd = kinds[*k];
                            f32s(&format!("g_{k}"), &[kd.n, kd.m])
                        }))
                        .chain([f32s("g_lm_head", &[d, v])])
                        .collect(),
                ),
                "grads_lora" | "grads_dora" => {
                    let aio = lora_io(base == "grads_dora");
                    (
                        pio.iter()
                            .cloned()
                            .chain(aio.clone())
                            .chain(bio.clone())
                            .collect(),
                        [f32s("loss", &[])]
                            .into_iter()
                            .chain(aio.iter().map(|s| {
                                f32s(
                                    &format!("g_{}", s.name),
                                    &s.shape,
                                )
                            }))
                            .collect(),
                    )
                }
                _ => unreachable!(),
            };
        artifacts.insert(
            art.to_string(),
            ArtifactSpec {
                name: art.to_string(),
                file: artifacts_dir
                    .join(name)
                    .join(format!("{art}.hlo.txt")),
                inputs,
                outputs,
            },
        );
    }

    Ok(ModelCfg {
        name: name.to_string(),
        vocab,
        d_model,
        n_heads,
        d_ff,
        n_layers,
        seq_len,
        batch,
        rank_factor,
        out_factor,
        vocab_sub,
        lora_rank,
        lora_alpha,
        param_count,
        linear_kinds: LINEAR_KINDS
            .iter()
            .map(|s| s.to_string())
            .collect(),
        kinds,
        params,
        artifacts,
    })
}

/// Fine-tuning method selector (paper Table 1 row set).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// full-parameter fine-tuning
    Fft,
    /// LoRA (Hu et al. 2022)
    Lora,
    /// PiSSA: LoRA with principal-singular-vector init
    Pissa,
    /// DoRA: magnitude/direction decomposition
    Dora,
    /// GaLore: low-rank gradient projection
    Galore,
    /// LoSiA: subnet localization, full-grad backward (gather on host)
    Losia,
    /// LoSiA-Pro: factorized subnet gradients via the Pallas kernel
    LosiaPro,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fft" | "full" => Method::Fft,
            "lora" => Method::Lora,
            "pissa" => Method::Pissa,
            "dora" => Method::Dora,
            "galore" => Method::Galore,
            "losia" => Method::Losia,
            "losia-pro" | "losiapro" | "losia_pro" => Method::LosiaPro,
            other => bail!("unknown method {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::Fft => "FFT",
            Method::Lora => "LoRA",
            Method::Pissa => "PiSSA",
            Method::Dora => "DoRA",
            Method::Galore => "GaLore",
            Method::Losia => "LoSiA",
            Method::LosiaPro => "LoSiA-Pro",
        }
    }
}

/// Ablation switches from paper Table 3.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct Ablation {
    /// SL: synchronous localization — every layer reselects at the same
    /// step instead of the staggered async timeline.
    pub synchronous: bool,
    /// GL: gradient-magnitude importance instead of sensitivity EMA.
    pub gradient_importance: bool,
    /// WDS: disable learning-rate rewarming after reselection.
    pub no_rewarm: bool,
    /// FFTO: fully fine-tune lm_head instead of the p_o subnet.
    pub fft_output: bool,
    /// ReLO: never re-localize (freeze the initial subnet).
    pub no_relocalize: bool,
}

/// Full run configuration for the trainer.
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub method: Method,
    pub steps: usize,
    pub lr: f64,
    /// warmup fraction of total steps (paper: 0.1)
    pub warmup_ratio: f64,
    /// LoSiA time slot T (steps per layer-profiling window)
    pub time_slot: usize,
    /// EMA factors β1 = β2 for sensitivity importance (paper: 0.85)
    pub ema_beta: f64,
    /// Adam moment decay rates
    pub adam_beta1: f64,
    pub adam_beta2: f64,
    pub adam_eps: f64,
    /// GaLore projection rank R and projector refresh period
    pub galore_rank: usize,
    pub galore_period: usize,
    pub ablation: Ablation,
    pub seed: u64,
    /// log loss every N steps (0 = never)
    pub log_every: usize,
    /// use the gradient-checkpointed (remat) artifact variants
    pub use_remat: bool,
    /// Override the manifest rank factor p (Table 11 sweep). Only the
    /// host-gather LoSiA path supports this — the Pro artifact's
    /// subnet shapes are baked at AOT time.
    pub rank_factor_override: Option<f64>,
    /// Data-parallel worker threads (plan replicas). 1 = the legacy
    /// single-plan loop; also settable via `LOSIA_DP_WORKERS` (see
    /// `runtime::dp::DpConfig::resolve`). Never affects numerics.
    pub dp_workers: usize,
    /// Logical batch shards per step — the dp *numerics* knob: the
    /// run's bits are a function of the shard count, not the worker
    /// count. Defaults to `dp_workers` when left at 1; also settable
    /// via `LOSIA_DP_SHARDS`.
    pub dp_shards: usize,
    /// Step pipeline (double-buffered uploads + bounded batch
    /// prefetch). `None` defers to the `LOSIA_PIPELINE` env var (off
    /// when unset); `Some(_)` wins over the env. Never affects
    /// numerics — the pipelined loop is bitwise identical to the
    /// synchronous one (see `runtime::pipeline`).
    pub pipeline: Option<bool>,
    /// Write a durable training checkpoint every N steps. `None`
    /// defers to `LOSIA_CKPT_EVERY` (0 = disabled when unset); see
    /// `coordinator::checkpoint::CheckpointConfig::resolve`.
    pub checkpoint_every: Option<usize>,
    /// Checkpoint directory. `None` defers to `LOSIA_CKPT_DIR`
    /// (default `checkpoints/`).
    pub checkpoint_dir: Option<PathBuf>,
    /// Newest checkpoints retained after each write (min 1). `None`
    /// defers to `LOSIA_CKPT_KEEP` (default 3).
    pub checkpoint_keep: Option<usize>,
    /// Resume from the newest loadable checkpoint before training.
    /// `None` defers to `LOSIA_CKPT_RESUME` (off when unset). Resumed
    /// runs are bitwise identical to uninterrupted ones (pinned by
    /// `tests/checkpoint_parity.rs`).
    pub resume: Option<bool>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            method: Method::LosiaPro,
            steps: 100,
            lr: 6e-5,
            warmup_ratio: 0.1,
            time_slot: 20,
            ema_beta: 0.85,
            adam_beta1: 0.9,
            adam_beta2: 0.999,
            adam_eps: 1e-8,
            galore_rank: 32,
            galore_period: 40,
            ablation: Ablation::default(),
            seed: 42,
            log_every: 0,
            use_remat: false,
            rank_factor_override: None,
            dp_workers: 1,
            dp_shards: 1,
            pipeline: None,
            checkpoint_every: None,
            checkpoint_dir: None,
            checkpoint_keep: None,
            resume: None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn method_parse_roundtrip() {
        for m in [
            Method::Fft,
            Method::Lora,
            Method::Pissa,
            Method::Dora,
            Method::Galore,
            Method::Losia,
            Method::LosiaPro,
        ] {
            assert_eq!(Method::parse(m.name()).unwrap(), m);
        }
        assert!(Method::parse("nope").is_err());
    }

    #[test]
    fn tiny_config_resolves() {
        // via manifest.json when lowered, else the builtin zoo
        let dir = crate::runtime::artifacts_dir();
        let cfg = resolve_config(&dir, "tiny").expect("tiny config");
        assert_eq!(cfg.n_layers, 2);
        assert_eq!(cfg.linear_kinds.len(), 7);
        let kd = cfg.kind("wq");
        assert_eq!(kd.n, cfg.d_model);
        assert_eq!(kd.np, (cfg.d_model as f64 * cfg.rank_factor) as usize);
        assert!(cfg.has_artifact("grads_losia"));
        let a = cfg.artifact("fwd_logits");
        assert_eq!(a.outputs[0].shape, vec![cfg.batch, cfg.seq_len, cfg.vocab]);
    }

    #[test]
    fn builtin_matches_manifest() {
        // Whenever lowered artifacts exist, the builtin zoo must agree
        // with them signature-for-signature — that equivalence is what
        // lets the reference backend stand in for the XLA path.
        let dir = crate::runtime::artifacts_dir();
        if !dir.join("manifest.json").exists() {
            return; // nothing to compare against
        }
        for name in ["tiny", "small", "medium", "gpt90m"] {
            let Ok(m) = load_manifest(&dir, name) else {
                continue; // config not lowered in this checkout
            };
            let b = builtin_config(name, &dir).unwrap();
            assert_eq!(m.vocab, b.vocab, "{name}: vocab");
            assert_eq!(m.d_model, b.d_model, "{name}: d_model");
            assert_eq!(m.n_heads, b.n_heads, "{name}: n_heads");
            assert_eq!(m.d_ff, b.d_ff, "{name}: d_ff");
            assert_eq!(m.n_layers, b.n_layers, "{name}: n_layers");
            assert_eq!(m.seq_len, b.seq_len, "{name}: seq_len");
            assert_eq!(m.batch, b.batch, "{name}: batch");
            assert_eq!(m.vocab_sub, b.vocab_sub, "{name}: vocab_sub");
            assert_eq!(m.lora_rank, b.lora_rank, "{name}: lora_rank");
            assert_eq!(
                m.param_count, b.param_count,
                "{name}: param_count"
            );
            assert_eq!(m.kinds, b.kinds, "{name}: kind dims");
            assert_eq!(m.params, b.params, "{name}: param ABI");
            assert_eq!(
                m.linear_kinds, b.linear_kinds,
                "{name}: kinds order"
            );
            for (art, ms) in &m.artifacts {
                let bs = b
                    .artifacts
                    .get(art)
                    .unwrap_or_else(|| {
                        panic!("{name}: builtin lacks artifact {art}")
                    });
                assert_eq!(
                    ms.inputs, bs.inputs,
                    "{name}/{art}: inputs"
                );
                assert_eq!(
                    ms.outputs, bs.outputs,
                    "{name}/{art}: outputs"
                );
            }
            // The builtin zoo may carry reference-only artifacts the
            // XLA lowering doesn't emit (the interpreted decode path);
            // anything else builtin-only is a drift bug.
            for art in b.artifacts.keys() {
                assert!(
                    m.artifacts.contains_key(art)
                        || art == "fwd_decode",
                    "{name}: builtin-only artifact {art}"
                );
            }
        }
    }

    #[test]
    fn builtin_unknown_config_is_typed_error() {
        let dir = std::path::PathBuf::from("/nonexistent");
        let err = builtin_config("nope", &dir).unwrap_err();
        assert!(err.to_string().contains("tiny"), "{err}");
    }

    #[test]
    fn signature_lists_inputs_and_outputs() {
        let dir = std::path::PathBuf::from("/nonexistent");
        let cfg = builtin_config("tiny", &dir).unwrap();
        let sig = cfg.artifact("fwd_loss").signature();
        assert!(sig.contains("tokens: i32[4,32]"), "{sig}");
        assert!(sig.contains("nll: f32[4]"), "{sig}");
    }
}
