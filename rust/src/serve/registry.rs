//! Multi-tenant adapter registry: named records, activation, and the
//! static-traffic ledger.
//!
//! Activation is where the serving economics live. A delta tenant
//! (LoSiA subnet, LoRA factors) activates by handing its
//! [`AdapterBinding`] to the next decode step — pure per-step traffic,
//! zero static uploads. A full-state tenant replaces the backbone
//! (one static upload), and switching away from it restores the base
//! backbone (one more). `backbone_uploads()` counts exactly those
//! events, so a delta-only serving loop must report 0.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::Result;

use crate::config::ModelCfg;
use crate::coordinator::state::ModelState;
use crate::serve::adapter::{AdapterBinding, AdapterRecord};
use crate::serve::decode::Decoder;

struct TenantEntry {
    /// `Some` only for full-state tenants
    full: Option<Box<ModelState>>,
    binding: AdapterBinding,
}

/// Named adapters over one base backbone.
pub struct AdapterRegistry {
    base: ModelState,
    tenants: BTreeMap<String, TenantEntry>,
    active: Option<String>,
    swaps: u64,
    backbone_uploads: u64,
}

impl AdapterRegistry {
    /// `base` is the frozen backbone the decoder was built on; it is
    /// kept so the registry can restore it after a full-state tenant.
    pub fn new(base: ModelState) -> AdapterRegistry {
        AdapterRegistry {
            base,
            tenants: BTreeMap::new(),
            active: None,
            swaps: 0,
            backbone_uploads: 0,
        }
    }

    /// Register (or replace) a tenant's adapter.
    pub fn register(
        &mut self,
        tenant: &str,
        record: AdapterRecord,
        cfg: &ModelCfg,
    ) -> Result<()> {
        let binding = AdapterBinding::from_record(cfg, &record)?;
        let full = match record {
            AdapterRecord::Full(state) => Some(state),
            AdapterRecord::Delta(_) => None,
        };
        self.tenants
            .insert(tenant.to_string(), TenantEntry { full, binding });
        Ok(())
    }

    /// Register a tenant from a record file (full checkpoint or
    /// compact adapter — the magic decides).
    pub fn load_file(
        &mut self,
        tenant: &str,
        path: &Path,
        cfg: &ModelCfg,
    ) -> Result<()> {
        let record = AdapterRecord::load(path, cfg)?;
        self.register(tenant, record, cfg)
    }

    pub fn has(&self, tenant: &str) -> bool {
        self.tenants.contains_key(tenant)
    }

    pub fn tenant_names(&self) -> Vec<&str> {
        self.tenants.keys().map(|k| k.as_str()).collect()
    }

    /// Make `tenant` current and return the binding the next decode
    /// step must carry. Only full-state tenants (in either direction)
    /// touch the decoder's static bindings.
    pub fn activate(
        &mut self,
        tenant: &str,
        dec: &mut Decoder<'_>,
    ) -> Result<&AdapterBinding> {
        anyhow::ensure!(
            self.tenants.contains_key(tenant),
            "unknown tenant {tenant:?} (registered: {:?})",
            self.tenant_names()
        );
        if self.active.as_deref() != Some(tenant) {
            let was_full = self
                .active
                .as_deref()
                .and_then(|t| self.tenants.get(t))
                .is_some_and(|e| e.full.is_some());
            let entry = &self.tenants[tenant];
            if let Some(state) = &entry.full {
                dec.rebind_backbone(state)?;
                self.backbone_uploads += 1;
            } else if was_full {
                dec.rebind_backbone(&self.base)?;
                self.backbone_uploads += 1;
            }
            self.active = Some(tenant.to_string());
            self.swaps += 1;
        }
        Ok(&self.tenants[tenant].binding)
    }

    pub fn active(&self) -> Option<&str> {
        self.active.as_deref()
    }

    /// Tenant switches performed so far.
    pub fn swaps(&self) -> u64 {
        self.swaps
    }

    /// Backbone (static) re-uploads caused by activations. Stays 0
    /// for any sequence of delta-tenant swaps.
    pub fn backbone_uploads(&self) -> u64 {
        self.backbone_uploads
    }
}
