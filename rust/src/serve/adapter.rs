//! Adapter records and their decode-ABI bindings.
//!
//! A tenant's fine-tuned delta arrives in one of three shapes:
//!
//! * **Full** — a complete `ModelState` checkpoint (`LOSIAST1`
//!   format). Activating it replaces the frozen backbone, which is the
//!   one swap that costs static uploads.
//! * **LoSiA** — the subnet selection (ρ/γ per linear kind plus the
//!   output γ) and the trained `dws` frames: exactly the compact
//!   artifact the paper's method produces.
//! * **LoRA** — per-kind A/B factor pairs.
//!
//! Compact records serialize to a `LOSIAAD1` file (same little-endian
//! framing as the `LOSIAST1` state checkpoint, plus i32 tensors for
//! the index vectors); [`AdapterRecord::load`] sniffs the magic so a
//! full checkpoint and a compact adapter load through one entry point.
//!
//! [`AdapterBinding`] is the materialized per-step bind set for the
//! `fwd_decode` artifact: *every* adapter input is always bound —
//! zeros for the families the record does not use, plus the
//! `adapter_mode` selector — so adapters ride entirely on per-step
//! traffic and tenant hot-swaps never touch the static backbone
//! bindings (`tests/serve_parity.rs` pins the zero-static-upload
//! invariant).

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelCfg;
use crate::coordinator::state::ModelState;
use crate::runtime::ExecPlan;
use crate::tensor::Tensor;

const ADAPTER_MAGIC: &[u8; 8] = b"LOSIAAD1";
const STATE_MAGIC: &[u8; 8] = b"LOSIAST1";

/// `adapter_mode` values of the `fwd_decode` ABI.
pub const MODE_PLAIN: i32 = 0;
pub const MODE_LOSIA: i32 = 1;
pub const MODE_LORA: i32 = 2;

/// A compact (non-full-state) adapter delta: named f32 tensors plus
/// named i32 index tensors, keyed by their `fwd_decode` input names.
#[derive(Debug, Clone)]
pub struct AdapterDelta {
    /// [`MODE_LOSIA`] or [`MODE_LORA`]
    pub mode: i32,
    pub f32s: Vec<(String, Tensor)>,
    pub i32s: Vec<(String, Vec<usize>, Vec<i32>)>,
}

/// One tenant's loadable fine-tuning artifact.
#[derive(Debug, Clone)]
pub enum AdapterRecord {
    /// Complete parameter checkpoint — swaps the backbone itself.
    Full(Box<ModelState>),
    /// LoSiA subnet / LoRA factors riding on the frozen backbone.
    Delta(AdapterDelta),
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

fn read_name_shape<R: Read>(r: &mut R) -> Result<(String, Vec<usize>)> {
    let nlen = read_u32(r)? as usize;
    let mut nbuf = vec![0u8; nlen];
    r.read_exact(&mut nbuf)?;
    let name = String::from_utf8(nbuf)
        .context("adapter record: non-utf8 tensor name")?;
    let ndims = read_u32(r)? as usize;
    let mut shape = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        shape.push(read_u64(r)? as usize);
    }
    Ok((name, shape))
}

fn write_name_shape<W: Write>(
    w: &mut W,
    name: &str,
    shape: &[usize],
) -> Result<()> {
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name.as_bytes())?;
    w.write_all(&(shape.len() as u32).to_le_bytes())?;
    for &d in shape {
        w.write_all(&(d as u64).to_le_bytes())?;
    }
    Ok(())
}

impl AdapterRecord {
    /// Serialize to `path`. Full records delegate to the `LOSIAST1`
    /// state format; compact deltas write a `LOSIAAD1` file.
    pub fn save(&self, path: &Path) -> Result<()> {
        match self {
            AdapterRecord::Full(state) => state.save(path),
            AdapterRecord::Delta(d) => {
                if let Some(dir) = path.parent() {
                    let _ = std::fs::create_dir_all(dir);
                }
                let f = std::fs::File::create(path).with_context(
                    || format!("creating {}", path.display()),
                )?;
                let mut w = BufWriter::new(f);
                w.write_all(ADAPTER_MAGIC)?;
                w.write_all(&d.mode.to_le_bytes())?;
                w.write_all(&(d.f32s.len() as u32).to_le_bytes())?;
                for (name, t) in &d.f32s {
                    write_name_shape(&mut w, name, &t.shape)?;
                    let bytes: Vec<u8> = t
                        .data
                        .iter()
                        .flat_map(|x| x.to_le_bytes())
                        .collect();
                    w.write_all(&bytes)?;
                }
                w.write_all(&(d.i32s.len() as u32).to_le_bytes())?;
                for (name, shape, data) in &d.i32s {
                    write_name_shape(&mut w, name, shape)?;
                    let bytes: Vec<u8> = data
                        .iter()
                        .flat_map(|x| x.to_le_bytes())
                        .collect();
                    w.write_all(&bytes)?;
                }
                w.flush()?;
                Ok(())
            }
        }
    }

    /// Load either record format, sniffing the 8-byte magic. Shape
    /// validation against the decode ABI happens at bind time, where
    /// the plan checks every named input against the manifest.
    pub fn load(path: &Path, cfg: &ModelCfg) -> Result<AdapterRecord> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic == STATE_MAGIC {
            drop(r);
            return Ok(AdapterRecord::Full(Box::new(
                ModelState::load(path, cfg)?,
            )));
        }
        if &magic != ADAPTER_MAGIC {
            bail!(
                "{} is neither a LoSiA state checkpoint nor an \
                 adapter record (bad magic)",
                path.display()
            );
        }
        let mut mbuf = [0u8; 4];
        r.read_exact(&mut mbuf)?;
        let mode = i32::from_le_bytes(mbuf);
        if mode != MODE_LOSIA && mode != MODE_LORA {
            bail!(
                "{}: adapter_mode {mode} out of range (1 = losia, \
                 2 = lora)",
                path.display()
            );
        }
        let nf = read_u32(&mut r)? as usize;
        let mut f32s = Vec::with_capacity(nf);
        for _ in 0..nf {
            let (name, shape) = read_name_shape(&mut r)?;
            let len: usize = shape.iter().product();
            let mut bytes = vec![0u8; len * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|c| {
                    f32::from_le_bytes([c[0], c[1], c[2], c[3]])
                })
                .collect();
            f32s.push((name, Tensor::from_vec(&shape, data)));
        }
        let ni = read_u32(&mut r)? as usize;
        let mut i32s = Vec::with_capacity(ni);
        for _ in 0..ni {
            let (name, shape) = read_name_shape(&mut r)?;
            let len: usize = shape.iter().product();
            let mut bytes = vec![0u8; len * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<i32> = bytes
                .chunks_exact(4)
                .map(|c| {
                    i32::from_le_bytes([c[0], c[1], c[2], c[3]])
                })
                .collect();
            i32s.push((name, shape, data));
        }
        Ok(AdapterRecord::Delta(AdapterDelta { mode, f32s, i32s }))
    }
}

/// Fully-materialized per-step bindings for every adapter input of the
/// `fwd_decode` artifact. Families the record does not use are bound
/// as zeros: a zero `dws`/`la`/`lb` contributes exactly nothing to the
/// forward, and index vectors of zeros are valid (clamped) selections.
#[derive(Debug, Clone)]
pub struct AdapterBinding {
    mode: i32,
    f32s: Vec<(String, Tensor)>,
    i32s: Vec<(String, Vec<usize>, Vec<i32>)>,
}

impl AdapterBinding {
    /// The no-adapter binding: plain-backbone decode.
    pub fn plain(cfg: &ModelCfg) -> AdapterBinding {
        let mut b = AdapterBinding {
            mode: MODE_PLAIN,
            f32s: Vec::new(),
            i32s: Vec::new(),
        };
        let l = cfg.n_layers;
        for kind in &cfg.linear_kinds {
            let kd = cfg.kind(kind);
            b.push_f32(&format!("dws_{kind}"), &[l, kd.np, kd.mp]);
            b.push_i32(&format!("rho_{kind}"), &[l, kd.np]);
            b.push_i32(&format!("gamma_{kind}"), &[l, kd.mp]);
            b.push_f32(
                &format!("la_{kind}"),
                &[l, kd.n, cfg.lora_rank],
            );
            b.push_f32(
                &format!("lb_{kind}"),
                &[l, cfg.lora_rank, kd.m],
            );
        }
        b.push_f32("dws_out", &[cfg.d_model, cfg.vocab_sub]);
        b.push_i32("gamma_out", &[cfg.vocab_sub]);
        b
    }

    /// Materialize a record into the dense bind set. Full records
    /// yield the plain binding — their weights travel through the
    /// backbone rebind instead (see `serve::registry`).
    pub fn from_record(
        cfg: &ModelCfg,
        record: &AdapterRecord,
    ) -> Result<AdapterBinding> {
        let mut b = AdapterBinding::plain(cfg);
        let AdapterRecord::Delta(d) = record else {
            return Ok(b);
        };
        b.mode = d.mode;
        for (name, t) in &d.f32s {
            let slot = b
                .f32s
                .iter_mut()
                .find(|(n, _)| n == name)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "adapter record: {name:?} is not a decode \
                         adapter input"
                    )
                })?;
            anyhow::ensure!(
                slot.1.shape == t.shape,
                "adapter record: {name:?} has shape {:?}, decode ABI \
                 wants {:?}",
                t.shape,
                slot.1.shape
            );
            slot.1 = t.clone();
        }
        for (name, shape, data) in &d.i32s {
            let slot = b
                .i32s
                .iter_mut()
                .find(|(n, _, _)| n == name)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "adapter record: {name:?} is not a decode \
                         adapter index input"
                    )
                })?;
            anyhow::ensure!(
                &slot.1 == shape,
                "adapter record: {name:?} has shape {:?}, decode ABI \
                 wants {:?}",
                shape,
                slot.1
            );
            slot.2 = data.clone();
        }
        Ok(b)
    }

    pub fn mode(&self) -> i32 {
        self.mode
    }

    /// Bind the whole adapter set (always per-step slots) onto a
    /// decode plan.
    pub fn bind(&self, plan: &mut ExecPlan) -> Result<()> {
        plan.bind_scalar_i32("adapter_mode", self.mode)?;
        for (name, t) in &self.f32s {
            plan.bind_f32(name, t)?;
        }
        for (name, shape, data) in &self.i32s {
            plan.bind_i32(name, shape, data)?;
        }
        Ok(())
    }

    fn push_f32(&mut self, name: &str, shape: &[usize]) {
        self.f32s.push((name.to_string(), Tensor::zeros(shape)));
    }

    fn push_i32(&mut self, name: &str, shape: &[usize]) {
        let len: usize = shape.iter().product();
        self.i32s.push((
            name.to_string(),
            shape.to_vec(),
            vec![0; len],
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny() -> ModelCfg {
        crate::config::builtin_config(
            "tiny",
            std::path::Path::new("/nonexistent"),
        )
        .unwrap()
    }

    #[test]
    fn plain_binding_covers_every_adapter_input() {
        let cfg = tiny();
        let spec = cfg.artifact("fwd_decode");
        let b = AdapterBinding::plain(&cfg);
        let bound: Vec<&str> = b
            .f32s
            .iter()
            .map(|(n, _)| n.as_str())
            .chain(b.i32s.iter().map(|(n, _, _)| n.as_str()))
            .chain(["adapter_mode"])
            .collect();
        for inp in &spec.inputs {
            let is_param =
                cfg.params.iter().any(|(n, _)| *n == inp.name);
            let is_step = matches!(
                inp.name.as_str(),
                "tokens" | "lens" | "reset"
            );
            if !is_param && !is_step {
                assert!(
                    bound.contains(&inp.name.as_str()),
                    "decode input {:?} not covered by the binding",
                    inp.name
                );
            }
        }
    }

    #[test]
    fn delta_record_roundtrips_through_disk() {
        let cfg = tiny();
        let mut rng = Rng::new(11);
        let kd = cfg.kind("wq");
        let l = cfg.n_layers;
        let delta = AdapterDelta {
            mode: MODE_LOSIA,
            f32s: vec![(
                "dws_wq".into(),
                Tensor::randn(&[l, kd.np, kd.mp], 0.1, &mut rng),
            )],
            i32s: vec![(
                "rho_wq".into(),
                vec![l, kd.np],
                (0..l * kd.np).map(|i| (i % kd.n) as i32).collect(),
            )],
        };
        let dir = std::env::temp_dir().join("losia_adapter_rt");
        let path = dir.join("t.adapter");
        AdapterRecord::Delta(delta.clone()).save(&path).unwrap();
        let back = AdapterRecord::load(&path, &cfg).unwrap();
        let AdapterRecord::Delta(d2) = back else {
            panic!("loaded as full state");
        };
        assert_eq!(d2.mode, MODE_LOSIA);
        assert_eq!(d2.f32s.len(), 1);
        assert_eq!(d2.f32s[0].0, "dws_wq");
        assert_eq!(d2.f32s[0].1.shape, delta.f32s[0].1.shape);
        assert_eq!(d2.f32s[0].1.data, delta.f32s[0].1.data);
        assert_eq!(d2.i32s, delta.i32s);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn full_checkpoint_loads_through_the_same_entry_point() {
        let cfg = tiny();
        let mut rng = Rng::new(5);
        let state = ModelState::init(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("losia_adapter_full");
        let path = dir.join("full.bin");
        state.save(&path).unwrap();
        let rec = AdapterRecord::load(&path, &cfg).unwrap();
        assert!(matches!(rec, AdapterRecord::Full(_)));
        // a full record materializes as the plain binding
        let b = AdapterBinding::from_record(&cfg, &rec).unwrap();
        assert_eq!(b.mode(), MODE_PLAIN);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_delta_shape_is_a_typed_error() {
        let cfg = tiny();
        let delta = AdapterDelta {
            mode: MODE_LORA,
            f32s: vec![(
                "la_wq".into(),
                Tensor::zeros(&[1, 2, 3]),
            )],
            i32s: vec![],
        };
        let err = AdapterBinding::from_record(
            &cfg,
            &AdapterRecord::Delta(delta),
        )
        .unwrap_err();
        assert!(err.to_string().contains("la_wq"), "{err}");
    }
}
