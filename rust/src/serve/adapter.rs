//! Adapter records and their decode-ABI bindings.
//!
//! A tenant's fine-tuned delta arrives in one of three shapes:
//!
//! * **Full** — a complete `ModelState` checkpoint (`LOSIAST1`
//!   format). Activating it replaces the frozen backbone, which is the
//!   one swap that costs static uploads.
//! * **LoSiA** — the subnet selection (ρ/γ per linear kind plus the
//!   output γ) and the trained `dws` frames: exactly the compact
//!   artifact the paper's method produces.
//! * **LoRA** — per-kind A/B factor pairs.
//!
//! Compact records serialize to a `LOSIAAD1` file (same little-endian
//! framing as the `LOSIAST1` state checkpoint, plus i32 tensors for
//! the index vectors); [`AdapterRecord::load`] sniffs the magic so a
//! full checkpoint and a compact adapter load through one entry point.
//!
//! [`AdapterBinding`] is the materialized per-step bind set for the
//! `fwd_decode` artifact: *every* adapter input is always bound —
//! zeros for the families the record does not use, plus the
//! `adapter_mode` selector — so adapters ride entirely on per-step
//! traffic and tenant hot-swaps never touch the static backbone
//! bindings (`tests/serve_parity.rs` pins the zero-static-upload
//! invariant).

use std::io::{BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelCfg;
use crate::coordinator::state::ModelState;
use crate::runtime::ExecPlan;
use crate::tensor::Tensor;
use crate::util::durable::{self, Header, SectionReader};

const ADAPTER_MAGIC: &[u8; 8] = b"LOSIAAD1";
const STATE_MAGIC: &[u8; 8] = b"LOSIAST1";

/// Format version after the sentinel (v1 = sectioned CRC layout).
/// Legacy files put the `adapter_mode` (1 or 2) where the sentinel
/// would be, so the two layouts can never be confused.
const ADAPTER_VERSION: u32 = 1;

/// `adapter_mode` values of the `fwd_decode` ABI.
pub const MODE_PLAIN: i32 = 0;
pub const MODE_LOSIA: i32 = 1;
pub const MODE_LORA: i32 = 2;

/// A compact (non-full-state) adapter delta: named f32 tensors plus
/// named i32 index tensors, keyed by their `fwd_decode` input names.
#[derive(Debug, Clone)]
pub struct AdapterDelta {
    /// [`MODE_LOSIA`] or [`MODE_LORA`]
    pub mode: i32,
    pub f32s: Vec<(String, Tensor)>,
    pub i32s: Vec<(String, Vec<usize>, Vec<i32>)>,
}

/// One tenant's loadable fine-tuning artifact.
#[derive(Debug, Clone)]
pub enum AdapterRecord {
    /// Complete parameter checkpoint — swaps the backbone itself.
    Full(Box<ModelState>),
    /// LoSiA subnet / LoRA factors riding on the frozen backbone.
    Delta(AdapterDelta),
}

fn read_name_shape<R: Read>(
    r: &mut SectionReader<R>,
) -> Result<(String, Vec<usize>)> {
    let name = r.str()?;
    let ndims = r.u32()? as usize;
    let mut shape = Vec::with_capacity(ndims);
    for _ in 0..ndims {
        shape.push(r.u64()? as usize);
    }
    Ok((name, shape))
}

impl AdapterRecord {
    /// Serialize to `path`. Full records delegate to the `LOSIAST1`
    /// state format; compact deltas write a `LOSIAAD1` file. Both
    /// paths are atomic (tmp + fsync + rename) with per-section
    /// CRC32s — a crash mid-save never tears an existing record.
    pub fn save(&self, path: &Path) -> Result<()> {
        match self {
            AdapterRecord::Full(state) => state.save(path),
            AdapterRecord::Delta(d) => {
                if let Some(dir) = path.parent() {
                    let _ = std::fs::create_dir_all(dir);
                }
                durable::atomic_write(path, "save", 0, |w| {
                    durable::write_header(
                        w,
                        ADAPTER_MAGIC,
                        ADAPTER_VERSION,
                    )?;
                    w.u32(d.mode as u32)?;
                    w.u32(d.f32s.len() as u32)?;
                    w.end_section()?;
                    for (name, t) in &d.f32s {
                        w.str(name)?;
                        w.u32(t.shape.len() as u32)?;
                        for &dim in &t.shape {
                            w.u64(dim as u64)?;
                        }
                        w.f32s(&t.data)?;
                        w.end_section()?;
                    }
                    w.u32(d.i32s.len() as u32)?;
                    w.end_section()?;
                    for (name, shape, data) in &d.i32s {
                        w.str(name)?;
                        w.u32(shape.len() as u32)?;
                        for &dim in shape {
                            w.u64(dim as u64)?;
                        }
                        for x in data {
                            w.write_all(&x.to_le_bytes())?;
                        }
                        w.end_section()?;
                    }
                    Ok(())
                })
            }
        }
    }

    /// Load either record format, sniffing the 8-byte magic. Shape
    /// validation against the decode ABI happens at bind time, where
    /// the plan checks every named input against the manifest.
    /// Records written before the durability rework (the mode word
    /// directly after the magic, no CRCs) still load, with a one-line
    /// warning.
    pub fn load(path: &Path, cfg: &ModelCfg) -> Result<AdapterRecord> {
        {
            let mut f = std::fs::File::open(path).with_context(
                || format!("opening {}", path.display()),
            )?;
            let mut magic = [0u8; 8];
            f.read_exact(&mut magic)?;
            if &magic == STATE_MAGIC {
                return Ok(AdapterRecord::Full(Box::new(
                    ModelState::load(path, cfg)?,
                )));
            }
            if &magic != ADAPTER_MAGIC {
                bail!(
                    "{} is neither a LoSiA state checkpoint nor an \
                     adapter record (bad magic)",
                    path.display()
                );
            }
        }
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = SectionReader::new(
            BufReader::new(f),
            path.display().to_string(),
        );
        let mode = match r.read_header(ADAPTER_MAGIC)? {
            Header::Versioned(v) => {
                if v > ADAPTER_VERSION {
                    bail!(
                        "{}: adapter format version {v} is newer \
                         than this build understands (max \
                         {ADAPTER_VERSION})",
                        path.display()
                    );
                }
                r.section("meta");
                r.u32()? as i32
            }
            Header::Legacy(first) => {
                crate::util::warn::warn(format!(
                    "{}: pre-durability adapter record (no CRC \
                     sections); loading without verification",
                    path.display()
                ));
                r.section("meta");
                first as i32
            }
        };
        if mode != MODE_LOSIA && mode != MODE_LORA {
            bail!(
                "{}: adapter_mode {mode} out of range (1 = losia, \
                 2 = lora)",
                path.display()
            );
        }
        let nf = r.u32()? as usize;
        r.end_section()?;
        let mut f32s = Vec::with_capacity(nf);
        for i in 0..nf {
            r.section(&format!("f32-tensor {i}"));
            let (name, shape) = read_name_shape(&mut r)?;
            let len: usize = shape.iter().product();
            let mut data = vec![0f32; len];
            r.f32s(&mut data)?;
            r.end_section()?;
            f32s.push((name, Tensor::from_vec(&shape, data)));
        }
        r.section("index-count");
        let ni = r.u32()? as usize;
        r.end_section()?;
        let mut i32s = Vec::with_capacity(ni);
        for i in 0..ni {
            r.section(&format!("i32-tensor {i}"));
            let (name, shape) = read_name_shape(&mut r)?;
            let len: usize = shape.iter().product();
            let mut bytes = vec![0u8; len * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<i32> = bytes
                .chunks_exact(4)
                .map(|c| {
                    i32::from_le_bytes([c[0], c[1], c[2], c[3]])
                })
                .collect();
            r.end_section()?;
            i32s.push((name, shape, data));
        }
        Ok(AdapterRecord::Delta(AdapterDelta { mode, f32s, i32s }))
    }
}

/// Fully-materialized per-step bindings for every adapter input of the
/// `fwd_decode` artifact. Families the record does not use are bound
/// as zeros: a zero `dws`/`la`/`lb` contributes exactly nothing to the
/// forward, and index vectors of zeros are valid (clamped) selections.
#[derive(Debug, Clone)]
pub struct AdapterBinding {
    mode: i32,
    f32s: Vec<(String, Tensor)>,
    i32s: Vec<(String, Vec<usize>, Vec<i32>)>,
}

impl AdapterBinding {
    /// The no-adapter binding: plain-backbone decode.
    pub fn plain(cfg: &ModelCfg) -> AdapterBinding {
        let mut b = AdapterBinding {
            mode: MODE_PLAIN,
            f32s: Vec::new(),
            i32s: Vec::new(),
        };
        let l = cfg.n_layers;
        for kind in &cfg.linear_kinds {
            let kd = cfg.kind(kind);
            b.push_f32(&format!("dws_{kind}"), &[l, kd.np, kd.mp]);
            b.push_i32(&format!("rho_{kind}"), &[l, kd.np]);
            b.push_i32(&format!("gamma_{kind}"), &[l, kd.mp]);
            b.push_f32(
                &format!("la_{kind}"),
                &[l, kd.n, cfg.lora_rank],
            );
            b.push_f32(
                &format!("lb_{kind}"),
                &[l, cfg.lora_rank, kd.m],
            );
        }
        b.push_f32("dws_out", &[cfg.d_model, cfg.vocab_sub]);
        b.push_i32("gamma_out", &[cfg.vocab_sub]);
        b
    }

    /// Materialize a record into the dense bind set. Full records
    /// yield the plain binding — their weights travel through the
    /// backbone rebind instead (see `serve::registry`).
    pub fn from_record(
        cfg: &ModelCfg,
        record: &AdapterRecord,
    ) -> Result<AdapterBinding> {
        let mut b = AdapterBinding::plain(cfg);
        let AdapterRecord::Delta(d) = record else {
            return Ok(b);
        };
        b.mode = d.mode;
        for (name, t) in &d.f32s {
            let slot = b
                .f32s
                .iter_mut()
                .find(|(n, _)| n == name)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "adapter record: {name:?} is not a decode \
                         adapter input"
                    )
                })?;
            anyhow::ensure!(
                slot.1.shape == t.shape,
                "adapter record: {name:?} has shape {:?}, decode ABI \
                 wants {:?}",
                t.shape,
                slot.1.shape
            );
            slot.1 = t.clone();
        }
        for (name, shape, data) in &d.i32s {
            let slot = b
                .i32s
                .iter_mut()
                .find(|(n, _, _)| n == name)
                .ok_or_else(|| {
                    anyhow::anyhow!(
                        "adapter record: {name:?} is not a decode \
                         adapter index input"
                    )
                })?;
            anyhow::ensure!(
                &slot.1 == shape,
                "adapter record: {name:?} has shape {:?}, decode ABI \
                 wants {:?}",
                shape,
                slot.1
            );
            slot.2 = data.clone();
        }
        Ok(b)
    }

    pub fn mode(&self) -> i32 {
        self.mode
    }

    /// Bind the whole adapter set (always per-step slots) onto a
    /// decode plan.
    pub fn bind(&self, plan: &mut ExecPlan) -> Result<()> {
        plan.bind_scalar_i32("adapter_mode", self.mode)?;
        for (name, t) in &self.f32s {
            plan.bind_f32(name, t)?;
        }
        for (name, shape, data) in &self.i32s {
            plan.bind_i32(name, shape, data)?;
        }
        Ok(())
    }

    fn push_f32(&mut self, name: &str, shape: &[usize]) {
        self.f32s.push((name.to_string(), Tensor::zeros(shape)));
    }

    fn push_i32(&mut self, name: &str, shape: &[usize]) {
        let len: usize = shape.iter().product();
        self.i32s.push((
            name.to_string(),
            shape.to_vec(),
            vec![0; len],
        ));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn tiny() -> ModelCfg {
        crate::config::builtin_config(
            "tiny",
            std::path::Path::new("/nonexistent"),
        )
        .unwrap()
    }

    #[test]
    fn plain_binding_covers_every_adapter_input() {
        let cfg = tiny();
        let spec = cfg.artifact("fwd_decode");
        let b = AdapterBinding::plain(&cfg);
        let bound: Vec<&str> = b
            .f32s
            .iter()
            .map(|(n, _)| n.as_str())
            .chain(b.i32s.iter().map(|(n, _, _)| n.as_str()))
            .chain(["adapter_mode"])
            .collect();
        for inp in &spec.inputs {
            let is_param =
                cfg.params.iter().any(|(n, _)| *n == inp.name);
            let is_step = matches!(
                inp.name.as_str(),
                "tokens" | "lens" | "reset"
            );
            if !is_param && !is_step {
                assert!(
                    bound.contains(&inp.name.as_str()),
                    "decode input {:?} not covered by the binding",
                    inp.name
                );
            }
        }
    }

    #[test]
    fn delta_record_roundtrips_through_disk() {
        let cfg = tiny();
        let mut rng = Rng::new(11);
        let kd = cfg.kind("wq");
        let l = cfg.n_layers;
        let delta = AdapterDelta {
            mode: MODE_LOSIA,
            f32s: vec![(
                "dws_wq".into(),
                Tensor::randn(&[l, kd.np, kd.mp], 0.1, &mut rng),
            )],
            i32s: vec![(
                "rho_wq".into(),
                vec![l, kd.np],
                (0..l * kd.np).map(|i| (i % kd.n) as i32).collect(),
            )],
        };
        let dir = std::env::temp_dir().join("losia_adapter_rt");
        let path = dir.join("t.adapter");
        AdapterRecord::Delta(delta.clone()).save(&path).unwrap();
        let back = AdapterRecord::load(&path, &cfg).unwrap();
        let AdapterRecord::Delta(d2) = back else {
            panic!("loaded as full state");
        };
        assert_eq!(d2.mode, MODE_LOSIA);
        assert_eq!(d2.f32s.len(), 1);
        assert_eq!(d2.f32s[0].0, "dws_wq");
        assert_eq!(d2.f32s[0].1.shape, delta.f32s[0].1.shape);
        assert_eq!(d2.f32s[0].1.data, delta.f32s[0].1.data);
        assert_eq!(d2.i32s, delta.i32s);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn legacy_adapter_record_loads_with_a_warning() {
        // pre-PR-10 layout: magic, i32 mode, u32 nf, tensors (name,
        // shape, raw f32s), u32 ni, i32 tensors — no sentinel, no CRC
        let cfg = tiny();
        let mut buf = Vec::new();
        buf.extend_from_slice(ADAPTER_MAGIC);
        buf.extend_from_slice(&MODE_LORA.to_le_bytes());
        buf.extend_from_slice(&1u32.to_le_bytes()); // one f32 tensor
        buf.extend_from_slice(&5u32.to_le_bytes());
        buf.extend_from_slice(b"la_wq");
        buf.extend_from_slice(&2u32.to_le_bytes());
        buf.extend_from_slice(&2u64.to_le_bytes());
        buf.extend_from_slice(&3u64.to_le_bytes());
        for i in 0..6 {
            buf.extend_from_slice(&(i as f32).to_le_bytes());
        }
        buf.extend_from_slice(&0u32.to_le_bytes()); // no i32 tensors
        let dir = std::env::temp_dir().join("losia_adapter_legacy");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("old.adapter");
        std::fs::write(&path, buf).unwrap();
        let cap = crate::util::warn::capture();
        let rec = AdapterRecord::load(&path, &cfg).unwrap();
        let warns = cap.drain();
        let _ = std::fs::remove_dir_all(&dir);
        assert!(
            warns.iter().any(|w| w.contains("pre-durability")),
            "expected a legacy-format warning, got {warns:?}"
        );
        let AdapterRecord::Delta(d) = rec else {
            panic!("loaded as full state");
        };
        assert_eq!(d.mode, MODE_LORA);
        assert_eq!(d.f32s[0].0, "la_wq");
        assert_eq!(d.f32s[0].1.shape, vec![2, 3]);
        assert_eq!(d.f32s[0].1.data, vec![0., 1., 2., 3., 4., 5.]);
    }

    #[test]
    fn full_checkpoint_loads_through_the_same_entry_point() {
        let cfg = tiny();
        let mut rng = Rng::new(5);
        let state = ModelState::init(&cfg, &mut rng);
        let dir = std::env::temp_dir().join("losia_adapter_full");
        let path = dir.join("full.bin");
        state.save(&path).unwrap();
        let rec = AdapterRecord::load(&path, &cfg).unwrap();
        assert!(matches!(rec, AdapterRecord::Full(_)));
        // a full record materializes as the plain binding
        let b = AdapterBinding::from_record(&cfg, &rec).unwrap();
        assert_eq!(b.mode(), MODE_PLAIN);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn mismatched_delta_shape_is_a_typed_error() {
        let cfg = tiny();
        let delta = AdapterDelta {
            mode: MODE_LORA,
            f32s: vec![(
                "la_wq".into(),
                Tensor::zeros(&[1, 2, 3]),
            )],
            i32s: vec![],
        };
        let err = AdapterBinding::from_record(
            &cfg,
            &AdapterRecord::Delta(delta),
        )
        .unwrap_err();
        assert!(err.to_string().contains("la_wq"), "{err}");
    }
}
