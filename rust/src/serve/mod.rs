//! Multi-tenant adapter serving over the KV-cached decode path.
//!
//! The production shape LoSiA's tiny deltas enable: one frozen
//! backbone resident on the device, many per-tenant adapters swapped
//! between requests, and incremental decoding so a token costs
//! O(prefix) attention + O(1) linears instead of a full-grid forward.
//! Four pieces:
//!
//! * [`decode::Decoder`] — a `fwd_decode` [`crate::runtime::ExecPlan`]
//!   with the backbone static and the KV cache plan-resident.
//! * [`adapter`] — adapter records (full checkpoint / LoSiA subnet /
//!   LoRA factors), their compact on-disk format, and the dense
//!   per-step [`adapter::AdapterBinding`] that makes hot-swaps free of
//!   static uploads.
//! * [`registry::AdapterRegistry`] — named tenants, activation, and
//!   the backbone-upload ledger.
//! * [`scheduler::Scheduler`] — request-level batching into the
//!   artifact batch dimension, with per-request EOS/`max_new`
//!   tracking and captured warnings.
//!
//! [`load`] drives it all under deterministic synthetic load for the
//! `losia serve` CLI and the `serve_load` bench; decode-vs-full-rerun
//! bitwise parity and the zero-static-upload swap invariant are pinned
//! by `tests/serve_parity.rs`.

pub mod adapter;
pub mod decode;
pub mod load;
pub mod registry;
pub mod scheduler;

pub use adapter::{
    AdapterBinding, AdapterDelta, AdapterRecord, MODE_LORA,
    MODE_LOSIA, MODE_PLAIN,
};
pub use decode::Decoder;
pub use load::{
    run_load, serve_runtime, synthetic_lora_record,
    synthetic_losia_record, LoadReport, LoadSpec,
};
pub use registry::AdapterRegistry;
pub use scheduler::{
    serve_metrics, GenResult, Scheduler, ServeMetrics,
};
