//! The serving decoder: a thin, stateful wrapper around a
//! `fwd_decode` [`ExecPlan`].
//!
//! Binding contract (who uploads/downloads what, per step):
//!
//! * **static** — the 12 backbone parameters, uploaded once at
//!   construction (or on an explicit [`Decoder::rebind_backbone`]).
//! * **per-step** — the full [`AdapterBinding`] (every adapter tensor
//!   plus `adapter_mode`) and the `tokens`/`lens`/`reset` control
//!   grid. Adapters riding per-step is what makes tenant hot-swaps
//!   free of static traffic.
//! * **download** — exactly one `[B, V]` logits tensor per step: the
//!   distribution at each row's last appended position. The KV cache
//!   itself never crosses the device boundary; it lives inside the
//!   plan's buffers (`ExecPlan::clear_state` drops it).

use std::sync::Arc;

use anyhow::Result;

use crate::config::ModelCfg;
use crate::coordinator::state::ModelState;
use crate::runtime::{ExecPlan, ExecSnapshot, Executable, Runtime};
use crate::serve::adapter::AdapterBinding;
use crate::tensor::Tensor;

/// One decode plan over one backbone. Holds the plan (and with it the
/// device-resident KV cache) for its lifetime.
pub struct Decoder<'rt> {
    rt: &'rt Runtime,
    exe: Arc<Executable>,
    plan: ExecPlan,
}

impl<'rt> Decoder<'rt> {
    /// Load `fwd_decode`, declare the backbone static, and upload it.
    pub fn new(rt: &'rt Runtime, state: &ModelState) -> Result<Self> {
        let exe = rt.load("fwd_decode")?;
        let param_names: Vec<&str> = rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut plan = ExecPlan::new(Arc::clone(&exe), &param_names)?;
        plan.bind_params(state)?;
        Ok(Decoder { rt, exe, plan })
    }

    pub fn cfg(&self) -> &ModelCfg {
        &self.rt.cfg
    }

    /// Replace the frozen backbone (a full-state tenant, or a new
    /// checkpoint). This is the only path that generates static
    /// uploads after construction.
    pub fn rebind_backbone(&mut self, state: &ModelState) -> Result<()> {
        self.plan.bind_params(state)
    }

    /// Drop the KV cache (every row): the next step must `reset` the
    /// rows it uses anyway, but clearing releases the backend state
    /// eagerly between decoding passes.
    pub fn clear_cache(&mut self) {
        self.plan.clear_state();
    }

    /// Cumulative executor counters for the decode artifact — the
    /// serve tests read `static_uploads` deltas off this to pin the
    /// zero-backbone-upload invariant.
    pub fn stats(&self) -> ExecSnapshot {
        self.exe.stats()
    }

    /// Bytes the static backbone occupies device-side right now:
    /// 4 B/element dense, codes + per-block scales when the
    /// quantization policy (`LOSIA_QUANT=int8`) stored it as int8.
    pub fn backbone_resident_bytes(&self) -> usize {
        self.plan.static_resident_bytes()
    }

    /// One incremental step: bind the adapter + control grid, run,
    /// download the `[B, V]` logits. `tokens` is the `[B, S]` grid
    /// with each row's new tokens packed at the row head; `lens[i]`
    /// counts them (0 = row idle); `reset[i] != 0` clears row `i`'s
    /// cache before appending.
    pub fn step(
        &mut self,
        adapter: &AdapterBinding,
        tokens: &[i32],
        lens: &[i32],
        reset: &[i32],
    ) -> Result<Tensor> {
        let (b, s) = (self.rt.cfg.batch, self.rt.cfg.seq_len);
        anyhow::ensure!(
            tokens.len() == b * s
                && lens.len() == b
                && reset.len() == b,
            "decode step: tokens/lens/reset are {}/{}/{}, artifact \
             wants {}/{b}/{b}",
            tokens.len(),
            lens.len(),
            reset.len(),
            b * s
        );
        adapter.bind(&mut self.plan)?;
        self.plan.bind_i32("tokens", &[b, s], tokens)?;
        self.plan.bind_i32("lens", &[b], lens)?;
        self.plan.bind_i32("reset", &[b], reset)?;
        self.plan
            .run()?
            .into_iter()
            .next()
            .ok_or_else(|| {
                anyhow::anyhow!("fwd_decode emitted no outputs")
            })?
            .into_host()
    }
}
