//! Request-level batching: pack concurrent tenant generation requests
//! into the artifact batch dimension and drive the shared decoder.
//!
//! Each **tick** serves one tenant (adapters bind per step, so a step
//! carries exactly one tenant's binding): every occupied batch row
//! belonging to that tenant advances one token — a fresh row prefills
//! its whole prompt in the same call (`lens = prompt_len`, `reset =
//! 1`), everyone else decodes one token (`lens = 1`), idle rows cost
//! nothing (`lens = 0`). Tenant choice is deterministic — the tenant
//! of the lowest-id active request — so a seeded run is replayable.
//! Rows complete independently on EOS / `max_new` / sequence capacity
//! and free their slot for the next queued request.
//!
//! Eval-style warnings raised while the scheduler runs (oversized
//! prompts, malformed requests) are captured through
//! [`crate::util::warn`] instead of leaking to stderr, and surface via
//! [`Scheduler::warnings`].
//!
//! Failures degrade per tenant, never per batch: an adapter that
//! fails to activate (or an armed `adapter-activate` fault — see
//! [`crate::util::faultpoint`]) rejects that tenant's in-flight and
//! queued requests with a typed [`GenResult::error`], and every other
//! tenant keeps decoding.

use std::collections::VecDeque;
use std::time::Instant;

use anyhow::Result;

use crate::coordinator::state::ModelState;
use crate::data::vocab::{BOS, EOS, PAD};
use crate::runtime::{ExecSnapshot, Runtime};
use crate::serve::adapter::AdapterRecord;
use crate::serve::decode::Decoder;
use crate::serve::registry::AdapterRegistry;
use crate::tensor::select::{argmax, sample_multinomial, softmax};
use crate::util::rng::Rng;
use crate::util::warn;

struct GenRequest {
    id: usize,
    tenant: String,
    prompt: Vec<u32>,
    max_new: usize,
}

/// One completed request.
#[derive(Debug, Clone)]
pub struct GenResult {
    pub id: usize,
    pub tenant: String,
    pub output: Vec<u32>,
    /// wall time of the prefill step that admitted this request
    pub prefill_ns: u64,
    /// wall time of the decode step that produced each output token
    pub token_latencies_ns: Vec<u64>,
    /// `Some` when the request was rejected (its tenant's adapter
    /// failed to activate) — `output` holds whatever tokens were
    /// produced before the failure
    pub error: Option<String>,
}

struct RowState {
    id: usize,
    tenant: String,
    seq: Vec<u32>,
    out: Vec<u32>,
    max_new: usize,
    fresh: bool,
    prefill_ns: u64,
    latencies: Vec<u64>,
}

/// The serving loop: queue + batch rows + decoder + registry.
pub struct Scheduler<'rt> {
    dec: Decoder<'rt>,
    registry: AdapterRegistry,
    queue: VecDeque<GenRequest>,
    rows: Vec<Option<RowState>>,
    results: Vec<GenResult>,
    warnings: Vec<String>,
    temperature: f32,
    rng: Rng,
    next_id: usize,
    ticks: u64,
    /// activation attempts, successful or not — the step key of the
    /// `adapter-activate` fault site (`ticks` would repeat after a
    /// rejected activation, re-firing a step-pinned fault on the
    /// next tenant)
    activations: usize,
}

impl<'rt> Scheduler<'rt> {
    /// Build the decoder over `base` (the frozen backbone) and an
    /// empty registry. `temperature <= 0` decodes greedily.
    pub fn new(
        rt: &'rt Runtime,
        base: &ModelState,
        temperature: f32,
        seed: u64,
    ) -> Result<Self> {
        let dec = Decoder::new(rt, base)?;
        let rows = (0..rt.cfg.batch).map(|_| None).collect();
        Ok(Scheduler {
            dec,
            registry: AdapterRegistry::new(base.clone()),
            queue: VecDeque::new(),
            rows,
            results: Vec::new(),
            warnings: Vec::new(),
            temperature,
            rng: Rng::new(seed),
            next_id: 0,
            ticks: 0,
            activations: 0,
        })
    }

    /// Register a tenant adapter under `name`.
    pub fn register(
        &mut self,
        name: &str,
        record: AdapterRecord,
    ) -> Result<()> {
        self.registry.register(name, record, self.dec.cfg())
    }

    /// Enqueue a generation request; returns its id. The tenant must
    /// already be registered.
    pub fn submit(
        &mut self,
        tenant: &str,
        prompt: &[u32],
        max_new: usize,
    ) -> Result<usize> {
        anyhow::ensure!(
            self.registry.has(tenant),
            "submit for unregistered tenant {tenant:?} (registered: \
             {:?})",
            self.registry.tenant_names()
        );
        let id = self.next_id;
        self.next_id += 1;
        self.queue.push_back(GenRequest {
            id,
            tenant: tenant.to_string(),
            prompt: prompt.to_vec(),
            max_new,
        });
        Ok(id)
    }

    /// Drain the queue to completion, returning results ordered by
    /// request id. Warnings raised along the way are captured (see
    /// [`Scheduler::warnings`]).
    pub fn run(&mut self) -> Result<Vec<GenResult>> {
        let cap = warn::capture();
        let r: Result<()> = (|| {
            while self.tick()? {}
            Ok(())
        })();
        self.warnings.extend(cap.drain());
        drop(cap);
        r?;
        let mut results = std::mem::take(&mut self.results);
        results.sort_by_key(|g| g.id);
        Ok(results)
    }

    /// One scheduling step. Returns `false` once queue and rows are
    /// both empty.
    fn tick(&mut self) -> Result<bool> {
        let b = self.dec.cfg().batch;
        let s = self.dec.cfg().seq_len;
        let v = self.dec.cfg().vocab;

        // admit queued requests into free rows
        for i in 0..b {
            if self.rows[i].is_some() {
                continue;
            }
            while let Some(req) = self.queue.pop_front() {
                let mut seq = vec![BOS];
                seq.extend_from_slice(&req.prompt);
                if seq.len() >= s || req.max_new == 0 {
                    if seq.len() >= s {
                        warn::warn(format!(
                            "[serve] request {}: prompt of {} tokens \
                             leaves no room to generate within \
                             seq_len {s}; returning empty output",
                            req.id,
                            req.prompt.len()
                        ));
                    }
                    self.results.push(GenResult {
                        id: req.id,
                        tenant: req.tenant,
                        output: Vec::new(),
                        prefill_ns: 0,
                        token_latencies_ns: Vec::new(),
                        error: None,
                    });
                    continue;
                }
                self.rows[i] = Some(RowState {
                    id: req.id,
                    tenant: req.tenant,
                    seq,
                    out: Vec::new(),
                    max_new: req.max_new,
                    fresh: true,
                    prefill_ns: 0,
                    latencies: Vec::new(),
                });
                break;
            }
        }

        // deterministic tenant pick: the lowest-id active request
        let Some(tenant) = self
            .rows
            .iter()
            .flatten()
            .min_by_key(|r| r.id)
            .map(|r| r.tenant.clone())
        else {
            return Ok(false);
        };

        // pack this tenant's rows into the control grid
        let mut tokens = vec![PAD as i32; b * s];
        let mut lens = vec![0i32; b];
        let mut reset = vec![0i32; b];
        let mut served = Vec::new();
        for i in 0..b {
            let Some(row) = &self.rows[i] else { continue };
            if row.tenant != tenant {
                continue;
            }
            if row.fresh {
                for (t, &tok) in row.seq.iter().enumerate() {
                    tokens[i * s + t] = tok as i32;
                }
                lens[i] = row.seq.len() as i32;
                reset[i] = 1;
            } else {
                tokens[i * s] = *row.seq.last().unwrap() as i32;
                lens[i] = 1;
            }
            served.push(i);
        }

        // Per-tenant containment: an adapter that fails to activate
        // (including an armed `adapter-activate` fault — keyed by the
        // activation-attempt counter) rejects only that tenant's
        // requests with a typed per-request error; every other tenant
        // keeps decoding. Probing first with a unit result keeps the
        // binding borrow out of the rejection path.
        let attempt = self.activations;
        self.activations += 1;
        let probe = crate::util::faultpoint::hit(
            "adapter-activate",
            attempt,
        )
        .and_then(|()| {
            self.registry.activate(&tenant, &mut self.dec)?;
            Ok(())
        });
        if let Err(e) = probe {
            self.reject_tenant(&tenant, &e);
            return Ok(true);
        }
        // re-activation of the already-active tenant is a no-op swap
        let binding =
            self.registry.activate(&tenant, &mut self.dec)?;
        let t0 = Instant::now();
        let logits = self.dec.step(binding, &tokens, &lens, &reset)?;
        let elapsed = t0.elapsed().as_nanos() as u64;
        self.ticks += 1;

        for i in served {
            let row = self.rows[i].as_mut().unwrap();
            let lrow = &logits.data[i * v..(i + 1) * v];
            let next = if self.temperature <= 0.0 {
                argmax(lrow) as u32
            } else {
                let scaled: Vec<f32> = lrow
                    .iter()
                    .map(|x| x / self.temperature)
                    .collect();
                sample_multinomial(
                    &softmax(&scaled),
                    self.rng.uniform(),
                ) as u32
            };
            if row.fresh {
                row.prefill_ns = elapsed;
                row.fresh = false;
            }
            let mut finished = next == EOS;
            if next != EOS {
                row.out.push(next);
                row.seq.push(next);
                row.latencies.push(elapsed);
                if row.out.len() >= row.max_new
                    || row.seq.len() >= s
                {
                    finished = true;
                }
            }
            if finished {
                let row = self.rows[i].take().unwrap();
                self.results.push(GenResult {
                    id: row.id,
                    tenant: row.tenant,
                    output: row.out,
                    prefill_ns: row.prefill_ns,
                    token_latencies_ns: row.latencies,
                    error: None,
                });
            }
        }
        Ok(true)
    }

    /// Degrade one tenant after its adapter failed to activate: every
    /// in-flight row and queued request of that tenant completes with
    /// a typed error (partial output preserved), its batch slots
    /// free, and the scheduler moves on to the remaining tenants.
    fn reject_tenant(&mut self, tenant: &str, err: &anyhow::Error) {
        let msg = format!("adapter activation failed: {err:#}");
        warn::warn(format!(
            "[serve] tenant {tenant:?}: {msg}; rejecting its \
             in-flight and queued requests"
        ));
        for slot in &mut self.rows {
            if slot.as_ref().is_some_and(|r| r.tenant == tenant) {
                let row = slot.take().unwrap();
                self.results.push(GenResult {
                    id: row.id,
                    tenant: row.tenant,
                    output: row.out,
                    prefill_ns: row.prefill_ns,
                    token_latencies_ns: row.latencies,
                    error: Some(msg.clone()),
                });
            }
        }
        let queued = std::mem::take(&mut self.queue);
        for req in queued {
            if req.tenant == tenant {
                self.results.push(GenResult {
                    id: req.id,
                    tenant: req.tenant,
                    output: Vec::new(),
                    prefill_ns: 0,
                    token_latencies_ns: Vec::new(),
                    error: Some(msg.clone()),
                });
            } else {
                self.queue.push_back(req);
            }
        }
    }

    /// Warnings captured across `run()` calls so far.
    pub fn warnings(&self) -> &[String] {
        &self.warnings
    }

    /// Decode steps executed.
    pub fn ticks(&self) -> u64 {
        self.ticks
    }

    /// Tenant switches performed by the registry.
    pub fn swaps(&self) -> u64 {
        self.registry.swaps()
    }

    /// Backbone re-uploads caused by tenant activations (0 for
    /// delta-only serving).
    pub fn backbone_uploads(&self) -> u64 {
        self.registry.backbone_uploads()
    }

    /// Executor counters of the decode artifact.
    pub fn decoder_stats(&self) -> ExecSnapshot {
        self.dec.stats()
    }

    /// Device-resident bytes of the static backbone (see
    /// [`Decoder::backbone_resident_bytes`]).
    pub fn backbone_resident_bytes(&self) -> usize {
        self.dec.backbone_resident_bytes()
    }
}

/// Aggregate serving metrics over a finished run.
#[derive(Debug, Clone)]
pub struct ServeMetrics {
    pub requests: usize,
    /// requests that ended with a per-request error (tenant adapter
    /// failed to activate) instead of completing
    pub rejected: usize,
    pub tokens: usize,
    pub ticks: u64,
    pub swaps: u64,
    pub backbone_uploads: u64,
    pub wall_ns: u64,
    pub throughput_tok_per_s: f64,
    pub p50_ns: u64,
    pub p90_ns: u64,
    pub p99_ns: u64,
    /// mean decode latency per output-token index — flat (not growing
    /// with the index) is the KV-cache win the bench pins
    pub mean_latency_by_index_ns: Vec<u64>,
}

/// Nearest-rank percentile over a sorted sample.
fn percentile(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let idx = ((p / 100.0) * (sorted.len() - 1) as f64).round()
        as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Fold per-request results into [`ServeMetrics`]. `wall_ns` is the
/// caller-measured wall time of the whole run.
pub fn serve_metrics(
    results: &[GenResult],
    wall_ns: u64,
    swaps: u64,
    backbone_uploads: u64,
    ticks: u64,
) -> ServeMetrics {
    let tokens: usize =
        results.iter().map(|r| r.output.len()).sum();
    let mut lat: Vec<u64> = results
        .iter()
        .flat_map(|r| r.token_latencies_ns.iter().copied())
        .collect();
    lat.sort_unstable();
    let max_len = results
        .iter()
        .map(|r| r.token_latencies_ns.len())
        .max()
        .unwrap_or(0);
    let mut mean_by_index = Vec::with_capacity(max_len);
    for j in 0..max_len {
        let (mut sum, mut n) = (0u64, 0u64);
        for r in results {
            if let Some(&x) = r.token_latencies_ns.get(j) {
                sum += x;
                n += 1;
            }
        }
        mean_by_index.push(if n == 0 { 0 } else { sum / n });
    }
    let secs = wall_ns as f64 / 1e9;
    ServeMetrics {
        requests: results.len(),
        rejected: results
            .iter()
            .filter(|r| r.error.is_some())
            .count(),
        tokens,
        ticks,
        swaps,
        backbone_uploads,
        wall_ns,
        throughput_tok_per_s: if secs > 0.0 {
            tokens as f64 / secs
        } else {
            0.0
        },
        p50_ns: percentile(&lat, 50.0),
        p90_ns: percentile(&lat, 90.0),
        p99_ns: percentile(&lat, 99.0),
        mean_latency_by_index_ns: mean_by_index,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::load::{
        serve_runtime, synthetic_lora_record, synthetic_losia_record,
    };
    use crate::util::faultpoint;

    /// An armed `adapter-activate` fault rejects exactly the tenant
    /// whose activation failed — typed per-request errors, freed batch
    /// slots — while the other tenant's requests complete normally.
    #[test]
    fn failed_activation_degrades_only_that_tenant() {
        let _guard = match faultpoint::ENV_LOCK.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let rt = serve_runtime("tiny").unwrap();
        let mut rng = Rng::new(5);
        let base = ModelState::init(&rt.cfg, &mut rng);
        let mut sched = Scheduler::new(&rt, &base, 0.0, 9).unwrap();
        sched
            .register("alpha", synthetic_losia_record(&rt.cfg, &mut rng))
            .unwrap();
        sched
            .register("beta", synthetic_lora_record(&rt.cfg, &mut rng))
            .unwrap();
        let a = sched.submit("alpha", &[6, 7, 8], 4).unwrap();
        let b = sched.submit("beta", &[9, 10, 11], 4).unwrap();
        // alpha holds the lowest request id, so activation attempt 0
        // is alpha's — arm the fault exactly there
        std::env::set_var(faultpoint::ENV, "adapter-activate@0:error");
        let run = sched.run();
        std::env::remove_var(faultpoint::ENV);
        let results = run.unwrap();
        assert_eq!(results.len(), 2);
        let ra = results.iter().find(|r| r.id == a).unwrap();
        let rb = results.iter().find(|r| r.id == b).unwrap();
        let msg = ra.error.as_deref().expect("alpha rejected");
        assert!(
            msg.contains("adapter activation failed"),
            "typed rejection message: {msg}"
        );
        assert!(ra.output.is_empty());
        assert!(rb.error.is_none(), "beta unaffected: {:?}", rb.error);
        assert!(!rb.output.is_empty(), "beta decoded to completion");
        let m = serve_metrics(&results, 1, sched.swaps(), 0, sched.ticks());
        assert_eq!(m.rejected, 1);
        assert_eq!(m.requests, 2);
        assert!(
            sched.warnings().iter().any(|w| w.contains("rejecting")),
            "degradation is surfaced as a warning: {:?}",
            sched.warnings()
        );
    }
}
