//! Deterministic synthetic multi-tenant load: the shared harness
//! behind `losia serve` and `benches/serve_load.rs`.
//!
//! Tenants alternate between synthetic LoSiA subnet adapters and LoRA
//! factor pairs (both seeded), requests round-robin across tenants
//! with slightly varying prompt lengths, and decoding is greedy — so
//! a `(config, spec)` pair replays bit-identically and bench numbers
//! are comparable PR-over-PR.

use std::time::Instant;

use anyhow::Result;

use crate::config::{builtin_config, ModelCfg};
use crate::coordinator::state::ModelState;
use crate::runtime::{artifacts_dir, RefBackend, Runtime};
use crate::serve::adapter::{
    AdapterDelta, AdapterRecord, MODE_LORA, MODE_LOSIA,
};
use crate::serve::scheduler::{
    serve_metrics, GenResult, Scheduler, ServeMetrics,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Shape of one synthetic load run.
#[derive(Debug, Clone)]
pub struct LoadSpec {
    pub tenants: usize,
    pub requests: usize,
    pub prompt_len: usize,
    pub max_new: usize,
    pub seed: u64,
}

impl Default for LoadSpec {
    fn default() -> Self {
        LoadSpec {
            tenants: 4,
            requests: 16,
            prompt_len: 8,
            max_new: 16,
            seed: 7,
        }
    }
}

/// Everything a load run produces.
pub struct LoadReport {
    pub metrics: ServeMetrics,
    pub results: Vec<GenResult>,
    pub warnings: Vec<String>,
    /// Static backbone footprint on the device while serving: dense
    /// f32 bytes, or codes + scales under `LOSIA_QUANT=int8`.
    pub backbone_resident_bytes: usize,
}

/// Runtime for serving: the decode artifact is interpreted, so this
/// is always the builtin config over the reference backend (a lowered
/// manifest does not carry `fwd_decode`).
pub fn serve_runtime(config: &str) -> Result<Runtime> {
    let cfg = builtin_config(config, &artifacts_dir())?;
    Ok(Runtime::with_backend(cfg, Box::new(RefBackend)))
}

/// A seeded LoSiA adapter: random `dws` frames over a random (but
/// distinct-index) subnet selection — structurally exactly what a
/// trained LoSiA checkpoint ships.
pub fn synthetic_losia_record(
    cfg: &ModelCfg,
    rng: &mut Rng,
) -> AdapterRecord {
    let l = cfg.n_layers;
    let mut f32s = Vec::new();
    let mut i32s = Vec::new();
    for kind in &cfg.linear_kinds {
        let kd = cfg.kind(kind);
        f32s.push((
            format!("dws_{kind}"),
            Tensor::randn(&[l, kd.np, kd.mp], 0.05, rng),
        ));
        let mut rho = Vec::with_capacity(l * kd.np);
        let mut gamma = Vec::with_capacity(l * kd.mp);
        for _ in 0..l {
            rho.extend(
                rng.choose_distinct(kd.n, kd.np)
                    .into_iter()
                    .map(|i| i as i32),
            );
            gamma.extend(
                rng.choose_distinct(kd.m, kd.mp)
                    .into_iter()
                    .map(|i| i as i32),
            );
        }
        i32s.push((format!("rho_{kind}"), vec![l, kd.np], rho));
        i32s.push((format!("gamma_{kind}"), vec![l, kd.mp], gamma));
    }
    f32s.push((
        "dws_out".into(),
        Tensor::randn(&[cfg.d_model, cfg.vocab_sub], 0.05, rng),
    ));
    i32s.push((
        "gamma_out".into(),
        vec![cfg.vocab_sub],
        rng.choose_distinct(cfg.vocab, cfg.vocab_sub)
            .into_iter()
            .map(|i| i as i32)
            .collect(),
    ));
    AdapterRecord::Delta(AdapterDelta {
        mode: MODE_LOSIA,
        f32s,
        i32s,
    })
}

/// A seeded LoRA adapter: random A/B factor pairs per linear kind.
pub fn synthetic_lora_record(
    cfg: &ModelCfg,
    rng: &mut Rng,
) -> AdapterRecord {
    let (l, r) = (cfg.n_layers, cfg.lora_rank);
    let mut f32s = Vec::new();
    for kind in &cfg.linear_kinds {
        let kd = cfg.kind(kind);
        f32s.push((
            format!("la_{kind}"),
            Tensor::randn(&[l, kd.n, r], 0.05, rng),
        ));
        f32s.push((
            format!("lb_{kind}"),
            Tensor::randn(&[l, r, kd.m], 0.05, rng),
        ));
    }
    AdapterRecord::Delta(AdapterDelta {
        mode: MODE_LORA,
        f32s,
        i32s: Vec::new(),
    })
}

/// Run the synthetic load to completion and fold the metrics.
pub fn run_load(rt: &Runtime, spec: &LoadSpec) -> Result<LoadReport> {
    anyhow::ensure!(
        spec.tenants > 0 && spec.requests > 0,
        "load spec needs at least one tenant and one request"
    );
    let mut rng = Rng::new(spec.seed);
    let base = ModelState::init(&rt.cfg, &mut rng);
    let mut sched =
        Scheduler::new(rt, &base, 0.0, spec.seed ^ 0x5eed)?;
    for t in 0..spec.tenants {
        let record = if t % 2 == 0 {
            synthetic_losia_record(&rt.cfg, &mut rng)
        } else {
            synthetic_lora_record(&rt.cfg, &mut rng)
        };
        sched.register(&format!("tenant{t}"), record)?;
    }
    // content-token range of the synthetic vocab (past the control
    // tokens), clamped to the config's vocabulary
    let lo = 5usize.min(rt.cfg.vocab.saturating_sub(1));
    let hi = rt.cfg.vocab.min(53).max(lo + 1);
    for req in 0..spec.requests {
        let tenant = format!("tenant{}", req % spec.tenants);
        // vary prompt lengths so prefills are ragged, like real load
        let len = (spec.prompt_len.max(1) + req % 3)
            .min(rt.cfg.seq_len.saturating_sub(2));
        let prompt: Vec<u32> = (0..len)
            .map(|_| rng.range(lo, hi) as u32)
            .collect();
        sched.submit(&tenant, &prompt, spec.max_new)?;
    }
    let t0 = Instant::now();
    let results = sched.run()?;
    let wall = t0.elapsed().as_nanos() as u64;
    let metrics = serve_metrics(
        &results,
        wall,
        sched.swaps(),
        sched.backbone_uploads(),
        sched.ticks(),
    );
    Ok(LoadReport {
        metrics,
        results,
        warnings: sched.warnings().to_vec(),
        backbone_resident_bytes: sched.backbone_resident_bytes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_load_completes_every_request() {
        let rt = serve_runtime("tiny").unwrap();
        let spec = LoadSpec {
            tenants: 3,
            requests: 7,
            prompt_len: 4,
            max_new: 5,
            seed: 11,
        };
        let rep = run_load(&rt, &spec).unwrap();
        assert_eq!(rep.metrics.requests, 7);
        assert_eq!(rep.results.len(), 7);
        // greedy + seeded → replay is identical
        let rep2 = run_load(&rt, &spec).unwrap();
        for (a, b) in rep.results.iter().zip(&rep2.results) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.output, b.output);
        }
        // delta-only tenants: the backbone never re-uploads
        assert_eq!(rep.metrics.backbone_uploads, 0);
        assert!(rep.metrics.swaps >= 2, "multi-tenant load swaps");
    }

    #[test]
    fn oversized_prompt_warns_and_returns_empty() {
        let rt = serve_runtime("tiny").unwrap();
        let mut rng = Rng::new(3);
        let base = ModelState::init(&rt.cfg, &mut rng);
        let mut sched = Scheduler::new(&rt, &base, 0.0, 1).unwrap();
        sched
            .register(
                "t0",
                synthetic_lora_record(&rt.cfg, &mut rng),
            )
            .unwrap();
        let long = vec![6u32; rt.cfg.seq_len + 3];
        let id = sched.submit("t0", &long, 4).unwrap();
        let ok = sched.submit("t0", &[6, 7, 8], 4).unwrap();
        let results = sched.run().unwrap();
        assert_eq!(results.len(), 2);
        assert_eq!(results[0].id, id);
        assert!(results[0].output.is_empty());
        assert!(!results[1].output.is_empty() || ok == results[1].id);
        let warns = sched.warnings();
        assert!(
            warns.iter().any(|w| w.contains("no room to generate")),
            "warning captured, not lost to stderr: {warns:?}"
        );
    }
}
