//! # LoSiA — Low-Resources Subnet Integration Adaptation
//!
//! Rust reproduction of *LoSiA: Efficient High-Rank Fine-Tuning via
//! Subnet Localization and Optimization* (EMNLP 2025) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! This crate is **Layer 3**: the training coordinator. It owns
//!
//! * sensitivity-importance accumulation (paper Eqs. 3–6),
//! * greedy core-subnet localization (Algorithm 1),
//! * the asynchronous periodic re-localization scheduler (§3.3),
//! * learning-rate rewarming (Eq. 8),
//! * the subnet Adam optimizer (Algorithm 2),
//! * every baseline (FFT, LoRA, PiSSA, DoRA, GaLore),
//! * and all substrates: tensor math + SVD, synthetic workloads,
//!   evaluation harness, metrics, config/CLI.
//!
//! Compute (model forward/backward, the LoSiA-Pro factorized subnet
//! gradient) happens inside AOT-compiled XLA artifacts produced once by
//! `python/compile/aot.py` and executed via PJRT ([`runtime`]).
//! Python is never on the training path.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod methods;
pub mod metrics;
pub mod runtime;
pub mod tensor;
pub mod util;
