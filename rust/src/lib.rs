//! # LoSiA — Low-Resources Subnet Integration Adaptation
//!
//! Rust reproduction of *LoSiA: Efficient High-Rank Fine-Tuning via
//! Subnet Localization and Optimization* (EMNLP 2025) as a three-layer
//! Rust + JAX + Pallas stack.
//!
//! ## The session layer
//!
//! Every run — CLI, bench, example, or test — goes through
//! [`session`], the crate's public surface:
//!
//! ```no_run
//! use losia::config::Method;
//! use losia::session::Session;
//!
//! let mut session = Session::builder()
//!     .config("tiny")
//!     .method(Method::LosiaPro)
//!     .task("modmath")
//!     .steps(200)
//!     .build()?;
//! let report = session.train()?; // serializable RunReport
//! # anyhow::Ok(())
//! ```
//!
//! * [`session::SessionBuilder`] owns runtime loading, task
//!   construction (via [`session::TaskRegistry`]), seeding, and driver
//!   assembly, returning `anyhow` errors instead of panics.
//! * Telemetry flows through the [`session::Observer`] event stream
//!   (`on_step`, `on_relocalize`, `on_task_boundary`, `on_finalize`);
//!   stock observers cover loss curves, µs/token latency, analytic
//!   memory, and subnet-selection tracking.
//! * Every run emits a [`session::RunReport`] that round-trips through
//!   JSON; multi-task continual learning (paper §4.4) is
//!   [`session::Session::train_sequence`] over
//!   [`session::TaskSpec`]s.
//!
//! ## The coordinator underneath
//!
//! This crate is **Layer 3**: the training coordinator. It owns
//!
//! * sensitivity-importance accumulation (paper Eqs. 3–6),
//! * greedy core-subnet localization (Algorithm 1),
//! * the asynchronous periodic re-localization scheduler (§3.3),
//! * learning-rate rewarming (Eq. 8),
//! * the subnet Adam optimizer (Algorithm 2),
//! * every baseline (FFT, LoRA, PiSSA, DoRA, GaLore),
//! * and all substrates: tensor math + SVD, synthetic workloads,
//!   evaluation harness, metrics, config/CLI.
//!
//! Compute (model forward/backward, the LoSiA-Pro factorized subnet
//! gradient) happens inside AOT-compiled XLA artifacts produced once by
//! `python/compile/aot.py` and executed via PJRT ([`runtime`]).
//! Python is never on the training path.

pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod methods;
pub mod metrics;
pub mod runtime;
pub mod serve;
pub mod session;
pub mod tensor;
pub mod util;

pub use session::{Session, SessionBuilder};
