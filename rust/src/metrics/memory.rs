//! Analytic memory model — a direct implementation of paper Table 14.
//!
//! All quantities in bytes for a model with `L` decoder layers, `K`
//! tunable matrices per layer, hidden dim `d`, FFN dim treated via the
//! per-matrix accounting below, vocab `V`, and `b`-byte precision.
//! The paper's table assumes square d×d matrices; we generalise to the
//! actual (n, m) per matrix kind so our configs and LLaMA-2 7B both
//! evaluate exactly.

use crate::config::{Method, ModelCfg, TrainConfig};

/// Byte counts for one method (paper Table 14 rows).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryBreakdown {
    pub trainable: f64,
    pub optimizer: f64,
    pub gradient: f64,
    pub auxiliary: f64,
}

impl MemoryBreakdown {
    pub fn total(&self) -> f64 {
        self.trainable + self.optimizer + self.gradient + self.auxiliary
    }
}

/// Matrix-kind inventory: (n, m) per tunable linear, repeated L times.
fn kind_dims(cfg: &ModelCfg) -> Vec<(usize, usize)> {
    cfg.linear_kinds
        .iter()
        .map(|k| {
            let kd = cfg.kind(k);
            (kd.n, kd.m)
        })
        .collect()
}

/// LoRA (rank r): #Trainable 2LKrd·b, #Optimizer 4LKrd·b,
/// #Gradient 2LKrd·b, #Auxiliary 2LKrd·b (upcast copies) → 8LKrd·b.
pub fn lora(cfg: &ModelCfg, r: usize, b: f64) -> MemoryBreakdown {
    let adapters: f64 = kind_dims(cfg)
        .iter()
        .map(|&(n, m)| (n * r + r * m) as f64)
        .sum::<f64>()
        * cfg.n_layers as f64;
    MemoryBreakdown {
        trainable: adapters * b,
        optimizer: 2.0 * adapters * b,
        gradient: adapters * b,
        auxiliary: adapters * b,
    }
}

/// GaLore (rank R, full output layer):
/// #Trainable LKR²b + Vdb, #Optimizer 2(LKR²b + Vdb),
/// #Gradient max{d²b, Vdb} (per-layer updates), #Auxiliary 2LKRdb.
pub fn galore(cfg: &ModelCfg, rr: usize, b: f64) -> MemoryBreakdown {
    let l = cfg.n_layers as f64;
    let d = cfg.d_model as f64;
    let v = cfg.vocab as f64;
    let dims = kind_dims(cfg);
    let proj_coords: f64 = dims
        .iter()
        .map(|&(n, m)| (rr.min(n) * m) as f64)
        .sum::<f64>()
        * l;
    let projectors: f64 = dims
        .iter()
        .map(|&(n, m)| (n * rr.min(n)) as f64 + 0.0 * m as f64)
        .sum::<f64>()
        * l;
    let grad_peak = dims
        .iter()
        .map(|&(n, m)| (n * m) as f64)
        .fold(0.0f64, f64::max)
        .max(v * d);
    MemoryBreakdown {
        trainable: (proj_coords + v * d) * b,
        optimizer: 2.0 * (proj_coords + v * d) * b,
        gradient: grad_peak * b,
        auxiliary: projectors * b,
    }
}

/// LoSiA (rank factor p, output factor p_o):
/// #Trainable (LKd²p² + Vdp_o)b, #Optimizer 2(…)b,
/// #Gradient max{d²b, Vdb} (per-layer updates),
/// #Auxiliary 2Kd²b — Ī/Ū for ONE layer only (the async schedule),
/// zero in gradient-importance mode.
pub fn losia(
    cfg: &ModelCfg,
    p: f64,
    p_o: f64,
    b: f64,
    gradient_importance: bool,
) -> MemoryBreakdown {
    let l = cfg.n_layers as f64;
    let d = cfg.d_model as f64;
    let v = cfg.vocab as f64;
    let dims = kind_dims(cfg);
    let subnet: f64 = dims
        .iter()
        .map(|&(n, m)| (n as f64 * p).floor() * (m as f64 * p).floor())
        .sum::<f64>()
        * l;
    let trainable = subnet + v * d * p_o;
    let grad_peak = dims
        .iter()
        .map(|&(n, m)| (n * m) as f64)
        .fold(0.0f64, f64::max)
        .max(v * d);
    let aux = if gradient_importance {
        0.0
    } else {
        2.0 * dims.iter().map(|&(n, m)| (n * m) as f64).sum::<f64>()
    };
    MemoryBreakdown {
        trainable: trainable * b,
        optimizer: 2.0 * trainable * b,
        gradient: grad_peak * b,
        auxiliary: aux * b,
    }
}

/// Full fine-tuning: everything dense.
pub fn fft(cfg: &ModelCfg, b: f64) -> MemoryBreakdown {
    let total = cfg.param_count as f64;
    MemoryBreakdown {
        trainable: total * b,
        optimizer: 2.0 * total * b,
        gradient: total * b,
        auxiliary: 0.0,
    }
}

/// Analytic total for a configured run, in GB-equivalent (f32
/// precision) — the estimate the session's `MemoryObserver` reports.
pub fn method_memory_gb(cfg: &ModelCfg, tc: &TrainConfig) -> f64 {
    let b = 4.0; // f32
    let bytes = match tc.method {
        Method::Fft => fft(cfg, b).total(),
        Method::Lora | Method::Pissa | Method::Dora => {
            lora(cfg, cfg.lora_rank, b).total()
        }
        Method::Galore => galore(cfg, tc.galore_rank, b).total(),
        Method::Losia | Method::LosiaPro => losia(
            cfg,
            tc.rank_factor_override.unwrap_or(cfg.rank_factor),
            cfg.out_factor,
            b,
            tc.ablation.gradient_importance,
        )
        .total(),
    };
    bytes / 1e9
}

/// Trainable-parameter counts for Table 15 (LoSiA across p, p_o).
pub fn losia_trainable_params(cfg: &ModelCfg, p: f64, p_o: f64) -> f64 {
    let dims = kind_dims(cfg);
    let subnet: f64 = dims
        .iter()
        .map(|&(n, m)| (n as f64 * p).floor() * (m as f64 * p).floor())
        .sum::<f64>()
        * cfg.n_layers as f64;
    subnet + cfg.d_model as f64 * cfg.vocab as f64 * p_o
}

/// Activation storage per step (Figure 5 / Table 16 w/o GC analysis):
/// LoSiA-Pro stores only the p-fraction of each linear's input.
pub fn activation_bytes(
    cfg: &ModelCfg,
    input_fraction: f64,
    b: f64,
) -> f64 {
    let tokens = (cfg.batch * cfg.seq_len) as f64;
    let per_layer: f64 = kind_dims(cfg)
        .iter()
        .map(|&(n, _)| n as f64 * input_fraction)
        .sum();
    tokens * per_layer * cfg.n_layers as f64 * b
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::resolve_config;
    use crate::runtime::artifacts_dir;

    fn cfg() -> ModelCfg {
        resolve_config(&artifacts_dir(), "tiny").unwrap()
    }

    #[test]
    fn losia_scales_quadratically_with_p() {
        let c = cfg();
        let m1 = losia(&c, 0.125, 0.125, 4.0, false);
        let m2 = losia(&c, 0.25, 0.125, 4.0, false);
        // subnet part scales ×4; output part is constant
        assert!(m2.trainable > 2.0 * m1.trainable * 0.9);
        assert!(m2.trainable < 4.0 * m1.trainable);
        // auxiliary does NOT scale with p (one layer's Ī/Ū)
        assert_eq!(m1.auxiliary, m2.auxiliary);
    }

    #[test]
    fn gradient_importance_removes_auxiliary() {
        let c = cfg();
        let m = losia(&c, 0.125, 0.125, 4.0, true);
        assert_eq!(m.auxiliary, 0.0);
    }

    #[test]
    fn lora_total_is_8x_adapters_equivalent() {
        let c = cfg();
        let m = lora(&c, 8, 4.0);
        let adapters = m.trainable;
        assert!((m.total() - 5.0 * adapters).abs() < 1e-6);
        // paper's 8LKrdb counts A+B as 2·LKrd; ours folds both into
        // `adapters`, so total = 5·(A+B) ≡ 8·LKrd exactly when n=m=d.
    }

    #[test]
    fn losia_grad_peak_is_layer_or_vocab_max() {
        let c = cfg();
        let m = losia(&c, 0.125, 0.125, 1.0, false);
        let d = c.d_model as f64;
        let v = c.vocab as f64;
        let ff = c.d_ff as f64;
        let peak = (d * ff).max(v * d);
        assert_eq!(m.gradient, peak);
    }

    #[test]
    fn fft_dominates_everything() {
        let c = cfg();
        let f = fft(&c, 4.0).total();
        assert!(f > losia(&c, 0.125, 0.125, 4.0, false).total());
        assert!(f > lora(&c, 8, 4.0).total());
    }

    #[test]
    fn activation_fraction_scales_linearly() {
        let c = cfg();
        let full = activation_bytes(&c, 1.0, 4.0);
        let pro = activation_bytes(&c, 0.125, 4.0);
        assert!((full / pro - 8.0).abs() < 1e-9);
    }

    #[test]
    fn trainable_counts_monotone_in_p() {
        let c = cfg();
        let a = losia_trainable_params(&c, 1.0 / 16.0, 0.125);
        let b = losia_trainable_params(&c, 0.125, 0.125);
        let d = losia_trainable_params(&c, 0.25, 0.125);
        assert!(a < b && b < d);
    }
}
