//! Latency bookkeeping for the benches: warmup + trimmed-mean timing
//! (criterion is unavailable offline, so this is the bench harness).

use std::time::Instant;

/// Timing summary over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Timing {
    pub mean_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
    pub runs: usize,
}

impl Timing {
    pub fn mean_micros(&self) -> f64 {
        self.mean_secs * 1e6
    }
}

/// Run `f` `warmup` + `runs` times; report a trimmed mean (drop the
/// single slowest run when there are ≥ 3 samples — JIT/pagefault
/// noise).
pub fn time_fn<F: FnMut()>(warmup: usize, runs: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    // total_cmp: a NaN sample (clock misbehaviour) must not panic the
    // whole bench run
    samples.sort_by(f64::total_cmp);
    let kept: &[f64] = if samples.len() >= 3 {
        &samples[..samples.len() - 1]
    } else {
        &samples
    };
    Timing {
        mean_secs: kept.iter().sum::<f64>() / kept.len() as f64,
        min_secs: *samples.first().unwrap(),
        max_secs: *samples.last().unwrap(),
        runs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_positive_and_ordered() {
        let t = time_fn(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(t.mean_secs >= 0.0);
        assert!(t.min_secs <= t.mean_secs * 1.5 + 1e-9);
        assert!(t.min_secs <= t.max_secs);
        assert_eq!(t.runs, 5);
    }
}
