//! Metrics: the analytic GPU-memory model (paper Table 14/15) and
//! latency bookkeeping helpers for the benches.

pub mod latency;
pub mod memory;
