//! Minimal JSON parser/writer (no external deps are available offline).
//!
//! Supports the full JSON grammar we need for `artifacts/manifest.json`
//! and `results/*.json`: objects, arrays, strings (with escapes),
//! numbers, booleans, null.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` style access; panics with a useful path on miss.
    pub fn at(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing json key {key:?}"))
    }

    pub fn as_f64(&self) -> f64 {
        match self {
            Json::Num(n) => *n,
            _ => panic!("expected number, got {self:?}"),
        }
    }

    pub fn as_usize(&self) -> usize {
        self.as_f64() as usize
    }

    pub fn as_str(&self) -> &str {
        match self {
            Json::Str(s) => s,
            _ => panic!("expected string, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> &[Json] {
        match self {
            Json::Arr(a) => a,
            _ => panic!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> &BTreeMap<String, Json> {
        match self {
            Json::Obj(m) => m,
            _ => panic!("expected object, got {self:?}"),
        }
    }

    /// Serialize back to a compact JSON string.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error string with byte offset.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser {
        b: input.as_bytes(),
        i: 0,
    };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!(
                "expected {:?} at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            ))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {other:?} at byte {}", self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = self
                                .b
                                .get(self.i + 1..self.i + 5)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| "bad \\u escape")?,
                                16,
                            )
                            .map_err(|_| "bad \\u escape")?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => {
                            return Err(format!("bad escape {other:?}"))
                        }
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a UTF-8 run verbatim
                    let start = self.i;
                    while let Some(c) = self.peek() {
                        if c == b'"' || c == b'\\' {
                            break;
                        }
                        self.i += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => return Err(format!("bad array sep {other:?}")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            map.insert(key, self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => return Err(format!("bad object sep {other:?}")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "x\ny"}], "c": {}}"#).unwrap();
        assert_eq!(v.at("a").as_arr()[0].as_f64(), 1.0);
        assert_eq!(v.at("a").as_arr()[2].at("b").as_str(), "x\ny");
        assert!(v.at("c").as_obj().is_empty());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"k":[1,2.5,true,null,"s\"q"],"z":{"n":-3}}"#;
        let v = parse(src).unwrap();
        let v2 = parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("\"open").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let v = parse("\"a\\u0041b\"").unwrap();
        assert_eq!(v.as_str(), "aAb");
    }
}
