//! Env-driven fault injection for crash-safety tests.
//!
//! `LOSIA_FAULT=site@step:kind` arms exactly one fault: the named
//! site fires when it is reached at the given step. Kinds:
//!
//! * `error`   — the site returns a typed
//!   [`TrainError::FaultInjected`] error.
//! * `panic`   — the site panics (exercises worker-panic containment).
//! * `partial` — only meaningful at write sites: the write is
//!   truncated mid-file and then fails (exercises the atomic-write
//!   discipline — the destination must never see the torn bytes).
//!
//! `step` may be `*` to fire on every visit. The env var is parsed on
//! every [`armed`] call rather than cached: tests arm and disarm
//! faults between runs inside one process, and worker threads observe
//! the same process-global environment.
//!
//! Named sites (see `runtime/README.md` for the full contract):
//! `save`, `stage-worker`, `prefetch-worker`, `dp-worker`, `reduce`,
//! `adapter-activate`.

use anyhow::Result;

use crate::util::error::TrainError;

pub const ENV: &str = "LOSIA_FAULT";

/// Serializes unit tests that arm faults — `LOSIA_FAULT` is
/// process-global, so concurrent test threads must take turns.
/// Integration-test binaries are separate processes and keep their
/// own locks.
#[cfg(test)]
pub static ENV_LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultKind {
    Error,
    Panic,
    Partial,
}

/// Parse a `site@step:kind` spec. Returns `None` for malformed specs
/// (fault injection must never break a production run).
fn parse(spec: &str) -> Option<(String, Option<usize>, FaultKind)> {
    let (site_step, kind) = spec.rsplit_once(':')?;
    let (site, step) = site_step.split_once('@')?;
    if site.is_empty() {
        return None;
    }
    let step = if step == "*" {
        None
    } else {
        Some(step.parse().ok()?)
    };
    let kind = match kind {
        "error" => FaultKind::Error,
        "panic" => FaultKind::Panic,
        "partial" => FaultKind::Partial,
        _ => return None,
    };
    Some((site.to_string(), step, kind))
}

/// Is a fault armed for `site` at `step`?
pub fn armed(site: &str, step: usize) -> Option<FaultKind> {
    let spec = std::env::var(ENV).ok()?;
    let (s, at, kind) = parse(&spec)?;
    (s == site && at.map_or(true, |t| t == step)).then_some(kind)
}

/// Fire the fault armed for `site` at `step`, if any: `panic` panics,
/// `error` and `partial` return the typed error (sites that cannot
/// express a partial write treat it as a plain error). No-op when
/// nothing is armed — this is the one line a fault site costs.
pub fn hit(site: &str, step: usize) -> Result<()> {
    match armed(site, step) {
        None => Ok(()),
        Some(FaultKind::Panic) => {
            panic!("injected fault: panic at {site} (step {step})")
        }
        Some(FaultKind::Error) | Some(FaultKind::Partial) => {
            Err(TrainError::FaultInjected {
                site: site.to_string(),
                step,
            }
            .into())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use super::ENV_LOCK;

    struct Arm;
    impl Arm {
        fn set(spec: &str) -> Arm {
            std::env::set_var(ENV, spec);
            Arm
        }
    }
    impl Drop for Arm {
        fn drop(&mut self) {
            std::env::remove_var(ENV);
        }
    }

    #[test]
    fn parses_specs() {
        assert_eq!(
            parse("save@3:error"),
            Some(("save".into(), Some(3), FaultKind::Error))
        );
        assert_eq!(
            parse("dp-worker@*:panic"),
            Some(("dp-worker".into(), None, FaultKind::Panic))
        );
        assert_eq!(parse("save@3"), None);
        assert_eq!(parse("@3:error"), None);
        assert_eq!(parse("save@x:error"), None);
        assert_eq!(parse("save@3:nuke"), None);
    }

    #[test]
    fn fires_only_at_the_armed_site_and_step() {
        let _guard = ENV_LOCK.lock().unwrap();
        let _arm = Arm::set("save@2:error");
        assert!(hit("save", 1).is_ok());
        assert!(hit("reduce", 2).is_ok());
        let err = hit("save", 2).unwrap_err();
        match err.downcast_ref::<TrainError>() {
            Some(TrainError::FaultInjected { site, step }) => {
                assert_eq!(site, "save");
                assert_eq!(*step, 2);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn wildcard_step_fires_everywhere() {
        let _guard = ENV_LOCK.lock().unwrap();
        let _arm = Arm::set("reduce@*:error");
        assert!(hit("reduce", 0).is_err());
        assert!(hit("reduce", 17).is_err());
    }

    #[test]
    fn unarmed_is_free() {
        let _guard = ENV_LOCK.lock().unwrap();
        std::env::remove_var(ENV);
        assert!(hit("save", 0).is_ok());
        assert_eq!(armed("save", 0), None);
    }

    #[test]
    #[should_panic(expected = "injected fault")]
    fn panic_kind_panics() {
        let _guard = match ENV_LOCK.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let _arm = Arm::set("site@0:panic");
        let _ = hit("site", 0);
    }
}
