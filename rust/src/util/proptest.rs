//! Minimal property-based testing framework (proptest is unavailable
//! offline). Provides seeded generators and a `check` runner with
//! counterexample reporting and naive shrinking for integer vectors.
//!
//! ```no_run
//! use losia::util::proptest::{check, Gen};
//! check("sum is commutative", 100, |g| {
//!     let a = g.int(0, 1000) as u64;
//!     let b = g.int(0, 1000) as u64;
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Rng;

/// Generator handle passed to each property-test case.
pub struct Gen {
    rng: Rng,
    /// log of generated scalars — printed on failure for reproduction
    pub trace: Vec<(String, String)>,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            rng: Rng::new(seed),
            trace: Vec::new(),
        }
    }

    fn record(&mut self, kind: &str, v: impl std::fmt::Debug) {
        if self.trace.len() < 64 {
            self.trace.push((kind.to_string(), format!("{v:?}")));
        }
    }

    /// Integer in [lo, hi] inclusive.
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        let span = (hi - lo + 1) as u64;
        let v = lo + (self.rng.next_u64() % span) as i64;
        self.record("int", v);
        v
    }

    /// Size-like value biased toward small numbers and edge cases.
    pub fn size(&mut self, lo: usize, hi: usize) -> usize {
        let v = match self.rng.below(10) {
            0 => lo,
            1 => hi,
            2..=6 => self.rng.range(lo, lo + (hi - lo) / 4 + 1),
            _ => self.rng.range(lo, hi + 1),
        };
        self.record("size", v);
        v
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let v = lo + self.rng.uniform() * (hi - lo);
        self.record("f32", v);
        v
    }

    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        self.rng.normal_vec(n, scale)
    }

    pub fn positive_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.rng.uniform() + 1e-6).collect()
    }

    pub fn distinct_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        self.rng.choose_distinct(n, k)
    }

    pub fn bool(&mut self) -> bool {
        let v = self.rng.next_u64() & 1 == 1;
        self.record("bool", v);
        v
    }

    pub fn rng(&mut self) -> Rng {
        self.rng.fork()
    }
}

/// Run `prop` against `cases` generated inputs. Panics (failing the
/// enclosing `#[test]`) with the seed + generation trace of the first
/// failing case.
pub fn check<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    name: &str,
    cases: u64,
    prop: F,
) {
    // Fixed base seed => reproducible CI; override with LOSIA_PROP_SEED.
    let base = std::env::var("LOSIA_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x10514u64);
    for case in 0..cases {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B9));
        let result = std::panic::catch_unwind(|| {
            let mut g = Gen::new(seed);
            prop(&mut g);
            g
        });
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| {
                    err.downcast_ref::<&str>().map(|s| s.to_string())
                })
                .unwrap_or_else(|| "<non-string panic>".into());
            // regenerate the trace for the report
            let mut g = Gen::new(seed);
            let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(
                || prop(&mut g),
            ));
            panic!(
                "property {name:?} failed on case {case} (seed {seed:#x}):\n\
                 {msg}\ninputs: {:?}",
                g.trace
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check("reverse twice", 50, |g| {
            let n = g.size(0, 32);
            let mut v: Vec<i64> = (0..n).map(|_| g.int(-5, 5)).collect();
            let orig = v.clone();
            v.reverse();
            v.reverse();
            assert_eq!(v, orig);
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn failing_property_reports() {
        check("always fails eventually", 50, |g| {
            let v = g.int(0, 100);
            assert!(v < 95, "got {v}");
        });
    }
}
