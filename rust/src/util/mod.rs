//! Support substrates: JSON, RNG, CLI parsing, tables, property
//! testing, and the shared warning sink.

pub mod cli;
pub mod durable;
pub mod error;
pub mod faultpoint;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;
pub mod warn;
