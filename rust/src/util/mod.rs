//! Support substrates: JSON, RNG, CLI parsing, tables, property testing.

pub mod cli;
pub mod json;
pub mod proptest;
pub mod rng;
pub mod table;
