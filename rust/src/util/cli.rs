//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, `--flag`, and positional
//! arguments, with typed getters and a usage printer.

use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (testable) — `flag_names` lists
    /// options that take no value.
    pub fn parse_from<I: IntoIterator<Item = String>>(
        iter: I,
        flag_names: &[&str],
    ) -> Self {
        let mut args = Args::default();
        let mut it = iter.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.opts.insert(k.to_string(), v.to_string());
                } else if flag_names.contains(&rest) {
                    args.flags.push(rest.to_string());
                } else if let Some(v) = it.peek() {
                    if v.starts_with("--") {
                        args.flags.push(rest.to_string());
                    } else {
                        let v = it.next().unwrap();
                        args.opts.insert(rest.to_string(), v);
                    }
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(a);
            }
        }
        args
    }

    pub fn parse(flag_names: &[&str]) -> Self {
        Self::parse_from(std::env::args().skip(1), flag_names)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.opts.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects an integer, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key)
            .map(|v| {
                v.parse().unwrap_or_else(|_| {
                    panic!("--{key} expects a number, got {v:?}")
                })
            })
            .unwrap_or(default)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixed() {
        let a = Args::parse_from(
            s(&["train", "--steps", "100", "--lr=0.01", "--verbose"]),
            &["verbose"],
        );
        assert_eq!(a.positional, vec!["train"]);
        assert_eq!(a.get_usize("steps", 0), 100);
        assert_eq!(a.get_f64("lr", 0.0), 0.01);
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn flag_before_option() {
        let a = Args::parse_from(
            s(&["--quiet", "--steps", "5"]),
            &["quiet"],
        );
        assert!(a.has_flag("quiet"));
        assert_eq!(a.get_usize("steps", 0), 5);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = Args::parse_from(s(&["--maybe"]), &[]);
        assert!(a.has_flag("maybe"));
    }

    #[test]
    fn defaults() {
        let a = Args::parse_from(s(&[]), &[]);
        assert_eq!(a.get_or("cfg", "tiny"), "tiny");
        assert_eq!(a.get_usize("steps", 7), 7);
    }
}
