//! Deterministic PRNG (xoshiro256**) — no external rand crate offline.
//!
//! Used for parameter init, data generation, and the property-test
//! framework. Seeded runs are bit-reproducible across machines.

/// xoshiro256** by Blackman & Vigna (public domain reference impl).
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed into the state vector
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below(hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-9);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt()
            * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Vector of standard normals scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() * scale).collect()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choose_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot choose {k} from {n}");
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = self.range(i, n);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Derive an independent child stream (for per-task seeding).
    pub fn fork(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }

    /// The raw state vector, for checkpointing a stream mid-flight.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a stream from [`Self::state`] — the restored generator
    /// continues the exact draw sequence.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

/// Splittable stream derivation: map `(seed, index, count)` to the
/// seed of shard `index` out of `count` sibling streams.
///
/// Pure function of its inputs — no shared mutable RNG is consulted,
/// so any worker can derive its own stream independently and the
/// result never depends on derivation order. Uses two rounds of
/// splitmix64-style mixing over the packed inputs so that sibling
/// streams (same `seed`, different `index`) and differently-split
/// families (same `seed`/`index`, different `count`) all land far
/// apart, and none collides with `Rng::new(seed)` itself.
pub fn derive_stream(seed: u64, index: u64, count: u64) -> u64 {
    fn mix(mut z: u64) -> u64 {
        z = z.wrapping_add(0x9E3779B97F4A7C15);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
    let a = mix(seed ^ 0xD1F2_4A5C_9B3E_7081);
    let b = mix(a ^ index.wrapping_mul(0xA24B_AED4_963E_E407));
    mix(b ^ count.wrapping_mul(0x9FB2_1C65_1E98_DF25))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs = r.normal_vec(20_000, 1.0);
        let mean: f32 = xs.iter().sum::<f32>() / xs.len() as f32;
        let var: f32 =
            xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>()
                / xs.len() as f32;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choose_distinct_is_distinct() {
        let mut r = Rng::new(3);
        for _ in 0..50 {
            let k = r.range(1, 20);
            let mut v = r.choose_distinct(32, k);
            v.sort_unstable();
            v.dedup();
            assert_eq!(v.len(), k);
        }
    }

    #[test]
    fn derive_stream_is_seed_stable_and_disjoint() {
        // same (seed, index, count) → same stream, always
        assert_eq!(derive_stream(42, 1, 4), derive_stream(42, 1, 4));
        // sibling shard streams are pairwise distinct and produce
        // disjoint draw prefixes (the practical "no shared stream"
        // property the dp engine relies on)
        let seeds: Vec<u64> =
            (0..8).map(|i| derive_stream(42, i, 8)).collect();
        for i in 0..seeds.len() {
            for j in (i + 1)..seeds.len() {
                assert_ne!(seeds[i], seeds[j], "shard {i} vs {j}");
                let mut a = Rng::new(seeds[i]);
                let mut b = Rng::new(seeds[j]);
                let da: Vec<u64> =
                    (0..16).map(|_| a.next_u64()).collect();
                let db: Vec<u64> =
                    (0..16).map(|_| b.next_u64()).collect();
                assert_ne!(da, db, "shard {i} vs {j} draw prefix");
            }
        }
        // distinct from the base stream and sensitive to the family
        // size (a 2-way split and a 4-way split must not alias)
        assert_ne!(derive_stream(42, 0, 4), 42);
        assert_ne!(derive_stream(42, 0, 2), derive_stream(42, 0, 4));
    }

    #[test]
    fn state_round_trips_mid_stream() {
        let mut a = Rng::new(11);
        for _ in 0..37 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(4);
        let mut v: Vec<usize> = (0..64).collect();
        r.shuffle(&mut v);
        let mut s = v.clone();
        s.sort_unstable();
        assert_eq!(s, (0..64).collect::<Vec<_>>());
    }
}
