//! Typed training/runtime errors.
//!
//! Lives in `util` (the lowest layer) so `runtime`, `data`, and
//! `coordinator` can all construct the same variants without a
//! dependency cycle. Errors flow through `anyhow` everywhere; tests
//! and callers that need to branch on the kind downcast:
//!
//! ```ignore
//! match err.downcast_ref::<TrainError>() {
//!     Some(TrainError::WorkerPanic { site }) => ...,
//!     _ => ...,
//! }
//! ```

use std::fmt;

/// Failures with a contract attached: worker panics are contained
/// (drained, joined, no leaked threads) and surfaced as
/// [`TrainError::WorkerPanic`]; corrupted or truncated durable files
/// name the file, section, and byte counts instead of bubbling a raw
/// `UnexpectedEof`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrainError {
    /// A worker thread (dp shard worker, pipeline stage worker, batch
    /// prefetcher) panicked. The launcher converted the panic into
    /// this error after joining the thread — no channel is left
    /// poisoned and no thread leaked.
    WorkerPanic { site: String },
    /// An injected fault (see `util::faultpoint`) fired at a named
    /// site. Only ever produced when `LOSIA_FAULT` is set.
    FaultInjected { site: String, step: usize },
    /// A durable file ended before a section's payload did.
    Truncated {
        file: String,
        section: String,
        expected: u64,
        available: u64,
    },
    /// A section's stored CRC32 does not match its payload.
    CrcMismatch { file: String, section: String },
}

impl fmt::Display for TrainError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrainError::WorkerPanic { site } => {
                write!(f, "worker panic contained at {site}")
            }
            TrainError::FaultInjected { site, step } => {
                write!(f, "injected fault at {site} (step {step})")
            }
            TrainError::Truncated {
                file,
                section,
                expected,
                available,
            } => write!(
                f,
                "{file}: truncated in section {section:?} \
                 (wanted {expected} bytes, {available} available)"
            ),
            TrainError::CrcMismatch { file, section } => write!(
                f,
                "{file}: CRC32 mismatch in section {section:?} \
                 (file is corrupt)"
            ),
        }
    }
}

impl std::error::Error for TrainError {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn messages_name_the_site_and_file() {
        let e = TrainError::WorkerPanic { site: "dp-worker".into() };
        assert!(e.to_string().contains("dp-worker"));
        let e = TrainError::Truncated {
            file: "ck.losia".into(),
            section: "state".into(),
            expected: 64,
            available: 12,
        };
        let s = e.to_string();
        assert!(s.contains("ck.losia"), "{s}");
        assert!(s.contains("64"), "{s}");
        assert!(s.contains("12"), "{s}");
        let e = TrainError::CrcMismatch {
            file: "ck.losia".into(),
            section: "meta".into(),
        };
        assert!(e.to_string().contains("CRC32"), "{}", e);
    }

    #[test]
    fn downcasts_through_anyhow() {
        let err: anyhow::Error = TrainError::FaultInjected {
            site: "save".into(),
            step: 3,
        }
        .into();
        match err.downcast_ref::<TrainError>() {
            Some(TrainError::FaultInjected { site, step }) => {
                assert_eq!(site, "save");
                assert_eq!(*step, 3);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }
}
