//! Durable file I/O: atomic writes and CRC-checked sections.
//!
//! Every on-disk record the trainer can be killed around — model
//! states (`LOSIAST1`), adapter records (`LOSIAAD1`), and training
//! checkpoints (`LOSIACK1`) — goes through the same discipline:
//!
//! * **Atomic replace.** [`atomic_write`] writes `<name>.tmp` in the
//!   destination directory, fsyncs, then renames over the target. A
//!   crash mid-write leaves a torn `.tmp` and an intact previous
//!   file; the destination path never holds partial bytes.
//! * **Sectioned CRC32.** Payloads are written through a
//!   [`SectionWriter`] that hashes bytes as they flow and appends a
//!   4-byte IEEE CRC32 at each [`SectionWriter::end_section`]. The
//!   [`SectionReader`] verifies each section and turns short reads
//!   into typed [`TrainError::Truncated`] errors naming the file,
//!   section, and byte counts (CRC failures get their own
//!   [`TrainError::CrcMismatch`]).
//! * **Versioned headers.** New-format files write the 8-byte magic,
//!   then a `0xFFFF_FFFF` sentinel `u32`, then a format version.
//!   Legacy (pre-CRC) files start their payload right after the
//!   magic with a `u32` that can never be the sentinel (a parameter
//!   count or adapter mode), so [`read_header`] distinguishes the two
//!   and legacy records keep loading — without CRC verification and
//!   with a one-line [`crate::util::warn`].
//!
//! Floats stream through fixed 16 KiB frames in both directions, so
//! saving a large state never materializes a second full copy.

use std::fs::File;
use std::io::{self, BufWriter, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::error::TrainError;
use crate::util::faultpoint::{self, FaultKind};

/// First `u32` after the magic in versioned files. Legacy formats
/// stored a parameter count or a 1/2 mode discriminant there, so the
/// all-ones pattern is unreachable for them.
pub const VERSION_SENTINEL: u32 = 0xFFFF_FFFF;

/// f32 elements per streaming frame (16 KiB of bytes).
const FRAME: usize = 4096;

// ------------------------------------------------------------- crc32

const fn make_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 {
                0xEDB8_8320 ^ (c >> 1)
            } else {
                c >> 1
            };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

const CRC_TABLE: [u32; 256] = make_crc_table();

/// Streaming IEEE CRC32 (the zlib/PNG polynomial), hand-rolled — the
/// crate has no checksum dependency and must not grow one.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Default for Crc32 {
    fn default() -> Self {
        Self::new()
    }
}

impl Crc32 {
    pub fn new() -> Self {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.state;
        for &b in bytes {
            c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    pub fn finish(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }

    fn reset(&mut self) {
        self.state = 0xFFFF_FFFF;
    }
}

/// One-shot convenience.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

// ----------------------------------------------------------- writing

/// A writer that hashes every payload byte and can close out a
/// section by appending its CRC32. The header helpers
/// ([`write_header`]) write *outside* any section; everything else
/// should land between section boundaries.
pub struct SectionWriter<W: Write> {
    inner: W,
    crc: Crc32,
}

impl<W: Write> SectionWriter<W> {
    pub fn new(inner: W) -> Self {
        SectionWriter { inner, crc: Crc32::new() }
    }

    pub fn write_all(&mut self, buf: &[u8]) -> io::Result<()> {
        self.crc.update(buf);
        self.inner.write_all(buf)
    }

    pub fn u32(&mut self, v: u32) -> io::Result<()> {
        self.write_all(&v.to_le_bytes())
    }

    pub fn u64(&mut self, v: u64) -> io::Result<()> {
        self.write_all(&v.to_le_bytes())
    }

    /// Length-prefixed UTF-8 string (u32 length).
    pub fn str(&mut self, s: &str) -> io::Result<()> {
        self.u32(s.len() as u32)?;
        self.write_all(s.as_bytes())
    }

    /// Stream a float slice through a fixed 16 KiB frame — no
    /// tensor-sized intermediate allocation.
    pub fn f32s(&mut self, xs: &[f32]) -> io::Result<()> {
        let mut buf = [0u8; 4 * FRAME];
        for chunk in xs.chunks(FRAME) {
            for (i, x) in chunk.iter().enumerate() {
                buf[i * 4..i * 4 + 4]
                    .copy_from_slice(&x.to_le_bytes());
            }
            self.write_all(&buf[..chunk.len() * 4])?;
        }
        Ok(())
    }

    /// Append the CRC32 of everything written since the last section
    /// boundary (the CRC bytes themselves are not hashed) and start a
    /// fresh section.
    pub fn end_section(&mut self) -> io::Result<()> {
        let crc = self.crc.finish();
        self.inner.write_all(&crc.to_le_bytes())?;
        self.crc.reset();
        Ok(())
    }

    pub fn into_inner(self) -> W {
        self.inner
    }
}

/// Write the versioned header: 8-byte magic, sentinel, version.
pub fn write_header<W: Write>(
    w: &mut SectionWriter<W>,
    magic: &[u8; 8],
    version: u32,
) -> io::Result<()> {
    w.write_all(magic)?;
    w.u32(VERSION_SENTINEL)?;
    w.u32(version)?;
    // the header is self-framing; CRC coverage starts at section 0
    w.crc.reset();
    Ok(())
}

/// The tmp-file twin of `path` (same directory, so the final rename
/// never crosses a filesystem boundary).
pub fn tmp_path(path: &Path) -> PathBuf {
    let mut name = path
        .file_name()
        .map(|s| s.to_os_string())
        .unwrap_or_default();
    name.push(".tmp");
    path.with_file_name(name)
}

pub fn is_tmp(path: &Path) -> bool {
    path.extension().map_or(false, |e| e == "tmp")
}

/// Atomic file replace: write `<path>.tmp` through the supplied
/// closure, flush + fsync, then rename over `path`. On any failure
/// the destination is untouched (a torn `.tmp` may remain; readers
/// skip them).
///
/// `site`/`step` name the fault point: `error`/`panic` faults fire
/// before any byte is written, and a `partial` fault truncates the
/// finished tmp file to half its length and fails *instead of
/// renaming* — simulating a crash mid-write under the discipline.
pub fn atomic_write<F>(
    path: &Path,
    site: &str,
    step: usize,
    body: F,
) -> Result<()>
where
    F: FnOnce(&mut SectionWriter<BufWriter<&File>>) -> Result<()>,
{
    let partial = match faultpoint::armed(site, step) {
        Some(FaultKind::Panic) => {
            panic!("injected fault: panic at {site} (step {step})")
        }
        Some(FaultKind::Error) => {
            return Err(TrainError::FaultInjected {
                site: site.to_string(),
                step,
            }
            .into());
        }
        Some(FaultKind::Partial) => true,
        None => false,
    };

    let tmp = tmp_path(path);
    let file = File::create(&tmp)
        .with_context(|| format!("creating {}", tmp.display()))?;
    {
        let mut w = SectionWriter::new(BufWriter::new(&file));
        body(&mut w)?;
        w.into_inner().flush().with_context(|| {
            format!("flushing {}", tmp.display())
        })?;
    }
    if partial {
        // crash simulation: half the bytes made it to disk, the
        // rename never happened — the destination must stay intact
        let len = file.metadata()?.len();
        file.set_len(len / 2)?;
        let _ = file.sync_all();
        return Err(TrainError::FaultInjected {
            site: site.to_string(),
            step,
        }
        .into());
    }
    file.sync_all()
        .with_context(|| format!("syncing {}", tmp.display()))?;
    drop(file);
    std::fs::rename(&tmp, path).with_context(|| {
        format!("renaming {} -> {}", tmp.display(), path.display())
    })?;
    // best-effort directory fsync so the rename itself is durable
    if let Some(dir) = path.parent() {
        if let Ok(d) = File::open(dir) {
            let _ = d.sync_all();
        }
    }
    Ok(())
}

// ----------------------------------------------------------- reading

/// Header sniff result: a versioned (CRC-checked) file, or a legacy
/// record whose first post-magic `u32` is returned for the caller to
/// interpret (parameter count, adapter mode, …).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Header {
    Versioned(u32),
    Legacy(u32),
}

/// A reader that verifies per-section CRCs and converts short reads
/// into typed errors naming the file and section.
pub struct SectionReader<R: Read> {
    inner: R,
    crc: Crc32,
    file: String,
    section: String,
    /// legacy files carry no section CRCs; [`Self::end_section`]
    /// becomes a no-op
    has_crc: bool,
}

impl<R: Read> SectionReader<R> {
    pub fn new(inner: R, file: impl Into<String>) -> Self {
        SectionReader {
            inner,
            crc: Crc32::new(),
            file: file.into(),
            section: "header".to_string(),
            has_crc: true,
        }
    }

    pub fn file(&self) -> &str {
        &self.file
    }

    /// Enter a named section (labels truncation/CRC errors).
    pub fn section(&mut self, name: &str) {
        self.section = name.to_string();
        self.crc.reset();
    }

    /// Read the magic + sniff the version sentinel. On a legacy file
    /// CRC verification is disabled for the rest of the read.
    pub fn read_header(&mut self, magic: &[u8; 8]) -> Result<Header> {
        let mut got = [0u8; 8];
        self.read_exact(&mut got)?;
        if &got != magic {
            anyhow::bail!(
                "{}: bad magic (expected {:?})",
                self.file,
                String::from_utf8_lossy(magic)
            );
        }
        let first = self.u32()?;
        if first == VERSION_SENTINEL {
            let version = self.u32()?;
            self.crc.reset();
            Ok(Header::Versioned(version))
        } else {
            self.has_crc = false;
            Ok(Header::Legacy(first))
        }
    }

    pub fn read_exact(&mut self, buf: &mut [u8]) -> Result<()> {
        let mut got = 0usize;
        while got < buf.len() {
            match self.inner.read(&mut buf[got..]) {
                Ok(0) => {
                    return Err(TrainError::Truncated {
                        file: self.file.clone(),
                        section: self.section.clone(),
                        expected: buf.len() as u64,
                        available: got as u64,
                    }
                    .into());
                }
                Ok(n) => got += n,
                Err(e) if e.kind() == io::ErrorKind::Interrupted => {}
                Err(e) => {
                    return Err(e).with_context(|| {
                        format!(
                            "{}: reading section {:?}",
                            self.file, self.section
                        )
                    });
                }
            }
        }
        self.crc.update(buf);
        Ok(())
    }

    pub fn u32(&mut self) -> Result<u32> {
        let mut b = [0u8; 4];
        self.read_exact(&mut b)?;
        Ok(u32::from_le_bytes(b))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.read_exact(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    /// Length-prefixed UTF-8 string. The length is capped so a
    /// corrupt prefix cannot trigger a huge allocation.
    pub fn str(&mut self) -> Result<String> {
        let len = self.u32()? as usize;
        anyhow::ensure!(
            len <= 1 << 20,
            "{}: section {:?}: implausible string length {len} \
             (file is corrupt)",
            self.file,
            self.section
        );
        let mut bytes = vec![0u8; len];
        self.read_exact(&mut bytes)?;
        String::from_utf8(bytes).with_context(|| {
            format!(
                "{}: section {:?}: non-UTF-8 string",
                self.file, self.section
            )
        })
    }

    /// Fill a float slice through the same fixed frames the writer
    /// used.
    pub fn f32s(&mut self, out: &mut [f32]) -> Result<()> {
        let mut buf = [0u8; 4 * FRAME];
        for chunk in out.chunks_mut(FRAME) {
            let n = chunk.len() * 4;
            self.read_exact(&mut buf[..n])?;
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = f32::from_le_bytes([
                    buf[i * 4],
                    buf[i * 4 + 1],
                    buf[i * 4 + 2],
                    buf[i * 4 + 3],
                ]);
            }
        }
        Ok(())
    }

    /// Verify the section CRC (no-op on legacy files).
    pub fn end_section(&mut self) -> Result<()> {
        if !self.has_crc {
            return Ok(());
        }
        let computed = self.crc.finish();
        let mut b = [0u8; 4];
        // the stored CRC is framing, not payload — read it without
        // feeding the hasher
        let section = self.section.clone();
        self.read_exact(&mut b)?;
        let stored = u32::from_le_bytes(b);
        if stored != computed {
            return Err(TrainError::CrcMismatch {
                file: self.file.clone(),
                section,
            }
            .into());
        }
        self.crc.reset();
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // the classic IEEE check value
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        // streaming == one-shot
        let mut c = Crc32::new();
        c.update(b"1234");
        c.update(b"56789");
        assert_eq!(c.finish(), 0xCBF4_3926);
    }

    #[test]
    fn sections_round_trip() {
        let mut buf = Vec::new();
        {
            let mut w = SectionWriter::new(&mut buf);
            write_header(&mut w, b"LOSIATST", 1).unwrap();
            w.u64(42).unwrap();
            w.str("hello").unwrap();
            w.end_section().unwrap();
            w.f32s(&[1.0, -2.5, 3.25]).unwrap();
            w.end_section().unwrap();
        }
        let mut r =
            SectionReader::new(std::io::Cursor::new(&buf), "test");
        assert_eq!(
            r.read_header(b"LOSIATST").unwrap(),
            Header::Versioned(1)
        );
        r.section("meta");
        assert_eq!(r.u64().unwrap(), 42);
        assert_eq!(r.str().unwrap(), "hello");
        r.end_section().unwrap();
        r.section("data");
        let mut xs = [0f32; 3];
        r.f32s(&mut xs).unwrap();
        assert_eq!(xs, [1.0, -2.5, 3.25]);
        r.end_section().unwrap();
    }

    #[test]
    fn large_float_blocks_cross_frames() {
        let xs: Vec<f32> =
            (0..3 * FRAME + 17).map(|i| i as f32 * 0.5).collect();
        let mut buf = Vec::new();
        {
            let mut w = SectionWriter::new(&mut buf);
            w.f32s(&xs).unwrap();
            w.end_section().unwrap();
        }
        let mut r =
            SectionReader::new(std::io::Cursor::new(&buf), "test");
        r.section("data");
        let mut back = vec![0f32; xs.len()];
        r.f32s(&mut back).unwrap();
        r.end_section().unwrap();
        assert_eq!(xs, back);
    }

    #[test]
    fn truncation_is_a_typed_error() {
        let mut buf = Vec::new();
        {
            let mut w = SectionWriter::new(&mut buf);
            w.u64(7).unwrap();
            w.end_section().unwrap();
        }
        buf.truncate(5);
        let mut r = SectionReader::new(
            std::io::Cursor::new(&buf),
            "short.bin",
        );
        r.section("meta");
        let err = r.u64().unwrap_err();
        match err.downcast_ref::<TrainError>() {
            Some(TrainError::Truncated {
                file,
                section,
                expected,
                available,
            }) => {
                assert_eq!(file, "short.bin");
                assert_eq!(section, "meta");
                assert_eq!(*expected, 8);
                assert_eq!(*available, 5);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn corruption_is_a_crc_mismatch() {
        let mut buf = Vec::new();
        {
            let mut w = SectionWriter::new(&mut buf);
            w.u64(7).unwrap();
            w.end_section().unwrap();
        }
        buf[2] ^= 0x40; // flip a payload bit
        let mut r = SectionReader::new(
            std::io::Cursor::new(&buf),
            "corrupt.bin",
        );
        r.section("meta");
        assert_eq!(r.u64().unwrap(), 7 | (0x40 << 16));
        let err = r.end_section().unwrap_err();
        match err.downcast_ref::<TrainError>() {
            Some(TrainError::CrcMismatch { file, section }) => {
                assert_eq!(file, "corrupt.bin");
                assert_eq!(section, "meta");
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn legacy_header_disables_crc() {
        // legacy layout: magic, then payload starting with a plain
        // count — no sentinel, no CRCs
        let mut buf = Vec::new();
        buf.extend_from_slice(b"LOSIATST");
        buf.extend_from_slice(&3u32.to_le_bytes());
        buf.extend_from_slice(&9u64.to_le_bytes());
        let mut r =
            SectionReader::new(std::io::Cursor::new(&buf), "old.bin");
        assert_eq!(
            r.read_header(b"LOSIATST").unwrap(),
            Header::Legacy(3)
        );
        r.section("body");
        assert_eq!(r.u64().unwrap(), 9);
        // no CRC bytes to consume
        r.end_section().unwrap();
    }

    #[test]
    fn bad_magic_is_rejected() {
        let buf = b"GARBAGE!rest".to_vec();
        let mut r =
            SectionReader::new(std::io::Cursor::new(&buf), "x.bin");
        let err = r.read_header(b"LOSIATST").unwrap_err();
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn atomic_write_replaces_and_failures_leave_target_intact() {
        let dir = std::env::temp_dir()
            .join(format!("losia_durable_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("record.bin");

        atomic_write(&path, "save", 0, |w| {
            w.u64(1)?;
            w.end_section()?;
            Ok(())
        })
        .unwrap();
        let v1 = std::fs::read(&path).unwrap();

        // a failing body must not disturb the existing file
        let err = atomic_write(&path, "save", 1, |w| {
            w.u64(2)?;
            anyhow::bail!("boom")
        })
        .unwrap_err();
        assert!(err.to_string().contains("boom"));
        assert_eq!(std::fs::read(&path).unwrap(), v1);

        // a successful rewrite replaces it
        atomic_write(&path, "save", 2, |w| {
            w.u64(2)?;
            w.end_section()?;
            Ok(())
        })
        .unwrap();
        assert_ne!(std::fs::read(&path).unwrap(), v1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn partial_fault_tears_the_tmp_not_the_target() {
        let _guard = match crate::util::faultpoint::ENV_LOCK.lock() {
            Ok(g) => g,
            Err(p) => p.into_inner(),
        };
        let dir = std::env::temp_dir()
            .join(format!("losia_partial_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("record.bin");
        atomic_write(&path, "save", 0, |w| {
            w.u64(1)?;
            w.end_section()?;
            Ok(())
        })
        .unwrap();
        let v1 = std::fs::read(&path).unwrap();

        std::env::set_var(faultpoint::ENV, "save@1:partial");
        let err = atomic_write(&path, "save", 1, |w| {
            w.u64(2)?;
            w.end_section()?;
            Ok(())
        })
        .unwrap_err();
        std::env::remove_var(faultpoint::ENV);
        match err.downcast_ref::<TrainError>() {
            Some(TrainError::FaultInjected { site, step }) => {
                assert_eq!(site, "save");
                assert_eq!(*step, 1);
            }
            other => panic!("wrong variant: {other:?}"),
        }
        // destination intact, torn tmp half-length
        assert_eq!(std::fs::read(&path).unwrap(), v1);
        let tmp = tmp_path(&path);
        assert!(is_tmp(&tmp));
        let torn = std::fs::metadata(&tmp).unwrap().len();
        assert_eq!(torn, v1.len() as u64 / 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
