//! ASCII table + CSV reporters for benches and examples.
//!
//! Every bench prints a paper-shaped table to stdout and mirrors it as
//! CSV under `results/` so figures can be re-plotted.

use std::fs;
use std::path::Path;

/// Column-aligned ASCII table builder.
pub struct Table {
    title: String,
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row width mismatch in table {:?}",
            self.title
        );
        self.rows.push(cells.to_vec());
        self
    }

    /// Convenience: build a row from display items.
    pub fn rowv(&mut self, cells: Vec<String>) -> &mut Self {
        self.row(&cells)
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> =
            self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = widths
            .iter()
            .map(|w| "-".repeat(w + 2))
            .collect::<Vec<_>>()
            .join("+");
        let fmt_row = |cells: &[String]| -> String {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = widths[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = format!("== {} ==\n", self.title);
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row));
            out.push('\n');
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write the table as CSV into `results/<name>.csv`.
    pub fn write_csv(&self, name: &str) {
        let dir = Path::new("results");
        let _ = fs::create_dir_all(dir);
        let mut csv = self.header.join(",");
        csv.push('\n');
        for row in &self.rows {
            let line: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            csv.push_str(&line.join(","));
            csv.push('\n');
        }
        let path = dir.join(format!("{name}.csv"));
        fs::write(&path, csv).expect("write csv");
        println!("[csv] results/{name}.csv");
    }
}

/// Format a float with fixed decimals, used across benches.
pub fn f(x: f64, decimals: usize) -> String {
    format!("{x:.decimals$}")
}

/// Write a machine-readable bench record to
/// `<repo-root>/BENCH_<name>.json` — the perf-trajectory artifact CI
/// uploads per run so bench numbers can be diffed PR-over-PR without
/// scraping stdout tables. The repo root is resolved from the crate
/// manifest dir, so benches land the file in the same place from any
/// working directory.
pub fn write_bench_json(name: &str, j: &crate::util::json::Json) {
    let root = Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .map(|p| p.to_path_buf())
        .unwrap_or_else(|| Path::new(".").to_path_buf());
    let path = root.join(format!("BENCH_{name}.json"));
    match fs::write(&path, j.to_string()) {
        Ok(()) => println!("[bench-json] {}", path.display()),
        Err(e) => eprintln!(
            "[bench-json] failed to write {}: {e}",
            path.display()
        ),
    }
}

/// Write a generic CSV series (e.g. loss curves) to results/.
pub fn write_series_csv(name: &str, header: &[&str], rows: &[Vec<f64>]) {
    let dir = Path::new("results");
    let _ = fs::create_dir_all(dir);
    let mut csv = header.join(",");
    csv.push('\n');
    for row in rows {
        csv.push_str(
            &row.iter()
                .map(|v| format!("{v}"))
                .collect::<Vec<_>>()
                .join(","),
        );
        csv.push('\n');
    }
    fs::write(dir.join(format!("{name}.csv")), csv).expect("write csv");
    println!("[csv] results/{name}.csv");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("T", &["method", "acc"]);
        t.row(&["LoSiA".into(), "44.66".into()]);
        t.row(&["LoRA".into(), "42.9".into()]);
        let s = t.render();
        assert!(s.contains("== T =="));
        assert!(s.contains("LoSiA"));
        let lines: Vec<&str> = s.lines().collect();
        // header + sep + 2 rows + title
        assert_eq!(lines.len(), 5);
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn rejects_ragged_rows() {
        let mut t = Table::new("T", &["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
