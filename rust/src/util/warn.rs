//! Shared warning sink: library code reports non-fatal conditions
//! through [`warn`] instead of raw `eprintln!`, so embedding layers
//! (the serve scheduler, future observers) can capture them instead of
//! losing them to stderr.
//!
//! Default behaviour is unchanged — with no capture scope active a
//! message goes straight to stderr. [`capture`] installs a process-
//! global collector for the guard's lifetime; scopes nest like a stack
//! (the innermost active scope receives the messages) and restore the
//! previous sink on drop.

use std::sync::{Arc, Mutex};

type Collector = Arc<Mutex<Vec<String>>>;

static SINKS: Mutex<Vec<Collector>> = Mutex::new(Vec::new());

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Report a non-fatal warning. Lands in the innermost active
/// [`capture`] scope's buffer, else on stderr.
pub fn warn(msg: impl Into<String>) {
    let msg = msg.into();
    match lock(&SINKS).last() {
        Some(c) => lock(c).push(msg),
        None => eprintln!("{msg}"),
    }
}

/// RAII capture scope returned by [`capture`]: warnings raised while
/// the guard lives are buffered instead of printed.
pub struct WarnCapture {
    collector: Collector,
}

/// Start capturing warnings until the returned guard is dropped.
pub fn capture() -> WarnCapture {
    let collector: Collector = Arc::new(Mutex::new(Vec::new()));
    lock(&SINKS).push(Arc::clone(&collector));
    WarnCapture { collector }
}

impl WarnCapture {
    /// Drain the messages captured so far (resets the buffer).
    pub fn drain(&self) -> Vec<String> {
        std::mem::take(&mut *lock(&self.collector))
    }
}

impl Drop for WarnCapture {
    fn drop(&mut self) {
        let mut sinks = lock(&SINKS);
        if let Some(i) = sinks
            .iter()
            .position(|c| Arc::ptr_eq(c, &self.collector))
        {
            sinks.remove(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // the sink is process-global, so the capture tests serialize on it
    static GUARD: Mutex<()> = Mutex::new(());

    #[test]
    fn capture_buffers_and_drains() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        let cap = capture();
        warn("first");
        warn(format!("second {}", 2));
        assert_eq!(cap.drain(), vec!["first", "second 2"]);
        assert!(cap.drain().is_empty(), "drain resets the buffer");
    }

    #[test]
    fn scopes_nest_and_restore() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        let outer = capture();
        warn("to-outer");
        {
            let inner = capture();
            warn("to-inner");
            assert_eq!(inner.drain(), vec!["to-inner"]);
        }
        warn("back-to-outer");
        assert_eq!(outer.drain(), vec!["to-outer", "back-to-outer"]);
    }
}
