//! Shared warning sink: library code reports non-fatal conditions
//! through [`warn`] instead of raw `eprintln!`, so embedding layers
//! (the serve scheduler, the step pipeline's stage worker, future
//! observers) can capture them instead of losing them to stderr.
//!
//! Default behaviour is unchanged — with no capture scope active a
//! message goes straight to stderr. [`capture`] installs a process-
//! global collector for the guard's lifetime; scopes nest like a stack
//! (the innermost active scope receives the messages) and restore the
//! previous sink on drop.
//!
//! Delivery is channel-based so the sink works across threads: each
//! scope registers an `mpsc` sender in a process-global registry, and
//! [`warn`] clones the innermost sender and sends outside the registry
//! lock. A warning raised on a worker thread (dp gradient worker,
//! pipeline stage thread) therefore lands in the scope that was active
//! when it fired, not on that worker's stderr — the
//! `capture_receives_warnings_from_worker_threads` test pins this. If
//! the capturing scope dies between the clone and the send, the
//! message falls back to stderr rather than being dropped.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Mutex;

static SINKS: Mutex<Vec<(u64, Sender<String>)>> = Mutex::new(Vec::new());
static NEXT_ID: AtomicU64 = AtomicU64::new(0);

fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Report a non-fatal warning. Lands in the innermost active
/// [`capture`] scope's buffer — regardless of which thread raises it —
/// else on stderr.
pub fn warn(msg: impl Into<String>) {
    let msg = msg.into();
    // clone the sender out so the send itself runs outside the
    // registry lock (a blocked receiver can't stall other warners)
    let tx = lock(&SINKS).last().map(|(_, tx)| tx.clone());
    match tx {
        Some(tx) => {
            if let Err(e) = tx.send(msg) {
                eprintln!("{}", e.0);
            }
        }
        None => eprintln!("{msg}"),
    }
}

/// RAII capture scope returned by [`capture`]: warnings raised while
/// the guard lives — from any thread — are buffered instead of
/// printed.
pub struct WarnCapture {
    id: u64,
    rx: Receiver<String>,
}

/// Start capturing warnings until the returned guard is dropped.
pub fn capture() -> WarnCapture {
    let (tx, rx) = channel();
    let id = NEXT_ID.fetch_add(1, Ordering::Relaxed);
    lock(&SINKS).push((id, tx));
    WarnCapture { id, rx }
}

impl WarnCapture {
    /// Drain the messages captured so far (resets the buffer).
    pub fn drain(&self) -> Vec<String> {
        self.rx.try_iter().collect()
    }
}

impl Drop for WarnCapture {
    fn drop(&mut self) {
        let mut sinks = lock(&SINKS);
        if let Some(i) = sinks.iter().position(|(id, _)| *id == self.id)
        {
            sinks.remove(i);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // the sink is process-global, so the capture tests serialize on it
    static GUARD: Mutex<()> = Mutex::new(());

    #[test]
    fn capture_buffers_and_drains() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        let cap = capture();
        warn("first");
        warn(format!("second {}", 2));
        assert_eq!(cap.drain(), vec!["first", "second 2"]);
        assert!(cap.drain().is_empty(), "drain resets the buffer");
    }

    #[test]
    fn scopes_nest_and_restore() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        let outer = capture();
        warn("to-outer");
        {
            let inner = capture();
            warn("to-inner");
            assert_eq!(inner.drain(), vec!["to-inner"]);
        }
        warn("back-to-outer");
        assert_eq!(outer.drain(), vec!["to-outer", "back-to-outer"]);
    }

    #[test]
    fn capture_receives_warnings_from_worker_threads() {
        let _g = GUARD.lock().unwrap_or_else(|e| e.into_inner());
        let cap = capture();
        // mirror the pipeline / dp shape: warnings fire on spawned
        // threads while the capturing scope lives on the test thread
        let handles: Vec<_> = (0..4)
            .map(|i| {
                std::thread::spawn(move || {
                    warn(format!("from-worker-{i}"));
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
        let mut got = cap.drain();
        got.sort();
        assert_eq!(
            got,
            vec![
                "from-worker-0",
                "from-worker-1",
                "from-worker-2",
                "from-worker-3"
            ],
            "cross-thread warnings must land in the active scope"
        );
    }
}
