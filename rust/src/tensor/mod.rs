//! Dense f32 tensor substrate: storage, matmul, gather/scatter, top-k,
//! SVD. Everything the coordinator needs host-side; heavy model math
//! stays in the XLA artifacts.

pub mod dense;
pub mod select;
pub mod svd;

pub use dense::Tensor;
