//! Selection primitives: top-k indices, argmax, softmax — used by the
//! localization algorithm, GaLore projector, and greedy decoding.

/// Indices of the `k` largest values (descending). Stable on ties by
/// preferring lower indices; O(n log n) via sort on (value, -index).
pub fn topk_indices(values: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(values.len());
    let mut idx: Vec<usize> = (0..values.len()).collect();
    idx.sort_by(|&a, &b| {
        values[b]
            .partial_cmp(&values[a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(&b))
    });
    idx.truncate(k);
    idx
}

/// Partial-selection top-k: O(n + k log k) — used on the hot path where
/// n is a hidden dimension and k = ⌊np⌋.
pub fn topk_indices_fast(values: &[f32], k: usize) -> Vec<usize> {
    let k = k.min(values.len());
    if k == 0 {
        return Vec::new();
    }
    let mut idx: Vec<usize> = (0..values.len()).collect();
    let cmp = |a: &usize, b: &usize| {
        values[*b]
            .partial_cmp(&values[*a])
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.cmp(b))
    };
    if k < idx.len() {
        // O(n) partition so the k largest land in idx[..k]
        idx.select_nth_unstable_by(k - 1, cmp);
    }
    let mut top = idx[..k].to_vec();
    top.sort_by(cmp);
    top
}

pub fn argmax(values: &[f32]) -> usize {
    let mut best = 0;
    for (i, &v) in values.iter().enumerate() {
        if v > values[best] {
            best = i;
        }
    }
    best
}

/// Multinomial draw from a probability vector given a uniform sample
/// `u ∈ [0, 1)`; the last index absorbs any rounding shortfall. Shared
/// by greedy/temperature decoding (`eval::generate`) and the serve
/// scheduler so both sample identically from the same uniform stream.
pub fn sample_multinomial(probs: &[f32], u: f32) -> usize {
    let mut acc = 0.0f32;
    for (i, &p) in probs.iter().enumerate() {
        acc += p;
        if u < acc {
            return i;
        }
    }
    probs.len().saturating_sub(1)
}

/// Numerically-stable softmax.
pub fn softmax(values: &[f32]) -> Vec<f32> {
    let max = values.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let exps: Vec<f32> = values.iter().map(|v| (v - max).exp()).collect();
    let sum: f32 = exps.iter().sum();
    exps.iter().map(|e| e / sum).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn topk_known() {
        let v = vec![0.1, 5.0, 3.0, 4.0, 2.0];
        assert_eq!(topk_indices(&v, 3), vec![1, 3, 2]);
    }

    #[test]
    fn topk_fast_matches_slow() {
        check("fast topk == sort topk (as sets + order)", 100, |g| {
            let n = g.size(1, 200);
            let k = g.size(1, n);
            let v = g.normal_vec(n, 1.0);
            let slow = topk_indices(&v, k);
            let fast = topk_indices_fast(&v, k);
            // both sorted descending by value; values must match exactly
            let sv: Vec<f32> = slow.iter().map(|&i| v[i]).collect();
            let fv: Vec<f32> = fast.iter().map(|&i| v[i]).collect();
            assert_eq!(sv, fv, "value sequences differ");
        });
    }

    #[test]
    fn topk_k_exceeds_len() {
        let v = vec![1.0, 2.0];
        assert_eq!(topk_indices(&v, 10).len(), 2);
        assert_eq!(topk_indices_fast(&v, 10).len(), 2);
    }

    #[test]
    fn topk_sum_is_maximal() {
        check("topk captures max mass", 50, |g| {
            let n = g.size(2, 64);
            let k = g.size(1, n);
            let v = g.positive_vec(n);
            let top = topk_indices_fast(&v, k);
            let top_sum: f32 = top.iter().map(|&i| v[i]).sum();
            let r = g.distinct_indices(n, k);
            let rand_sum: f32 = r.iter().map(|&i| v[i]).sum();
            assert!(top_sum >= rand_sum - 1e-5);
        });
    }

    #[test]
    fn softmax_sums_to_one() {
        let p = softmax(&[1.0, 2.0, 3.0]);
        let s: f32 = p.iter().sum();
        assert!((s - 1.0).abs() < 1e-6);
        assert!(p[2] > p[1] && p[1] > p[0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
