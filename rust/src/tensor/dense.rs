//! Row-major dense f32 tensor with the small set of ops the coordinator
//! hot path needs. Deliberately simple: contiguous `Vec<f32>` + shape.

use crate::util::rng::Rng;

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; n],
        }
    }

    pub fn ones(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: vec![1.0; n],
        }
    }

    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} != data len {}",
            data.len()
        );
        Tensor {
            shape: shape.to_vec(),
            data,
        }
    }

    pub fn randn(shape: &[usize], scale: f32, rng: &mut Rng) -> Self {
        let n: usize = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: rng.normal_vec(n, scale),
        }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// (rows, cols) of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.ndim(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        let (_, m) = self.dims2();
        self.data[i * m + j]
    }

    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        let m = self.shape[1];
        self.data[i * m + j] = v;
    }

    /// Slice out sub-tensor `idx` along axis 0 (e.g. one layer of a
    /// stacked [L, ...] parameter).
    pub fn index_axis0(&self, idx: usize) -> Tensor {
        assert!(idx < self.shape[0]);
        let inner: usize = self.shape[1..].iter().product();
        let start = idx * inner;
        Tensor {
            shape: self.shape[1..].to_vec(),
            data: self.data[start..start + inner].to_vec(),
        }
    }

    /// Write `src` into position `idx` along axis 0.
    pub fn set_axis0(&mut self, idx: usize, src: &Tensor) {
        let inner: usize = self.shape[1..].iter().product();
        assert_eq!(src.len(), inner);
        let start = idx * inner;
        self.data[start..start + inner].copy_from_slice(&src.data);
    }

    /// Stack equal-shaped tensors along a new leading axis.
    pub fn stack(parts: &[Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let inner = parts[0].shape.clone();
        let mut shape = vec![parts.len()];
        shape.extend_from_slice(&inner);
        let mut data = Vec::with_capacity(
            parts.len() * parts[0].len(),
        );
        for p in parts {
            assert_eq!(p.shape, inner, "stack: ragged shapes");
            data.extend_from_slice(&p.data);
        }
        Tensor { shape, data }
    }

    /// C = A @ B for 2-D tensors (ikj loop order, no blocking — host
    /// matmul is only used for SVD/projections on small matrices).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        let (n, k) = self.dims2();
        let (k2, m) = other.dims2();
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let arow = &self.data[i * k..(i + 1) * k];
            let orow = &mut out[i * m..(i + 1) * m];
            for (kk, &a) in arow.iter().enumerate() {
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * m..(kk + 1) * m];
                for j in 0..m {
                    orow[j] += a * brow[j];
                }
            }
        }
        Tensor::from_vec(&[n, m], out)
    }

    pub fn transpose2(&self) -> Tensor {
        let (n, m) = self.dims2();
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            for j in 0..m {
                out[j * n + i] = self.data[i * m + j];
            }
        }
        Tensor::from_vec(&[m, n], out)
    }

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        for (a, b) in self.data.iter_mut().zip(&other.data) {
            *a += b;
        }
    }

    pub fn scale_assign(&mut self, s: f32) {
        for a in self.data.iter_mut() {
            *a *= s;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn abs_sum(&self) -> f64 {
        self.data.iter().map(|x| x.abs() as f64).sum()
    }

    /// Row sums of a 2-D tensor -> Vec of length n.
    pub fn row_sums(&self) -> Vec<f32> {
        let (n, m) = self.dims2();
        (0..n)
            .map(|i| self.data[i * m..(i + 1) * m].iter().sum())
            .collect()
    }

    /// Column sums of a 2-D tensor -> Vec of length m.
    pub fn col_sums(&self) -> Vec<f32> {
        let (n, m) = self.dims2();
        let mut out = vec![0.0f32; m];
        for i in 0..n {
            for j in 0..m {
                out[j] += self.data[i * m + j];
            }
        }
        out
    }

    /// Gather the (rows × cols) submatrix at (rho, gamma).
    pub fn gather2(&self, rho: &[usize], gamma: &[usize]) -> Tensor {
        let (_, m) = self.dims2();
        let mut out = Vec::with_capacity(rho.len() * gamma.len());
        for &i in rho {
            let row = &self.data[i * m..(i + 1) * m];
            for &j in gamma {
                out.push(row[j]);
            }
        }
        Tensor::from_vec(&[rho.len(), gamma.len()], out)
    }

    /// `self[rho, gamma] += delta` (subnet update scatter).
    pub fn scatter_add2(
        &mut self,
        rho: &[usize],
        gamma: &[usize],
        delta: &Tensor,
    ) {
        let (dn, dm) = delta.dims2();
        assert_eq!(dn, rho.len());
        assert_eq!(dm, gamma.len());
        let m = self.shape[1];
        for (a, &i) in rho.iter().enumerate() {
            let row = &mut self.data[i * m..(i + 1) * m];
            let drow = &delta.data[a * dm..(a + 1) * dm];
            for (b, &j) in gamma.iter().enumerate() {
                row[j] += drow[b];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn matmul_known() {
        let a = Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let b = Tensor::from_vec(&[2, 2], vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_identity_property() {
        check("A @ I == A", 30, |g| {
            let n = g.size(1, 12);
            let m = g.size(1, 12);
            let a = Tensor::from_vec(
                &[n, m],
                g.normal_vec(n * m, 1.0),
            );
            let mut eye = Tensor::zeros(&[m, m]);
            for i in 0..m {
                eye.set2(i, i, 1.0);
            }
            let c = a.matmul(&eye);
            for (x, y) in c.data.iter().zip(&a.data) {
                assert!((x - y).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn transpose_involution() {
        check("transpose twice", 30, |g| {
            let n = g.size(1, 10);
            let m = g.size(1, 10);
            let a = Tensor::from_vec(
                &[n, m],
                g.normal_vec(n * m, 1.0),
            );
            assert_eq!(a.transpose2().transpose2(), a);
        });
    }

    #[test]
    fn gather_scatter_roundtrip() {
        check("scatter undoes gather delta", 30, |g| {
            let n = g.size(2, 16);
            let m = g.size(2, 16);
            let k1 = g.size(1, n);
            let k2 = g.size(1, m);
            let rho = g.distinct_indices(n, k1);
            let gamma = g.distinct_indices(m, k2);
            let mut w = Tensor::from_vec(
                &[n, m],
                g.normal_vec(n * m, 1.0),
            );
            let orig = w.clone();
            let delta = Tensor::from_vec(
                &[k1, k2],
                g.normal_vec(k1 * k2, 1.0),
            );
            w.scatter_add2(&rho, &gamma, &delta);
            let got = w.gather2(&rho, &gamma);
            let want = orig.gather2(&rho, &gamma);
            for ((a, b), d) in
                got.data.iter().zip(&want.data).zip(&delta.data)
            {
                assert!((a - b - d).abs() < 1e-5);
            }
            // untouched entries unchanged
            let mut neg = delta.clone();
            neg.scale_assign(-1.0);
            w.scatter_add2(&rho, &gamma, &neg);
            for (a, b) in w.data.iter().zip(&orig.data) {
                assert!((a - b).abs() < 1e-5);
            }
        });
    }

    #[test]
    fn row_col_sums() {
        let a = Tensor::from_vec(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(a.row_sums(), vec![6.0, 15.0]);
        assert_eq!(a.col_sums(), vec![5.0, 7.0, 9.0]);
    }

    #[test]
    fn stack_and_index_axis0() {
        let a = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let b = Tensor::from_vec(&[2], vec![3.0, 4.0]);
        let s = Tensor::stack(&[a.clone(), b.clone()]);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.index_axis0(0), a);
        assert_eq!(s.index_axis0(1), b);
    }
}
