//! One-sided Jacobi SVD (Hestenes) for small dense matrices.
//!
//! Used by the GaLore baseline (gradient projector), PiSSA
//! initialisation, and the Figure-8 intruder-dimension analysis.
//! Dimensions here are ≤ 1024, where Jacobi is accurate and fast
//! enough; convergence is quadratic once sweeps start passing.

use super::dense::Tensor;

/// Result of `svd(A)`: `A ≈ U · diag(S) · Vᵀ` with singular values in
/// descending order; U is n×r, V is m×r with r = min(n, m).
pub struct Svd {
    pub u: Tensor,
    pub s: Vec<f32>,
    pub v: Tensor,
}

/// One-sided Jacobi on the columns of A (n×m). For n < m we factor the
/// transpose and swap U/V.
pub fn svd(a: &Tensor) -> Svd {
    let (n, m) = a.dims2();
    if n < m {
        let t = svd(&a.transpose2());
        return Svd {
            u: t.v,
            s: t.s,
            v: t.u,
        };
    }
    // Work on columns of A: after rotations the columns become
    // orthogonal; their norms are the singular values.
    let mut u = a.clone(); // n×m, columns rotated in place
    let mut v = Tensor::zeros(&[m, m]);
    for i in 0..m {
        v.set2(i, i, 1.0);
    }

    let eps = 1e-10f64;
    for _sweep in 0..60 {
        let mut off = 0.0f64;
        for p in 0..m {
            for q in (p + 1)..m {
                // Gram entries over column pair (p, q)
                let (mut app, mut aqq, mut apq) = (0.0f64, 0.0f64, 0.0f64);
                for i in 0..n {
                    let up = u.data[i * m + p] as f64;
                    let uq = u.data[i * m + q] as f64;
                    app += up * up;
                    aqq += uq * uq;
                    apq += up * uq;
                }
                off += apq * apq;
                if apq.abs() <= eps * (app * aqq).sqrt().max(1e-30) {
                    continue;
                }
                // Jacobi rotation zeroing the (p, q) Gram entry
                let tau = (aqq - app) / (2.0 * apq);
                let t = tau.signum() / (tau.abs() + (1.0 + tau * tau).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..n {
                    let up = u.data[i * m + p] as f64;
                    let uq = u.data[i * m + q] as f64;
                    u.data[i * m + p] = (c * up - s * uq) as f32;
                    u.data[i * m + q] = (s * up + c * uq) as f32;
                }
                for i in 0..m {
                    let vp = v.data[i * m + p] as f64;
                    let vq = v.data[i * m + q] as f64;
                    v.data[i * m + p] = (c * vp - s * vq) as f32;
                    v.data[i * m + q] = (s * vp + c * vq) as f32;
                }
            }
        }
        if off.sqrt() < 1e-12 {
            break;
        }
    }

    // Column norms = singular values; normalise U columns.
    let mut order: Vec<(f32, usize)> = (0..m)
        .map(|j| {
            let norm: f32 = (0..n)
                .map(|i| u.data[i * m + j] * u.data[i * m + j])
                .sum::<f32>()
                .sqrt();
            (norm, j)
        })
        .collect();
    order.sort_by(|a, b| b.0.total_cmp(&a.0));

    let mut u_out = Tensor::zeros(&[n, m]);
    let mut v_out = Tensor::zeros(&[m, m]);
    let mut s_out = Vec::with_capacity(m);
    for (dst, &(norm, src)) in order.iter().enumerate() {
        s_out.push(norm);
        let inv = if norm > 1e-20 { 1.0 / norm } else { 0.0 };
        for i in 0..n {
            u_out.data[i * m + dst] = u.data[i * m + src] * inv;
        }
        for i in 0..m {
            v_out.data[i * m + dst] = v.data[i * m + src];
        }
    }
    Svd {
        u: u_out,
        s: s_out,
        v: v_out,
    }
}

/// First `k` left singular vectors as an n×k matrix (GaLore projector).
pub fn left_singular_topk(a: &Tensor, k: usize) -> Tensor {
    let (n, _) = a.dims2();
    let d = svd(a);
    let k = k.min(d.s.len());
    let mut p = Tensor::zeros(&[n, k]);
    let m = d.u.shape[1];
    for i in 0..n {
        for j in 0..k {
            p.data[i * k + j] = d.u.data[i * m + j];
        }
    }
    p
}

/// Cosine-similarity matrix between the top-k left singular vectors of
/// two matrices (Figure 8 intruder-dimension analysis): returns, for
/// each of the first `k` vectors of `a`, the maximum |cos| against any
/// of the first `k` vectors of `b`.
pub fn singular_vector_similarity(a: &Tensor, b: &Tensor, k: usize) -> Vec<f32> {
    let da = svd(a);
    let db = svd(b);
    let (n, ma) = da.u.dims2();
    let (_, mb) = db.u.dims2();
    let k = k.min(ma).min(mb);
    let mut out = Vec::with_capacity(k);
    for j in 0..k {
        let mut best = 0.0f32;
        for j2 in 0..k {
            let mut dot = 0.0f32;
            for i in 0..n {
                dot += da.u.data[i * ma + j] * db.u.data[i * mb + j2];
            }
            best = best.max(dot.abs());
        }
        out.push(best);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn reconstruct(d: &Svd) -> Tensor {
        let (n, r) = d.u.dims2();
        let (m, _) = d.v.dims2();
        let mut out = Tensor::zeros(&[n, m]);
        for i in 0..n {
            for j in 0..m {
                let mut acc = 0.0f32;
                for t in 0..r {
                    acc += d.u.data[i * r + t]
                        * d.s[t]
                        * d.v.data[j * r + t];
                }
                out.data[i * m + j] = acc;
            }
        }
        out
    }

    #[test]
    fn reconstructs_random_matrices() {
        check("U S V^T == A", 10, |g| {
            let n = g.size(2, 20);
            let m = g.size(2, 20);
            let a = Tensor::from_vec(&[n, m], g.normal_vec(n * m, 1.0));
            let d = svd(&a);
            let r = reconstruct(&d);
            let num: f32 = a
                .data
                .iter()
                .zip(&r.data)
                .map(|(x, y)| (x - y) * (x - y))
                .sum::<f32>()
                .sqrt();
            let den = a.frob_norm().max(1e-6);
            assert!(num / den < 1e-3, "rel err {}", num / den);
        });
    }

    #[test]
    fn singular_values_sorted_nonneg() {
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[16, 12], 1.0, &mut rng);
        let d = svd(&a);
        for w in d.s.windows(2) {
            assert!(w[0] >= w[1] - 1e-6);
        }
        assert!(d.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn diagonal_matrix_exact() {
        let mut a = Tensor::zeros(&[3, 3]);
        a.set2(0, 0, 3.0);
        a.set2(1, 1, 1.0);
        a.set2(2, 2, 2.0);
        let d = svd(&a);
        assert!((d.s[0] - 3.0).abs() < 1e-5);
        assert!((d.s[1] - 2.0).abs() < 1e-5);
        assert!((d.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn u_columns_orthonormal() {
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[20, 8], 1.0, &mut rng);
        let d = svd(&a);
        let (n, r) = d.u.dims2();
        for p in 0..r {
            for q in 0..r {
                let dot: f32 = (0..n)
                    .map(|i| d.u.data[i * r + p] * d.u.data[i * r + q])
                    .sum();
                let want = if p == q { 1.0 } else { 0.0 };
                assert!(
                    (dot - want).abs() < 1e-3,
                    "U^T U [{p},{q}] = {dot}"
                );
            }
        }
    }

    #[test]
    fn identical_matrices_have_similarity_one() {
        let mut rng = Rng::new(11);
        let a = Tensor::randn(&[12, 12], 1.0, &mut rng);
        let sim = singular_vector_similarity(&a, &a, 6);
        for s in sim {
            assert!(s > 0.999, "self-similarity {s}");
        }
    }

    #[test]
    fn wide_matrix_via_transpose() {
        let mut rng = Rng::new(13);
        let a = Tensor::randn(&[6, 18], 1.0, &mut rng);
        let d = svd(&a);
        let r = reconstruct(&d);
        let err: f32 = a
            .data
            .iter()
            .zip(&r.data)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0, f32::max);
        assert!(err < 1e-3, "max err {err}");
    }
}
