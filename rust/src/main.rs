//! `losia` CLI — train and evaluate with any method on any config,
//! built entirely on the [`losia::session`] layer.
//!
//! ```text
//! losia train --config tiny --method losia-pro --task modmath \
//!             --steps 200 --lr 1e-3 --time-slot 20 \
//!             [--workers N] [--dp-shards N] [--pipeline on|off] \
//!             [--checkpoint-every N] [--checkpoint-dir DIR] \
//!             [--checkpoint-keep K] [--resume] \
//!             [--save-state model.bin] [--report out.json] [--json]
//! losia eval  --config tiny --task modmath [--state model.bin] [--no-gen]
//! losia serve --config tiny --tenants 4 --requests 16 \
//!             [--prompt-len N] [--max-new N] [--seed N] [--json]
//! losia info  --config small
//! ```
//!
//! `train` and `eval` both emit a structured `RunReport`; `train`
//! writes it to `results/` (or `--report PATH`) and `--json` prints
//! the JSON to stdout.

use anyhow::{Context, Result};

use losia::config::fmt_specs;
use losia::session::Session;
use losia::util::cli::Args;

/// Shared builder assembly for `train` and `eval`.
fn session_from_args(args: &Args) -> Result<losia::SessionBuilder<'static>> {
    if let Some(backend) = args.get("backend") {
        // the runtime reads LOSIA_BACKEND at build time
        std::env::set_var("LOSIA_BACKEND", backend);
    }
    let mut b = Session::builder()
        .config(&args.get_or("config", "tiny"))
        .method_str(&args.get_or("method", "losia-pro"))?
        .task(&args.get_or("task", "modmath"))
        .steps(args.get_usize("steps", 200))
        .lr(args.get_f64("lr", 1e-3))
        .time_slot(args.get_usize("time-slot", 20))
        .log_every(args.get_usize("log-every", 20))
        .seed(args.get_usize("seed", 42) as u64)
        .use_remat(args.has_flag("remat"))
        .train_n(args.get_usize("train-n", 2000))
        .eval_n(args.get_usize("eval-n", 200));
    if let Some(r) = args.get("galore-rank") {
        b = b.galore_rank(
            r.parse().context("--galore-rank expects an integer")?,
        );
    }
    if let Some(w) = args.get("workers") {
        b = b.workers(
            w.parse().context("--workers expects an integer")?,
        );
    }
    if let Some(s) = args.get("dp-shards") {
        b = b.dp_shards(
            s.parse().context("--dp-shards expects an integer")?,
        );
    }
    if let Some(p) = args.get("pipeline") {
        b = b.pipeline(match p.to_ascii_lowercase().as_str() {
            "on" | "1" | "true" | "yes" => true,
            "off" | "0" | "false" | "no" => false,
            other => anyhow::bail!(
                "--pipeline expects on|off, got {other:?}"
            ),
        });
    }
    if let Some(n) = args.get("checkpoint-every") {
        b = b.checkpoint_every(
            n.parse()
                .context("--checkpoint-every expects an integer")?,
        );
    }
    if let Some(dir) = args.get("checkpoint-dir") {
        b = b.checkpoint_dir(dir);
    }
    if let Some(k) = args.get("checkpoint-keep") {
        b = b.checkpoint_keep(
            k.parse()
                .context("--checkpoint-keep expects an integer")?,
        );
    }
    if args.has_flag("resume") {
        b = b.resume(true);
    }
    if let Some(path) = args.get("state") {
        b = b.initial_state(path);
    }
    Ok(b)
}

fn cmd_train(args: &Args) -> Result<()> {
    let mut session = session_from_args(args)?
        .measure_gen(true)
        .build()?;
    let report = session.train()?;
    if let Some(pre) = report.ppl_acc_pre {
        eprintln!("[eval] pre-train PPL-accuracy: {pre:.2}%");
    }
    println!("{}", report.summary_line());
    for p in &report.exec {
        eprintln!("[exec] {}", p.summary_line());
    }
    if args.has_flag("json") {
        println!("{}", report.to_json_string());
    }
    let path = match args.get("report") {
        Some(p) => {
            let p = std::path::PathBuf::from(p);
            report.save(&p)?;
            p
        }
        None => report.save_results(&format!(
            "run_{}_{}_{}",
            report.config,
            report.method.to_lowercase().replace('-', ""),
            report.task
        ))?,
    };
    eprintln!("[report] {}", path.display());
    if let Some(out) = args.get("save-state") {
        session.save_state(out)?;
        eprintln!("[state] saved to {out}");
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> Result<()> {
    let mut session = session_from_args(args)?
        .measure_gen(!args.has_flag("no-gen"))
        .build()?;
    let report = session.evaluate()?;
    println!(
        "config={} task={} ppl_acc={:.2}% gen_acc={} ({} items, {})",
        report.config,
        report.task,
        report.ppl_acc_post.unwrap_or(f64::NAN),
        report
            .gen_acc
            .map(|g| format!("{g:.2}%"))
            .unwrap_or_else(|| "-".into()),
        args.get_usize("eval-n", 200),
        if args.get("state").is_some() {
            "saved state"
        } else {
            "fresh state"
        },
    );
    if args.has_flag("json") {
        println!("{}", report.to_json_string());
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> Result<()> {
    use losia::serve::{run_load, serve_runtime, LoadSpec};
    use losia::util::table::{f, Table};

    let cfg_name = args.get_or("config", "tiny");
    let rt = serve_runtime(&cfg_name)?;
    let spec = LoadSpec {
        tenants: args.get_usize("tenants", 4),
        requests: args.get_usize("requests", 16),
        prompt_len: args.get_usize("prompt-len", 8),
        max_new: args.get_usize("max-new", 16),
        seed: args.get_usize("seed", 7) as u64,
    };
    let rep = run_load(&rt, &spec)?;
    for w in &rep.warnings {
        eprintln!("[warn] {w}");
    }
    let m = &rep.metrics;
    let mut t = Table::new(
        &format!("serve {} — synthetic multi-tenant load", cfg_name),
        &["metric", "value"],
    );
    t.rowv(vec!["requests".into(), m.requests.to_string()]);
    t.rowv(vec!["tokens".into(), m.tokens.to_string()]);
    t.rowv(vec!["decode steps".into(), m.ticks.to_string()]);
    t.rowv(vec!["adapter swaps".into(), m.swaps.to_string()]);
    t.rowv(vec![
        "backbone uploads".into(),
        m.backbone_uploads.to_string(),
    ]);
    t.rowv(vec![
        "throughput tok/s".into(),
        f(m.throughput_tok_per_s, 1),
    ]);
    t.rowv(vec![
        "token latency p50/p90/p99 µs".into(),
        format!(
            "{} / {} / {}",
            m.p50_ns / 1_000,
            m.p90_ns / 1_000,
            m.p99_ns / 1_000
        ),
    ]);
    t.print();
    if args.has_flag("json") {
        use losia::util::json::Json;
        let mut j = std::collections::BTreeMap::new();
        j.insert("config".into(), Json::Str(cfg_name));
        j.insert("requests".into(), Json::Num(m.requests as f64));
        j.insert("tokens".into(), Json::Num(m.tokens as f64));
        j.insert(
            "throughput_tok_per_s".into(),
            Json::Num(m.throughput_tok_per_s),
        );
        j.insert("p50_ns".into(), Json::Num(m.p50_ns as f64));
        j.insert("p90_ns".into(), Json::Num(m.p90_ns as f64));
        j.insert("p99_ns".into(), Json::Num(m.p99_ns as f64));
        j.insert("swaps".into(), Json::Num(m.swaps as f64));
        j.insert(
            "backbone_uploads".into(),
            Json::Num(m.backbone_uploads as f64),
        );
        println!("{}", Json::Obj(j).to_string());
    }
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    // `losia info --report run.json` summarises a saved RunReport,
    // including the per-artifact executor stats
    if let Some(path) = args.get("report") {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading report {path}"))?;
        let report =
            losia::session::RunReport::from_json_str(&text)?;
        println!("{}", report.summary_line());
        if report.exec.is_empty() {
            println!("  (no executor stats recorded)");
        }
        for p in &report.exec {
            println!("  exec {}", p.summary_line());
        }
        match &report.checkpoint {
            None => println!(
                "  checkpoints: none (run without --checkpoint-every \
                 / --resume, or an older report)"
            ),
            Some(ck) => {
                if let Some(step) = ck.resume_step {
                    println!("  checkpoints: resumed at step {step}");
                }
                println!(
                    "  checkpoints: {} written ({:.1} KB){}",
                    ck.writes,
                    ck.bytes as f64 / 1024.0,
                    match &ck.last_path {
                        Some(p) => format!(", newest {p}"),
                        None => String::new(),
                    }
                );
            }
        }
        return Ok(());
    }
    let cfg_name = args.get_or("config", "tiny");
    let dir = losia::runtime::artifacts_dir();
    let cfg = losia::config::resolve_config(&dir, &cfg_name)?;
    println!(
        "config {} — vocab {} d_model {} heads {} ff {} layers {} \
         seq {} batch {} params {}",
        cfg.name,
        cfg.vocab,
        cfg.d_model,
        cfg.n_heads,
        cfg.d_ff,
        cfg.n_layers,
        cfg.seq_len,
        cfg.batch,
        cfg.param_count
    );
    // per-parameter storage: dense f32 next to the block-quantized
    // int8 footprint, with the active LOSIA_QUANT policy's pick
    // starred — the total line is what a static (frozen-backbone)
    // plan keeps device-resident
    let mode = losia::runtime::quant::mode();
    println!(
        "  parameters (LOSIA_QUANT={}):",
        match mode {
            losia::runtime::QuantMode::Int8 => "int8",
            losia::runtime::QuantMode::Off => "off",
        }
    );
    let (mut total_f32, mut total_resident) = (0usize, 0usize);
    for (name, shape) in &cfg.params {
        let f32_bytes = shape.iter().product::<usize>() * 4;
        let q8_bytes =
            losia::runtime::quant::quantized_byte_len(shape);
        let quantized = mode == losia::runtime::QuantMode::Int8
            && losia::runtime::quant::quantizable(name);
        let resident =
            if quantized { q8_bytes } else { f32_bytes };
        total_f32 += f32_bytes;
        total_resident += resident;
        println!(
            "    {name:<10} {shape:?} f32 {f32_bytes} B{} int8 \
             {q8_bytes} B{}",
            if quantized { "" } else { " *" },
            if quantized { " *" } else { "" },
        );
    }
    println!(
        "    static resident bytes: {total_resident} \
         (dense f32: {total_f32}, {:.2}× reduction)",
        total_f32 as f64 / total_resident.max(1) as f64
    );
    // active data-parallel configuration (TrainConfig defaults +
    // LOSIA_DP_WORKERS / LOSIA_DP_SHARDS): the shard count fixes the
    // numerics, the worker count only splits the kernel-thread
    // budget, and the reduce set is what each method ships across
    // shards per step
    let dp = losia::runtime::DpConfig::resolve(
        &losia::config::TrainConfig::default(),
    );
    println!(
        "  data-parallel: workers {} shards {} \
         ({} kernel threads per worker)",
        dp.workers,
        dp.shards,
        dp.worker_thread_budget()
    );
    // resolved step-pipeline configuration (TrainConfig defaults +
    // LOSIA_PIPELINE / LOSIA_PIPELINE_DEPTH): pipelining overlaps
    // batch packing and per-step uploads with the previous step and
    // never changes numerics, so this block is purely a performance
    // readout
    let pipe = losia::runtime::PipelineConfig::resolve(
        &losia::config::TrainConfig::default(),
    );
    if pipe.enabled {
        println!(
            "  pipeline: on (queue depth {}, {} prefetch threads, \
             {} kernel threads left for the step loop)",
            pipe.queue_depth,
            pipe.prefetch_threads(),
            pipe.main_thread_budget()
        );
    } else {
        println!(
            "  pipeline: off (enable with --pipeline on or \
             LOSIA_PIPELINE=on; queue depth {})",
            pipe.queue_depth
        );
    }
    println!("    per-step reduce set (bytes crossing shards):");
    let full: u64 = cfg
        .params
        .iter()
        .map(|(_, s)| 4 * s.iter().product::<usize>() as u64)
        .sum();
    let sub: u64 = cfg
        .linear_kinds
        .iter()
        .map(|k| {
            let kd = cfg.kind(k);
            4 * (cfg.n_layers * kd.np * kd.mp) as u64
        })
        .sum::<u64>()
        + 4 * (cfg.d_model * cfg.vocab_sub) as u64;
    let lora: u64 = cfg
        .linear_kinds
        .iter()
        .map(|k| {
            let kd = cfg.kind(k);
            4 * (cfg.n_layers * cfg.lora_rank * (kd.n + kd.m)) as u64
        })
        .sum();
    let galore: u64 = cfg
        .linear_kinds
        .iter()
        .map(|k| {
            let kd = cfg.kind(k);
            4 * (cfg.n_layers * kd.n * kd.m) as u64
        })
        .sum::<u64>()
        + 4 * (cfg.d_model * cfg.vocab) as u64;
    println!("      losia-pro  {sub} B (subnet deltas)");
    println!("      losia      {full} B (full gradients)");
    println!("      lora/dora  {lora} B (adapter gradients)");
    println!("      galore     {galore} B (linear + lm_head grads)");
    println!("      fft        {full} B (full gradients)");
    for (name, a) in &cfg.artifacts {
        println!("  artifact {name} ({})", a.file.display());
        println!("    inputs : {}", fmt_specs(&a.inputs));
        println!("    outputs: {}", fmt_specs(&a.outputs));
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(&["remat", "json", "no-gen", "resume"]);
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("eval") => cmd_eval(&args),
        Some("serve") => cmd_serve(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: losia <train|eval|serve|info> [--config C] \
                 [--method M] [--task T] [--steps N] [--lr F] \
                 [--time-slot N] [--remat] [--state PATH] \
                 [--save-state PATH] [--report PATH] [--json] \
                 [--backend ref|pjrt|auto] [--workers N] \
                 [--dp-shards N] [--pipeline on|off] \
                 [--checkpoint-every N] [--checkpoint-dir DIR] \
                 [--checkpoint-keep K] [--resume] \
                 [--tenants N] [--requests N] \
                 [--prompt-len N] [--max-new N]"
            );
            Ok(())
        }
    }
}
