//! `losia` CLI — train and evaluate with any method on any config.
//!
//! ```text
//! losia train --config tiny --method losia-pro --task modmath \
//!             --steps 200 --lr 1e-3 --time-slot 20
//! losia info  --config small
//! ```

use anyhow::Result;

use losia::config::{Method, TrainConfig};
use losia::coordinator::state::ModelState;
use losia::coordinator::trainer::Trainer;
use losia::data::domain::{KvFacts, ModMath, StackEval};
use losia::data::{gen_eval_set, gen_train_set, Batcher, Task};
use losia::eval::{generate_accuracy, ppl_accuracy};
use losia::runtime::Runtime;
use losia::util::cli::Args;
use losia::util::rng::Rng;

fn task_by_name(name: &str) -> Box<dyn Task> {
    match name {
        "modmath" => Box::new(ModMath),
        "stack" => Box::new(StackEval),
        "kvfacts" => Box::new(KvFacts::new(64, 4, 7)),
        other => panic!("unknown task {other:?} (modmath|stack|kvfacts)"),
    }
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg_name = args.get_or("config", "tiny");
    let rt = Runtime::from_config_name(&cfg_name)?;
    let mut tc = TrainConfig {
        method: Method::parse(&args.get_or("method", "losia-pro"))?,
        steps: args.get_usize("steps", 200),
        lr: args.get_f64("lr", 1e-3),
        time_slot: args.get_usize("time-slot", 20),
        log_every: args.get_usize("log-every", 20),
        seed: args.get_usize("seed", 42) as u64,
        use_remat: args.has_flag("remat"),
        ..TrainConfig::default()
    };
    tc.galore_rank = args.get_usize("galore-rank", rt.cfg.d_model / 4);

    let task = task_by_name(&args.get_or("task", "modmath"));
    let train = gen_train_set(task.as_ref(), args.get_usize("train-n", 2000), tc.seed);
    let eval = gen_eval_set(task.as_ref(), args.get_usize("eval-n", 200), tc.seed);
    let mut batcher =
        Batcher::new(train, rt.cfg.batch, rt.cfg.seq_len, tc.seed);

    let mut rng = Rng::new(tc.seed);
    let mut state = ModelState::init(&rt.cfg, &mut rng);
    let mut trainer = Trainer::new(&rt, tc)?;

    let acc0 = ppl_accuracy(&rt, &state, &eval)?;
    eprintln!("[eval] pre-train PPL-accuracy: {acc0:.2}%");
    trainer.train(&mut state, &mut batcher)?;
    let acc1 = ppl_accuracy(&rt, &state, &eval)?;
    let gen1 = generate_accuracy(&rt, &state, &eval)?;
    println!(
        "method={} steps={} final_loss={:.4} ppl_acc={:.2}% gen_acc={:.2}% \
         us_per_token={:.1} trainable={}",
        trainer.driver.method().name(),
        trainer.tc.steps,
        trainer.tail_loss(10),
        acc1,
        gen1,
        trainer.us_per_token(),
        trainer.driver.trainable_params(),
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let cfg_name = args.get_or("config", "tiny");
    let dir = losia::runtime::artifacts_dir();
    let cfg = losia::config::load_manifest(&dir, &cfg_name)?;
    println!(
        "config {} — vocab {} d_model {} heads {} ff {} layers {} \
         seq {} batch {} params {}",
        cfg.name,
        cfg.vocab,
        cfg.d_model,
        cfg.n_heads,
        cfg.d_ff,
        cfg.n_layers,
        cfg.seq_len,
        cfg.batch,
        cfg.param_count
    );
    for (name, a) in &cfg.artifacts {
        println!(
            "  artifact {name}: {} inputs, {} outputs ({})",
            a.inputs.len(),
            a.outputs.len(),
            a.file.display()
        );
    }
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::parse(&["remat"]);
    match args.positional.first().map(|s| s.as_str()) {
        Some("train") => cmd_train(&args),
        Some("info") => cmd_info(&args),
        _ => {
            eprintln!(
                "usage: losia <train|info> [--config C] [--method M] \
                 [--task T] [--steps N] [--lr F] [--time-slot N] [--remat]"
            );
            Ok(())
        }
    }
}
