//! Eight commonsense-analogue tasks (Table 2 / Table 5 suites).
//!
//! Each mirrors one benchmark's *shape*: small discrete reasoning with
//! min-PPL option scoring. Distributions are pairwise distinct so the
//! continual-learning sequence (Table 5) has real task boundaries.

use super::vocab::*;
use super::{EvalItem, Example, Task};
use crate::util::rng::Rng;

/// Build the 8-task suite in paper order (ARC-C … BoolQ analogues).
pub fn suite() -> Vec<Box<dyn Task>> {
    (0..SUITE_NAMES.len()).filter_map(suite_task).collect()
}

/// Construct suite task `i` (paper order, named by `SUITE_NAMES[i]`)
/// without building the rest of the suite; `None` when `i` is out of
/// range.
pub fn suite_task(i: usize) -> Option<Box<dyn Task>> {
    Some(match i {
        0 => Box::new(Parity { len: 5 }) as Box<dyn Task>, // ARC-C (hard)
        1 => Box::new(Parity { len: 3 }),     // ARC-E analogue (easy)
        2 => Box::new(Copy { len: 6 }),       // HellaSwag (continuation)
        3 => Box::new(Compare),               // WinoGrande (binary choice)
        4 => Box::new(Majority { len: 5 }),   // PIQA
        5 => Box::new(Successor),             // OBQA
        6 => Box::new(Member { set_len: 4 }), // SIQA
        7 => Box::new(BoolFact),              // BoolQ
        _ => return None,
    })
}

pub const SUITE_NAMES: [&str; 8] = [
    "parity-5", "parity-3", "copy", "compare",
    "majority", "succ", "member", "boolfact",
];

/// Parity of a bit string → even/odd.
pub struct Parity {
    pub len: usize,
}

impl Task for Parity {
    fn name(&self) -> &'static str {
        "parity"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let bits: Vec<u32> =
            (0..self.len).map(|_| rng.below(2) as u32).collect();
        let ones: u32 = bits.iter().sum();
        let prompt: Vec<u32> = bits
            .iter()
            .map(|&b| digit(b))
            .chain([QRY])
            .collect();
        let answer = vec![if ones % 2 == 0 { EVEN } else { ODD }];
        Example { prompt, answer }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let ex = self.gen_train(rng);
        let correct = usize::from(ex.answer[0] == ODD);
        EvalItem {
            prompt: ex.prompt,
            options: vec![vec![EVEN], vec![ODD]],
            correct,
            category: "parity",
        }
    }
}

/// Which of two letters occurs more often.
pub struct Majority {
    pub len: usize,
}

impl Task for Majority {
    fn name(&self) -> &'static str {
        "majority"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        // odd length guarantees a strict majority of a vs b
        let n = self.len | 1;
        let seq: Vec<u32> =
            (0..n).map(|_| rng.below(2) as u32).collect();
        let count_a = seq.iter().filter(|&&x| x == 0).count();
        let prompt: Vec<u32> = seq
            .iter()
            .map(|&x| letter(x))
            .chain([QRY])
            .collect();
        let answer =
            vec![letter(u32::from(count_a * 2 < n))];
        Example { prompt, answer }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let ex = self.gen_train(rng);
        let correct = usize::from(ex.answer[0] == letter(1));
        EvalItem {
            prompt: ex.prompt,
            options: vec![vec![letter(0)], vec![letter(1)]],
            correct,
            category: "majority",
        }
    }
}

/// Is `a > b` or `a < b` for distinct digits.
pub struct Compare;

impl Task for Compare {
    fn name(&self) -> &'static str {
        "compare"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let a = rng.below(10) as u32;
        let mut b = rng.below(10) as u32;
        while b == a {
            b = rng.below(10) as u32;
        }
        Example {
            prompt: vec![digit(a), digit(b), QRY],
            answer: vec![if a > b { GT } else { LT }],
        }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let ex = self.gen_train(rng);
        let correct = usize::from(ex.answer[0] == LT);
        EvalItem {
            prompt: ex.prompt,
            options: vec![vec![GT], vec![LT]],
            correct,
            category: "compare",
        }
    }
}

/// Recall the first token of a sequence (continuation-style memory).
pub struct Copy {
    pub len: usize,
}

impl Task for Copy {
    fn name(&self) -> &'static str {
        "copy"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let first = rng.below(8) as u32;
        let mut prompt = vec![letter(first)];
        for _ in 1..self.len {
            prompt.push(letter(rng.below(8) as u32));
        }
        prompt.push(QRY);
        Example {
            prompt,
            answer: vec![letter(first)],
        }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let ex = self.gen_train(rng);
        let truth = ex.answer[0];
        let mut options = vec![truth];
        let mut rr = rng.fork();
        while options.len() < 4 {
            let cand = letter(rr.below(8) as u32);
            if !options.contains(&cand) {
                options.push(cand);
            }
        }
        let mut order: Vec<usize> = (0..4).collect();
        rng.shuffle(&mut order);
        let correct = order.iter().position(|&i| i == 0).unwrap();
        EvalItem {
            prompt: ex.prompt,
            options: order.iter().map(|&i| vec![options[i]]).collect(),
            correct,
            category: "copy",
        }
    }
}

/// Successor of a digit mod 10.
pub struct Successor;

impl Task for Successor {
    fn name(&self) -> &'static str {
        "succ"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let a = rng.below(10) as u32;
        Example {
            prompt: vec![digit(a), QRY],
            answer: vec![digit((a + 1) % 10)],
        }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let ex = self.gen_train(rng);
        let truth = ex.answer[0] - DIGIT0;
        let wrong1 = (truth + 5) % 10;
        let wrong2 = (truth + 8) % 10;
        let opts = [truth, wrong1, wrong2];
        let mut order: Vec<usize> = (0..3).collect();
        rng.shuffle(&mut order);
        let correct = order.iter().position(|&i| i == 0).unwrap();
        EvalItem {
            prompt: ex.prompt,
            options: order.iter().map(|&i| vec![digit(opts[i])]).collect(),
            correct,
            category: "succ",
        }
    }
}

/// Set membership: is the queried letter in the shown set?
pub struct Member {
    pub set_len: usize,
}

impl Task for Member {
    fn name(&self) -> &'static str {
        "member"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let set: Vec<u32> = rng
            .choose_distinct(10, self.set_len)
            .into_iter()
            .map(|i| letter(i as u32))
            .collect();
        let inside = rng.below(2) == 0;
        let probe = if inside {
            set[rng.below(set.len())]
        } else {
            loop {
                let cand = letter(rng.below(10) as u32);
                if !set.contains(&cand) {
                    break cand;
                }
            }
        };
        let mut prompt = set;
        prompt.push(SEMI);
        prompt.push(probe);
        prompt.push(QRY);
        Example {
            prompt,
            answer: vec![if inside { YES } else { NO }],
        }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let ex = self.gen_train(rng);
        let correct = usize::from(ex.answer[0] == NO);
        EvalItem {
            prompt: ex.prompt,
            options: vec![vec![YES], vec![NO]],
            correct,
            category: "member",
        }
    }
}

/// Two asserted facts, then a yes/no consistency question (BoolQ-ish):
/// `x=v ; x=v' ?` — yes iff v == v'.
pub struct BoolFact;

impl Task for BoolFact {
    fn name(&self) -> &'static str {
        "boolfact"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let k = letter(rng.below(6) as u32);
        let v1 = letter(6 + rng.below(6) as u32);
        let same = rng.below(2) == 0;
        let v2 = if same {
            v1
        } else {
            loop {
                let cand = letter(6 + rng.below(6) as u32);
                if cand != v1 {
                    break cand;
                }
            }
        };
        Example {
            prompt: vec![k, SEP, v1, SEMI, k, SEP, v2, QRY],
            answer: vec![if same { YES } else { NO }],
        }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let ex = self.gen_train(rng);
        let correct = usize::from(ex.answer[0] == NO);
        EvalItem {
            prompt: ex.prompt,
            options: vec![vec![YES], vec![NO]],
            correct,
            category: "boolfact",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn suite_has_eight_distinct_tasks() {
        let s = suite();
        assert_eq!(s.len(), 8);
        assert_eq!(SUITE_NAMES.len(), 8);
    }

    #[test]
    fn suite_task_covers_exactly_the_suite_range() {
        for i in 0..SUITE_NAMES.len() {
            assert!(suite_task(i).is_some(), "index {i}");
        }
        assert!(suite_task(SUITE_NAMES.len()).is_none());
    }

    #[test]
    fn all_tasks_produce_valid_items() {
        check("eval items well-formed across suite", 20, |g| {
            let mut rng = g.rng();
            for task in suite() {
                let ex = task.gen_train(&mut rng);
                assert!(!ex.prompt.is_empty());
                assert!(!ex.answer.is_empty());
                assert!(ex
                    .prompt
                    .iter()
                    .chain(&ex.answer)
                    .all(|&t| t < VOCAB_USED));
                let item = task.gen_eval(&mut rng);
                assert!(item.correct < item.options.len());
                assert!(item.options.len() >= 2);
                // correct option must be unique among options
                let c = &item.options[item.correct];
                assert_eq!(
                    item.options.iter().filter(|o| *o == c).count(),
                    1
                );
            }
        });
    }

    #[test]
    fn parity_ground_truth() {
        check("parity answers", 50, |g| {
            let mut rng = g.rng();
            let ex = Parity { len: 5 }.gen_train(&mut rng);
            let ones: u32 = ex.prompt[..5]
                .iter()
                .map(|&t| t - DIGIT0)
                .sum();
            let want = if ones % 2 == 0 { EVEN } else { ODD };
            assert_eq!(ex.answer[0], want);
        });
    }

    #[test]
    fn compare_ground_truth() {
        check("compare answers", 50, |g| {
            let mut rng = g.rng();
            let ex = Compare.gen_train(&mut rng);
            let a = ex.prompt[0] - DIGIT0;
            let b = ex.prompt[1] - DIGIT0;
            assert_eq!(ex.answer[0], if a > b { GT } else { LT });
        });
    }

    #[test]
    fn member_ground_truth() {
        check("member answers", 50, |g| {
            let mut rng = g.rng();
            let ex = Member { set_len: 4 }.gen_train(&mut rng);
            let probe = ex.prompt[ex.prompt.len() - 2];
            let inside = ex.prompt[..4].contains(&probe);
            assert_eq!(ex.answer[0], if inside { YES } else { NO });
        });
    }
}
