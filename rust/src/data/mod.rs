//! Synthetic workload substrate.
//!
//! The paper trains on MetaMathQA / Magicoder / Alpaca-GPT4 and
//! evaluates on GSM8K / MBPP / MMLU plus eight commonsense suites.
//! None of those corpora fit a from-scratch CPU reproduction, so this
//! module provides generators with the same *task taxonomy* (see
//! DESIGN.md §Substitutions):
//!
//! * [`domain`] — `modmath` (exact-answer arithmetic ≈ GSM8K),
//!   `stack` (program evaluation ≈ MBPP), `kvfacts` (knowledge
//!   recall with categories ≈ MMLU).
//! * [`commonsense`] — eight small classification/completion tasks
//!   scored by min-perplexity option choice (≈ lm-eval-harness ACC).
//! * [`vocab`] — the shared symbolic token space (< 64 ids, so every
//!   model config can host every task).
//! * [`batcher`] — SFT packing: loss mask on answer tokens only.

pub mod batcher;
pub mod commonsense;
pub mod domain;
pub mod vocab;

pub use batcher::{Batch, BatchPrefetcher, Batcher};

use crate::util::rng::Rng;

/// One supervised example: prompt tokens and answer tokens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Example {
    pub prompt: Vec<u32>,
    pub answer: Vec<u32>,
}

/// An evaluation item: either multiple-choice (PPL-scored) or
/// exact-answer generation.
#[derive(Debug, Clone)]
pub struct EvalItem {
    pub prompt: Vec<u32>,
    /// candidate answers; `correct` indexes into this list
    pub options: Vec<Vec<u32>>,
    pub correct: usize,
    /// category label (used by the MMLU-style breakdown)
    pub category: &'static str,
}

/// A task that can generate training examples and eval items.
pub trait Task {
    fn name(&self) -> &'static str;
    fn gen_train(&self, rng: &mut Rng) -> Example;
    fn gen_eval(&self, rng: &mut Rng) -> EvalItem;
}

/// Deterministic train/eval split sizes used across benches.
pub fn gen_train_set(
    task: &dyn Task,
    n: usize,
    seed: u64,
) -> Vec<Example> {
    let mut rng = Rng::new(seed);
    (0..n).map(|_| task.gen_train(&mut rng)).collect()
}

pub fn gen_eval_set(task: &dyn Task, n: usize, seed: u64) -> Vec<EvalItem> {
    // disjoint stream from training by construction (different seed
    // stream); collisions are possible but rare and harmless for the
    // relative comparisons the benches make.
    let mut rng = Rng::new(seed ^ 0xEEEE_7777_0000_1111);
    (0..n).map(|_| task.gen_eval(&mut rng)).collect()
}
