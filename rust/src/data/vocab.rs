//! Shared symbolic token space (< 64 ids so the `tiny` config hosts
//! every task). Layout is append-only: benches depend on stability.

pub const PAD: u32 = 0;
pub const BOS: u32 = 1;
pub const EOS: u32 = 2;
/// prompt/answer separator ("=")
pub const SEP: u32 = 3;
/// query marker ("?")
pub const QRY: u32 = 4;

/// digits 0..=9 → tokens 5..=14
pub const DIGIT0: u32 = 5;

pub const PLUS: u32 = 15;
pub const MINUS: u32 = 16;
pub const TIMES: u32 = 17;

/// letters a..=z → tokens 18..=43
pub const LETTER_A: u32 = 18;

pub const YES: u32 = 44;
pub const NO: u32 = 45;
pub const GT: u32 = 46;
pub const LT: u32 = 47;
pub const EVEN: u32 = 48;
pub const ODD: u32 = 49;
pub const OPEN: u32 = 50;
pub const CLOSE: u32 = 51;
pub const SEMI: u32 = 52;

/// total ids in use — must stay ≤ the smallest model vocab (64)
pub const VOCAB_USED: u32 = 53;

pub fn digit(d: u32) -> u32 {
    debug_assert!(d < 10);
    DIGIT0 + d
}

pub fn letter(i: u32) -> u32 {
    debug_assert!(i < 26);
    LETTER_A + i
}

/// Render token ids for debugging / logs.
pub fn detok(tokens: &[u32]) -> String {
    tokens
        .iter()
        .map(|&t| match t {
            PAD => "·".to_string(),
            BOS => "<s>".to_string(),
            EOS => "</s>".to_string(),
            SEP => "=".to_string(),
            QRY => "?".to_string(),
            PLUS => "+".to_string(),
            MINUS => "-".to_string(),
            TIMES => "*".to_string(),
            YES => "yes".to_string(),
            NO => "no".to_string(),
            GT => ">".to_string(),
            LT => "<".to_string(),
            EVEN => "even".to_string(),
            ODD => "odd".to_string(),
            OPEN => "(".to_string(),
            CLOSE => ")".to_string(),
            SEMI => ";".to_string(),
            t if (DIGIT0..DIGIT0 + 10).contains(&t) => {
                (t - DIGIT0).to_string()
            }
            t if (LETTER_A..LETTER_A + 26).contains(&t) => {
                char::from(b'a' + (t - LETTER_A) as u8).to_string()
            }
            t => format!("<{t}>"),
        })
        .collect::<Vec<_>>()
        .join(" ")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vocab_fits_tiny_model() {
        assert!(VOCAB_USED <= 64);
    }

    #[test]
    fn no_token_collisions() {
        let mut all = vec![PAD, BOS, EOS, SEP, QRY];
        all.extend((0..10).map(digit));
        all.extend([PLUS, MINUS, TIMES]);
        all.extend((0..26).map(letter));
        all.extend([YES, NO, GT, LT, EVEN, ODD, OPEN, CLOSE, SEMI]);
        let n = all.len();
        all.sort_unstable();
        all.dedup();
        assert_eq!(all.len(), n, "token ids collide");
        assert!(*all.last().unwrap() < VOCAB_USED);
    }

    #[test]
    fn detok_is_readable() {
        let s = detok(&[BOS, digit(3), PLUS, digit(4), SEP, digit(7), EOS]);
        assert_eq!(s, "<s> 3 + 4 = 7 </s>");
    }
}
