//! SFT sequence packing: `[BOS, prompt, answer, EOS, PAD…]` with the
//! loss mask covering only answer+EOS predictions (standard
//! instruction-tuning masking).

use anyhow::{ensure, Result};

use super::vocab::{BOS, EOS, PAD};
use super::Example;
use crate::util::rng::Rng;

/// One training batch in artifact ABI form; uploaded by name through
/// `ExecPlan::bind_batch`.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    /// Number of loss-bearing tokens.
    pub fn mask_count(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }
}

/// Packed length of an example: `BOS + prompt + answer + EOS`.
pub fn packed_len(ex: &Example) -> usize {
    2 + ex.prompt.len() + ex.answer.len()
}

/// Whether an example fits a row of length `seq` (the last packed
/// token is only ever predicted, never fed, so `seq + 1` is the cap).
pub fn fits(ex: &Example, seq: usize) -> bool {
    packed_len(ex) <= seq + 1
}

/// Pack one example into (tokens, targets, mask) rows of length `seq`.
///
/// Position t predicts token t+1; mask is 1 exactly where the predicted
/// token belongs to `answer ++ [EOS]`. Callers must pre-validate sizes
/// ([`fits`] / [`Batcher::new`]); an oversized example here is a
/// programming error and asserts.
pub fn pack_example(
    ex: &Example,
    seq: usize,
) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let mut full: Vec<u32> = Vec::with_capacity(seq + 1);
    full.push(BOS);
    full.extend_from_slice(&ex.prompt);
    let answer_start = full.len(); // first answer position in `full`
    full.extend_from_slice(&ex.answer);
    full.push(EOS);
    assert!(
        full.len() <= seq + 1,
        "example length {} exceeds seq {}",
        full.len(),
        seq
    );
    let mut tokens = vec![PAD as i32; seq];
    let mut targets = vec![PAD as i32; seq];
    let mut mask = vec![0.0f32; seq];
    for t in 0..seq {
        if t < full.len() {
            tokens[t] = full[t] as i32;
        }
        if t + 1 < full.len() {
            targets[t] = full[t + 1] as i32;
            // predicted token full[t+1] is loss-bearing iff it is part
            // of the answer span (answer tokens + the closing EOS)
            if t + 1 >= answer_start {
                mask[t] = 1.0;
            }
        }
    }
    (tokens, targets, mask)
}

/// Batches examples into fixed-shape artifact inputs, cycling the
/// dataset and reshuffling every epoch.
pub struct Batcher {
    examples: Vec<Example>,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    seed: u64,
    pub batch: usize,
    pub seq: usize,
}

impl Batcher {
    /// Validate and shuffle a training set. Every example must fit a
    /// `seq`-length row — a bad example is a typed error **here, at
    /// construction**, not an assert at step N deep inside
    /// [`Batcher::next_batch`].
    pub fn new(
        examples: Vec<Example>,
        batch: usize,
        seq: usize,
        seed: u64,
    ) -> Result<Self> {
        ensure!(batch >= 1, "batcher: batch size must be ≥ 1");
        ensure!(
            !examples.is_empty(),
            "batcher: empty training set (nothing to batch)"
        );
        for (i, ex) in examples.iter().enumerate() {
            ensure!(
                fits(ex, seq),
                "batcher: example {i} packs to {} tokens \
                 (BOS + {} prompt + {} answer + EOS), which exceeds \
                 the model's seq_len {seq}",
                packed_len(ex),
                ex.prompt.len(),
                ex.answer.len()
            );
        }
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..examples.len()).collect();
        rng.shuffle(&mut order);
        Ok(Batcher {
            examples,
            order,
            cursor: 0,
            rng,
            seed,
            batch,
            seq,
        })
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Split the dataset into `n` disjoint shard batchers for
    /// data-parallel training.
    ///
    /// Partitioning is round-robin over the **raw example order**
    /// (example `j` goes to shard `j % n`), so it depends only on the
    /// dataset and `n` — never on this batcher's shuffle state — and
    /// the remainder policy is defined: when `len % n != 0` the first
    /// `len % n` shards hold one extra example; every example lands in
    /// exactly one shard, none dropped, none duplicated. Each shard
    /// seeds its own RNG via [`rng::derive_stream`] from this
    /// batcher's seed, so shard shuffle streams are seed-stable and
    /// independent (no shared mutable RNG across workers).
    pub fn shard(&self, n: usize) -> Result<Vec<Batcher>> {
        ensure!(n >= 1, "batcher: shard count must be ≥ 1");
        ensure!(
            n <= self.examples.len(),
            "batcher: cannot split {} examples into {n} shards \
             (a shard would be empty)",
            self.examples.len()
        );
        (0..n)
            .map(|i| {
                let subset: Vec<Example> = self
                    .examples
                    .iter()
                    .enumerate()
                    .filter(|(j, _)| j % n == i)
                    .map(|(_, ex)| ex.clone())
                    .collect();
                Batcher::new(
                    subset,
                    self.batch,
                    self.seq,
                    crate::util::rng::derive_stream(
                        self.seed, i as u64, n as u64,
                    ),
                )
            })
            .collect()
    }

    /// Seed this batcher was built with (shard derivation input).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Next batch (wraps around with a reshuffle at epoch boundaries).
    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        let mut mask = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.rng.shuffle(&mut self.order);
            }
            let ex = &self.examples[self.order[self.cursor]];
            self.cursor += 1;
            let (t, y, m) = pack_example(ex, self.seq);
            tokens.extend(t);
            targets.extend(y);
            mask.extend(m);
        }
        Batch {
            tokens,
            targets,
            mask,
            batch: self.batch,
            seq: self.seq,
        }
    }

    /// Advance the draw state exactly as one [`Self::next_batch`]
    /// call would — same cursor walk, same epoch-boundary reshuffles —
    /// without packing any tensors. Checkpoint resume fast-forwards
    /// rebuilt shard batchers through the already-trained steps with
    /// this (the batcher state after step t is a pure function of the
    /// constructor inputs and the draw count), pinned bitwise by
    /// `skip_batch_matches_draw_and_discard` below.
    pub fn skip_batch(&mut self) {
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.rng.shuffle(&mut self.order);
            }
            self.cursor += 1;
        }
    }
}

// ------------------------------------------------------------- prefetch

/// Bounded batch prefetch: moves [`Batcher::next_batch`] packing off
/// the training thread into one worker that pre-packs "groups" (one
/// [`Batch`] per shard, in shard order) into a depth-bounded queue.
///
/// Determinism argument: the worker owns the intact `Batcher` state
/// machines and draws from them **in the exact order the synchronous
/// loop would** (group by group, shard 0..S within each group), so the
/// delivered byte sequence is identical to calling `next_batch` inline
/// — the queue changes *when* packing happens, never *what* is packed.
/// Pinned by `prefetched_groups_match_inline_draws_bytewise` below and
/// `tests/pipeline_parity.rs`.
pub struct BatchPrefetcher {
    rx: Option<std::sync::mpsc::Receiver<Result<Vec<Batch>>>>,
    worker: Option<std::thread::JoinHandle<Vec<Batcher>>>,
    remaining: usize,
    last_stall_nanos: u64,
}

impl BatchPrefetcher {
    /// Spawn the pack worker. `groups` is the total number of step
    /// groups the run will draw (the worker packs no more than that);
    /// `depth` bounds how far ahead it may run.
    pub fn new(
        batchers: Vec<Batcher>,
        groups: usize,
        depth: usize,
    ) -> Result<Self> {
        ensure!(
            !batchers.is_empty(),
            "prefetch: need at least one shard batcher"
        );
        ensure!(depth >= 1, "prefetch: queue depth must be ≥ 1");
        let (tx, rx) = std::sync::mpsc::sync_channel(depth);
        let worker = std::thread::Builder::new()
            .name("losia-prefetch".into())
            .spawn(move || {
                let mut batchers = batchers;
                for g in 0..groups {
                    // crash-safety fault site: an `error` fault flows
                    // through the queue as a typed error; a `panic`
                    // fault exercises the join-based containment in
                    // `next_group`
                    if let Err(e) =
                        crate::util::faultpoint::hit("prefetch-worker", g)
                    {
                        let _ = tx.send(Err(e));
                        break;
                    }
                    let group: Vec<Batch> = batchers
                        .iter_mut()
                        .map(Batcher::next_batch)
                        .collect();
                    if tx.send(Ok(group)).is_err() {
                        // consumer dropped the queue (early stop)
                        break;
                    }
                }
                batchers
            })?;
        Ok(BatchPrefetcher {
            rx: Some(rx),
            worker: Some(worker),
            remaining: groups,
            last_stall_nanos: 0,
        })
    }

    /// Batches this prefetcher has not yet handed out.
    pub fn remaining(&self) -> usize {
        self.remaining
    }

    /// The next group: one batch per shard, in shard order — exactly
    /// what the synchronous loop's per-step `next_batch` calls would
    /// have produced. Blocks (and records the exposed stall) when the
    /// worker has not packed that far ahead yet.
    pub fn next_group(&mut self) -> Result<Vec<Batch>> {
        ensure!(
            self.remaining > 0,
            "prefetch: all scheduled groups were already drawn"
        );
        let rx = self.rx.as_ref().expect("receiver lives until drop");
        let t0 = std::time::Instant::now();
        let group = match rx.recv() {
            Ok(Ok(g)) => g,
            Ok(Err(e)) => return Err(e),
            // channel closed without a result: join the worker so a
            // panic surfaces as the typed containment error instead
            // of a poisoned-channel mystery (and no thread leaks)
            Err(_) => return Err(self.worker_exit_error()),
        };
        self.last_stall_nanos = t0.elapsed().as_nanos() as u64;
        self.remaining -= 1;
        Ok(group)
    }

    /// The worker died before delivering: distinguish a panic (typed
    /// [`crate::util::error::TrainError::WorkerPanic`]) from a clean
    /// early exit. Always joins — the thread is gone either way.
    fn worker_exit_error(&mut self) -> anyhow::Error {
        match self.worker.take().map(|h| h.join()) {
            Some(Err(_)) => crate::util::error::TrainError::WorkerPanic {
                site: "prefetch-worker".to_string(),
            }
            .into(),
            _ => anyhow::anyhow!("prefetch: pack worker exited early"),
        }
    }

    /// Wall time the last [`Self::next_group`] spent blocked on the
    /// queue — the *exposed* share of batch packing.
    pub fn last_stall_nanos(&self) -> u64 {
        self.last_stall_nanos
    }

    /// Shut the worker down and recover the shard batchers. A worker
    /// that panicked has no batchers to return; that is warned, not
    /// swallowed (the panic itself already surfaced as a typed error
    /// from [`Self::next_group`]).
    pub fn into_batchers(mut self) -> Vec<Batcher> {
        self.rx.take(); // unblocks a worker mid-send
        match self.worker.take() {
            Some(h) => h.join().unwrap_or_else(|_| {
                crate::util::warn::warn(
                    "prefetch: pack worker panicked; shard batchers \
                     were lost",
                );
                Vec::new()
            }),
            None => Vec::new(),
        }
    }
}

impl Drop for BatchPrefetcher {
    fn drop(&mut self) {
        // receiver first: a worker blocked on a full queue sees the
        // send fail and exits, so the join below cannot deadlock
        self.rx.take();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::{digit, PLUS, SEP};

    fn ex() -> Example {
        Example {
            prompt: vec![digit(3), PLUS, digit(4), SEP],
            answer: vec![digit(7)],
        }
    }

    #[test]
    fn pack_shapes_and_mask() {
        let (t, y, m) = pack_example(&ex(), 12);
        assert_eq!(t.len(), 12);
        assert_eq!(y.len(), 12);
        assert_eq!(m.len(), 12);
        // full = BOS 3 + 4 = 7 EOS  (7 tokens)
        assert_eq!(t[0], BOS as i32);
        assert_eq!(y[0], digit(3) as i32);
        // answer "7" is predicted at position 4 (token SEP → 7)
        assert_eq!(y[4], digit(7) as i32);
        assert_eq!(m[4], 1.0);
        // EOS predicted at position 5
        assert_eq!(y[5], EOS as i32);
        assert_eq!(m[5], 1.0);
        // prompt predictions carry no loss
        assert_eq!(m[0], 0.0);
        assert_eq!(m[3], 0.0);
        // padding carries no loss
        assert_eq!(m[8], 0.0);
        // exactly answer+EOS = 2 loss tokens
        let total: f32 = m.iter().sum();
        assert_eq!(total, 2.0);
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let (t, y, _) = pack_example(&ex(), 12);
        for i in 0..6 {
            assert_eq!(y[i], t[i + 1], "shift mismatch at {i}");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds seq")]
    fn oversized_example_panics() {
        let big = Example {
            prompt: vec![digit(1); 30],
            answer: vec![digit(2)],
        };
        pack_example(&big, 16);
    }

    #[test]
    fn oversized_example_rejected_at_construction() {
        // bad data must fail when the batcher is built, not at step N
        let good = ex();
        let big = Example {
            prompt: vec![digit(1); 30],
            answer: vec![digit(2)],
        };
        let err =
            Batcher::new(vec![good, big], 2, 16, 0).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("example 1"), "{msg}");
        assert!(msg.contains("seq_len 16"), "{msg}");
        assert!(msg.contains("30 prompt"), "{msg}");
    }

    #[test]
    fn empty_set_rejected_at_construction() {
        assert!(Batcher::new(vec![], 2, 8, 0).is_err());
    }

    #[test]
    fn fits_matches_pack_boundary() {
        // exactly seq+1 packed tokens is the largest packable example
        let ex = Example {
            prompt: vec![digit(1); 6],
            answer: vec![digit(2)],
        };
        assert_eq!(packed_len(&ex), 9); // BOS + 6 + 1 + EOS
        assert!(fits(&ex, 8));
        assert!(!fits(&ex, 7));
        let (t, _, m) = pack_example(&ex, 8);
        assert_eq!(t.len(), 8);
        assert!(m.iter().sum::<f32>() > 0.0);
    }

    #[test]
    fn batcher_cycles_and_reshuffles() {
        let exs: Vec<Example> = (0..5)
            .map(|i| Example {
                prompt: vec![digit(i as u32), SEP],
                answer: vec![digit(i as u32)],
            })
            .collect();
        let mut b = Batcher::new(exs, 2, 8, 0).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..10 {
            let batch = b.next_batch();
            assert_eq!(batch.tokens.len(), 16);
            for row in 0..2 {
                seen.insert(batch.tokens[row * 8 + 1]);
            }
        }
        // all five examples appear across 20 draws
        assert_eq!(seen.len(), 5);
    }

    fn tagged(n: usize) -> Vec<Example> {
        // each example's prompt[0] identifies it, so shard membership
        // can be read back out of packed batches
        (0..n)
            .map(|i| Example {
                prompt: vec![digit(i as u32 % 10), SEP],
                answer: vec![digit(i as u32 % 10)],
            })
            .collect()
    }

    #[test]
    fn shard_remainder_is_assigned_not_dropped() {
        // 7 examples over 2 shards with batch 2: 7 % (2 × 2) != 0 —
        // the remainder must land in a defined shard, never vanish
        let b = Batcher::new(tagged(7), 2, 8, 9).unwrap();
        let shards = b.shard(2).unwrap();
        assert_eq!(shards[0].len(), 4); // examples 0 2 4 6
        assert_eq!(shards[1].len(), 3); // examples 1 3 5
        assert_eq!(shards[0].len() + shards[1].len(), 7);
        // round-robin membership: tags are disjoint and cover all 7
        let tags = |s: &Batcher| -> std::collections::BTreeSet<u32> {
            s.examples.iter().map(|e| e.prompt[0]).collect()
        };
        let t0 = tags(&shards[0]);
        let t1 = tags(&shards[1]);
        assert!(t0.is_disjoint(&t1));
        assert_eq!(t0.union(&t1).count(), 7);
        // and an epoch of draws from each shard reaches every member
        let mut seen = std::collections::BTreeSet::new();
        for mut s in shards {
            for _ in 0..2 {
                let batch = s.next_batch();
                for row in 0..batch.batch {
                    seen.insert(batch.tokens[row * batch.seq + 1]);
                }
            }
        }
        assert_eq!(seen.len(), 7, "an example was dropped: {seen:?}");
    }

    #[test]
    fn shard_streams_are_seed_stable() {
        let draws = |seed: u64| -> Vec<Vec<i32>> {
            let b = Batcher::new(tagged(8), 2, 8, seed).unwrap();
            b.shard(2)
                .unwrap()
                .into_iter()
                .map(|mut s| {
                    (0..4).flat_map(|_| s.next_batch().tokens).collect()
                })
                .collect()
        };
        // same seed → identical shard streams; the two shards differ
        let a = draws(5);
        let b = draws(5);
        assert_eq!(a, b);
        assert_ne!(a[0], a[1]);
        // shard iteration never consults the parent's shuffle state:
        // draining the parent first must not change the shard streams
        let mut parent = Batcher::new(tagged(8), 2, 8, 5).unwrap();
        for _ in 0..3 {
            parent.next_batch();
        }
        let after: Vec<Vec<i32>> = parent
            .shard(2)
            .unwrap()
            .into_iter()
            .map(|mut s| {
                (0..4).flat_map(|_| s.next_batch().tokens).collect()
            })
            .collect();
        assert_eq!(a, after);
    }

    #[test]
    fn shard_bounds_are_checked() {
        let b = Batcher::new(tagged(3), 1, 8, 0).unwrap();
        assert!(b.shard(0).is_err());
        assert!(b.shard(4).is_err(), "empty shard must be rejected");
        assert_eq!(b.shard(3).unwrap().len(), 3);
    }

    fn batch_bytes(b: &Batch) -> (Vec<i32>, Vec<i32>, Vec<u32>) {
        (
            b.tokens.clone(),
            b.targets.clone(),
            b.mask.iter().map(|m| m.to_bits()).collect(),
        )
    }

    #[test]
    fn prefetched_groups_match_inline_draws_bytewise() {
        let mk = || {
            Batcher::new(tagged(8), 2, 8, 5)
                .unwrap()
                .shard(2)
                .unwrap()
        };
        // inline reference: per step, shard 0 then shard 1
        let mut inline = mk();
        let mut want = Vec::new();
        for _ in 0..6 {
            for s in inline.iter_mut() {
                want.push(batch_bytes(&s.next_batch()));
            }
        }
        let mut pf = BatchPrefetcher::new(mk(), 6, 2).unwrap();
        let mut got = Vec::new();
        for _ in 0..6 {
            for b in pf.next_group().unwrap() {
                got.push(batch_bytes(&b));
            }
        }
        assert_eq!(want, got, "prefetch reordered or altered batches");
        assert!(pf.next_group().is_err(), "over-draw must fail loudly");
    }

    #[test]
    fn dropping_a_prefetcher_mid_run_does_not_hang() {
        let b = Batcher::new(tagged(8), 2, 8, 1).unwrap();
        let mut pf = BatchPrefetcher::new(vec![b], 100, 1).unwrap();
        pf.next_group().unwrap();
        drop(pf); // worker is blocked on the full queue; must exit
    }

    #[test]
    fn into_batchers_recovers_the_shards() {
        let b = Batcher::new(tagged(6), 2, 8, 1).unwrap();
        let pf = BatchPrefetcher::new(vec![b], 3, 2).unwrap();
        let shards = pf.into_batchers();
        assert_eq!(shards.len(), 1);
    }

    #[test]
    fn skip_batch_matches_draw_and_discard() {
        // skipping N batches must leave the state machine bitwise
        // identical to drawing-and-discarding N batches — including
        // across epoch-boundary reshuffles (7 examples, batch 2: the
        // boundary falls mid-batch)
        for skips in [0usize, 1, 3, 7, 11] {
            let mut drawn = Batcher::new(tagged(7), 2, 8, 3).unwrap();
            let mut skipped = Batcher::new(tagged(7), 2, 8, 3).unwrap();
            for _ in 0..skips {
                let _ = drawn.next_batch();
                skipped.skip_batch();
            }
            for _ in 0..4 {
                assert_eq!(
                    batch_bytes(&drawn.next_batch()),
                    batch_bytes(&skipped.next_batch()),
                    "divergence after {skips} skips"
                );
            }
        }
    }

    #[test]
    fn prefetch_error_fault_flows_through_the_queue() {
        let _guard = crate::util::faultpoint::ENV_LOCK.lock().unwrap();
        std::env::set_var(
            crate::util::faultpoint::ENV,
            "prefetch-worker@1:error",
        );
        let b = Batcher::new(tagged(8), 2, 8, 1).unwrap();
        let mut pf = BatchPrefetcher::new(vec![b], 4, 1).unwrap();
        pf.next_group().unwrap(); // group 0 is clean
        let err = pf.next_group().unwrap_err();
        std::env::remove_var(crate::util::faultpoint::ENV);
        match err.downcast_ref::<crate::util::error::TrainError>() {
            Some(crate::util::error::TrainError::FaultInjected {
                site,
                step,
            }) => {
                assert_eq!(site, "prefetch-worker");
                assert_eq!(*step, 1);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn prefetch_worker_panic_is_contained_and_typed() {
        let _guard = crate::util::faultpoint::ENV_LOCK
            .lock()
            .unwrap_or_else(|e| e.into_inner());
        std::env::set_var(
            crate::util::faultpoint::ENV,
            "prefetch-worker@0:panic",
        );
        let b = Batcher::new(tagged(8), 2, 8, 1).unwrap();
        let mut pf = BatchPrefetcher::new(vec![b], 4, 1).unwrap();
        let err = pf.next_group().unwrap_err();
        match err.downcast_ref::<crate::util::error::TrainError>() {
            Some(crate::util::error::TrainError::WorkerPanic {
                site,
            }) => assert_eq!(site, "prefetch-worker"),
            other => panic!("wrong variant: {other:?}"),
        }
        // tearing down a prefetcher whose worker panicked before it was
        // ever polled: the batchers are gone — warned, not fatal
        let b = Batcher::new(tagged(8), 2, 8, 1).unwrap();
        let pf = BatchPrefetcher::new(vec![b], 4, 1).unwrap();
        let cap = crate::util::warn::capture();
        assert!(pf.into_batchers().is_empty());
        std::env::remove_var(crate::util::faultpoint::ENV);
        let warns = cap.drain();
        assert!(
            warns.iter().any(|w| w.contains("panicked")),
            "expected a lost-batchers warning, got {warns:?}"
        );
    }

    #[test]
    fn batch_tensors_have_abi_shapes() {
        let mut b = Batcher::new(vec![ex()], 3, 10, 1).unwrap();
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), batch.batch * batch.seq);
        assert_eq!(batch.targets.len(), batch.batch * batch.seq);
        assert_eq!(batch.mask.len(), batch.batch * batch.seq);
        assert!(batch.mask_count() > 0);
    }
}
