//! SFT sequence packing: `[BOS, prompt, answer, EOS, PAD…]` with the
//! loss mask covering only answer+EOS predictions (standard
//! instruction-tuning masking).

use anyhow::{ensure, Result};

use super::vocab::{BOS, EOS, PAD};
use super::Example;
use crate::util::rng::Rng;

/// One training batch in artifact ABI form; uploaded by name through
/// `ExecPlan::bind_batch`.
#[derive(Debug, Clone)]
pub struct Batch {
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
    pub mask: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
}

impl Batch {
    /// Number of loss-bearing tokens.
    pub fn mask_count(&self) -> usize {
        self.mask.iter().filter(|&&m| m > 0.0).count()
    }
}

/// Packed length of an example: `BOS + prompt + answer + EOS`.
pub fn packed_len(ex: &Example) -> usize {
    2 + ex.prompt.len() + ex.answer.len()
}

/// Whether an example fits a row of length `seq` (the last packed
/// token is only ever predicted, never fed, so `seq + 1` is the cap).
pub fn fits(ex: &Example, seq: usize) -> bool {
    packed_len(ex) <= seq + 1
}

/// Pack one example into (tokens, targets, mask) rows of length `seq`.
///
/// Position t predicts token t+1; mask is 1 exactly where the predicted
/// token belongs to `answer ++ [EOS]`. Callers must pre-validate sizes
/// ([`fits`] / [`Batcher::new`]); an oversized example here is a
/// programming error and asserts.
pub fn pack_example(
    ex: &Example,
    seq: usize,
) -> (Vec<i32>, Vec<i32>, Vec<f32>) {
    let mut full: Vec<u32> = Vec::with_capacity(seq + 1);
    full.push(BOS);
    full.extend_from_slice(&ex.prompt);
    let answer_start = full.len(); // first answer position in `full`
    full.extend_from_slice(&ex.answer);
    full.push(EOS);
    assert!(
        full.len() <= seq + 1,
        "example length {} exceeds seq {}",
        full.len(),
        seq
    );
    let mut tokens = vec![PAD as i32; seq];
    let mut targets = vec![PAD as i32; seq];
    let mut mask = vec![0.0f32; seq];
    for t in 0..seq {
        if t < full.len() {
            tokens[t] = full[t] as i32;
        }
        if t + 1 < full.len() {
            targets[t] = full[t + 1] as i32;
            // predicted token full[t+1] is loss-bearing iff it is part
            // of the answer span (answer tokens + the closing EOS)
            if t + 1 >= answer_start {
                mask[t] = 1.0;
            }
        }
    }
    (tokens, targets, mask)
}

/// Batches examples into fixed-shape artifact inputs, cycling the
/// dataset and reshuffling every epoch.
pub struct Batcher {
    examples: Vec<Example>,
    order: Vec<usize>,
    cursor: usize,
    rng: Rng,
    pub batch: usize,
    pub seq: usize,
}

impl Batcher {
    /// Validate and shuffle a training set. Every example must fit a
    /// `seq`-length row — a bad example is a typed error **here, at
    /// construction**, not an assert at step N deep inside
    /// [`Batcher::next_batch`].
    pub fn new(
        examples: Vec<Example>,
        batch: usize,
        seq: usize,
        seed: u64,
    ) -> Result<Self> {
        ensure!(batch >= 1, "batcher: batch size must be ≥ 1");
        ensure!(
            !examples.is_empty(),
            "batcher: empty training set (nothing to batch)"
        );
        for (i, ex) in examples.iter().enumerate() {
            ensure!(
                fits(ex, seq),
                "batcher: example {i} packs to {} tokens \
                 (BOS + {} prompt + {} answer + EOS), which exceeds \
                 the model's seq_len {seq}",
                packed_len(ex),
                ex.prompt.len(),
                ex.answer.len()
            );
        }
        let mut rng = Rng::new(seed);
        let mut order: Vec<usize> = (0..examples.len()).collect();
        rng.shuffle(&mut order);
        Ok(Batcher {
            examples,
            order,
            cursor: 0,
            rng,
            batch,
            seq,
        })
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Next batch (wraps around with a reshuffle at epoch boundaries).
    pub fn next_batch(&mut self) -> Batch {
        let mut tokens = Vec::with_capacity(self.batch * self.seq);
        let mut targets = Vec::with_capacity(self.batch * self.seq);
        let mut mask = Vec::with_capacity(self.batch * self.seq);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.cursor = 0;
                self.rng.shuffle(&mut self.order);
            }
            let ex = &self.examples[self.order[self.cursor]];
            self.cursor += 1;
            let (t, y, m) = pack_example(ex, self.seq);
            tokens.extend(t);
            targets.extend(y);
            mask.extend(m);
        }
        Batch {
            tokens,
            targets,
            mask,
            batch: self.batch,
            seq: self.seq,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::vocab::{digit, PLUS, SEP};

    fn ex() -> Example {
        Example {
            prompt: vec![digit(3), PLUS, digit(4), SEP],
            answer: vec![digit(7)],
        }
    }

    #[test]
    fn pack_shapes_and_mask() {
        let (t, y, m) = pack_example(&ex(), 12);
        assert_eq!(t.len(), 12);
        assert_eq!(y.len(), 12);
        assert_eq!(m.len(), 12);
        // full = BOS 3 + 4 = 7 EOS  (7 tokens)
        assert_eq!(t[0], BOS as i32);
        assert_eq!(y[0], digit(3) as i32);
        // answer "7" is predicted at position 4 (token SEP → 7)
        assert_eq!(y[4], digit(7) as i32);
        assert_eq!(m[4], 1.0);
        // EOS predicted at position 5
        assert_eq!(y[5], EOS as i32);
        assert_eq!(m[5], 1.0);
        // prompt predictions carry no loss
        assert_eq!(m[0], 0.0);
        assert_eq!(m[3], 0.0);
        // padding carries no loss
        assert_eq!(m[8], 0.0);
        // exactly answer+EOS = 2 loss tokens
        let total: f32 = m.iter().sum();
        assert_eq!(total, 2.0);
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let (t, y, _) = pack_example(&ex(), 12);
        for i in 0..6 {
            assert_eq!(y[i], t[i + 1], "shift mismatch at {i}");
        }
    }

    #[test]
    #[should_panic(expected = "exceeds seq")]
    fn oversized_example_panics() {
        let big = Example {
            prompt: vec![digit(1); 30],
            answer: vec![digit(2)],
        };
        pack_example(&big, 16);
    }

    #[test]
    fn oversized_example_rejected_at_construction() {
        // bad data must fail when the batcher is built, not at step N
        let good = ex();
        let big = Example {
            prompt: vec![digit(1); 30],
            answer: vec![digit(2)],
        };
        let err =
            Batcher::new(vec![good, big], 2, 16, 0).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("example 1"), "{msg}");
        assert!(msg.contains("seq_len 16"), "{msg}");
        assert!(msg.contains("30 prompt"), "{msg}");
    }

    #[test]
    fn empty_set_rejected_at_construction() {
        assert!(Batcher::new(vec![], 2, 8, 0).is_err());
    }

    #[test]
    fn fits_matches_pack_boundary() {
        // exactly seq+1 packed tokens is the largest packable example
        let ex = Example {
            prompt: vec![digit(1); 6],
            answer: vec![digit(2)],
        };
        assert_eq!(packed_len(&ex), 9); // BOS + 6 + 1 + EOS
        assert!(fits(&ex, 8));
        assert!(!fits(&ex, 7));
        let (t, _, m) = pack_example(&ex, 8);
        assert_eq!(t.len(), 8);
        assert!(m.iter().sum::<f32>() > 0.0);
    }

    #[test]
    fn batcher_cycles_and_reshuffles() {
        let exs: Vec<Example> = (0..5)
            .map(|i| Example {
                prompt: vec![digit(i as u32), SEP],
                answer: vec![digit(i as u32)],
            })
            .collect();
        let mut b = Batcher::new(exs, 2, 8, 0).unwrap();
        let mut seen = std::collections::BTreeSet::new();
        for _ in 0..10 {
            let batch = b.next_batch();
            assert_eq!(batch.tokens.len(), 16);
            for row in 0..2 {
                seen.insert(batch.tokens[row * 8 + 1]);
            }
        }
        // all five examples appear across 20 draws
        assert_eq!(seen.len(), 5);
    }

    #[test]
    fn batch_tensors_have_abi_shapes() {
        let mut b = Batcher::new(vec![ex()], 3, 10, 1).unwrap();
        let batch = b.next_batch();
        assert_eq!(batch.tokens.len(), batch.batch * batch.seq);
        assert_eq!(batch.targets.len(), batch.batch * batch.seq);
        assert_eq!(batch.mask.len(), batch.batch * batch.seq);
        assert!(batch.mask_count() > 0);
    }
}
