//! Domain-specialization tasks (Table 1 analogues).
//!
//! * [`ModMath`]  — modular arithmetic word problems (GSM8K analogue):
//!   `a OP b = c (mod 10)`, exact-answer generation.
//! * [`StackEval`] — postfix program evaluation (MBPP analogue):
//!   `x y op z op' = r (mod 10)`, exact-answer generation; Pass@k via
//!   repeated temperature sampling in the eval harness.
//! * [`KvFacts`]  — entity–attribute knowledge recall with four
//!   categories (MMLU analogue): trained facts, multiple-choice or
//!   generative queries.

use super::vocab::*;
use super::{EvalItem, Example, Task};
use crate::util::rng::Rng;

// ---------------------------------------------------------------------
// ModMath
// ---------------------------------------------------------------------

/// `a op b (mod 10)` with single-digit operands: learnable from
/// scratch within a few hundred steps, with a clear accuracy signal.
pub struct ModMath;

fn mod_op(a: u32, op: u32, b: u32) -> u32 {
    match op {
        PLUS => (a + b) % 10,
        MINUS => (10 + a - b) % 10,
        TIMES => (a * b) % 10,
        _ => unreachable!(),
    }
}

impl ModMath {
    fn sample(&self, rng: &mut Rng) -> (Vec<u32>, u32) {
        let a = rng.below(10) as u32;
        let b = rng.below(10) as u32;
        let op = [PLUS, MINUS, TIMES][rng.below(3)];
        let c = mod_op(a, op, b);
        (vec![digit(a), op, digit(b), SEP], c)
    }
}

impl Task for ModMath {
    fn name(&self) -> &'static str {
        "modmath"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let (prompt, c) = self.sample(rng);
        Example {
            prompt,
            answer: vec![digit(c)],
        }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let (prompt, c) = self.sample(rng);
        // options = all 10 digits, exact-match generation also works
        let options: Vec<Vec<u32>> =
            (0..10).map(|d| vec![digit(d)]).collect();
        EvalItem {
            prompt,
            options,
            correct: c as usize,
            category: "math",
        }
    }
}

// ---------------------------------------------------------------------
// StackEval
// ---------------------------------------------------------------------

/// Postfix expression evaluation over Z₁₀ — a tiny "program execution"
/// task: `d1 d2 op1 d3 op2 =` evaluates `((d1 op1 d2) op2 d3)`.
pub struct StackEval;

impl StackEval {
    fn sample(&self, rng: &mut Rng) -> (Vec<u32>, u32) {
        let d1 = rng.below(10) as u32;
        let d2 = rng.below(10) as u32;
        let d3 = rng.below(10) as u32;
        let op1 = [PLUS, MINUS, TIMES][rng.below(3)];
        let op2 = [PLUS, MINUS, TIMES][rng.below(3)];
        let r1 = mod_op(d1, op1, d2);
        let r = mod_op(r1, op2, d3);
        (
            vec![digit(d1), digit(d2), op1, digit(d3), op2, SEP],
            r,
        )
    }
}

impl Task for StackEval {
    fn name(&self) -> &'static str {
        "stack"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let (prompt, r) = self.sample(rng);
        Example {
            prompt,
            answer: vec![digit(r)],
        }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let (prompt, r) = self.sample(rng);
        let options: Vec<Vec<u32>> =
            (0..10).map(|d| vec![digit(d)]).collect();
        EvalItem {
            prompt,
            options,
            correct: r as usize,
            category: "code",
        }
    }
}

// ---------------------------------------------------------------------
// KvFacts
// ---------------------------------------------------------------------

/// Knowledge recall over a fixed fact table: entity (letter pair) ×
/// attribute (letter) → value (letter). Four attribute groups act as
/// the MMLU category breakdown. Training asserts facts; evaluation
/// queries them with distractor options.
pub struct KvFacts {
    /// facts[(entity, attr)] = value, as flat vectors
    entities: usize,
    attrs: usize,
    table: Vec<u32>,
}

pub const KV_CATEGORIES: [&str; 4] =
    ["humanities", "stem", "social", "other"];

impl KvFacts {
    pub fn new(entities: usize, attrs: usize, seed: u64) -> Self {
        assert!(entities <= 26 * 26 && attrs <= 8);
        let mut rng = Rng::new(seed);
        let table = (0..entities * attrs)
            .map(|_| letter(rng.below(26) as u32))
            .collect();
        KvFacts {
            entities,
            attrs,
            table,
        }
    }

    fn fact(&self, e: usize, a: usize) -> (Vec<u32>, u32) {
        let e1 = letter((e / 26) as u32);
        let e2 = letter((e % 26) as u32);
        let attr = letter(a as u32);
        let value = self.table[e * self.attrs + a];
        (vec![e1, e2, attr, SEP], value)
    }

    fn category(&self, a: usize) -> &'static str {
        KV_CATEGORIES[a % KV_CATEGORIES.len()]
    }
}

impl Task for KvFacts {
    fn name(&self) -> &'static str {
        "kvfacts"
    }

    fn gen_train(&self, rng: &mut Rng) -> Example {
        let e = rng.below(self.entities);
        let a = rng.below(self.attrs);
        let (prompt, value) = self.fact(e, a);
        Example {
            prompt,
            answer: vec![value],
        }
    }

    fn gen_eval(&self, rng: &mut Rng) -> EvalItem {
        let e = rng.below(self.entities);
        let a = rng.below(self.attrs);
        let (prompt, value) = self.fact(e, a);
        // 4-way multiple choice with distinct distractor letters
        let mut options = vec![value];
        while options.len() < 4 {
            let cand = letter(rng.below(26) as u32);
            if !options.contains(&cand) {
                options.push(cand);
            }
        }
        // shuffle, remember where the right answer lands
        let mut order: Vec<usize> = (0..4).collect();
        rng.shuffle(&mut order);
        let correct = order.iter().position(|&i| i == 0).unwrap();
        let options: Vec<Vec<u32>> =
            order.iter().map(|&i| vec![options[i]]).collect();
        EvalItem {
            prompt,
            options,
            correct,
            category: self.category(a),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn modmath_answers_are_correct() {
        check("modmath: answer = a op b mod 10", 100, |g| {
            let mut rng = g.rng();
            let ex = ModMath.gen_train(&mut rng);
            let a = ex.prompt[0] - DIGIT0;
            let op = ex.prompt[1];
            let b = ex.prompt[2] - DIGIT0;
            assert_eq!(ex.prompt[3], SEP);
            assert_eq!(ex.answer, vec![digit(mod_op(a, op, b))]);
        });
    }

    #[test]
    fn stack_matches_manual_evaluation() {
        check("stack: postfix eval", 100, |g| {
            let mut rng = g.rng();
            let ex = StackEval.gen_train(&mut rng);
            let d1 = ex.prompt[0] - DIGIT0;
            let d2 = ex.prompt[1] - DIGIT0;
            let op1 = ex.prompt[2];
            let d3 = ex.prompt[3] - DIGIT0;
            let op2 = ex.prompt[4];
            let want = mod_op(mod_op(d1, op1, d2), op2, d3);
            assert_eq!(ex.answer, vec![digit(want)]);
        });
    }

    #[test]
    fn kvfacts_consistent_between_train_and_eval() {
        let kv = KvFacts::new(10, 4, 7);
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let item = kv.gen_eval(&mut rng);
            // re-derive the fact from the prompt
            let e = ((item.prompt[0] - LETTER_A) * 26
                + (item.prompt[1] - LETTER_A)) as usize;
            let a = (item.prompt[2] - LETTER_A) as usize;
            let want = kv.table[e * kv.attrs + a];
            assert_eq!(item.options[item.correct], vec![want]);
        }
    }

    #[test]
    fn kvfacts_options_distinct() {
        let kv = KvFacts::new(16, 4, 1);
        let mut rng = Rng::new(9);
        for _ in 0..50 {
            let item = kv.gen_eval(&mut rng);
            let mut opts = item.options.clone();
            opts.sort();
            opts.dedup();
            assert_eq!(opts.len(), 4);
        }
    }

    #[test]
    fn kvfacts_deterministic_by_seed() {
        let a = KvFacts::new(8, 4, 5);
        let b = KvFacts::new(8, 4, 5);
        assert_eq!(a.table, b.table);
        let c = KvFacts::new(8, 4, 6);
        assert_ne!(a.table, c.table);
    }

    #[test]
    fn categories_cover_all_four() {
        let kv = KvFacts::new(8, 4, 5);
        let cats: Vec<&str> =
            (0..4).map(|a| kv.category(a)).collect();
        assert_eq!(cats, KV_CATEGORIES.to_vec());
    }
}
