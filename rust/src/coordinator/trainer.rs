//! The training loop: schedules steps, drives the method driver over
//! batches, and reports telemetry into an observer set.
//!
//! The trainer owns no telemetry of its own — loss curves, per-step
//! wall time, and subnet-selection events all flow through
//! [`crate::session::observer::ObserverSet`], so benches and the CLI
//! compose metrics instead of forking the loop. Most callers should
//! reach this through [`crate::session::Session`], which also owns
//! runtime loading, task construction, and report assembly.

use anyhow::Result;
use std::time::Instant;

use crate::config::TrainConfig;
use crate::coordinator::rewarm::LrSchedule;
use crate::coordinator::state::ModelState;
use crate::data::Batcher;
use crate::methods::{build_driver, Driver};
use crate::runtime::Runtime;
use crate::session::observer::ObserverSet;

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub tc: TrainConfig,
    pub schedule: LrSchedule,
    pub driver: Box<dyn Driver>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, tc: TrainConfig) -> Result<Self> {
        let schedule =
            LrSchedule::new(tc.lr, tc.steps, tc.warmup_ratio);
        let mut driver = build_driver(rt, &tc)?;
        // LoSiA needs the global warmup horizon for Eq. 8's Cond;
        // a no-op for every other driver.
        driver.set_warmup(schedule.warmup_steps);
        Ok(Trainer {
            rt,
            tc,
            schedule,
            driver,
        })
    }

    /// Run `tc.steps` optimization steps over the batcher, reporting
    /// step / relocalize / finalize events into `obs`.
    pub fn train(
        &mut self,
        state: &mut ModelState,
        batcher: &mut Batcher,
        obs: &mut ObserverSet,
    ) -> Result<()> {
        let tokens = self.rt.cfg.tokens_per_step();
        self.driver.prepare(state)?;
        // initial subnet selections installed at construction time
        for ev in self.driver.drain_events() {
            obs.emit_relocalize(&ev);
        }
        for t in 0..self.tc.steps {
            let batch = batcher.next_batch();
            let lr = self.schedule.lr(t);
            let t0 = Instant::now();
            let loss = self.driver.step(state, &batch, t, lr)?;
            let secs = t0.elapsed().as_secs_f64();
            for ev in self.driver.drain_events() {
                obs.emit_relocalize(&ev);
            }
            obs.emit_step(t, loss, lr, secs, tokens);
            if self.tc.log_every > 0 && t % self.tc.log_every == 0 {
                eprintln!(
                    "[train:{}] step {t:>5} loss {loss:.4} lr {lr:.2e}",
                    self.driver.method().name(),
                );
            }
        }
        // merge external adapters into the backbone (paper protocol:
        // LoRA modules are merged before evaluation / the next task)
        self.driver.finalize(state)?;
        obs.emit_finalize(self.tc.steps);
        Ok(())
    }
}
