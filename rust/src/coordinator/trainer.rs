//! The training loop: schedules steps, drives the method driver over
//! batches, and reports telemetry into an observer set.
//!
//! The trainer owns no telemetry of its own — loss curves, per-step
//! wall time, subnet-selection events, and per-artifact executor
//! stats all flow through
//! [`crate::session::observer::ObserverSet`], so benches and the CLI
//! compose metrics instead of forking the loop. Executor profiling
//! works by snapshotting the runtime's per-artifact counters around
//! each step and emitting the deltas as
//! [`crate::session::observer::ExecEvent`]s — including the upload
//! split that distinguishes static (weights) from per-step (batch)
//! host→device traffic. Most callers should reach this through
//! [`crate::session::Session`], which also owns runtime loading, task
//! construction, and report assembly.

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::rewarm::LrSchedule;
use crate::coordinator::state::ModelState;
use crate::data::{Batch, Batcher};
use crate::methods::{build_driver, Driver};
use crate::runtime::dp::{self, DpConfig};
use crate::runtime::{ExecSnapshot, Runtime};
use crate::session::observer::{DpEvent, ExecEvent, ObserverSet};

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub tc: TrainConfig,
    pub schedule: LrSchedule,
    pub driver: Box<dyn Driver>,
}

/// Tracks runtime exec counters between emissions and turns the
/// movement into `ExecEvent`s.
struct ExecTracker {
    prev: BTreeMap<String, ExecSnapshot>,
}

impl ExecTracker {
    fn new(rt: &Runtime) -> Self {
        ExecTracker {
            prev: rt.exec_snapshots().into_iter().collect(),
        }
    }

    fn emit(&mut self, rt: &Runtime, step: usize, obs: &mut ObserverSet) {
        for (artifact, snap) in rt.exec_snapshots() {
            let base =
                self.prev.get(&artifact).copied().unwrap_or_default();
            let d = snap.delta_since(&base);
            if d.calls > 0
                || d.static_uploads > 0
                || d.step_uploads > 0
                || d.downloads > 0
            {
                obs.emit_exec(&ExecEvent {
                    step,
                    artifact: artifact.clone(),
                    calls: d.calls,
                    secs: d.total_secs(),
                    upload_secs: d.upload_secs(),
                    download_secs: d.download_secs(),
                    static_uploads: d.static_uploads,
                    step_uploads: d.step_uploads,
                    downloads: d.downloads,
                    download_bytes: d.download_bytes,
                });
            }
            self.prev.insert(artifact, snap);
        }
    }
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, tc: TrainConfig) -> Result<Self> {
        let schedule =
            LrSchedule::new(tc.lr, tc.steps, tc.warmup_ratio);
        let mut driver = build_driver(rt, &tc)?;
        // LoSiA needs the global warmup horizon for Eq. 8's Cond;
        // a no-op for every other driver.
        driver.set_warmup(schedule.warmup_steps);
        Ok(Trainer {
            rt,
            tc,
            schedule,
            driver,
        })
    }

    /// Run `tc.steps` optimization steps over the batcher, reporting
    /// step / relocalize / exec / dp / finalize events into `obs`.
    ///
    /// With `DpConfig::enabled()` the batcher is split once into
    /// `shards` seed-stable sub-streams; each step draws one batch per
    /// shard, runs the driver's gradient phase across the plan
    /// replicas, folds the frames with the fixed-order tree reduce,
    /// and applies the update once. Otherwise the legacy single-batch
    /// loop runs — which is the same code path with one shard.
    pub fn train(
        &mut self,
        state: &mut ModelState,
        batcher: &mut Batcher,
        obs: &mut ObserverSet,
    ) -> Result<()> {
        let dp_cfg = DpConfig::resolve(&self.tc);
        let tokens = self.rt.cfg.tokens_per_step()
            * if dp_cfg.enabled() { dp_cfg.shards } else { 1 };
        let mut shard_batchers: Vec<Batcher> = if dp_cfg.enabled() {
            batcher.shard(dp_cfg.shards)?
        } else {
            Vec::new()
        };
        let mut exec = ExecTracker::new(self.rt);
        self.driver.prepare(state)?;
        // initial subnet selections installed at construction time
        for ev in self.driver.drain_events() {
            obs.emit_relocalize(&ev);
        }
        // prepare-time uploads (LoRA/LoSiA-Pro bind their static
        // parameter set here) are attributed to step 0
        exec.emit(self.rt, 0, obs);
        for t in 0..self.tc.steps {
            let lr = self.schedule.lr(t);
            let t0 = Instant::now();
            let loss = if dp_cfg.enabled() {
                let batches: Vec<Batch> = shard_batchers
                    .iter_mut()
                    .map(|b| b.next_batch())
                    .collect();
                let sharded = self
                    .driver
                    .grad_frames_sharded(state, &batches, t)?;
                let workers =
                    sharded.worker_nanos.len().max(1);
                let worker_nanos = sharded.worker_nanos.clone();
                let r0 = Instant::now();
                let (reduced, frame_bytes) =
                    dp::reduce(sharded.shards)?;
                let reduce_nanos = r0.elapsed().as_nanos() as u64;
                obs.emit_dp(&DpEvent {
                    step: t,
                    workers,
                    shards: dp_cfg.shards,
                    reduce_nanos,
                    frame_bytes,
                    worker_nanos,
                });
                self.driver.apply_frames(state, reduced, t, lr)?
            } else {
                let batch = batcher.next_batch();
                self.driver.step(state, &batch, t, lr)?
            };
            let secs = t0.elapsed().as_secs_f64();
            for ev in self.driver.drain_events() {
                obs.emit_relocalize(&ev);
            }
            exec.emit(self.rt, t, obs);
            obs.emit_step(t, loss, lr, secs, tokens);
            if self.tc.log_every > 0 && t % self.tc.log_every == 0 {
                eprintln!(
                    "[train:{}] step {t:>5} loss {loss:.4} lr {lr:.2e}",
                    self.driver.method().name(),
                );
            }
        }
        // merge external adapters into the backbone (paper protocol:
        // LoRA modules are merged before evaluation / the next task)
        self.driver.finalize(state)?;
        exec.emit(self.rt, self.tc.steps, obs);
        obs.emit_finalize(self.tc.steps);
        Ok(())
    }
}
