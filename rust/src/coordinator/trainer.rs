//! The training loop: schedules, drives the method driver over
//! batches, and records losses + per-step wall time.

use anyhow::Result;
use std::time::Instant;

use crate::config::TrainConfig;
use crate::coordinator::rewarm::LrSchedule;
use crate::coordinator::state::ModelState;
use crate::data::Batcher;
use crate::methods::{build_driver, Driver};
use crate::runtime::Runtime;

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub tc: TrainConfig,
    pub schedule: LrSchedule,
    pub driver: Box<dyn Driver>,
    /// (step, loss)
    pub loss_log: Vec<(usize, f64)>,
    /// seconds per step
    pub step_secs: Vec<f64>,
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, tc: TrainConfig) -> Result<Self> {
        let schedule =
            LrSchedule::new(tc.lr, tc.steps, tc.warmup_ratio);
        let mut driver = build_driver(rt, &tc)?;
        // LoSiA needs the global warmup horizon for Eq. 8's Cond;
        // a no-op for every other driver.
        driver.set_warmup(schedule.warmup_steps);
        Ok(Trainer {
            rt,
            tc,
            schedule,
            driver,
            loss_log: Vec::new(),
            step_secs: Vec::new(),
        })
    }

    /// Run `tc.steps` optimization steps over the batcher.
    pub fn train(
        &mut self,
        state: &mut ModelState,
        batcher: &mut Batcher,
    ) -> Result<()> {
        self.driver.prepare(state)?;
        for t in 0..self.tc.steps {
            let batch = batcher.next_batch();
            let lr = self.schedule.lr(t);
            let t0 = Instant::now();
            let loss = self.driver.step(state, &batch, t, lr)?;
            self.step_secs.push(t0.elapsed().as_secs_f64());
            self.loss_log.push((t, loss));
            if self.tc.log_every > 0 && t % self.tc.log_every == 0 {
                eprintln!(
                    "[train:{}] step {t:>5} loss {loss:.4} lr {lr:.2e}",
                    self.driver.method().name(),
                );
            }
        }
        // merge external adapters into the backbone (paper protocol:
        // LoRA modules are merged before evaluation / the next task)
        self.driver.finalize(state)?;
        Ok(())
    }

    /// Mean µs/token over steps (skipping the first, which pays
    /// compile/warmup costs).
    pub fn us_per_token(&self) -> f64 {
        if self.step_secs.len() <= 1 {
            return f64::NAN;
        }
        let secs: f64 = self.step_secs[1..].iter().sum();
        let steps = (self.step_secs.len() - 1) as f64;
        secs / steps * 1e6 / self.rt.cfg.tokens_per_step() as f64
    }

    /// Mean loss over the last `k` steps (convergence summary).
    pub fn tail_loss(&self, k: usize) -> f64 {
        let n = self.loss_log.len();
        let k = k.min(n).max(1);
        self.loss_log[n - k..]
            .iter()
            .map(|(_, l)| l)
            .sum::<f64>()
            / k as f64
    }
}
