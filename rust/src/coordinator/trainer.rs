//! The training loop: schedules steps, drives the method driver over
//! batches, and reports telemetry into an observer set.
//!
//! The trainer owns no telemetry of its own — loss curves, per-step
//! wall time, subnet-selection events, and per-artifact executor
//! stats all flow through
//! [`crate::session::observer::ObserverSet`], so benches and the CLI
//! compose metrics instead of forking the loop. Executor profiling
//! works by snapshotting the runtime's per-artifact counters around
//! each step and emitting the deltas as
//! [`crate::session::observer::ExecEvent`]s — including the upload
//! split that distinguishes static (weights) from per-step (batch)
//! host→device traffic, and the overlapped-vs-exposed transfer split
//! the step pipeline introduces. Most callers should reach this
//! through [`crate::session::Session`], which also owns runtime
//! loading, task construction, and report assembly.
//!
//! Two step loops share every phase but batch acquisition:
//!
//! * **synchronous** — pack the batch, bind it, run, apply;
//! * **pipelined** ([`crate::runtime::pipeline`]) — batches are packed
//!   and staged into idle device buffers by worker threads while the
//!   previous step executes; the loop commits the staged set (O(1)
//!   pointer swaps) and runs. Gradient math is untouched, so the two
//!   loops are bitwise identical (`tests/pipeline_parity.rs`).

use std::collections::BTreeMap;
use std::time::Instant;

use anyhow::Result;

use crate::config::TrainConfig;
use crate::coordinator::checkpoint::{
    self, CheckpointConfig, CheckpointWriter,
};
use crate::coordinator::rewarm::LrSchedule;
use crate::coordinator::state::ModelState;
use crate::data::{Batch, BatchPrefetcher, Batcher};
use crate::methods::{build_driver, Driver};
use crate::runtime::dp::{self, DpConfig};
use crate::runtime::kernels;
use crate::runtime::pipeline::{PipelineConfig, StepPipeline};
use crate::runtime::{ExecSnapshot, Runtime};
use crate::session::observer::{
    CheckpointEvent, DpEvent, ExecEvent, ObserverSet, PipelineEvent,
};
use crate::util::warn;

pub struct Trainer<'rt> {
    pub rt: &'rt Runtime,
    pub tc: TrainConfig,
    pub schedule: LrSchedule,
    pub driver: Box<dyn Driver>,
}

/// Tracks runtime exec counters between emissions and turns the
/// movement into `ExecEvent`s.
struct ExecTracker {
    prev: BTreeMap<String, ExecSnapshot>,
}

impl ExecTracker {
    fn new(rt: &Runtime) -> Self {
        ExecTracker {
            prev: rt.exec_snapshots().into_iter().collect(),
        }
    }

    fn emit(&mut self, rt: &Runtime, step: usize, obs: &mut ObserverSet) {
        for (artifact, snap) in rt.exec_snapshots() {
            let base =
                self.prev.get(&artifact).copied().unwrap_or_default();
            let d = snap.delta_since(&base);
            if d.calls > 0
                || d.static_uploads > 0
                || d.step_uploads > 0
                || d.downloads > 0
            {
                obs.emit_exec(&ExecEvent {
                    step,
                    artifact: artifact.clone(),
                    calls: d.calls,
                    secs: d.total_secs(),
                    upload_secs: d.upload_secs(),
                    download_secs: d.download_secs(),
                    overlap_secs: d.overlap_secs(),
                    static_uploads: d.static_uploads,
                    step_uploads: d.step_uploads,
                    downloads: d.downloads,
                    download_bytes: d.download_bytes,
                });
            }
            self.prev.insert(artifact, snap);
        }
    }
}

impl<'rt> Trainer<'rt> {
    pub fn new(rt: &'rt Runtime, tc: TrainConfig) -> Result<Self> {
        let schedule =
            LrSchedule::new(tc.lr, tc.steps, tc.warmup_ratio);
        let mut driver = build_driver(rt, &tc)?;
        // LoSiA needs the global warmup horizon for Eq. 8's Cond;
        // a no-op for every other driver.
        driver.set_warmup(schedule.warmup_steps);
        Ok(Trainer {
            rt,
            tc,
            schedule,
            driver,
        })
    }

    /// Run `tc.steps` optimization steps over the batcher, reporting
    /// step / relocalize / exec / dp / pipeline / finalize events into
    /// `obs`. Takes the batcher by value: the pipelined loop moves it
    /// into the prefetch worker.
    ///
    /// With `DpConfig::enabled()` the batcher is split once into
    /// `shards` seed-stable sub-streams; each step draws one batch per
    /// shard, runs the driver's gradient phase across the plan
    /// replicas, folds the frames with the fixed-order tree reduce,
    /// and applies the update once. Otherwise the legacy single-batch
    /// loop runs — which is the same code path with one shard. With
    /// `PipelineConfig::enabled` either loop additionally overlaps
    /// batch packing and per-step uploads with the previous step.
    pub fn train(
        &mut self,
        state: &mut ModelState,
        batcher: Batcher,
        obs: &mut ObserverSet,
    ) -> Result<()> {
        let dp_cfg = DpConfig::resolve(&self.tc);
        let pipe_cfg = PipelineConfig::resolve(&self.tc);
        pipe_cfg.validate(self.rt, &dp_cfg)?;
        let shards = if dp_cfg.enabled() { dp_cfg.shards } else { 1 };
        let tokens = self.rt.cfg.tokens_per_step() * shards;
        let mut exec = ExecTracker::new(self.rt);
        let ck_cfg = CheckpointConfig::resolve(&self.tc);
        let method = self.driver.method().name();
        let mut ckpt = ck_cfg.enabled().then(|| {
            CheckpointWriter::new(
                ck_cfg.clone(),
                &self.rt.cfg.name,
                method,
                self.tc.seed,
                shards,
            )
        });
        // ---- resume: restore instead of prepare ----------------------
        // A resumed run swaps in the checkpointed model state and the
        // driver's serialized optimizer/selection state, then fast-
        // forwards the batch streams below — bitwise identical to the
        // uninterrupted run (`tests/checkpoint_parity.rs`).
        let mut start = 0usize;
        let mut resumed = false;
        if ck_cfg.resume {
            match checkpoint::load_latest(&ck_cfg.dir, &self.rt.cfg)? {
                Some((ck, path)) => {
                    ck.validate(method, self.tc.seed, shards)?;
                    anyhow::ensure!(
                        ck.step <= self.tc.steps,
                        "checkpoint {} is at step {}, past this run's \
                         {} steps",
                        path.display(),
                        ck.step,
                        self.tc.steps
                    );
                    start = ck.step;
                    *state = ck.state;
                    // restore, NOT prepare: prepare mutates the
                    // backbone for some methods (PiSSA's SVD
                    // subtraction, DoRA's magnitude init) and the
                    // checkpointed state already carries that
                    self.driver.restore(&ck.driver_blob, state)?;
                    resumed = true;
                    obs.emit_checkpoint(&CheckpointEvent {
                        step: start,
                        bytes: 0,
                        path: path.display().to_string(),
                        resume: true,
                    });
                }
                None => warn::warn(format!(
                    "resume requested but {} holds no loadable \
                     checkpoint; starting fresh",
                    ck_cfg.dir.display()
                )),
            }
        }
        if !resumed {
            self.driver.prepare(state)?;
        }
        // initial subnet selections installed at construction time
        // (already consumed pre-checkpoint on the resume path, where
        // restore clears them)
        for ev in self.driver.drain_events() {
            obs.emit_relocalize(&ev);
        }
        // prepare/restore-time uploads (LoRA/LoSiA-Pro bind their
        // static parameter set here) are attributed to the first step
        exec.emit(self.rt, start, obs);
        if pipe_cfg.enabled {
            self.pipelined_loop(
                state, batcher, obs, &dp_cfg, &pipe_cfg, tokens,
                &mut exec, start, &mut ckpt,
            )?;
        } else {
            self.synchronous_loop(
                state, batcher, obs, &dp_cfg, tokens, &mut exec,
                start, &mut ckpt,
            )?;
        }
        // merge external adapters into the backbone (paper protocol:
        // LoRA modules are merged before evaluation / the next task)
        self.driver.finalize(state)?;
        exec.emit(self.rt, self.tc.steps, obs);
        obs.emit_finalize(self.tc.steps);
        Ok(())
    }

    /// One step's gradient + reduce + apply, shared verbatim by both
    /// loops — the reason the pipeline cannot drift numerically.
    fn sharded_step(
        &mut self,
        state: &mut ModelState,
        batches: &[Batch],
        t: usize,
        lr: f64,
        shards: usize,
        obs: &mut ObserverSet,
    ) -> Result<f64> {
        let sharded =
            self.driver.grad_frames_sharded(state, batches, t)?;
        let workers = sharded.worker_nanos.len().max(1);
        let worker_nanos = sharded.worker_nanos.clone();
        let r0 = Instant::now();
        crate::util::faultpoint::hit("reduce", t)?;
        let (reduced, frame_bytes) = dp::reduce(sharded.shards)?;
        let reduce_nanos = r0.elapsed().as_nanos() as u64;
        obs.emit_dp(&DpEvent {
            step: t,
            workers,
            shards,
            reduce_nanos,
            frame_bytes,
            worker_nanos,
        });
        self.driver.apply_frames(state, reduced, t, lr)
    }

    #[allow(clippy::too_many_arguments)]
    fn synchronous_loop(
        &mut self,
        state: &mut ModelState,
        mut batcher: Batcher,
        obs: &mut ObserverSet,
        dp_cfg: &DpConfig,
        tokens: usize,
        exec: &mut ExecTracker,
        start: usize,
        ckpt: &mut Option<CheckpointWriter>,
    ) -> Result<()> {
        let mut shard_batchers: Vec<Batcher> = if dp_cfg.enabled() {
            batcher.shard(dp_cfg.shards)?
        } else {
            Vec::new()
        };
        // fast-forward a resumed run: the batch sequence is a pure
        // function of (seed, shards, draw count), so discarding the
        // first `start` draws replays the uninterrupted stream exactly
        if dp_cfg.enabled() {
            for b in &mut shard_batchers {
                for _ in 0..start {
                    b.skip_batch();
                }
            }
        } else {
            for _ in 0..start {
                batcher.skip_batch();
            }
        }
        for t in start..self.tc.steps {
            let lr = self.schedule.lr(t);
            let t0 = Instant::now();
            let loss = if dp_cfg.enabled() {
                let batches: Vec<Batch> = shard_batchers
                    .iter_mut()
                    .map(|b| b.next_batch())
                    .collect();
                self.sharded_step(
                    state,
                    &batches,
                    t,
                    lr,
                    dp_cfg.shards,
                    obs,
                )?
            } else {
                let batch = batcher.next_batch();
                self.driver.step(state, &batch, t, lr)?
            };
            let secs = t0.elapsed().as_secs_f64();
            self.end_step(
                state, obs, exec, ckpt, t, loss, lr, secs, tokens,
            )?;
        }
        Ok(())
    }

    /// The pipelined loop: per-step batches arrive pre-packed and
    /// pre-staged from the pipeline workers; the training thread
    /// commits them (pointer swaps), recycles the displaced buffers,
    /// and runs the identical [`Self::sharded_step`] / `Driver::step`
    /// body. The loop itself runs under a reduced kernel budget so the
    /// pipeline's worker threads come out of the same process-wide
    /// budget the dp engine divides.
    #[allow(clippy::too_many_arguments)]
    fn pipelined_loop(
        &mut self,
        state: &mut ModelState,
        batcher: Batcher,
        obs: &mut ObserverSet,
        dp_cfg: &DpConfig,
        pipe_cfg: &PipelineConfig,
        tokens: usize,
        exec: &mut ExecTracker,
        start: usize,
        ckpt: &mut Option<CheckpointWriter>,
    ) -> Result<()> {
        // identical shard split to the synchronous loop; one "shard"
        // (the parent batcher itself) when dp is off, so the batch
        // byte stream matches the legacy path exactly
        let mut shard_batchers: Vec<Batcher> = if dp_cfg.enabled() {
            batcher.shard(dp_cfg.shards)?
        } else {
            vec![batcher]
        };
        // fast-forward a resumed run before the prefetch worker takes
        // the batchers (same discipline as the synchronous loop)
        for b in &mut shard_batchers {
            for _ in 0..start {
                b.skip_batch();
            }
        }
        let prefetch = BatchPrefetcher::new(
            shard_batchers,
            self.tc.steps - start,
            pipe_cfg.queue_depth,
        )?;
        let mut sets = Vec::with_capacity(pipe_cfg.queue_depth);
        for _ in 0..pipe_cfg.queue_depth {
            sets.push(self.driver.make_stagers()?);
        }
        let mut pipe = StepPipeline::new(prefetch, sets)?;
        let budget = pipe_cfg.main_thread_budget();
        let prefetch_threads = pipe_cfg.prefetch_threads();
        kernels::with_thread_budget(budget, || -> Result<()> {
            for t in start..self.tc.steps {
                let lr = self.schedule.lr(t);
                let (batches, stagers, staged_bytes) = pipe.next()?;
                let stall_nanos = pipe.last_stall_nanos();
                let t0 = Instant::now();
                let mut displaced =
                    Vec::with_capacity(stagers.len());
                for (i, s) in stagers.into_iter().enumerate() {
                    displaced.push(self.driver.commit_stager(i, s)?);
                }
                // hand the displaced buffers straight back so the
                // stage worker fills them while this step executes
                pipe.recycle(displaced);
                let loss = if dp_cfg.enabled() {
                    self.sharded_step(
                        state,
                        &batches,
                        t,
                        lr,
                        dp_cfg.shards,
                        obs,
                    )?
                } else {
                    self.driver.step(state, &batches[0], t, lr)?
                };
                let secs = t0.elapsed().as_secs_f64();
                obs.emit_pipeline(&PipelineEvent {
                    step: t,
                    queue_depth: pipe.queue_depth(),
                    prefetch_threads,
                    stall_nanos,
                    staged_bytes,
                });
                self.end_step(
                    state, obs, exec, ckpt, t, loss, lr, secs, tokens,
                )?;
            }
            Ok(())
        })
    }

    /// Post-step reporting shared by both loops, plus the periodic
    /// durable checkpoint (the one place a `LOSIACK1` record is cut).
    #[allow(clippy::too_many_arguments)]
    fn end_step(
        &mut self,
        state: &mut ModelState,
        obs: &mut ObserverSet,
        exec: &mut ExecTracker,
        ckpt: &mut Option<CheckpointWriter>,
        t: usize,
        loss: f64,
        lr: f64,
        secs: f64,
        tokens: usize,
    ) -> Result<()> {
        for ev in self.driver.drain_events() {
            obs.emit_relocalize(&ev);
        }
        exec.emit(self.rt, t, obs);
        obs.emit_step(t, loss, lr, secs, tokens);
        if let Some(cw) = ckpt {
            if cw.due(t) {
                let blob = self.driver.snapshot()?;
                let (path, bytes) = cw.write(state, t, &blob)?;
                obs.emit_checkpoint(&CheckpointEvent {
                    step: t + 1,
                    bytes,
                    path: path.display().to_string(),
                    resume: false,
                });
            }
        }
        if self.tc.log_every > 0 && t % self.tc.log_every == 0 {
            eprintln!(
                "[train:{}] step {t:>5} loss {loss:.4} lr {lr:.2e}",
                self.driver.method().name(),
            );
        }
        Ok(())
    }
}
