//! The LoSiA coordinator: everything from §3 of the paper.
//!
//! * [`importance`] — sensitivity-based parameter importance (Eqs. 3–6)
//! * [`localize`] — greedy core-subnet localization (Algorithm 1, Eq. 7)
//! * [`schedule`] — asynchronous periodic re-localization timeline (§3.3)
//! * [`rewarm`] — learning-rate rewarming (Eq. 8)
//! * [`subnet`] — subnet state + compact Adam moments (Algorithm 2)
//! * [`state`] — model parameter store (the ABI mirror of `aot.py`)
//! * [`checkpoint`] — durable training checkpoints + resume (PR 10)
//! * [`trainer`] — the training loop driving AOT artifacts

pub mod checkpoint;
pub mod importance;
pub mod localize;
pub mod rewarm;
pub mod schedule;
pub mod state;
pub mod subnet;
pub mod trainer;
