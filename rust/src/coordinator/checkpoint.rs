//! Durable training checkpoints (`LOSIACK1`).
//!
//! A checkpoint captures everything a killed run needs to continue
//! bitwise-identically: the model parameters, the step counter, and an
//! opaque driver blob holding optimizer moments, subnet selections,
//! and importance accumulators (written by
//! `crate::methods::Driver::snapshot`). Batcher position is *not*
//! stored — batch order is a pure function of `(seed, shards, step)`,
//! so resume rebuilds the batchers and fast-forwards them with
//! `Batcher::skip_batch`.
//!
//! Files go through [`crate::util::durable`]: atomic tmp + fsync +
//! rename writes (fault site `"save"`), per-section CRC32s, and typed
//! truncation/corruption errors. [`load_latest`] scans a directory
//! newest-first and skips torn or corrupt files with a warning, so an
//! injected crash mid-save can never leave the directory without a
//! loadable checkpoint (pinned by `tests/crash_safety.rs`).

use std::io::{BufReader, Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::config::{ModelCfg, TrainConfig};
use crate::coordinator::importance::{ImportanceAccum, ImportanceMode};
use crate::coordinator::state::ModelState;
use crate::coordinator::subnet::{AdamParams, AdamState};
use crate::tensor::Tensor;
use crate::util::durable::{
    self, Header, SectionReader, SectionWriter,
};
use crate::util::warn::warn;

const CKPT_MAGIC: &[u8; 8] = b"LOSIACK1";
const CKPT_VERSION: u32 = 1;

/// Checkpoint files are `ckpt-<step, zero-padded>.losia`, so
/// lexicographic order equals step order.
const CKPT_PREFIX: &str = "ckpt-";
const CKPT_EXT: &str = "losia";

// ------------------------------------------------------ configuration

/// Resolved checkpoint knobs. Precedence per knob: explicit
/// [`TrainConfig`] setting > `LOSIA_CKPT_*` env var > default
/// (disabled, `checkpoints/`, keep 3, no resume) — the same layering
/// as `runtime::dp::DpConfig::resolve`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CheckpointConfig {
    /// write a checkpoint every N steps; 0 disables checkpointing
    pub every: usize,
    /// directory holding the rotation window
    pub dir: PathBuf,
    /// newest checkpoints retained after each write (min 1)
    pub keep: usize,
    /// resume from the newest loadable checkpoint in `dir`
    pub resume: bool,
}

fn env_usize(name: &str) -> Option<usize> {
    std::env::var(name).ok()?.trim().parse().ok()
}

fn env_flag(name: &str) -> Option<bool> {
    match std::env::var(name).ok()?.trim().to_ascii_lowercase().as_str()
    {
        "1" | "true" | "on" | "yes" => Some(true),
        "0" | "false" | "off" | "no" => Some(false),
        _ => None,
    }
}

impl CheckpointConfig {
    pub fn resolve(tc: &TrainConfig) -> Self {
        let every = tc
            .checkpoint_every
            .or_else(|| env_usize("LOSIA_CKPT_EVERY"))
            .unwrap_or(0);
        let dir = tc
            .checkpoint_dir
            .clone()
            .or_else(|| {
                std::env::var("LOSIA_CKPT_DIR").ok().map(PathBuf::from)
            })
            .unwrap_or_else(|| PathBuf::from("checkpoints"));
        let keep = tc
            .checkpoint_keep
            .or_else(|| env_usize("LOSIA_CKPT_KEEP"))
            .unwrap_or(3)
            .max(1);
        let resume = tc
            .resume
            .or_else(|| env_flag("LOSIA_CKPT_RESUME"))
            .unwrap_or(false);
        CheckpointConfig {
            every,
            dir,
            keep,
            resume,
        }
    }

    pub fn enabled(&self) -> bool {
        self.every > 0
    }
}

// ------------------------------------------------- low-level helpers
//
// Shared shapes for the driver snapshot blobs: every `Driver` writes
// its state through these so the on-disk vocabulary (tensor, index
// list, Adam moments, importance accumulator) stays uniform across
// methods.

pub fn write_tensor<W: Write>(
    w: &mut SectionWriter<W>,
    t: &Tensor,
) -> Result<()> {
    w.u32(t.shape.len() as u32)?;
    for &d in &t.shape {
        w.u64(d as u64)?;
    }
    w.f32s(&t.data)?;
    Ok(())
}

pub fn read_tensor<R: Read>(r: &mut SectionReader<R>) -> Result<Tensor> {
    let ndim = r.u32()? as usize;
    ensure!(
        ndim <= 8,
        "{}: section {:?}: implausible tensor rank {ndim} (file is \
         corrupt)",
        r.file(),
        "tensor"
    );
    let mut shape = Vec::with_capacity(ndim);
    for _ in 0..ndim {
        shape.push(r.u64()? as usize);
    }
    let numel: usize = shape.iter().product();
    ensure!(
        numel <= 1 << 31,
        "{}: implausible tensor size {numel} (file is corrupt)",
        r.file()
    );
    let mut data = vec![0f32; numel];
    r.f32s(&mut data)?;
    Ok(Tensor::from_vec(&shape, data))
}

pub fn write_usizes<W: Write>(
    w: &mut SectionWriter<W>,
    xs: &[usize],
) -> Result<()> {
    w.u64(xs.len() as u64)?;
    for &x in xs {
        w.u64(x as u64)?;
    }
    Ok(())
}

pub fn read_usizes<R: Read>(
    r: &mut SectionReader<R>,
) -> Result<Vec<usize>> {
    let n = r.u64()? as usize;
    ensure!(
        n <= 1 << 28,
        "{}: implausible index-list length {n} (file is corrupt)",
        r.file()
    );
    let mut xs = Vec::with_capacity(n);
    for _ in 0..n {
        xs.push(r.u64()? as usize);
    }
    Ok(xs)
}

pub fn write_adam<W: Write>(
    w: &mut SectionWriter<W>,
    a: &AdamState,
) -> Result<()> {
    write_tensor(w, &a.m)?;
    write_tensor(w, &a.v)?;
    w.u32(a.step)?;
    Ok(())
}

/// Rebuild an [`AdamState`] with the caller's hyperparameters (hp are
/// run configuration, not checkpoint payload).
pub fn read_adam<R: Read>(
    r: &mut SectionReader<R>,
    hp: AdamParams,
) -> Result<AdamState> {
    let m = read_tensor(r)?;
    let v = read_tensor(r)?;
    let step = r.u32()?;
    ensure!(
        m.shape == v.shape,
        "{}: Adam moment shapes disagree ({:?} vs {:?})",
        r.file(),
        m.shape,
        v.shape
    );
    Ok(AdamState { m, v, step, hp })
}

/// Overwrite an existing [`AdamState`] in place, validating that the
/// checkpointed moments match the shape the current run allocated.
pub fn read_adam_into<R: Read>(
    r: &mut SectionReader<R>,
    a: &mut AdamState,
) -> Result<()> {
    let loaded = read_adam(r, a.hp)?;
    ensure!(
        loaded.m.shape == a.m.shape,
        "{}: checkpointed Adam moments have shape {:?}, this run \
         expects {:?} (config/method mismatch?)",
        r.file(),
        loaded.m.shape,
        a.m.shape
    );
    a.m = loaded.m;
    a.v = loaded.v;
    a.step = loaded.step;
    Ok(())
}

pub fn write_accum<W: Write>(
    w: &mut SectionWriter<W>,
    a: &ImportanceAccum,
) -> Result<()> {
    w.u32(match a.mode {
        ImportanceMode::Sensitivity => 0,
        ImportanceMode::GradientMagnitude => 1,
    })?;
    w.f32s(&[a.beta1, a.beta2])?;
    write_tensor(w, &a.i_bar)?;
    write_tensor(w, &a.u_bar)?;
    w.u64(a.updates as u64)?;
    Ok(())
}

pub fn read_accum<R: Read>(
    r: &mut SectionReader<R>,
) -> Result<ImportanceAccum> {
    let mode = match r.u32()? {
        0 => ImportanceMode::Sensitivity,
        1 => ImportanceMode::GradientMagnitude,
        other => bail!(
            "{}: unknown importance mode {other} (file is corrupt)",
            r.file()
        ),
    };
    let mut betas = [0f32; 2];
    r.f32s(&mut betas)?;
    let i_bar = read_tensor(r)?;
    let u_bar = read_tensor(r)?;
    let updates = r.u64()? as usize;
    ensure!(
        i_bar.shape == u_bar.shape,
        "{}: importance accumulator shapes disagree",
        r.file()
    );
    Ok(ImportanceAccum {
        mode,
        beta1: betas[0],
        beta2: betas[1],
        i_bar,
        u_bar,
        updates,
    })
}

// --------------------------------------------------------- the record

/// One loaded training checkpoint.
#[derive(Debug, Clone)]
pub struct TrainCheckpoint {
    /// optimization steps completed when the checkpoint was written;
    /// resume continues at step index `step`
    pub step: usize,
    /// model config name the run used
    pub config: String,
    /// method name (`Method::name`)
    pub method: String,
    /// run seed
    pub seed: u64,
    /// logical dp shard count (the numerics knob — a resumed run must
    /// match it or the batch streams diverge)
    pub dp_shards: usize,
    pub state: ModelState,
    /// opaque `Driver::snapshot` payload
    pub driver_blob: Vec<u8>,
}

/// `<dir>/ckpt-<step>.losia`, zero-padded so name order is step order.
pub fn checkpoint_path(dir: &Path, step: usize) -> PathBuf {
    dir.join(format!("{CKPT_PREFIX}{step:08}.{CKPT_EXT}"))
}

/// Write one checkpoint atomically (fault site `"save"` at `step`).
/// Borrows the state — no full-model clone is made to checkpoint.
#[allow(clippy::too_many_arguments)]
pub fn write_checkpoint(
    path: &Path,
    config: &str,
    method: &str,
    seed: u64,
    dp_shards: usize,
    step: usize,
    state: &ModelState,
    driver_blob: &[u8],
) -> Result<()> {
    if let Some(dir) = path.parent() {
        let _ = std::fs::create_dir_all(dir);
    }
    durable::atomic_write(path, "save", step, |w| {
        durable::write_header(w, CKPT_MAGIC, CKPT_VERSION)?;
        w.u64(step as u64)?;
        w.str(config)?;
        w.str(method)?;
        w.u64(seed)?;
        w.u64(dp_shards as u64)?;
        w.end_section()?;
        state.write_into(w)?;
        w.u64(driver_blob.len() as u64)?;
        w.write_all(driver_blob)?;
        w.end_section()?;
        Ok(())
    })
    .with_context(|| {
        format!("writing checkpoint {}", path.display())
    })
}

impl TrainCheckpoint {
    pub fn save(&self, path: &Path) -> Result<()> {
        write_checkpoint(
            path,
            &self.config,
            &self.method,
            self.seed,
            self.dp_shards,
            self.step,
            &self.state,
            &self.driver_blob,
        )
    }

    pub fn load(path: &Path, cfg: &ModelCfg) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = SectionReader::new(
            BufReader::new(f),
            path.display().to_string(),
        );
        match r.read_header(CKPT_MAGIC)? {
            Header::Versioned(v) if v <= CKPT_VERSION => {}
            Header::Versioned(v) => bail!(
                "{}: checkpoint format version {v} is newer than this \
                 build understands (max {CKPT_VERSION})",
                path.display()
            ),
            // checkpoints never existed before the versioned layout,
            // so a non-sentinel first word means torn/corrupt bytes
            Header::Legacy(_) => bail!(
                "{}: not a versioned checkpoint (file is corrupt)",
                path.display()
            ),
        }
        r.section("meta");
        let step = r.u64()? as usize;
        let config = r.str()?;
        let method = r.str()?;
        let seed = r.u64()?;
        let dp_shards = r.u64()? as usize;
        r.end_section()?;
        if config != cfg.name {
            bail!(
                "{}: checkpoint was written for config {config:?}, \
                 this run uses {:?}",
                path.display(),
                cfg.name
            );
        }
        r.section("count");
        let count = r.u32()? as usize;
        r.end_section()?;
        let state = ModelState::read_from(&mut r, cfg, count)?;
        r.section("driver");
        let blob_len = r.u64()? as usize;
        ensure!(
            blob_len <= 1 << 32,
            "{}: implausible driver blob length {blob_len} (file is \
             corrupt)",
            path.display()
        );
        let mut driver_blob = vec![0u8; blob_len];
        r.read_exact(&mut driver_blob)?;
        r.end_section()?;
        Ok(TrainCheckpoint {
            step,
            config,
            method,
            seed,
            dp_shards,
            state,
            driver_blob,
        })
    }

    /// Reject a checkpoint written by a differently-configured run —
    /// resuming across a method/seed/shard change would silently break
    /// the bitwise-parity contract.
    pub fn validate(
        &self,
        method: &str,
        seed: u64,
        dp_shards: usize,
    ) -> Result<()> {
        ensure!(
            self.method == method,
            "checkpoint was written by method {:?}, this run uses \
             {method:?}",
            self.method
        );
        ensure!(
            self.seed == seed,
            "checkpoint was written with seed {}, this run uses {seed}",
            self.seed
        );
        ensure!(
            self.dp_shards == dp_shards,
            "checkpoint was written with {} dp shard(s), this run \
             uses {dp_shards} — the shard count is a numerics knob \
             and must match to resume",
            self.dp_shards
        );
        Ok(())
    }
}

// ------------------------------------------------- directory scanning

/// `(step, path)` for every checkpoint-named file in `dir`, ascending
/// by step. Tmp files and foreign names are ignored. A missing
/// directory is an empty list, not an error.
pub fn list(dir: &Path) -> Vec<(usize, PathBuf)> {
    let mut out = Vec::new();
    let Ok(entries) = std::fs::read_dir(dir) else {
        return out;
    };
    for entry in entries.flatten() {
        let path = entry.path();
        if durable::is_tmp(&path) {
            continue;
        }
        let Some(name) = path.file_name().and_then(|s| s.to_str())
        else {
            continue;
        };
        let Some(stem) = name
            .strip_prefix(CKPT_PREFIX)
            .and_then(|s| s.strip_suffix(&format!(".{CKPT_EXT}")))
        else {
            continue;
        };
        if let Ok(step) = stem.parse::<usize>() {
            out.push((step, path));
        }
    }
    out.sort();
    out
}

/// Load the newest checkpoint that parses cleanly, warning about and
/// skipping torn/corrupt files. `Ok(None)` when nothing loadable
/// exists.
pub fn load_latest(
    dir: &Path,
    cfg: &ModelCfg,
) -> Result<Option<(TrainCheckpoint, PathBuf)>> {
    for (_, path) in list(dir).into_iter().rev() {
        match TrainCheckpoint::load(&path, cfg) {
            Ok(ck) => return Ok(Some((ck, path))),
            Err(e) => warn(format!(
                "skipping unloadable checkpoint {}: {e}",
                path.display()
            )),
        }
    }
    Ok(None)
}

/// Keep the newest `keep` checkpoints, deleting older ones and any
/// stale `.tmp` files left by interrupted writes. Called after every
/// successful save, so the newest file is always a just-verified
/// write and the rotation can never delete the only valid checkpoint.
/// Deletion failures warn instead of failing the step.
pub fn rotate(dir: &Path, keep: usize) {
    let keep = keep.max(1);
    let all = list(dir);
    if all.len() > keep {
        for (_, path) in &all[..all.len() - keep] {
            if let Err(e) = std::fs::remove_file(path) {
                warn(format!(
                    "could not rotate out {}: {e}",
                    path.display()
                ));
            }
        }
    }
    if let Ok(entries) = std::fs::read_dir(dir) {
        for entry in entries.flatten() {
            let path = entry.path();
            if durable::is_tmp(&path) {
                let _ = std::fs::remove_file(&path);
            }
        }
    }
}

// ------------------------------------------------------- step driver

/// The trainer's checkpointing arm: owns the resolved config and run
/// identity, decides when a step is due, writes + rotates, and keeps
/// the counters the run report surfaces.
#[derive(Debug)]
pub struct CheckpointWriter {
    pub cfg: CheckpointConfig,
    config: String,
    method: String,
    seed: u64,
    dp_shards: usize,
    /// checkpoints written this stage
    pub writes: usize,
    /// total bytes those writes put on disk
    pub bytes: u64,
    pub last_path: Option<PathBuf>,
}

impl CheckpointWriter {
    pub fn new(
        cfg: CheckpointConfig,
        config: &str,
        method: &str,
        seed: u64,
        dp_shards: usize,
    ) -> Self {
        CheckpointWriter {
            cfg,
            config: config.to_string(),
            method: method.to_string(),
            seed,
            dp_shards,
            writes: 0,
            bytes: 0,
            last_path: None,
        }
    }

    /// A checkpoint is due after step `t` when `t + 1` completed steps
    /// is a multiple of the interval.
    pub fn due(&self, t: usize) -> bool {
        self.cfg.every > 0 && (t + 1) % self.cfg.every == 0
    }

    /// Write the checkpoint for completed-step count `t + 1` and
    /// rotate the retention window. Returns the new file's path and
    /// size.
    pub fn write(
        &mut self,
        state: &ModelState,
        t: usize,
        driver_blob: &[u8],
    ) -> Result<(PathBuf, u64)> {
        let step = t + 1;
        let path = checkpoint_path(&self.cfg.dir, step);
        write_checkpoint(
            &path,
            &self.config,
            &self.method,
            self.seed,
            self.dp_shards,
            step,
            state,
            driver_blob,
        )?;
        rotate(&self.cfg.dir, self.cfg.keep);
        let size = std::fs::metadata(&path)
            .map(|m| m.len())
            .unwrap_or(0);
        self.writes += 1;
        self.bytes += size;
        self.last_path = Some(path.clone());
        Ok((path, size))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::resolve_config;
    use crate::runtime::artifacts_dir;
    use crate::util::rng::Rng;

    fn tiny() -> ModelCfg {
        resolve_config(&artifacts_dir(), "tiny").unwrap()
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!(
            "losia_ckpt_{tag}_{}",
            std::process::id()
        ));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn helper_payloads_round_trip() {
        let t = Tensor::from_vec(&[2, 3], vec![0.5; 6]);
        let adam = AdamState {
            m: Tensor::from_vec(&[4], vec![1.0, -1.0, 2.0, 0.0]),
            v: Tensor::from_vec(&[4], vec![0.1, 0.2, 0.3, 0.4]),
            step: 17,
            hp: AdamParams::default(),
        };
        let accum = ImportanceAccum {
            mode: ImportanceMode::GradientMagnitude,
            beta1: 0.85,
            beta2: 0.85,
            i_bar: Tensor::from_vec(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]),
            u_bar: Tensor::from_vec(&[2, 2], vec![5.0, 6.0, 7.0, 8.0]),
            updates: 9,
        };
        let mut buf = Vec::new();
        {
            let mut w = SectionWriter::new(&mut buf);
            write_tensor(&mut w, &t).unwrap();
            write_usizes(&mut w, &[3, 1, 4, 1, 5]).unwrap();
            write_adam(&mut w, &adam).unwrap();
            write_accum(&mut w, &accum).unwrap();
            w.end_section().unwrap();
        }
        let mut r = SectionReader::new(
            std::io::Cursor::new(&buf),
            "blob",
        );
        r.section("body");
        assert_eq!(read_tensor(&mut r).unwrap(), t);
        assert_eq!(read_usizes(&mut r).unwrap(), vec![3, 1, 4, 1, 5]);
        let mut into = AdamState::new(&[4], AdamParams::default());
        read_adam_into(&mut r, &mut into).unwrap();
        assert_eq!(into.m, adam.m);
        assert_eq!(into.v, adam.v);
        assert_eq!(into.step, 17);
        let back = read_accum(&mut r).unwrap();
        assert_eq!(back.mode, accum.mode);
        assert_eq!(back.i_bar, accum.i_bar);
        assert_eq!(back.u_bar, accum.u_bar);
        assert_eq!(back.updates, 9);
        r.end_section().unwrap();
    }

    #[test]
    fn adam_shape_mismatch_is_rejected() {
        let adam = AdamState::new(&[3], AdamParams::default());
        let mut buf = Vec::new();
        {
            let mut w = SectionWriter::new(&mut buf);
            write_adam(&mut w, &adam).unwrap();
            w.end_section().unwrap();
        }
        let mut r = SectionReader::new(
            std::io::Cursor::new(&buf),
            "blob",
        );
        r.section("body");
        let mut into = AdamState::new(&[4], AdamParams::default());
        let err = read_adam_into(&mut r, &mut into).unwrap_err();
        assert!(err.to_string().contains("shape"), "{err}");
    }

    #[test]
    fn checkpoint_record_round_trips() {
        let cfg = tiny();
        let mut rng = Rng::new(5);
        let state = ModelState::init(&cfg, &mut rng);
        let dir = tmp_dir("roundtrip");
        let ck = TrainCheckpoint {
            step: 12,
            config: cfg.name.clone(),
            method: "LoSiA-Pro".into(),
            seed: 42,
            dp_shards: 2,
            state,
            driver_blob: vec![7u8; 1000],
        };
        let path = checkpoint_path(&dir, ck.step);
        ck.save(&path).unwrap();
        let back = TrainCheckpoint::load(&path, &cfg).unwrap();
        assert_eq!(back.step, 12);
        assert_eq!(back.method, "LoSiA-Pro");
        assert_eq!(back.seed, 42);
        assert_eq!(back.dp_shards, 2);
        assert_eq!(back.driver_blob, ck.driver_blob);
        for ((n0, t0), (n1, t1)) in
            ck.state.params.iter().zip(&back.state.params)
        {
            assert_eq!(n0, n1);
            assert_eq!(t0.data, t1.data);
        }
        back.validate("LoSiA-Pro", 42, 2).unwrap();
        assert!(back.validate("LoRA", 42, 2).is_err());
        assert!(back.validate("LoSiA-Pro", 43, 2).is_err());
        assert!(back.validate("LoSiA-Pro", 42, 4).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_keeps_newest_and_clears_tmps() {
        let cfg = tiny();
        let mut rng = Rng::new(6);
        let state = ModelState::init(&cfg, &mut rng);
        let dir = tmp_dir("rotate");
        let mut cw = CheckpointWriter::new(
            CheckpointConfig {
                every: 1,
                dir: dir.clone(),
                keep: 2,
                resume: false,
            },
            &cfg.name,
            "LoSiA-Pro",
            42,
            1,
        );
        assert!(cw.due(0));
        for t in 0..4 {
            cw.write(&state, t, b"blob").unwrap();
        }
        // a stale tmp from a simulated crash gets swept
        std::fs::write(dir.join("ckpt-00000009.losia.tmp"), b"torn")
            .unwrap();
        cw.write(&state, 4, b"blob").unwrap();
        let steps: Vec<usize> =
            list(&dir).into_iter().map(|(s, _)| s).collect();
        assert_eq!(steps, vec![4, 5]);
        assert!(!dir.join("ckpt-00000009.losia.tmp").exists());
        assert_eq!(cw.writes, 5);
        assert!(cw.bytes > 0);
        assert_eq!(
            cw.last_path.as_deref(),
            Some(checkpoint_path(&dir, 5).as_path())
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn load_latest_skips_corrupt_newest() {
        let cfg = tiny();
        let mut rng = Rng::new(7);
        let state = ModelState::init(&cfg, &mut rng);
        let dir = tmp_dir("latest");
        for step in [3usize, 6] {
            write_checkpoint(
                &checkpoint_path(&dir, step),
                &cfg.name,
                "LoRA",
                1,
                1,
                step,
                &state,
                b"",
            )
            .unwrap();
        }
        // tear the newest one: resume must fall back to step 3
        let newest = checkpoint_path(&dir, 6);
        let bytes = std::fs::read(&newest).unwrap();
        std::fs::write(&newest, &bytes[..bytes.len() / 3]).unwrap();
        let cap = crate::util::warn::capture();
        let (ck, path) = load_latest(&dir, &cfg).unwrap().unwrap();
        let warns = cap.drain();
        assert_eq!(ck.step, 3);
        assert_eq!(path, checkpoint_path(&dir, 3));
        assert!(
            warns.iter().any(|w| w.contains("unloadable")),
            "expected a skip warning, got {warns:?}"
        );
        // empty / missing directories are a clean None
        assert!(load_latest(&tmp_dir("empty"), &cfg)
            .unwrap()
            .is_none());
        assert!(load_latest(Path::new("/nonexistent/ckpts"), &cfg)
            .unwrap()
            .is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn config_resolution_layers_builder_over_env() {
        let _guard =
            match crate::util::faultpoint::ENV_LOCK.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        for k in [
            "LOSIA_CKPT_EVERY",
            "LOSIA_CKPT_DIR",
            "LOSIA_CKPT_KEEP",
            "LOSIA_CKPT_RESUME",
        ] {
            std::env::remove_var(k);
        }
        let tc = TrainConfig::default();
        let c = CheckpointConfig::resolve(&tc);
        assert!(!c.enabled());
        assert_eq!(c.dir, PathBuf::from("checkpoints"));
        assert_eq!(c.keep, 3);
        assert!(!c.resume);

        std::env::set_var("LOSIA_CKPT_EVERY", "5");
        std::env::set_var("LOSIA_CKPT_DIR", "/tmp/ck");
        std::env::set_var("LOSIA_CKPT_KEEP", "0");
        std::env::set_var("LOSIA_CKPT_RESUME", "true");
        let c = CheckpointConfig::resolve(&tc);
        assert_eq!(c.every, 5);
        assert_eq!(c.dir, PathBuf::from("/tmp/ck"));
        // keep is clamped to at least one retained checkpoint
        assert_eq!(c.keep, 1);
        assert!(c.resume);

        let mut tc = TrainConfig::default();
        tc.checkpoint_every = Some(2);
        tc.checkpoint_dir = Some(PathBuf::from("/tmp/other"));
        tc.checkpoint_keep = Some(7);
        tc.resume = Some(false);
        let c = CheckpointConfig::resolve(&tc);
        assert_eq!(c.every, 2);
        assert_eq!(c.dir, PathBuf::from("/tmp/other"));
        assert_eq!(c.keep, 7);
        assert!(!c.resume);

        for k in [
            "LOSIA_CKPT_EVERY",
            "LOSIA_CKPT_DIR",
            "LOSIA_CKPT_KEEP",
            "LOSIA_CKPT_RESUME",
        ] {
            std::env::remove_var(k);
        }
    }
}
