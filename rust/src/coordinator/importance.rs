//! Sensitivity-based parameter importance (paper §3.2, Eqs. 3–6).
//!
//! During a layer's profiling slot the trainer feeds each micro-batch
//! gradient here; the accumulator maintains the smoothed sensitivity
//! Ī and uncertainty Ū, whose product is the localization score
//! (mirrors the L1 `importance.py` kernel — the host copy exists so
//! importance state lives beside the optimizer without an extra PJRT
//! round-trip per matrix).

use crate::tensor::Tensor;

/// Importance mode: sensitivity EMA (LoSiA) or raw gradient magnitude
/// (the GL ablation from Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ImportanceMode {
    Sensitivity,
    GradientMagnitude,
}

/// Per-matrix accumulator for one profiling window.
#[derive(Debug, Clone)]
pub struct ImportanceAccum {
    pub mode: ImportanceMode,
    pub beta1: f32,
    pub beta2: f32,
    /// Ī — smoothed sensitivity (Eq. 4)
    pub i_bar: Tensor,
    /// Ū — uncertainty (Eq. 5)
    pub u_bar: Tensor,
    pub updates: usize,
}

impl ImportanceAccum {
    pub fn new(
        shape: &[usize],
        beta1: f32,
        beta2: f32,
        mode: ImportanceMode,
    ) -> Self {
        ImportanceAccum {
            mode,
            beta1,
            beta2,
            i_bar: Tensor::zeros(shape),
            u_bar: Tensor::zeros(shape),
            updates: 0,
        }
    }

    /// Micro-batch importance I (Eq. 3 in Algorithm-2 form):
    /// `I = |w·g − ½(w·g)²|`, or `|g|` in gradient mode.
    fn micro_importance(&self, w: f32, g: f32) -> f32 {
        match self.mode {
            ImportanceMode::Sensitivity => {
                let wg = w * g;
                (wg - 0.5 * wg * wg).abs()
            }
            ImportanceMode::GradientMagnitude => g.abs(),
        }
    }

    /// Fold one micro-batch gradient into the EMA state (Eqs. 4–5).
    pub fn update(&mut self, w: &Tensor, g: &Tensor) {
        assert_eq!(w.shape, g.shape, "importance: W/G shape mismatch");
        assert_eq!(w.shape, self.i_bar.shape);
        let (b1, b2) = (self.beta1, self.beta2);
        for k in 0..w.data.len() {
            let imp = self.micro_importance(w.data[k], g.data[k]);
            let i_new = b1 * self.i_bar.data[k] + (1.0 - b1) * imp;
            let u_new = b2 * self.u_bar.data[k]
                + (1.0 - b2) * (imp - i_new).abs();
            self.i_bar.data[k] = i_new;
            self.u_bar.data[k] = u_new;
        }
        self.updates += 1;
    }

    /// Localization score s(W) = Ī · Ū (Eq. 6); gradient mode scores by
    /// Ī alone (accumulated |g|).
    pub fn score(&self) -> Tensor {
        match self.mode {
            ImportanceMode::Sensitivity => Tensor {
                shape: self.i_bar.shape.clone(),
                data: self
                    .i_bar
                    .data
                    .iter()
                    .zip(&self.u_bar.data)
                    .map(|(i, u)| i * u)
                    .collect(),
            },
            ImportanceMode::GradientMagnitude => self.i_bar.clone(),
        }
    }

    /// Memory footprint in bytes (Table 14 §Auxiliary accounting).
    pub fn bytes(&self) -> usize {
        (self.i_bar.len() + self.u_bar.len()) * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn first_update_from_zero_state() {
        // Ī₁ = (1-β₁)·I₁ and Ū₁ = (1-β₂)·|I₁ - Ī₁| = (1-β₂)β₁·I₁
        let w = Tensor::from_vec(&[1, 2], vec![2.0, -1.0]);
        let g = Tensor::from_vec(&[1, 2], vec![0.5, 0.25]);
        let mut acc = ImportanceAccum::new(
            &[1, 2],
            0.85,
            0.85,
            ImportanceMode::Sensitivity,
        );
        acc.update(&w, &g);
        let i1 = |w: f32, g: f32| {
            let wg = w * g;
            (wg - 0.5 * wg * wg).abs()
        };
        for k in 0..2 {
            let imp = i1(w.data[k], g.data[k]);
            assert!((acc.i_bar.data[k] - 0.15 * imp).abs() < 1e-6);
            assert!(
                (acc.u_bar.data[k] - 0.15 * (imp - 0.15 * imp).abs())
                    .abs()
                    < 1e-6
            );
        }
    }

    #[test]
    fn scores_nonnegative_and_bounded() {
        check("score >= 0, EMA bounded by max importance", 30, |g| {
            let n = g.size(1, 16);
            let m = g.size(1, 16);
            let mut acc = ImportanceAccum::new(
                &[n, m],
                0.85,
                0.85,
                ImportanceMode::Sensitivity,
            );
            let steps = g.size(1, 10);
            let mut max_imp = 0.0f32;
            for _ in 0..steps {
                let w =
                    Tensor::from_vec(&[n, m], g.normal_vec(n * m, 1.0));
                let gr =
                    Tensor::from_vec(&[n, m], g.normal_vec(n * m, 1.0));
                for k in 0..n * m {
                    let wg = w.data[k] * gr.data[k];
                    max_imp = max_imp.max((wg - 0.5 * wg * wg).abs());
                }
                acc.update(&w, &gr);
            }
            let s = acc.score();
            for &v in &s.data {
                assert!(v >= 0.0);
            }
            for &v in &acc.i_bar.data {
                assert!(v <= max_imp + 1e-5, "EMA exceeded max: {v}");
            }
        });
    }

    #[test]
    fn gradient_mode_ignores_weights() {
        let w1 = Tensor::from_vec(&[1, 1], vec![100.0]);
        let w2 = Tensor::from_vec(&[1, 1], vec![0.0]);
        let g = Tensor::from_vec(&[1, 1], vec![0.3]);
        let mut a1 = ImportanceAccum::new(
            &[1, 1],
            0.85,
            0.85,
            ImportanceMode::GradientMagnitude,
        );
        let mut a2 = a1.clone();
        a1.update(&w1, &g);
        a2.update(&w2, &g);
        assert_eq!(a1.score().data, a2.score().data);
    }

    #[test]
    fn constant_importance_converges() {
        // Feeding the same (w, g) repeatedly: Ī → I, Ū → |I - Ī| → 0.
        let w = Tensor::from_vec(&[1, 1], vec![0.8]);
        let g = Tensor::from_vec(&[1, 1], vec![0.4]);
        let mut acc = ImportanceAccum::new(
            &[1, 1],
            0.85,
            0.85,
            ImportanceMode::Sensitivity,
        );
        for _ in 0..400 {
            acc.update(&w, &g);
        }
        let wg = 0.8f32 * 0.4;
        let imp = (wg - 0.5 * wg * wg).abs();
        assert!((acc.i_bar.data[0] - imp).abs() < 1e-4);
        assert!(acc.u_bar.data[0] < 1e-3);
    }
}
