//! Asynchronous periodic subnet re-localization timeline (paper §3.3).
//!
//! The timeline is chopped into slots of length `T`. With `G` weight
//! groups (the L decoder layers, plus one group for the output layer),
//! group `g`:
//!
//! * accumulates importance statistics during steps
//!   `[(kG + g)T, (kG + g + 1)T)` for k = 0, 1, …
//! * re-localizes at the *end* of that slot (just before the first step
//!   of the next slot), and
//! * rewarms its learning rate over the following slot (see
//!   [`crate::coordinator::rewarm`]).
//!
//! At any moment exactly one group is profiling, so the Ī/Ū storage
//! cost is one layer's worth rather than the whole model's. Every group
//! refreshes exactly once per `G·T` steps.

/// What the trainer must do for a given group at a given step.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SlotAction {
    /// this group should fold this step's gradients into Ī/Ū
    pub profile: bool,
    /// this group re-localizes *after* this step's update
    pub relocalize: bool,
}

/// The asynchronous schedule (plus the SL-ablation synchronous mode).
#[derive(Debug, Clone)]
pub struct AsyncSchedule {
    pub groups: usize,
    pub time_slot: usize,
    pub synchronous: bool,
}

impl AsyncSchedule {
    pub fn new(groups: usize, time_slot: usize, synchronous: bool) -> Self {
        assert!(groups > 0 && time_slot > 0);
        AsyncSchedule {
            groups,
            time_slot,
            synchronous,
        }
    }

    /// Period between refreshes of the same group (T̄ = G·T).
    pub fn full_period(&self) -> usize {
        if self.synchronous {
            self.time_slot
        } else {
            self.groups * self.time_slot
        }
    }

    /// Which group is profiling at step `t` (async mode).
    pub fn profiling_group(&self, t: usize) -> usize {
        (t / self.time_slot) % self.groups
    }

    /// Action for group `g` at 0-based step `t`.
    pub fn action(&self, t: usize, g: usize) -> SlotAction {
        debug_assert!(g < self.groups);
        if self.synchronous {
            // SL ablation: every group profiles every step and all
            // reselect together at slot boundaries.
            let relocalize = (t + 1) % self.time_slot == 0;
            return SlotAction {
                profile: true,
                relocalize,
            };
        }
        let profile = self.profiling_group(t) == g;
        // last step of g's slot → reselect after the update
        let relocalize = profile && (t + 1) % self.time_slot == 0;
        SlotAction {
            profile,
            relocalize,
        }
    }

    /// Step at which group `g` last re-localized before or at step `t`
    /// (None if it never has). Used by the rewarming schedule.
    pub fn last_relocalize(&self, t: usize, g: usize) -> Option<usize> {
        if self.synchronous {
            let k = (t + 1) / self.time_slot;
            return (k > 0).then(|| k * self.time_slot - 1);
        }
        // g reselects at steps (kG + g + 1)·T − 1 for k ≥ 0
        let period = self.full_period();
        let first = (g + 1) * self.time_slot - 1;
        if t < first {
            return None;
        }
        Some(first + ((t - first) / period) * period)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn exactly_one_group_profiles_async() {
        check("async: one profiler per step", 50, |g| {
            let groups = g.size(1, 8);
            let t_slot = g.size(1, 20);
            let s = AsyncSchedule::new(groups, t_slot, false);
            let t = g.size(0, 500);
            let profiling: Vec<usize> = (0..groups)
                .filter(|&gr| s.action(t, gr).profile)
                .collect();
            assert_eq!(profiling.len(), 1);
            assert_eq!(profiling[0], s.profiling_group(t));
        });
    }

    #[test]
    fn every_group_refreshes_once_per_full_period() {
        check("async: refresh exactly once per G·T", 30, |g| {
            let groups = g.size(1, 6);
            let t_slot = g.size(1, 10);
            let s = AsyncSchedule::new(groups, t_slot, false);
            let period = s.full_period();
            for gr in 0..groups {
                let count = (0..period)
                    .filter(|&t| s.action(t, gr).relocalize)
                    .count();
                assert_eq!(count, 1, "group {gr}");
            }
        });
    }

    #[test]
    fn relocalize_follows_profiling_window() {
        let s = AsyncSchedule::new(3, 10, false);
        // group 0 profiles steps 0..10, reselects after step 9
        assert!(s.action(9, 0).relocalize);
        assert!(!s.action(9, 1).relocalize);
        // group 1 profiles 10..20, reselects after 19
        assert!(s.action(15, 1).profile);
        assert!(s.action(19, 1).relocalize);
        // wraps: group 0 profiles again at 30..40
        assert!(s.action(31, 0).profile);
        assert!(s.action(39, 0).relocalize);
    }

    #[test]
    fn last_relocalize_is_consistent_with_actions() {
        check("last_relocalize matches action log", 20, |g| {
            let groups = g.size(1, 5);
            let t_slot = g.size(1, 8);
            let sync = g.bool();
            let s = AsyncSchedule::new(groups, t_slot, sync);
            let horizon = g.size(1, 200);
            for gr in 0..groups {
                let mut last: Option<usize> = None;
                for t in 0..horizon {
                    if s.action(t, gr).relocalize {
                        last = Some(t);
                    }
                    assert_eq!(
                        s.last_relocalize(t, gr),
                        last,
                        "group {gr} step {t} sync={sync}"
                    );
                }
            }
        });
    }

    #[test]
    fn synchronous_mode_reselects_all_together() {
        let s = AsyncSchedule::new(4, 5, true);
        for gr in 0..4 {
            assert!(s.action(4, gr).relocalize);
            assert!(s.action(9, gr).relocalize);
            assert!(!s.action(7, gr).relocalize);
            assert!(s.action(0, gr).profile);
        }
    }
}
