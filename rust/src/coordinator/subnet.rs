//! Subnet optimizer state (Algorithm 2): per-matrix (ρ, γ) selection
//! plus compact Adam moments in the [np, mp] subnet frame, and the
//! generic dense Adam used by the baselines.

use crate::coordinator::localize::Selection;
use crate::tensor::Tensor;

/// Adam hyperparameters (β′₁, β′₂ in Algorithm 2).
#[derive(Debug, Clone, Copy)]
pub struct AdamParams {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
}

impl Default for AdamParams {
    fn default() -> Self {
        AdamParams {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
        }
    }
}

/// Dense Adam state over an arbitrary-shaped tensor.
#[derive(Debug, Clone)]
pub struct AdamState {
    pub m: Tensor,
    pub v: Tensor,
    pub step: u32,
    pub hp: AdamParams,
}

impl AdamState {
    pub fn new(shape: &[usize], hp: AdamParams) -> Self {
        AdamState {
            m: Tensor::zeros(shape),
            v: Tensor::zeros(shape),
            step: 0,
            hp,
        }
    }

    /// Compute the Adam update `lr · m̂ / (√v̂ + ε)` for gradient `g`
    /// and advance the moments. Returned tensor has `g`'s shape.
    pub fn update(&mut self, g: &Tensor, lr: f32) -> Tensor {
        assert_eq!(g.shape, self.m.shape, "adam: grad shape mismatch");
        self.step += 1;
        let (b1, b2) = (self.hp.beta1, self.hp.beta2);
        let bc1 = 1.0 - b1.powi(self.step as i32);
        let bc2 = 1.0 - b2.powi(self.step as i32);
        let mut out = Tensor::zeros(&g.shape);
        for k in 0..g.data.len() {
            let m = b1 * self.m.data[k] + (1.0 - b1) * g.data[k];
            let v = b2 * self.v.data[k]
                + (1.0 - b2) * g.data[k] * g.data[k];
            self.m.data[k] = m;
            self.v.data[k] = v;
            let m_hat = m / bc1;
            let v_hat = v / bc2;
            out.data[k] = lr * m_hat / (v_hat.sqrt() + self.hp.eps);
        }
        out
    }

    /// Reset moments (Algorithm 2 line 34 — after re-localization the
    /// subnet coordinates change meaning, so stale moments are invalid).
    pub fn reset(&mut self) {
        self.m.data.iter_mut().for_each(|x| *x = 0.0);
        self.v.data.iter_mut().for_each(|x| *x = 0.0);
        self.step = 0;
    }

    pub fn bytes(&self) -> usize {
        (self.m.len() + self.v.len()) * 4
    }
}

/// State of one matrix's core subnet: which neurons are selected and
/// the Adam moments living in the compact subnet frame.
#[derive(Debug, Clone)]
pub struct SubnetState {
    pub sel: Selection,
    pub adam: AdamState,
    /// full-matrix dims (n, m) for bounds checking
    pub n: usize,
    pub m: usize,
}

impl SubnetState {
    pub fn new(
        n: usize,
        m: usize,
        sel: Selection,
        hp: AdamParams,
    ) -> Self {
        let shape = [sel.rho.len(), sel.gamma.len()];
        SubnetState {
            sel,
            adam: AdamState::new(&shape, hp),
            n,
            m,
        }
    }

    /// One subnet Adam step in the compact [np, mp] frame: advance the
    /// moments and return `−lr·m̂/(√v̂+ε)` — the delta to *add*. The
    /// LoSiA-Pro driver accumulates these in the device-side `dws`
    /// frame; the host-gather path scatters them into W directly.
    pub fn delta_update(&mut self, g: &Tensor, lr: f32) -> Tensor {
        let mut upd = self.adam.update(g, lr);
        upd.scale_assign(-1.0);
        upd
    }

    /// Apply one subnet Adam step: given the subnet gradient
    /// `g ∈ R^{np×mp}`, update the moments and scatter
    /// `−lr·m̂/(√v̂+ε)` into the full weight `w` (Algorithm 2
    /// lines 18–24).
    pub fn apply_update(&mut self, w: &mut Tensor, g: &Tensor, lr: f32) {
        debug_assert_eq!(w.shape, vec![self.n, self.m]);
        let upd = self.delta_update(g, lr);
        w.scatter_add2(&self.sel.rho, &self.sel.gamma, &upd);
    }

    /// Swap in a new selection after re-localization; moments reset.
    pub fn relocalize(&mut self, sel: Selection) {
        assert_eq!(sel.rho.len(), self.sel.rho.len());
        assert_eq!(sel.gamma.len(), self.sel.gamma.len());
        self.sel = sel;
        self.adam.reset();
    }

    pub fn trainable_params(&self) -> usize {
        self.sel.rho.len() * self.sel.gamma.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    fn sel(rho: Vec<usize>, gamma: Vec<usize>) -> Selection {
        Selection { rho, gamma }
    }

    #[test]
    fn adam_first_step_is_signed_lr() {
        // With bias correction, step 1 gives lr · g/(|g|+ε) ≈ lr·sign(g).
        let mut a = AdamState::new(&[3], AdamParams::default());
        let g = Tensor::from_vec(&[3], vec![0.5, -2.0, 0.0]);
        let upd = a.update(&g, 0.01);
        assert!((upd.data[0] - 0.01).abs() < 1e-4);
        assert!((upd.data[1] + 0.01).abs() < 1e-4);
        assert_eq!(upd.data[2], 0.0);
    }

    #[test]
    fn adam_reset_clears_moments() {
        let mut a = AdamState::new(&[2], AdamParams::default());
        let g = Tensor::from_vec(&[2], vec![1.0, 1.0]);
        a.update(&g, 0.1);
        assert!(a.m.data[0] != 0.0);
        a.reset();
        assert_eq!(a.m.data, vec![0.0, 0.0]);
        assert_eq!(a.step, 0);
    }

    #[test]
    fn subnet_update_touches_only_subnet() {
        check("off-subnet weights frozen", 30, |g| {
            let n = g.size(2, 16);
            let m = g.size(2, 16);
            let np = g.size(1, n);
            let mp = g.size(1, m);
            let rho = g.distinct_indices(n, np);
            let gamma = g.distinct_indices(m, mp);
            let mut w =
                Tensor::from_vec(&[n, m], g.normal_vec(n * m, 1.0));
            let orig = w.clone();
            let mut st = SubnetState::new(
                n,
                m,
                sel(rho.clone(), gamma.clone()),
                AdamParams::default(),
            );
            let grad =
                Tensor::from_vec(&[np, mp], g.normal_vec(np * mp, 1.0));
            st.apply_update(&mut w, &grad, 0.1);
            for i in 0..n {
                for j in 0..m {
                    let inside = rho.contains(&i) && gamma.contains(&j);
                    let changed =
                        (w.at2(i, j) - orig.at2(i, j)).abs() > 0.0;
                    if !inside {
                        assert!(!changed, "off-subnet ({i},{j}) moved");
                    }
                }
            }
        });
    }

    #[test]
    fn subnet_update_descends_quadratic() {
        // Minimize f(W) = ½‖W‖² over the subnet: grad = W_sub.
        let n = 8;
        let mut w = Tensor::ones(&[n, n]);
        let rho = vec![0, 2, 4];
        let gamma = vec![1, 3];
        let mut st = SubnetState::new(
            n,
            n,
            sel(rho.clone(), gamma.clone()),
            AdamParams::default(),
        );
        for _ in 0..300 {
            let g = w.gather2(&rho, &gamma);
            st.apply_update(&mut w, &g, 0.05);
        }
        for &i in &rho {
            for &j in &gamma {
                assert!(w.at2(i, j).abs() < 0.05, "did not converge");
            }
        }
        assert_eq!(w.at2(1, 1), 1.0); // frozen
    }

    #[test]
    fn relocalize_resets_and_swaps() {
        let mut st = SubnetState::new(
            8,
            8,
            sel(vec![0, 1], vec![2, 3]),
            AdamParams::default(),
        );
        let g = Tensor::ones(&[2, 2]);
        let mut w = Tensor::zeros(&[8, 8]);
        st.apply_update(&mut w, &g, 0.1);
        assert!(st.adam.step == 1);
        st.relocalize(sel(vec![4, 5], vec![6, 7]));
        assert_eq!(st.adam.step, 0);
        assert_eq!(st.sel.rho, vec![4, 5]);
    }

    #[test]
    #[should_panic]
    fn relocalize_rejects_budget_change() {
        let mut st = SubnetState::new(
            8,
            8,
            sel(vec![0, 1], vec![2, 3]),
            AdamParams::default(),
        );
        st.relocalize(sel(vec![0], vec![1]));
    }
}
