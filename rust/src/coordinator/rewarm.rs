//! Learning-rate schedules + the LoSiA rewarming wrapper (Eq. 8).
//!
//! The base schedule is linear-warmup → cosine decay (the paper trains
//! with warmup ratio 0.1). After a group re-localizes at step `t_r`,
//! its effective LR ramps linearly from 0 back to the base schedule
//! over the following time slot:
//!
//! `lr̄(t) = ((t − t_r) / T) · lr(t)`  while `t − t_r < T` and the
//! global warmup already finished (Eq. 8's Cond).

/// Base LR schedule: linear warmup then cosine decay to `floor`.
#[derive(Debug, Clone)]
pub struct LrSchedule {
    pub base_lr: f64,
    pub total_steps: usize,
    pub warmup_steps: usize,
    pub floor: f64,
}

impl LrSchedule {
    pub fn new(base_lr: f64, total_steps: usize, warmup_ratio: f64) -> Self {
        LrSchedule {
            base_lr,
            total_steps: total_steps.max(1),
            warmup_steps: ((total_steps as f64) * warmup_ratio) as usize,
            floor: 0.0,
        }
    }

    /// lr(t) — 0-based step.
    pub fn lr(&self, t: usize) -> f64 {
        if self.warmup_steps > 0 && t < self.warmup_steps {
            return self.base_lr * (t + 1) as f64
                / self.warmup_steps as f64;
        }
        let denom = (self.total_steps - self.warmup_steps).max(1) as f64;
        let progress = ((t - self.warmup_steps) as f64 / denom).min(1.0);
        let cos = 0.5 * (1.0 + (std::f64::consts::PI * progress).cos());
        self.floor + (self.base_lr - self.floor) * cos
    }
}

/// Rewarming state for one weight group (Eq. 8).
#[derive(Debug, Clone, Copy)]
pub struct Rewarmer {
    /// time slot T (ramp length)
    pub time_slot: usize,
    /// disabled by the WDS ablation
    pub enabled: bool,
}

impl Rewarmer {
    /// Multiplier on the base LR for a group whose last re-localization
    /// happened at `last_reloc` (None = never), evaluated at step `t`.
    /// `warmup_steps` is the global warmup duration T_w: rewarmings
    /// only trigger after the initial warmup has finished.
    pub fn factor(
        &self,
        t: usize,
        last_reloc: Option<usize>,
        warmup_steps: usize,
    ) -> f64 {
        if !self.enabled {
            return 1.0;
        }
        let Some(tr) = last_reloc else {
            return 1.0;
        };
        if t <= warmup_steps {
            return 1.0;
        }
        let since = t.saturating_sub(tr);
        if since >= self.time_slot {
            1.0
        } else {
            since as f64 / self.time_slot as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;

    #[test]
    fn warmup_ramps_linearly() {
        let s = LrSchedule::new(1.0, 100, 0.1);
        assert!((s.lr(0) - 0.1).abs() < 1e-9);
        assert!((s.lr(4) - 0.5).abs() < 1e-9);
        assert!((s.lr(9) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn cosine_decays_monotonically() {
        let s = LrSchedule::new(1.0, 200, 0.1);
        let mut prev = f64::INFINITY;
        for t in s.warmup_steps..200 {
            let lr = s.lr(t);
            assert!(lr <= prev + 1e-12, "not monotone at {t}");
            assert!(lr >= 0.0);
            prev = lr;
        }
        assert!(s.lr(199) < 1e-3);
    }

    #[test]
    fn lr_never_exceeds_base() {
        check("0 <= lr(t) <= base", 30, |g| {
            let base = g.f32(1e-6, 1.0) as f64;
            let steps = g.size(2, 500);
            let ratio = g.f32(0.0, 0.5) as f64;
            let s = LrSchedule::new(base, steps, ratio);
            for t in 0..steps {
                let lr = s.lr(t);
                assert!(lr >= 0.0 && lr <= base + 1e-12);
            }
        });
    }

    #[test]
    fn rewarm_ramp_shape() {
        let r = Rewarmer {
            time_slot: 10,
            enabled: true,
        };
        // just re-localized at t=49 (after warmup of 5)
        assert_eq!(r.factor(49, Some(49), 5), 0.0);
        assert!((r.factor(54, Some(49), 5) - 0.5).abs() < 1e-12);
        assert_eq!(r.factor(59, Some(49), 5), 1.0);
        assert_eq!(r.factor(200, Some(49), 5), 1.0);
    }

    #[test]
    fn rewarm_suppressed_during_global_warmup() {
        let r = Rewarmer {
            time_slot: 10,
            enabled: true,
        };
        // Cond requires t > T_w: before warmup completes, no rewarming
        assert_eq!(r.factor(3, Some(2), 10), 1.0);
    }

    #[test]
    fn disabled_rewarmer_is_identity() {
        let r = Rewarmer {
            time_slot: 10,
            enabled: false,
        };
        assert_eq!(r.factor(50, Some(49), 0), 1.0);
    }

    #[test]
    fn factor_in_unit_interval() {
        check("0 <= factor <= 1", 50, |g| {
            let r = Rewarmer {
                time_slot: g.size(1, 50),
                enabled: g.bool(),
            };
            let t = g.size(0, 1000);
            let reloc = if g.bool() {
                Some(g.size(0, t.max(1)))
            } else {
                None
            };
            let w = g.size(0, 100);
            let f = r.factor(t, reloc, w);
            assert!((0.0..=1.0).contains(&f));
        });
    }
}
