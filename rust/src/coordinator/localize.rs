//! Core-subnet localization (paper §3.2, Algorithm 1, Appendix A.1.3).
//!
//! Given an importance matrix `s ∈ R^{n×m}` and rank factor `p`, find
//! input/output neuron sets (ρ, γ) with |ρ| = ⌊np⌋, |γ| = ⌊mp⌋
//! maximizing `s(S) = Σ_{i∈ρ} Σ_{j∈γ} s_ij` (Eq. 7). Exact optimization
//! is NP-hard (reduction from Maximum Clique — Appendix A.1.3), so two
//! greedy passes are run and the better one kept:
//!
//! * **Row2Column**: lock the ⌊np⌋ rows with the largest row sums, then
//!   keep the ⌊mp⌋ columns with the largest residual mass in those rows.
//! * **Column2Row**: the symmetric order.

use crate::tensor::select::topk_indices_fast;
use crate::tensor::Tensor;

/// A localized subnet: selected input neurons ρ and output neurons γ.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Selection {
    pub rho: Vec<usize>,
    pub gamma: Vec<usize>,
}

impl Selection {
    /// Random selection (used at step 0, Algorithm 2 line 3).
    pub fn random(
        n: usize,
        m: usize,
        np: usize,
        mp: usize,
        rng: &mut crate::util::rng::Rng,
    ) -> Selection {
        Selection {
            rho: rng.choose_distinct(n, np),
            gamma: rng.choose_distinct(m, mp),
        }
    }

    /// Subnet importance s(S) — Eq. 7.
    pub fn score(&self, s: &Tensor) -> f64 {
        let (_, m) = s.dims2();
        let mut total = 0.0f64;
        for &i in &self.rho {
            let row = &s.data[i * m..(i + 1) * m];
            for &j in &self.gamma {
                total += row[j] as f64;
            }
        }
        total
    }
}

/// Row-major greedy policy (Algorithm 1).
pub fn row2column(s: &Tensor, np: usize, mp: usize) -> Selection {
    let (_, m) = s.dims2();
    let rho = topk_indices_fast(&s.row_sums(), np);
    // residual mass per column restricted to the locked rows
    let mut col_mass = vec![0.0f32; m];
    for &i in &rho {
        let row = &s.data[i * m..(i + 1) * m];
        for j in 0..m {
            col_mass[j] += row[j];
        }
    }
    let gamma = topk_indices_fast(&col_mass, mp);
    Selection { rho, gamma }
}

/// Column-major greedy policy (the symmetric variant).
pub fn column2row(s: &Tensor, np: usize, mp: usize) -> Selection {
    let (n, m) = s.dims2();
    let gamma = topk_indices_fast(&s.col_sums(), mp);
    let mut row_mass = vec![0.0f32; n];
    for i in 0..n {
        let row = &s.data[i * m..(i + 1) * m];
        for &j in &gamma {
            row_mass[i] += row[j];
        }
    }
    let rho = topk_indices_fast(&row_mass, np);
    Selection { rho, gamma }
}

/// Run both greedy policies and keep the higher-scoring subnet
/// (Algorithm 2 lines 27–31).
pub fn localize(s: &Tensor, np: usize, mp: usize) -> Selection {
    let a = row2column(s, np, mp);
    let b = column2row(s, np, mp);
    if a.score(s) >= b.score(s) {
        a
    } else {
        b
    }
}

/// Output-layer localization (§3.2 "Dimensionality Reduction"): all
/// input neurons, top-⌊p_o·V⌋ output columns by column importance.
pub fn localize_columns(col_importance: &[f32], k: usize) -> Vec<usize> {
    topk_indices_fast(col_importance, k)
}

/// Ideal (unstructured) Top-K mass — the upper bound from Table 6.
pub fn topk_mass(s: &Tensor, k: usize) -> f64 {
    let mut vals: Vec<f32> = s.data.clone();
    vals.sort_by(|a, b| b.total_cmp(a));
    vals.iter().take(k).map(|&v| v as f64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::check;
    use crate::util::rng::Rng;

    fn planted_matrix(
        n: usize,
        m: usize,
        rho: &[usize],
        gamma: &[usize],
        rng: &mut Rng,
    ) -> Tensor {
        // background noise + strong block on (rho × gamma)
        let mut s = Tensor::zeros(&[n, m]);
        for v in s.data.iter_mut() {
            *v = rng.uniform() * 0.1;
        }
        for &i in rho {
            for &j in gamma {
                s.data[i * m + j] = 10.0 + rng.uniform();
            }
        }
        s
    }

    #[test]
    fn recovers_planted_subnet() {
        check("planted block is found exactly", 50, |g| {
            let n = g.size(8, 48);
            let m = g.size(8, 48);
            let np = g.size(1, n / 2);
            let mp = g.size(1, m / 2);
            let mut rng = g.rng();
            let rho_true = rng.choose_distinct(n, np);
            let gamma_true = rng.choose_distinct(m, mp);
            let s = planted_matrix(n, m, &rho_true, &gamma_true, &mut rng);
            let sel = localize(&s, np, mp);
            let mut want_r = rho_true.clone();
            let mut got_r = sel.rho.clone();
            want_r.sort_unstable();
            got_r.sort_unstable();
            assert_eq!(got_r, want_r, "rows");
            let mut want_c = gamma_true.clone();
            let mut got_c = sel.gamma.clone();
            want_c.sort_unstable();
            got_c.sort_unstable();
            assert_eq!(got_c, want_c, "cols");
        });
    }

    #[test]
    fn respects_cardinality_budget() {
        check("|rho| = np, |gamma| = mp, all distinct", 50, |g| {
            let n = g.size(2, 64);
            let m = g.size(2, 64);
            let np = g.size(1, n);
            let mp = g.size(1, m);
            let s = Tensor::from_vec(
                &[n, m],
                g.positive_vec(n * m),
            );
            let sel = localize(&s, np, mp);
            assert_eq!(sel.rho.len(), np);
            assert_eq!(sel.gamma.len(), mp);
            let mut r = sel.rho.clone();
            r.sort_unstable();
            r.dedup();
            assert_eq!(r.len(), np);
            assert!(r.iter().all(|&i| i < n));
            let mut c = sel.gamma.clone();
            c.sort_unstable();
            c.dedup();
            assert_eq!(c.len(), mp);
            assert!(c.iter().all(|&j| j < m));
        });
    }

    #[test]
    fn beats_random_selection() {
        check("greedy >= random score", 50, |g| {
            let n = g.size(4, 64);
            let m = g.size(4, 64);
            let np = g.size(1, n);
            let mp = g.size(1, m);
            let s = Tensor::from_vec(&[n, m], g.positive_vec(n * m));
            let sel = localize(&s, np, mp);
            let mut rng = g.rng();
            let rand = Selection::random(n, m, np, mp, &mut rng);
            assert!(sel.score(&s) >= rand.score(&s) - 1e-6);
        });
    }

    #[test]
    fn bounded_by_ideal_topk() {
        check("subnet mass <= ideal topk mass", 50, |g| {
            let n = g.size(2, 32);
            let m = g.size(2, 32);
            let np = g.size(1, n);
            let mp = g.size(1, m);
            let s = Tensor::from_vec(&[n, m], g.positive_vec(n * m));
            let sel = localize(&s, np, mp);
            let ideal = topk_mass(&s, np * mp);
            assert!(sel.score(&s) <= ideal + 1e-4);
        });
    }

    #[test]
    fn best_of_two_is_max() {
        check("localize == max(row2col, col2row)", 50, |g| {
            let n = g.size(2, 32);
            let m = g.size(2, 32);
            let np = g.size(1, n);
            let mp = g.size(1, m);
            let s = Tensor::from_vec(&[n, m], g.positive_vec(n * m));
            let a = row2column(&s, np, mp).score(&s);
            let b = column2row(&s, np, mp).score(&s);
            let best = localize(&s, np, mp).score(&s);
            assert!((best - a.max(b)).abs() < 1e-9);
        });
    }

    #[test]
    fn column_localization_picks_top_columns() {
        let imp = vec![0.1, 5.0, 0.2, 4.0, 0.3];
        assert_eq!(localize_columns(&imp, 2), vec![1, 3]);
    }
}
