//! Model parameter store — the host-side mirror of the artifact ABI.
//!
//! Parameters are kept in the canonical order defined by
//! `python/compile/model.py::param_specs`; [`ModelState::as_inputs`]
//! produces the flat `HostValue` list every artifact starts with.

use std::collections::BTreeMap;

use crate::config::ModelCfg;
use crate::runtime::HostValue;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Named parameter tensors in ABI order.
#[derive(Debug, Clone)]
pub struct ModelState {
    /// ABI order (name, tensor)
    pub params: Vec<(String, Tensor)>,
    index: BTreeMap<String, usize>,
}

impl ModelState {
    /// Scaled-normal init matching `model.init_params` semantics:
    /// norms = 1, everything else ~ N(0, 1/fan_in).
    pub fn init(cfg: &ModelCfg, rng: &mut Rng) -> Self {
        let mut params = Vec::new();
        let mut index = BTreeMap::new();
        for (name, shape) in &cfg.params {
            let t = if name.starts_with("norm") {
                Tensor::ones(shape)
            } else {
                let fan_in = if shape.len() >= 2 {
                    shape[shape.len() - 2]
                } else {
                    shape[shape.len() - 1]
                };
                Tensor::randn(
                    shape,
                    1.0 / (fan_in as f32).sqrt(),
                    rng,
                )
            };
            index.insert(name.clone(), params.len());
            params.push((name.clone(), t));
        }
        ModelState { params, index }
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.params[self.index[name]].1
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        let i = self.index[name];
        &mut self.params[i].1
    }

    /// Flat parameter inputs for an artifact call (cheap clones of the
    /// backing Vec<f32>; see metrics for the copy-cost accounting).
    pub fn as_inputs(&self) -> Vec<HostValue> {
        self.params
            .iter()
            .map(|(_, t)| HostValue::F32(t.clone()))
            .collect()
    }

    /// One layer of a stacked parameter ([L, ...] → [...]).
    pub fn layer(&self, name: &str, l: usize) -> Tensor {
        self.get(name).index_axis0(l)
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|(_, t)| t.len()).sum()
    }

    /// L2 distance to another state (continual-learning drift metric).
    pub fn l2_distance(&self, other: &ModelState) -> f64 {
        let mut acc = 0.0f64;
        for ((_, a), (_, b)) in self.params.iter().zip(&other.params) {
            for (x, y) in a.data.iter().zip(&b.data) {
                acc += ((x - y) as f64).powi(2);
            }
        }
        acc.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::load_manifest;
    use crate::runtime::artifacts_dir;

    fn tiny() -> ModelCfg {
        load_manifest(&artifacts_dir(), "tiny").unwrap()
    }

    #[test]
    fn init_matches_manifest_shapes() {
        let cfg = tiny();
        let mut rng = Rng::new(0);
        let st = ModelState::init(&cfg, &mut rng);
        assert_eq!(st.params.len(), cfg.params.len());
        for ((name, t), (mname, mshape)) in
            st.params.iter().zip(&cfg.params)
        {
            assert_eq!(name, mname);
            assert_eq!(&t.shape, mshape);
        }
        assert_eq!(st.total_params(), cfg.param_count);
    }

    #[test]
    fn norms_are_ones() {
        let cfg = tiny();
        let mut rng = Rng::new(0);
        let st = ModelState::init(&cfg, &mut rng);
        assert!(st.get("norm_f").data.iter().all(|&x| x == 1.0));
        assert!(st.get("norm1").data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn layer_slicing() {
        let cfg = tiny();
        let mut rng = Rng::new(1);
        let st = ModelState::init(&cfg, &mut rng);
        let wq = st.get("wq");
        let l0 = st.layer("wq", 0);
        assert_eq!(l0.shape, vec![cfg.d_model, cfg.d_model]);
        assert_eq!(l0.data[..8], wq.data[..8]);
    }

    #[test]
    fn l2_distance_zero_to_self() {
        let cfg = tiny();
        let mut rng = Rng::new(2);
        let st = ModelState::init(&cfg, &mut rng);
        assert_eq!(st.l2_distance(&st), 0.0);
    }
}
