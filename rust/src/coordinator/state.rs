//! Model parameter store — the host-side mirror of the artifact ABI.
//!
//! Parameters are kept in the canonical order defined by
//! `python/compile/model.py::param_specs`;
//! `ExecPlan::bind_params` uploads them by name.

use std::collections::BTreeMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelCfg;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

const STATE_MAGIC: &[u8; 8] = b"LOSIAST1";

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut buf = [0u8; 4];
    r.read_exact(&mut buf)?;
    Ok(u32::from_le_bytes(buf))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut buf = [0u8; 8];
    r.read_exact(&mut buf)?;
    Ok(u64::from_le_bytes(buf))
}

/// Named parameter tensors in ABI order.
#[derive(Debug, Clone)]
pub struct ModelState {
    /// ABI order (name, tensor)
    pub params: Vec<(String, Tensor)>,
    index: BTreeMap<String, usize>,
}

impl ModelState {
    /// Scaled-normal init matching `model.init_params` semantics:
    /// norms = 1, everything else ~ N(0, 1/fan_in).
    pub fn init(cfg: &ModelCfg, rng: &mut Rng) -> Self {
        let mut params = Vec::new();
        let mut index = BTreeMap::new();
        for (name, shape) in &cfg.params {
            let t = if name.starts_with("norm") {
                Tensor::ones(shape)
            } else {
                let fan_in = if shape.len() >= 2 {
                    shape[shape.len() - 2]
                } else {
                    shape[shape.len() - 1]
                };
                Tensor::randn(
                    shape,
                    1.0 / (fan_in as f32).sqrt(),
                    rng,
                )
            };
            index.insert(name.clone(), params.len());
            params.push((name.clone(), t));
        }
        ModelState { params, index }
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.params[self.index[name]].1
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        let i = self.index[name];
        &mut self.params[i].1
    }

    /// One layer of a stacked parameter ([L, ...] → [...]).
    pub fn layer(&self, name: &str, l: usize) -> Tensor {
        self.get(name).index_axis0(l)
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|(_, t)| t.len()).sum()
    }

    /// Serialize all parameters to a checkpoint file (little-endian
    /// f32, ABI order) loadable via [`ModelState::load`].
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        let f = std::fs::File::create(path)
            .with_context(|| format!("creating {}", path.display()))?;
        let mut w = BufWriter::new(f);
        w.write_all(STATE_MAGIC)?;
        w.write_all(&(self.params.len() as u32).to_le_bytes())?;
        for (name, t) in &self.params {
            w.write_all(&(name.len() as u32).to_le_bytes())?;
            w.write_all(name.as_bytes())?;
            w.write_all(&(t.shape.len() as u32).to_le_bytes())?;
            for &d in &t.shape {
                w.write_all(&(d as u64).to_le_bytes())?;
            }
            // one bulk write per tensor (multi-million-element params)
            let bytes: Vec<u8> = t
                .data
                .iter()
                .flat_map(|x| x.to_le_bytes())
                .collect();
            w.write_all(&bytes)?;
        }
        w.flush()?;
        Ok(())
    }

    /// Load a checkpoint saved by [`ModelState::save`], validating
    /// every parameter name and shape against `cfg`'s ABI.
    pub fn load(path: &Path, cfg: &ModelCfg) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = BufReader::new(f);
        let mut magic = [0u8; 8];
        r.read_exact(&mut magic)?;
        if &magic != STATE_MAGIC {
            bail!(
                "{} is not a LoSiA state file (bad magic)",
                path.display()
            );
        }
        let count = read_u32(&mut r)? as usize;
        if count != cfg.params.len() {
            bail!(
                "state file has {count} params, config {:?} expects {}",
                cfg.name,
                cfg.params.len()
            );
        }
        let mut params = Vec::with_capacity(count);
        let mut index = BTreeMap::new();
        for (ename, eshape) in &cfg.params {
            let nlen = read_u32(&mut r)? as usize;
            let mut nbuf = vec![0u8; nlen];
            r.read_exact(&mut nbuf)?;
            let name = String::from_utf8(nbuf)
                .context("state file: non-UTF8 parameter name")?;
            if &name != ename {
                bail!(
                    "state file param {name:?} does not match config \
                     ABI order (expected {ename:?})"
                );
            }
            let ndim = read_u32(&mut r)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut r)? as usize);
            }
            if &shape != eshape {
                bail!(
                    "state file param {name:?} has shape {shape:?}, \
                     config expects {eshape:?}"
                );
            }
            let numel: usize = shape.iter().product();
            let mut bytes = vec![0u8; numel * 4];
            r.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            index.insert(name.clone(), params.len());
            params.push((name, Tensor::from_vec(&shape, data)));
        }
        Ok(ModelState { params, index })
    }

    /// L2 distance to another state (continual-learning drift metric).
    pub fn l2_distance(&self, other: &ModelState) -> f64 {
        let mut acc = 0.0f64;
        for ((_, a), (_, b)) in self.params.iter().zip(&other.params) {
            for (x, y) in a.data.iter().zip(&b.data) {
                acc += ((x - y) as f64).powi(2);
            }
        }
        acc.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::resolve_config;
    use crate::runtime::artifacts_dir;

    fn tiny() -> ModelCfg {
        resolve_config(&artifacts_dir(), "tiny").unwrap()
    }

    #[test]
    fn init_matches_manifest_shapes() {
        let cfg = tiny();
        let mut rng = Rng::new(0);
        let st = ModelState::init(&cfg, &mut rng);
        assert_eq!(st.params.len(), cfg.params.len());
        for ((name, t), (mname, mshape)) in
            st.params.iter().zip(&cfg.params)
        {
            assert_eq!(name, mname);
            assert_eq!(&t.shape, mshape);
        }
        assert_eq!(st.total_params(), cfg.param_count);
    }

    #[test]
    fn norms_are_ones() {
        let cfg = tiny();
        let mut rng = Rng::new(0);
        let st = ModelState::init(&cfg, &mut rng);
        assert!(st.get("norm_f").data.iter().all(|&x| x == 1.0));
        assert!(st.get("norm1").data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn layer_slicing() {
        let cfg = tiny();
        let mut rng = Rng::new(1);
        let st = ModelState::init(&cfg, &mut rng);
        let wq = st.get("wq");
        let l0 = st.layer("wq", 0);
        assert_eq!(l0.shape, vec![cfg.d_model, cfg.d_model]);
        assert_eq!(l0.data[..8], wq.data[..8]);
    }

    #[test]
    fn save_load_round_trips() {
        let cfg = tiny();
        let mut rng = Rng::new(3);
        let st = ModelState::init(&cfg, &mut rng);
        let path = std::env::temp_dir()
            .join(format!("losia_state_{}.bin", std::process::id()));
        st.save(&path).unwrap();
        let back = ModelState::load(&path, &cfg).unwrap();
        let _ = std::fs::remove_file(&path);
        for ((n0, t0), (n1, t1)) in st.params.iter().zip(&back.params)
        {
            assert_eq!(n0, n1);
            assert_eq!(t0.shape, t1.shape);
            assert_eq!(t0.data, t1.data);
        }
    }

    #[test]
    fn load_rejects_garbage() {
        let cfg = tiny();
        let path = std::env::temp_dir()
            .join(format!("losia_garbage_{}.bin", std::process::id()));
        std::fs::write(&path, b"definitely not a state file").unwrap();
        let err = ModelState::load(&path, &cfg).unwrap_err();
        let _ = std::fs::remove_file(&path);
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn l2_distance_zero_to_self() {
        let cfg = tiny();
        let mut rng = Rng::new(2);
        let st = ModelState::init(&cfg, &mut rng);
        assert_eq!(st.l2_distance(&st), 0.0);
    }
}
