//! Model parameter store — the host-side mirror of the artifact ABI.
//!
//! Parameters are kept in the canonical order defined by
//! `python/compile/model.py::param_specs`;
//! `ExecPlan::bind_params` uploads them by name.

use std::collections::BTreeMap;
use std::io::{BufReader, Read, Write};
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::config::ModelCfg;
use crate::tensor::Tensor;
use crate::util::durable::{
    self, Header, SectionReader, SectionWriter,
};
use crate::util::rng::Rng;

const STATE_MAGIC: &[u8; 8] = b"LOSIAST1";

/// Format version written after the sentinel; bumped when the payload
/// layout changes (v1 = sectioned CRC layout, PR 10).
const STATE_VERSION: u32 = 1;

/// Named parameter tensors in ABI order.
#[derive(Debug, Clone)]
pub struct ModelState {
    /// ABI order (name, tensor)
    pub params: Vec<(String, Tensor)>,
    index: BTreeMap<String, usize>,
}

impl ModelState {
    /// Scaled-normal init matching `model.init_params` semantics:
    /// norms = 1, everything else ~ N(0, 1/fan_in).
    pub fn init(cfg: &ModelCfg, rng: &mut Rng) -> Self {
        let mut params = Vec::new();
        let mut index = BTreeMap::new();
        for (name, shape) in &cfg.params {
            let t = if name.starts_with("norm") {
                Tensor::ones(shape)
            } else {
                let fan_in = if shape.len() >= 2 {
                    shape[shape.len() - 2]
                } else {
                    shape[shape.len() - 1]
                };
                Tensor::randn(
                    shape,
                    1.0 / (fan_in as f32).sqrt(),
                    rng,
                )
            };
            index.insert(name.clone(), params.len());
            params.push((name.clone(), t));
        }
        ModelState { params, index }
    }

    pub fn get(&self, name: &str) -> &Tensor {
        &self.params[self.index[name]].1
    }

    pub fn get_mut(&mut self, name: &str) -> &mut Tensor {
        let i = self.index[name];
        &mut self.params[i].1
    }

    /// One layer of a stacked parameter ([L, ...] → [...]).
    pub fn layer(&self, name: &str, l: usize) -> Tensor {
        self.get(name).index_axis0(l)
    }

    pub fn total_params(&self) -> usize {
        self.params.iter().map(|(_, t)| t.len()).sum()
    }

    /// Serialize the parameter payload (count section, then one
    /// CRC-closed section per tensor) into an open section writer.
    /// Shared by [`ModelState::save`] and the training-checkpoint
    /// record, which embeds a state inline. Floats stream through the
    /// writer's fixed frames — no tensor-sized byte buffer is built.
    pub fn write_into<W: Write>(
        &self,
        w: &mut SectionWriter<W>,
    ) -> Result<()> {
        w.u32(self.params.len() as u32)?;
        w.end_section()?;
        for (name, t) in &self.params {
            w.str(name)?;
            w.u32(t.shape.len() as u32)?;
            for &d in &t.shape {
                w.u64(d as u64)?;
            }
            w.f32s(&t.data)?;
            w.end_section()?;
        }
        Ok(())
    }

    /// Read a parameter payload written by [`ModelState::write_into`]
    /// (or by the legacy pre-CRC writer — the byte layout inside
    /// sections is identical), validating every name and shape
    /// against `cfg`'s ABI. `count` is the already-read parameter
    /// count (header word in legacy files, count section otherwise).
    pub fn read_from<R: Read>(
        r: &mut SectionReader<R>,
        cfg: &ModelCfg,
        count: usize,
    ) -> Result<Self> {
        if count != cfg.params.len() {
            bail!(
                "state file has {count} params, config {:?} expects {}",
                cfg.name,
                cfg.params.len()
            );
        }
        let mut params = Vec::with_capacity(count);
        let mut index = BTreeMap::new();
        for (ename, eshape) in &cfg.params {
            r.section(ename);
            let name = r.str()?;
            if &name != ename {
                bail!(
                    "state file param {name:?} does not match config \
                     ABI order (expected {ename:?})"
                );
            }
            let ndim = r.u32()? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(r.u64()? as usize);
            }
            if &shape != eshape {
                bail!(
                    "state file param {name:?} has shape {shape:?}, \
                     config expects {eshape:?}"
                );
            }
            let numel: usize = shape.iter().product();
            let mut data = vec![0f32; numel];
            r.f32s(&mut data)?;
            r.end_section()?;
            index.insert(name.clone(), params.len());
            params.push((name, Tensor::from_vec(&shape, data)));
        }
        Ok(ModelState { params, index })
    }

    /// Serialize all parameters to a state file (little-endian f32,
    /// ABI order) loadable via [`ModelState::load`]. The write is
    /// atomic (tmp + fsync + rename) and every section carries a
    /// CRC32, so a crash mid-save leaves the previous file intact and
    /// torn bytes are detected at load, never silently trained on.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            let _ = std::fs::create_dir_all(dir);
        }
        durable::atomic_write(path, "save", 0, |w| {
            durable::write_header(w, STATE_MAGIC, STATE_VERSION)?;
            self.write_into(w)
        })
    }

    /// Load a state file saved by [`ModelState::save`], validating
    /// every parameter name and shape against `cfg`'s ABI. Files
    /// written before the durability rework (no version sentinel, no
    /// CRCs) still load, with a one-line warning and no checksum
    /// verification.
    pub fn load(path: &Path, cfg: &ModelCfg) -> Result<Self> {
        let f = std::fs::File::open(path)
            .with_context(|| format!("opening {}", path.display()))?;
        let mut r = SectionReader::new(
            BufReader::new(f),
            path.display().to_string(),
        );
        let count = match r.read_header(STATE_MAGIC)? {
            Header::Versioned(v) => {
                if v > STATE_VERSION {
                    bail!(
                        "{}: state format version {v} is newer than \
                         this build understands (max {STATE_VERSION})",
                        path.display()
                    );
                }
                r.section("count");
                let count = r.u32()? as usize;
                r.end_section()?;
                count
            }
            Header::Legacy(count) => {
                crate::util::warn::warn(format!(
                    "{}: pre-durability state file (no CRC \
                     sections); loading without verification",
                    path.display()
                ));
                count as usize
            }
        };
        Self::read_from(&mut r, cfg, count)
    }

    /// L2 distance to another state (continual-learning drift metric).
    pub fn l2_distance(&self, other: &ModelState) -> f64 {
        let mut acc = 0.0f64;
        for ((_, a), (_, b)) in self.params.iter().zip(&other.params) {
            for (x, y) in a.data.iter().zip(&b.data) {
                acc += ((x - y) as f64).powi(2);
            }
        }
        acc.sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::resolve_config;
    use crate::runtime::artifacts_dir;

    fn tiny() -> ModelCfg {
        resolve_config(&artifacts_dir(), "tiny").unwrap()
    }

    #[test]
    fn init_matches_manifest_shapes() {
        let cfg = tiny();
        let mut rng = Rng::new(0);
        let st = ModelState::init(&cfg, &mut rng);
        assert_eq!(st.params.len(), cfg.params.len());
        for ((name, t), (mname, mshape)) in
            st.params.iter().zip(&cfg.params)
        {
            assert_eq!(name, mname);
            assert_eq!(&t.shape, mshape);
        }
        assert_eq!(st.total_params(), cfg.param_count);
    }

    #[test]
    fn norms_are_ones() {
        let cfg = tiny();
        let mut rng = Rng::new(0);
        let st = ModelState::init(&cfg, &mut rng);
        assert!(st.get("norm_f").data.iter().all(|&x| x == 1.0));
        assert!(st.get("norm1").data.iter().all(|&x| x == 1.0));
    }

    #[test]
    fn layer_slicing() {
        let cfg = tiny();
        let mut rng = Rng::new(1);
        let st = ModelState::init(&cfg, &mut rng);
        let wq = st.get("wq");
        let l0 = st.layer("wq", 0);
        assert_eq!(l0.shape, vec![cfg.d_model, cfg.d_model]);
        assert_eq!(l0.data[..8], wq.data[..8]);
    }

    #[test]
    fn save_load_round_trips() {
        let cfg = tiny();
        let mut rng = Rng::new(3);
        let st = ModelState::init(&cfg, &mut rng);
        let path = std::env::temp_dir()
            .join(format!("losia_state_{}.bin", std::process::id()));
        st.save(&path).unwrap();
        let back = ModelState::load(&path, &cfg).unwrap();
        let _ = std::fs::remove_file(&path);
        for ((n0, t0), (n1, t1)) in st.params.iter().zip(&back.params)
        {
            assert_eq!(n0, n1);
            assert_eq!(t0.shape, t1.shape);
            assert_eq!(t0.data, t1.data);
        }
    }

    /// Write `st` in the pre-PR-10 layout: magic, bare u32 count, then
    /// per param (u32 name len, name, u32 ndim, u64 dims, raw f32s) —
    /// no version sentinel, no CRCs.
    fn write_legacy(st: &ModelState, path: &Path) {
        let mut buf = Vec::new();
        buf.extend_from_slice(STATE_MAGIC);
        buf.extend_from_slice(
            &(st.params.len() as u32).to_le_bytes(),
        );
        for (name, t) in &st.params {
            buf.extend_from_slice(
                &(name.len() as u32).to_le_bytes(),
            );
            buf.extend_from_slice(name.as_bytes());
            buf.extend_from_slice(
                &(t.shape.len() as u32).to_le_bytes(),
            );
            for &d in &t.shape {
                buf.extend_from_slice(&(d as u64).to_le_bytes());
            }
            for x in &t.data {
                buf.extend_from_slice(&x.to_le_bytes());
            }
        }
        std::fs::write(path, buf).unwrap();
    }

    #[test]
    fn legacy_state_file_loads_with_a_warning() {
        let cfg = tiny();
        let mut rng = Rng::new(8);
        let st = ModelState::init(&cfg, &mut rng);
        let path = std::env::temp_dir()
            .join(format!("losia_legacy_{}.bin", std::process::id()));
        write_legacy(&st, &path);
        let cap = crate::util::warn::capture();
        let back = ModelState::load(&path, &cfg).unwrap();
        let warns = cap.drain();
        let _ = std::fs::remove_file(&path);
        assert!(
            warns.iter().any(|w| w.contains("pre-durability")),
            "expected a legacy-format warning, got {warns:?}"
        );
        for ((n0, t0), (n1, t1)) in st.params.iter().zip(&back.params)
        {
            assert_eq!(n0, n1);
            assert_eq!(t0.data, t1.data);
        }
    }

    #[test]
    fn truncated_state_file_is_a_typed_error() {
        let cfg = tiny();
        let mut rng = Rng::new(9);
        let st = ModelState::init(&cfg, &mut rng);
        let path = std::env::temp_dir().join(format!(
            "losia_truncated_{}.bin",
            std::process::id()
        ));
        st.save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        std::fs::write(&path, &full[..full.len() - 10]).unwrap();
        let err = ModelState::load(&path, &cfg).unwrap_err();
        let _ = std::fs::remove_file(&path);
        use crate::util::error::TrainError;
        match err.downcast_ref::<TrainError>() {
            Some(TrainError::Truncated {
                file,
                expected,
                available,
                ..
            }) => {
                assert!(file.contains("losia_truncated"));
                assert!(expected > available);
            }
            other => panic!("wrong variant: {other:?}"),
        }
    }

    #[test]
    fn corrupted_state_file_is_a_crc_mismatch() {
        let cfg = tiny();
        let mut rng = Rng::new(10);
        let st = ModelState::init(&cfg, &mut rng);
        let path = std::env::temp_dir().join(format!(
            "losia_corrupt_{}.bin",
            std::process::id()
        ));
        st.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0x01;
        std::fs::write(&path, &bytes).unwrap();
        let res = ModelState::load(&path, &cfg);
        let _ = std::fs::remove_file(&path);
        let err = match res {
            // the flipped bit usually only breaks a CRC …
            Err(e) => e,
            Ok(_) => panic!("corruption must not load cleanly"),
        };
        // … but may also corrupt a length/shape word first; either
        // way the load fails — when it reaches the CRC, the error is
        // the typed mismatch
        use crate::util::error::TrainError;
        if let Some(TrainError::CrcMismatch { file, .. }) =
            err.downcast_ref::<TrainError>()
        {
            assert!(file.contains("losia_corrupt"));
        }
    }

    #[test]
    fn save_is_atomic_under_an_injected_partial_write() {
        let _guard =
            match crate::util::faultpoint::ENV_LOCK.lock() {
                Ok(g) => g,
                Err(p) => p.into_inner(),
            };
        let cfg = tiny();
        let mut rng = Rng::new(11);
        let st = ModelState::init(&cfg, &mut rng);
        let path = std::env::temp_dir().join(format!(
            "losia_atomic_{}.bin",
            std::process::id()
        ));
        st.save(&path).unwrap();
        let v1 = std::fs::read(&path).unwrap();
        // second save dies mid-write: previous file must still load
        std::env::set_var(
            crate::util::faultpoint::ENV,
            "save@0:partial",
        );
        let mut st2 = st.clone();
        st2.params[0].1.data[0] += 1.0;
        assert!(st2.save(&path).is_err());
        std::env::remove_var(crate::util::faultpoint::ENV);
        assert_eq!(std::fs::read(&path).unwrap(), v1);
        let back = ModelState::load(&path, &cfg).unwrap();
        assert_eq!(back.params[0].1.data[0], st.params[0].1.data[0]);
        let _ = std::fs::remove_file(&path);
        let _ =
            std::fs::remove_file(crate::util::durable::tmp_path(&path));
    }

    #[test]
    fn load_rejects_garbage() {
        let cfg = tiny();
        let path = std::env::temp_dir()
            .join(format!("losia_garbage_{}.bin", std::process::id()));
        std::fs::write(&path, b"definitely not a state file").unwrap();
        let err = ModelState::load(&path, &cfg).unwrap_err();
        let _ = std::fs::remove_file(&path);
        assert!(err.to_string().contains("bad magic"), "{err}");
    }

    #[test]
    fn l2_distance_zero_to_self() {
        let cfg = tiny();
        let mut rng = Rng::new(2);
        let st = ModelState::init(&cfg, &mut rng);
        assert_eq!(st.l2_distance(&st), 0.0);
    }
}
