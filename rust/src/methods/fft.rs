//! Full-parameter fine-tuning (the FFT upper-bound baseline).
//!
//! Every parameter is mutated every step, so the execution plans hold
//! no static bindings — the whole state re-uploads per step (that IS
//! the method's traffic cost; Table 16's "Other" column shows it).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{Method, TrainConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::state::ModelState;
use crate::coordinator::subnet::{AdamParams, AdamState};
use crate::util::durable::{SectionReader, SectionWriter};
use crate::data::Batch;
use crate::methods::{batch_stagers, grads_artifact, Driver};
use crate::runtime::dp::{self, Frame, GradFrames, ShardedGrads};
use crate::runtime::{ExecPlan, Runtime, Stager};

pub struct FftDriver {
    /// One replicated plan per data-parallel worker (one when dp is
    /// off); workers execute disjoint shard blocks on their replica.
    plans: Vec<ExecPlan>,
    adam: BTreeMap<String, AdamState>,
    total: usize,
    /// pipelined mode: the trainer commits staged batch uploads, so
    /// the shard closure skips the inline `bind_batch`
    pipelined: bool,
}

impl FftDriver {
    pub fn new(rt: &Runtime, tc: &TrainConfig) -> Result<Self> {
        let exe =
            rt.load(&grads_artifact("grads_full", tc.use_remat, rt))?;
        let n_plans = dp::plan_count(rt, tc)?;
        let mut plans = Vec::with_capacity(n_plans);
        for _ in 0..n_plans {
            plans.push(ExecPlan::new(exe.clone(), &[])?);
        }
        let hp = AdamParams {
            beta1: tc.adam_beta1 as f32,
            beta2: tc.adam_beta2 as f32,
            eps: tc.adam_eps as f32,
        };
        let mut adam = BTreeMap::new();
        let mut total = 0usize;
        for (name, shape) in &rt.cfg.params {
            adam.insert(name.clone(), AdamState::new(shape, hp));
            total += shape.iter().product::<usize>();
        }
        Ok(FftDriver {
            plans,
            adam,
            total,
            pipelined: false,
        })
    }
}

impl Driver for FftDriver {
    fn method(&self) -> Method {
        Method::Fft
    }

    fn trainable_params(&self) -> usize {
        self.total
    }

    fn grad_frames_sharded(
        &mut self,
        state: &ModelState,
        batches: &[Batch],
        t: usize,
    ) -> Result<ShardedGrads> {
        let pipelined = self.pipelined;
        let (shards, worker_nanos) =
            dp::run_sharded(&mut self.plans, batches, t, |_, plan, batch| {
                plan.bind_params(state)?;
                if !pipelined {
                    plan.bind_batch(batch)?;
                }
                // full fine-tuning consumes every gradient, so every
                // handle downloads — Table 16's "Other" column shows
                // this traffic
                let mut out = plan.run()?.into_iter();
                let loss = out
                    .next()
                    .expect("loss output")
                    .into_host()?
                    .data[0] as f64;
                let mut frames = Vec::new();
                for h in out {
                    let name = h
                        .name()
                        .strip_prefix("g_")
                        .expect("grad output name")
                        .to_string();
                    frames.push(Frame { name, grad: h.into_host()? });
                }
                Ok(GradFrames { loss, frames, probe: None })
            })?;
        Ok(ShardedGrads { shards, worker_nanos })
    }

    fn apply_frames(
        &mut self,
        state: &mut ModelState,
        reduced: GradFrames,
        _t: usize,
        lr: f64,
    ) -> Result<f64> {
        for Frame { name, grad } in reduced.frames {
            let adam = self.adam.get_mut(&name).unwrap();
            let mut upd = adam.update(&grad, lr as f32);
            upd.scale_assign(-1.0);
            state.get_mut(&name).add_assign(&upd);
        }
        Ok(reduced.loss)
    }

    fn make_stagers(&mut self) -> Result<Vec<Stager>> {
        let stagers =
            batch_stagers(&self.plans, &self.prefetchable())?;
        self.pipelined = true;
        Ok(stagers)
    }

    fn commit_stager(
        &mut self,
        shard: usize,
        stager: Stager,
    ) -> Result<Stager> {
        self.plans[shard].commit_stager(stager)
    }

    fn reduce_set(&self) -> Vec<(String, u64)> {
        // every parameter gradient crosses the reduction
        self.adam
            .iter()
            .map(|(name, st)| (name.clone(), 4 * st.m.len() as u64))
            .collect()
    }

    fn snapshot(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        let mut w = SectionWriter::new(&mut buf);
        w.u32(self.adam.len() as u32)?;
        for (name, a) in &self.adam {
            w.str(name)?;
            checkpoint::write_adam(&mut w, a)?;
        }
        w.end_section()?;
        drop(w);
        Ok(buf)
    }

    fn restore(
        &mut self,
        blob: &[u8],
        _state: &ModelState,
    ) -> Result<()> {
        let mut r = SectionReader::new(
            std::io::Cursor::new(blob),
            "driver snapshot (FFT)",
        );
        r.section("adam");
        let count = r.u32()? as usize;
        anyhow::ensure!(
            count == self.adam.len(),
            "checkpoint has {count} Adam entries, this run expects {}",
            self.adam.len()
        );
        for _ in 0..count {
            let name = r.str()?;
            let a = self.adam.get_mut(&name).ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint names unknown parameter {name:?}"
                )
            })?;
            checkpoint::read_adam_into(&mut r, a)?;
        }
        r.end_section()?;
        // no static bindings: FFT re-uploads the whole state per step
        Ok(())
    }
}
