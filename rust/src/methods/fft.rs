//! Full-parameter fine-tuning (the FFT upper-bound baseline).
//!
//! Every parameter is mutated every step, so the execution plan holds
//! no static bindings — the whole state re-uploads per step (that IS
//! the method's traffic cost; Table 16's "Other" column shows it).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{Method, TrainConfig};
use crate::coordinator::state::ModelState;
use crate::coordinator::subnet::{AdamParams, AdamState};
use crate::data::Batch;
use crate::methods::{grads_artifact, Driver};
use crate::runtime::{ExecPlan, Runtime};

pub struct FftDriver {
    plan: ExecPlan,
    adam: BTreeMap<String, AdamState>,
    total: usize,
}

impl FftDriver {
    pub fn new(rt: &Runtime, tc: &TrainConfig) -> Result<Self> {
        let exe =
            rt.load(&grads_artifact("grads_full", tc.use_remat, rt))?;
        let plan = ExecPlan::new(exe, &[])?;
        let hp = AdamParams {
            beta1: tc.adam_beta1 as f32,
            beta2: tc.adam_beta2 as f32,
            eps: tc.adam_eps as f32,
        };
        let mut adam = BTreeMap::new();
        let mut total = 0usize;
        for (name, shape) in &rt.cfg.params {
            adam.insert(name.clone(), AdamState::new(shape, hp));
            total += shape.iter().product::<usize>();
        }
        Ok(FftDriver { plan, adam, total })
    }
}

impl Driver for FftDriver {
    fn method(&self) -> Method {
        Method::Fft
    }

    fn trainable_params(&self) -> usize {
        self.total
    }

    fn step(
        &mut self,
        state: &mut ModelState,
        batch: &Batch,
        _t: usize,
        lr: f64,
    ) -> Result<f64> {
        self.plan.bind_params(state)?;
        self.plan.bind_batch(batch)?;
        // full fine-tuning consumes every gradient, so every handle
        // downloads — Table 16's "Other" column shows this traffic
        let mut out = self.plan.run()?.into_iter();
        let loss = out
            .next()
            .expect("loss output")
            .into_host()?
            .data[0] as f64;
        for h in out {
            let name = h
                .name()
                .strip_prefix("g_")
                .expect("grad output name")
                .to_string();
            let g = h.into_host()?;
            let adam = self.adam.get_mut(&name).unwrap();
            let mut upd = adam.update(&g, lr as f32);
            upd.scale_assign(-1.0);
            state.get_mut(&name).add_assign(&upd);
        }
        Ok(loss)
    }
}
