//! Fine-tuning method drivers.
//!
//! Each driver owns its optimizer state and implements one training
//! step against the AOT artifacts: LoSiA / LoSiA-Pro ([`losia`]), LoRA
//! + PiSSA and DoRA ([`lora`]), GaLore ([`galore`]), and full
//! fine-tuning ([`fft`]).

pub mod fft;
pub mod galore;
pub mod lora;
pub mod losia;

use anyhow::Result;

use crate::config::{Method, TrainConfig};
use crate::coordinator::state::ModelState;
use crate::data::Batch;
use crate::runtime::dp::{self, GradFrames, ShardedGrads};
use crate::runtime::{ExecPlan, Runtime, Stager};

/// A subnet selection installed by a driver — the event behind the
/// Figure 3/7 selection analyses. Drivers queue these and the trainer
/// drains them into the observer stream after every step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SelectionEvent {
    /// 0-based step at which the selection was installed
    pub step: usize,
    /// schedule group: decoder layer index, or `n_layers` for lm_head
    pub group: usize,
    /// linear kind (`wq` … `w2`, or `lm_head`)
    pub kind: String,
    /// selected input neurons ρ (empty for the output-layer group)
    pub rho: Vec<usize>,
    /// selected output neurons γ
    pub gamma: Vec<usize>,
    /// true for the random selection installed before step 0
    pub initial: bool,
}

/// A fine-tuning method: one optimization step over a batch.
///
/// The step is split into a gradient phase and an update phase so the
/// data-parallel engine ([`crate::runtime::dp`]) can interpose a
/// fixed-order reduction between them. The provided [`Driver::step`]
/// routes a single batch through the same two phases with a one-shard
/// reduce — which `dp::reduce` defines as an exact bitwise
/// pass-through — so the legacy single-plan loop *is* the one-shard
/// data-parallel path, not a separate code path that could drift.
pub trait Driver {
    /// Perform step `t` (0-based) at base learning rate `lr`; mutate
    /// `state` in place and return the training loss.
    ///
    /// Default: gradient phase on one shard, degenerate reduce, update
    /// phase. Drivers implement the two phases, not this.
    fn step(
        &mut self,
        state: &mut ModelState,
        batch: &Batch,
        t: usize,
        lr: f64,
    ) -> Result<f64> {
        let sharded = self.grad_frames_sharded(
            state,
            std::slice::from_ref(batch),
            t,
        )?;
        let (reduced, _bytes) = dp::reduce(sharded.shards)?;
        self.apply_frames(state, reduced, t, lr)
    }

    /// Gradient phase: compute per-shard gradient frames, one
    /// [`GradFrames`] per batch in `batches`, executing shards on the
    /// driver's replicated plans via [`dp::run_sharded`]. Frames carry
    /// the method's *reduce set* — the tensors that must be summed
    /// across shards (subnet deltas for LoSiA-Pro, adapter gradients
    /// for LoRA, full gradients for FFT/GaLore/LoSiA) — and must come
    /// back in the same order and shapes for every shard. Read-only on
    /// `state`; no optimizer state may be touched here.
    fn grad_frames_sharded(
        &mut self,
        state: &ModelState,
        batches: &[Batch],
        t: usize,
    ) -> Result<ShardedGrads>;

    /// Update phase: consume the reduced (shard-averaged) frames and
    /// apply the method's optimizer update to `state`, returning the
    /// (shard-averaged) training loss. All optimizer-state mutation
    /// and any relocalization live here, so they run exactly once per
    /// step regardless of shard count.
    fn apply_frames(
        &mut self,
        state: &mut ModelState,
        reduced: GradFrames,
        t: usize,
        lr: f64,
    ) -> Result<f64>;

    /// The cross-shard reduce set as `(frame name, bytes per step)` —
    /// what one shard contributes to the fixed-order reduction. For
    /// LoSiA-Pro this is exactly the subnet-delta frames (communication
    /// ∝ subnet size), not the full gradient set.
    fn reduce_set(&self) -> Vec<(String, u64)>;

    fn method(&self) -> Method;

    /// Trainable parameter count (paper Table 15).
    fn trainable_params(&self) -> usize;

    /// One-time setup before training (e.g. PiSSA SVD init). Default
    /// no-op.
    fn prepare(&mut self, _state: &mut ModelState) -> Result<()> {
        Ok(())
    }

    /// Receive the global warmup horizon T_w (LoSiA's Eq. 8 Cond);
    /// default no-op for methods without rewarming.
    fn set_warmup(&mut self, _warmup_steps: usize) {}

    /// Fold any external trainable state into the backbone at the end
    /// of training (LoRA-family adapter merge — the paper merges
    /// modules into the backbone before evaluation and before each
    /// subsequent continual-learning task). Default no-op: methods
    /// that update W in place need nothing.
    fn finalize(&mut self, _state: &mut ModelState) -> Result<()> {
        Ok(())
    }

    /// Drain selection events queued since the last call. The trainer
    /// forwards them to `Observer::on_relocalize`; empty for
    /// non-subnet methods.
    fn drain_events(&mut self) -> Vec<SelectionEvent> {
        Vec::new()
    }

    /// Serialize the driver's resumable state — optimizer moments,
    /// adapter/subnet tensors, importance accumulators — into a
    /// self-contained CRC-sectioned blob (the payload embedded in a
    /// `LOSIACK1` checkpoint). Pure read; must not touch device state.
    fn snapshot(&self) -> Result<Vec<u8>>;

    /// Rebuild from a blob written by [`Driver::snapshot`] under the
    /// same config/method/seed, then re-bind static device state
    /// against `state`. Called **instead of** [`Driver::prepare`] on
    /// resume: prepare mutates the backbone for some methods (PiSSA's
    /// SVD subtraction, DoRA's magnitude init), and the checkpointed
    /// state already carries those mutations.
    fn restore(
        &mut self,
        blob: &[u8],
        state: &ModelState,
    ) -> Result<()>;

    /// Per-step inputs that are **prefetchable**: computable for step
    /// N+1 before step N's update phase ran. For every current method
    /// that is exactly the batch grid — the LoSiA-Pro `dws_*` frames,
    /// adapter tensors, and the probe index are all produced by
    /// `apply_frames(N)`, so they are step-dependent by construction
    /// and must stay on the critical path.
    fn prefetchable(&self) -> Vec<String> {
        vec!["tokens".into(), "targets".into(), "mask".into()]
    }

    /// Build one [`Stager`] per plan replica over the prefetchable
    /// inputs and switch the driver into pipelined mode: its gradient
    /// phase stops binding the batch inline (the trainer commits
    /// staged batches before calling it). Default: the method does
    /// not support staged uploads.
    fn make_stagers(&mut self) -> Result<Vec<Stager>> {
        anyhow::bail!(
            "method {:?} does not support staged (pipelined) uploads",
            self.method()
        )
    }

    /// Commit a filled stager into plan replica `shard`, returning
    /// the displaced staging set for the next step.
    fn commit_stager(
        &mut self,
        _shard: usize,
        _stager: Stager,
    ) -> Result<Stager> {
        anyhow::bail!(
            "method {:?} does not support staged (pipelined) uploads",
            self.method()
        )
    }
}

/// Build one stager per plan replica over whichever of `prefetchable`
/// the artifact actually takes (`fwd_logits`-style artifacts lack
/// `targets`/`mask`) — the shared body behind every driver's
/// [`Driver::make_stagers`].
pub(crate) fn batch_stagers(
    plans: &[ExecPlan],
    prefetchable: &[String],
) -> Result<Vec<Stager>> {
    plans
        .iter()
        .map(|p| {
            let names: Vec<&str> = prefetchable
                .iter()
                .map(String::as_str)
                .filter(|n| p.has_input(n))
                .collect();
            p.make_stager(&names)
        })
        .collect()
}

/// Build the driver for `tc.method` against a runtime.
pub fn build_driver(
    rt: &Runtime,
    tc: &TrainConfig,
) -> Result<Box<dyn Driver>> {
    Ok(match tc.method {
        Method::Losia | Method::LosiaPro => {
            Box::new(losia::LosiaDriver::new(rt, tc)?)
        }
        Method::Lora | Method::Pissa => {
            Box::new(lora::LoraDriver::new(rt, tc, false)?)
        }
        Method::Dora => Box::new(lora::LoraDriver::new(rt, tc, true)?),
        Method::Galore => Box::new(galore::GaloreDriver::new(rt, tc)?),
        Method::Fft => Box::new(fft::FftDriver::new(rt, tc)?),
    })
}

/// Pick the plain or remat train-step artifact name.
pub fn grads_artifact(base: &str, remat: bool, rt: &Runtime) -> String {
    if remat {
        let name = format!("{base}_remat");
        if rt.cfg.has_artifact(&name) {
            return name;
        }
    }
    base.to_string()
}
