//! Fine-tuning method drivers.
//!
//! Each driver owns its optimizer state and implements one training
//! step against the AOT artifacts: LoSiA / LoSiA-Pro ([`losia`]), LoRA
//! + PiSSA and DoRA ([`lora`]), GaLore ([`galore`]), and full
//! fine-tuning ([`fft`]).

pub mod fft;
pub mod galore;
pub mod lora;
pub mod losia;

use anyhow::Result;

use crate::config::{Method, TrainConfig};
use crate::coordinator::state::ModelState;
use crate::data::Batch;
use crate::runtime::Runtime;

/// A subnet selection installed by a driver — the event behind the
/// Figure 3/7 selection analyses. Drivers queue these and the trainer
/// drains them into the observer stream after every step.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SelectionEvent {
    /// 0-based step at which the selection was installed
    pub step: usize,
    /// schedule group: decoder layer index, or `n_layers` for lm_head
    pub group: usize,
    /// linear kind (`wq` … `w2`, or `lm_head`)
    pub kind: String,
    /// selected input neurons ρ (empty for the output-layer group)
    pub rho: Vec<usize>,
    /// selected output neurons γ
    pub gamma: Vec<usize>,
    /// true for the random selection installed before step 0
    pub initial: bool,
}

/// A fine-tuning method: one optimization step over a batch.
pub trait Driver {
    /// Perform step `t` (0-based) at base learning rate `lr`; mutate
    /// `state` in place and return the training loss.
    fn step(
        &mut self,
        state: &mut ModelState,
        batch: &Batch,
        t: usize,
        lr: f64,
    ) -> Result<f64>;

    fn method(&self) -> Method;

    /// Trainable parameter count (paper Table 15).
    fn trainable_params(&self) -> usize;

    /// One-time setup before training (e.g. PiSSA SVD init). Default
    /// no-op.
    fn prepare(&mut self, _state: &mut ModelState) -> Result<()> {
        Ok(())
    }

    /// Receive the global warmup horizon T_w (LoSiA's Eq. 8 Cond);
    /// default no-op for methods without rewarming.
    fn set_warmup(&mut self, _warmup_steps: usize) {}

    /// Fold any external trainable state into the backbone at the end
    /// of training (LoRA-family adapter merge — the paper merges
    /// modules into the backbone before evaluation and before each
    /// subsequent continual-learning task). Default no-op: methods
    /// that update W in place need nothing.
    fn finalize(&mut self, _state: &mut ModelState) -> Result<()> {
        Ok(())
    }

    /// Drain selection events queued since the last call. The trainer
    /// forwards them to `Observer::on_relocalize`; empty for
    /// non-subnet methods.
    fn drain_events(&mut self) -> Vec<SelectionEvent> {
        Vec::new()
    }
}

/// Build the driver for `tc.method` against a runtime.
pub fn build_driver(
    rt: &Runtime,
    tc: &TrainConfig,
) -> Result<Box<dyn Driver>> {
    Ok(match tc.method {
        Method::Losia | Method::LosiaPro => {
            Box::new(losia::LosiaDriver::new(rt, tc)?)
        }
        Method::Lora | Method::Pissa => {
            Box::new(lora::LoraDriver::new(rt, tc, false)?)
        }
        Method::Dora => Box::new(lora::LoraDriver::new(rt, tc, true)?),
        Method::Galore => Box::new(galore::GaloreDriver::new(rt, tc)?),
        Method::Fft => Box::new(fft::FftDriver::new(rt, tc)?),
    })
}

/// Pick the plain or remat train-step artifact name.
pub fn grads_artifact(base: &str, remat: bool, rt: &Runtime) -> String {
    if remat {
        let name = format!("{base}_remat");
        if rt.cfg.has_artifact(&name) {
            return name;
        }
    }
    base.to_string()
}
