//! LoRA / PiSSA / DoRA drivers.
//!
//! LoRA (Hu et al. 2022): ΔW = (α/r)·A·B with A ~ N(0, 1/n), B = 0.
//! PiSSA (Meng et al. 2024): same architecture, but (A, B) initialised
//! from the top-r singular triplets of W, with the principal component
//! subtracted from the frozen weight.
//! DoRA (Liu et al. 2024): adds a per-column magnitude vector over the
//! direction-normalised W + ΔW (its own artifact with the extra
//! backward cost the paper's Table 16 measures).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{Method, ModelCfg, TrainConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::state::ModelState;
use crate::coordinator::subnet::{AdamParams, AdamState};
use crate::util::durable::{SectionReader, SectionWriter};
use crate::data::Batch;
use crate::methods::{batch_stagers, grads_artifact, Driver};
use crate::runtime::dp::{self, Frame, GradFrames, ShardedGrads};
use crate::runtime::{ExecPlan, Runtime, Stager};
use crate::tensor::svd::svd;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

pub struct LoraDriver {
    dora: bool,
    pissa: bool,
    cfg: ModelCfg,
    /// The whole backbone is frozen during a stage, so every model
    /// parameter is a static binding — per-step traffic is adapters +
    /// batch only. (The end-of-stage merge mutates host state after
    /// the last artifact call, so no re-upload is ever needed.) One
    /// replicated plan per data-parallel worker.
    plans: Vec<ExecPlan>,
    /// adapter tensors by artifact input name (la_*, lb_*, mag_*)
    adapters: BTreeMap<String, Tensor>,
    adam: BTreeMap<String, AdamState>,
    /// pipelined mode: the trainer commits staged batch uploads, so
    /// the shard closure skips the inline `bind_batch`
    pipelined: bool,
}

impl LoraDriver {
    pub fn new(rt: &Runtime, tc: &TrainConfig, dora: bool) -> Result<Self> {
        let cfg = rt.cfg.clone();
        let base = if dora { "grads_dora" } else { "grads_lora" };
        let exe = rt.load(&grads_artifact(base, tc.use_remat, rt))?;
        let param_names: Vec<&str> =
            cfg.params.iter().map(|(n, _)| n.as_str()).collect();
        let n_plans = dp::plan_count(rt, tc)?;
        let mut plans = Vec::with_capacity(n_plans);
        for _ in 0..n_plans {
            plans.push(ExecPlan::new(exe.clone(), &param_names)?);
        }
        let hp = AdamParams {
            beta1: tc.adam_beta1 as f32,
            beta2: tc.adam_beta2 as f32,
            eps: tc.adam_eps as f32,
        };
        let mut rng = Rng::new(tc.seed ^ 0x70A);
        let mut adapters = BTreeMap::new();
        let mut adam = BTreeMap::new();
        let (l, r) = (cfg.n_layers, cfg.lora_rank);
        for kind in &cfg.linear_kinds {
            let kd = cfg.kind(kind);
            let la = Tensor::randn(
                &[l, kd.n, r],
                1.0 / (kd.n as f32).sqrt(),
                &mut rng,
            );
            let lb = Tensor::zeros(&[l, r, kd.m]);
            adam.insert(
                format!("la_{kind}"),
                AdamState::new(&la.shape, hp),
            );
            adam.insert(
                format!("lb_{kind}"),
                AdamState::new(&lb.shape, hp),
            );
            adapters.insert(format!("la_{kind}"), la);
            adapters.insert(format!("lb_{kind}"), lb);
            if dora {
                let mag = Tensor::ones(&[l, kd.m]);
                adam.insert(
                    format!("mag_{kind}"),
                    AdamState::new(&mag.shape, hp),
                );
                adapters.insert(format!("mag_{kind}"), mag);
            }
        }
        Ok(LoraDriver {
            dora,
            pissa: tc.method == Method::Pissa,
            cfg,
            plans,
            adapters,
            adam,
            pipelined: false,
        })
    }
}

impl Driver for LoraDriver {
    fn method(&self) -> Method {
        if self.dora {
            Method::Dora
        } else if self.pissa {
            Method::Pissa
        } else {
            Method::Lora
        }
    }

    fn trainable_params(&self) -> usize {
        self.adapters.values().map(|t| t.len()).sum()
    }

    fn prepare(&mut self, state: &mut ModelState) -> Result<()> {
        if self.dora {
            // DoRA init: magnitude = column norm of W (so W' = W at t=0)
            for kind in self.cfg.linear_kinds.clone() {
                let kd = self.cfg.kind(&kind);
                let mag = self.adapters.get_mut(&format!("mag_{kind}")).unwrap();
                for l in 0..self.cfg.n_layers {
                    let w = state.layer(&kind, l);
                    for j in 0..kd.m {
                        let norm: f32 = (0..kd.n)
                            .map(|i| w.at2(i, j) * w.at2(i, j))
                            .sum::<f32>()
                            .sqrt();
                        mag.data[l * kd.m + j] = norm;
                    }
                }
            }
        }
        if self.pissa {
            // PiSSA init: A = U_r √S / √s, B = √S V_rᵀ / √s with
            // s = α/r so the artifact's scale cancels; the principal
            // component is subtracted from the frozen weight.
            let scale =
                (self.cfg.lora_alpha / self.cfg.lora_rank as f64) as f32;
            let root = scale.sqrt();
            for kind in self.cfg.linear_kinds.clone() {
                let kd = self.cfg.kind(&kind);
                let r = self.cfg.lora_rank.min(kd.n).min(kd.m);
                for l in 0..self.cfg.n_layers {
                    let w = state.layer(&kind, l);
                    let dec = svd(&w);
                    let mut la =
                        Tensor::zeros(&[kd.n, self.cfg.lora_rank]);
                    let mut lb =
                        Tensor::zeros(&[self.cfg.lora_rank, kd.m]);
                    let ucols = dec.u.shape[1];
                    let vcols = dec.v.shape[1];
                    for t in 0..r {
                        let s_sqrt = dec.s[t].sqrt();
                        for i in 0..kd.n {
                            la.data[i * self.cfg.lora_rank + t] =
                                dec.u.data[i * ucols + t] * s_sqrt
                                    / root;
                        }
                        for j in 0..kd.m {
                            lb.data[t * kd.m + j] =
                                dec.v.data[j * vcols + t] * s_sqrt
                                    / root;
                        }
                    }
                    // W_res = W − scale·(A·B)  (== W − U_r S V_rᵀ)
                    let mut principal = la.matmul(&lb);
                    principal.scale_assign(-scale);
                    let mut w_res = w.clone();
                    w_res.add_assign(&principal);
                    state.get_mut(&kind).set_axis0(l, &w_res);
                    self.adapters
                        .get_mut(&format!("la_{kind}"))
                        .unwrap()
                        .set_axis0(l, &la);
                    self.adapters
                        .get_mut(&format!("lb_{kind}"))
                        .unwrap()
                        .set_axis0(l, &lb);
                }
            }
        }
        // upload the (now final) frozen backbone once per replica;
        // steps bind only adapters + batch from here on
        for plan in &mut self.plans {
            plan.bind_params(state)?;
        }
        Ok(())
    }

    fn finalize(&mut self, state: &mut ModelState) -> Result<()> {
        // Merge adapters into the backbone: W ← W + scale·A·B (LoRA,
        // PiSSA) or the full magnitude/direction recomposition (DoRA).
        // Adapters are zeroed afterwards so finalize is idempotent.
        let scale =
            (self.cfg.lora_alpha / self.cfg.lora_rank as f64) as f32;
        for kind in self.cfg.linear_kinds.clone() {
            let kd = self.cfg.kind(&kind);
            for l in 0..self.cfg.n_layers {
                let la = self.adapters[&format!("la_{kind}")]
                    .index_axis0(l);
                let lb = self.adapters[&format!("lb_{kind}")]
                    .index_axis0(l);
                let mut delta = la.matmul(&lb);
                delta.scale_assign(scale);
                let mut w = state.layer(&kind, l);
                w.add_assign(&delta);
                if self.dora {
                    let mag = self.adapters[&format!("mag_{kind}")]
                        .index_axis0(l);
                    for j in 0..kd.m {
                        let norm: f32 = (0..kd.n)
                            .map(|i| w.at2(i, j) * w.at2(i, j))
                            .sum::<f32>()
                            .sqrt()
                            .max(1e-8);
                        let s = mag.data[j] / norm;
                        for i in 0..kd.n {
                            let v = w.at2(i, j) * s;
                            w.set2(i, j, v);
                        }
                    }
                }
                state.get_mut(&kind).set_axis0(l, &w);
            }
            // zero the merged adapters (keep A, zero B ⇒ ΔW = 0)
            let lb =
                self.adapters.get_mut(&format!("lb_{kind}")).unwrap();
            lb.data.iter_mut().for_each(|x| *x = 0.0);
            if self.dora {
                // reset magnitudes to the merged column norms
                let kdm = self.cfg.kind(&kind);
                let mag = self
                    .adapters
                    .get_mut(&format!("mag_{kind}"))
                    .unwrap();
                for l in 0..self.cfg.n_layers {
                    let w = state.layer(&kind, l);
                    for j in 0..kdm.m {
                        let norm: f32 = (0..kdm.n)
                            .map(|i| w.at2(i, j) * w.at2(i, j))
                            .sum::<f32>()
                            .sqrt();
                        mag.data[l * kdm.m + j] = norm;
                    }
                }
            }
        }
        Ok(())
    }

    fn grad_frames_sharded(
        &mut self,
        _state: &ModelState,
        batches: &[Batch],
        t: usize,
    ) -> Result<ShardedGrads> {
        let pipelined = self.pipelined;
        let (plans, adapters) = (&mut self.plans, &self.adapters);
        let (shards, worker_nanos) =
            dp::run_sharded(plans, batches, t, |_, plan, batch| {
                for (name, t) in adapters {
                    plan.bind_f32(name, t)?;
                }
                if !pipelined {
                    plan.bind_batch(batch)?;
                }
                // every output is consumed (scalar loss +
                // adapter-sized grads), so each handle downloads
                // exactly once
                let mut out = plan.run()?.into_iter();
                let loss = out
                    .next()
                    .expect("loss output")
                    .into_host()?
                    .data[0] as f64;
                let mut frames = Vec::new();
                for h in out {
                    let name = h
                        .name()
                        .strip_prefix("g_")
                        .expect("grad output name")
                        .to_string();
                    frames.push(Frame { name, grad: h.into_host()? });
                }
                Ok(GradFrames { loss, frames, probe: None })
            })?;
        Ok(ShardedGrads { shards, worker_nanos })
    }

    fn apply_frames(
        &mut self,
        _state: &mut ModelState,
        reduced: GradFrames,
        _t: usize,
        lr: f64,
    ) -> Result<f64> {
        for Frame { name, grad } in reduced.frames {
            let adam = self.adam.get_mut(&name).unwrap();
            let mut upd = adam.update(&grad, lr as f32);
            upd.scale_assign(-1.0);
            self.adapters.get_mut(&name).unwrap().add_assign(&upd);
        }
        Ok(reduced.loss)
    }

    fn make_stagers(&mut self) -> Result<Vec<Stager>> {
        let stagers =
            batch_stagers(&self.plans, &self.prefetchable())?;
        self.pipelined = true;
        Ok(stagers)
    }

    fn commit_stager(
        &mut self,
        shard: usize,
        stager: Stager,
    ) -> Result<Stager> {
        self.plans[shard].commit_stager(stager)
    }

    fn reduce_set(&self) -> Vec<(String, u64)> {
        // adapter gradients only — the frozen backbone never crosses
        self.adapters
            .iter()
            .map(|(name, t)| (name.clone(), 4 * t.len() as u64))
            .collect()
    }

    fn snapshot(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        let mut w = SectionWriter::new(&mut buf);
        w.u32(self.adapters.len() as u32)?;
        for (name, t) in &self.adapters {
            w.str(name)?;
            checkpoint::write_tensor(&mut w, t)?;
        }
        w.end_section()?;
        w.u32(self.adam.len() as u32)?;
        for (name, a) in &self.adam {
            w.str(name)?;
            checkpoint::write_adam(&mut w, a)?;
        }
        w.end_section()?;
        drop(w);
        Ok(buf)
    }

    fn restore(
        &mut self,
        blob: &[u8],
        state: &ModelState,
    ) -> Result<()> {
        let mut r = SectionReader::new(
            std::io::Cursor::new(blob),
            "driver snapshot (LoRA)",
        );
        r.section("adapters");
        let count = r.u32()? as usize;
        anyhow::ensure!(
            count == self.adapters.len(),
            "checkpoint has {count} adapter tensors, this run expects \
             {} (DoRA/method mismatch?)",
            self.adapters.len()
        );
        for _ in 0..count {
            let name = r.str()?;
            let t = checkpoint::read_tensor(&mut r)?;
            let slot = self.adapters.get_mut(&name).ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint names unknown adapter {name:?}"
                )
            })?;
            anyhow::ensure!(
                t.shape == slot.shape,
                "checkpointed adapter {name:?} has shape {:?}, this \
                 run expects {:?}",
                t.shape,
                slot.shape
            );
            *slot = t;
        }
        r.end_section()?;
        r.section("adam");
        let count = r.u32()? as usize;
        anyhow::ensure!(
            count == self.adam.len(),
            "checkpoint has {count} Adam entries, this run expects {}",
            self.adam.len()
        );
        for _ in 0..count {
            let name = r.str()?;
            let a = self.adam.get_mut(&name).ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint names unknown Adam entry {name:?}"
                )
            })?;
            checkpoint::read_adam_into(&mut r, a)?;
        }
        r.end_section()?;
        // re-upload the frozen backbone, but do NOT run prepare: the
        // checkpointed state already carries PiSSA's principal-
        // component subtraction, and the adapters map already carries
        // PiSSA/DoRA initialisation — prepare would apply both twice
        for plan in &mut self.plans {
            plan.bind_params(state)?;
        }
        Ok(())
    }
}
