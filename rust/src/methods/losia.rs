//! LoSiA / LoSiA-Pro driver (paper Algorithm 2).
//!
//! * **LoSiA** executes the full-gradient artifact every step and
//!   gathers the subnet slice on the host; importance profiling comes
//!   free from the already-materialised full gradients. Weights fold
//!   in place, so every parameter re-uploads per step.
//! * **LoSiA-Pro** executes the factorized-subnet artifact (whose
//!   backward runs the L1 Pallas gather-GEMM kernel, Eq. 9). The
//!   frozen backbone and the (ρ, γ) indices are **static** bindings:
//!   subnet updates accumulate host-side in the tiny `dws` frame
//!   (bound per-step) and fold into W only at re-localization — so
//!   between relocalizations the static re-upload count is exactly 0,
//!   which is the latency story of the paper's Table 16.
//!
//! Both share: asynchronous slot schedule, sensitivity importance EMA,
//! greedy localization, LR rewarming, compact subnet Adam moments, and
//! the p_o-reduced output-layer subnet.

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{Method, ModelCfg, TrainConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::importance::{ImportanceAccum, ImportanceMode};
use crate::coordinator::localize::{localize, localize_columns, Selection};
use crate::coordinator::rewarm::Rewarmer;
use crate::coordinator::schedule::AsyncSchedule;
use crate::coordinator::state::ModelState;
use crate::coordinator::subnet::{AdamParams, AdamState, SubnetState};
use crate::data::Batch;
use crate::methods::{
    batch_stagers, grads_artifact, Driver, SelectionEvent,
};
use crate::runtime::dp::{
    self, Frame, GradFrames, ProbePayload, ShardedGrads,
};
use crate::runtime::{
    ExecPlan, OutputHandle, QTensor, Runtime, Stager,
};
use crate::tensor::Tensor;
use crate::util::durable::{SectionReader, SectionWriter};
use crate::util::rng::Rng;

pub struct LosiaDriver {
    pro: bool,
    cfg: ModelCfg,
    tc: TrainConfig,
    /// One replicated plan per data-parallel worker (a single plan
    /// when dp is off). Statics — Pro's frozen backbone and (ρ, γ)
    /// indices — are mirrored across every replica by the bind
    /// helpers below so all workers compute against the same image.
    plans: Vec<ExecPlan>,
    /// per-layer, per-kind subnet state
    subnets: Vec<BTreeMap<String, SubnetState>>,
    /// Pro: pending subnet updates in the stacked [L, np, mp] dws
    /// frame per kind (empty map for the host-gather path)
    deltas: BTreeMap<String, Tensor>,
    /// Pro: pending output-layer update in the [d, |γ_out|] frame
    delta_out: Tensor,
    /// output-layer selected columns γ_out (|γ| = p_o·V)
    lm_sel: Vec<usize>,
    /// Adam over the [d, |γ_out|] output subnet
    lm_adam: AdamState,
    /// FFTO ablation: dense Adam over the full lm_head
    lm_full_adam: Option<AdamState>,
    /// importance accumulators for the currently-profiled group
    accums: Option<(usize, BTreeMap<String, ImportanceAccum>)>,
    /// SL-ablation accumulators (all layers profile simultaneously)
    sl_accums: Vec<BTreeMap<String, ImportanceAccum>>,
    sched: AsyncSchedule,
    rewarmer: Rewarmer,
    warmup_steps: usize,
    /// selection events queued for the trainer's observer stream
    /// (drained via `Driver::drain_events`)
    events: Vec<SelectionEvent>,
    /// Pro + `LOSIA_QUANT=int8`: the quantized device image of each
    /// backbone parameter. Folds at relocalization requantize only
    /// the touched blocks of this cache (exact — a block's codes
    /// depend on nothing outside the block) instead of re-encoding
    /// the full tensor. Empty when quantization is off.
    qcache: BTreeMap<String, QTensor>,
    /// Pipelined mode (set by `make_stagers`): the trainer commits
    /// staged batch uploads before the gradient phase, so the shard
    /// closures skip the inline `bind_batch`.
    pipelined: bool,
}

impl LosiaDriver {
    pub fn new(rt: &Runtime, tc: &TrainConfig) -> Result<Self> {
        let cfg = rt.cfg.clone();
        let pro = tc.method == Method::LosiaPro;
        anyhow::ensure!(
            !(tc.ablation.synchronous && pro),
            "SL ablation requires full gradients: use method=losia"
        );
        anyhow::ensure!(
            !(tc.ablation.fft_output && pro),
            "FFTO ablation uses full lm_head grads: use method=losia"
        );
        anyhow::ensure!(
            !(tc.rank_factor_override.is_some() && pro),
            "rank-factor override needs the host-gather path: \
             use method=losia"
        );
        // Table-11 sweep: recompute subnet dims under an overridden p.
        let mut cfg = cfg;
        if let Some(p) = tc.rank_factor_override {
            anyhow::ensure!(p > 0.0 && p <= 1.0, "bad rank factor {p}");
            for kd in cfg.kinds.values_mut() {
                kd.np = ((kd.n as f64 * p) as usize).max(1);
                kd.mp = ((kd.m as f64 * p) as usize).max(1);
            }
        }
        let step_name = if pro {
            grads_artifact("grads_losia", tc.use_remat, rt)
        } else {
            grads_artifact("grads_full", tc.use_remat, rt)
        };
        let exe = rt.load(&step_name)?;
        let n_plans = dp::plan_count(rt, tc)?;
        let mut plans = Vec::with_capacity(n_plans);
        for _ in 0..n_plans {
            plans.push(if pro {
                // frozen backbone + selection indices live device-side;
                // dws deltas, probe, and the batch re-bind per step
                let mut statics: Vec<String> = cfg
                    .params
                    .iter()
                    .map(|(n, _)| n.clone())
                    .collect();
                for kind in &cfg.linear_kinds {
                    statics.push(format!("rho_{kind}"));
                    statics.push(format!("gamma_{kind}"));
                }
                statics.push("gamma_out".into());
                let refs: Vec<&str> =
                    statics.iter().map(|s| s.as_str()).collect();
                ExecPlan::new(exe.clone(), &refs)?
            } else {
                ExecPlan::new(exe.clone(), &[])?
            });
        }

        let hp = AdamParams {
            beta1: tc.adam_beta1 as f32,
            beta2: tc.adam_beta2 as f32,
            eps: tc.adam_eps as f32,
        };
        let mut rng = Rng::new(tc.seed ^ 0x105A);
        // Algorithm 2 line 3: random initial selection per matrix
        let subnets: Vec<BTreeMap<String, SubnetState>> = (0..cfg
            .n_layers)
            .map(|_| {
                cfg.linear_kinds
                    .iter()
                    .map(|kind| {
                        let kd = cfg.kind(kind);
                        let sel = Selection::random(
                            kd.n, kd.m, kd.np, kd.mp, &mut rng,
                        );
                        (
                            kind.clone(),
                            SubnetState::new(kd.n, kd.m, sel, hp),
                        )
                    })
                    .collect()
            })
            .collect();
        let lm_sel = rng.choose_distinct(cfg.vocab, cfg.vocab_sub);
        // report the initial random selections (Algorithm 2 line 3)
        // so observers can reconstruct the current subnet even when
        // re-localization never fires (ReLO ablation)
        let mut events = Vec::new();
        for (l, layer) in subnets.iter().enumerate() {
            for (kind, st) in layer {
                events.push(SelectionEvent {
                    step: 0,
                    group: l,
                    kind: kind.clone(),
                    rho: st.sel.rho.clone(),
                    gamma: st.sel.gamma.clone(),
                    initial: true,
                });
            }
        }
        events.push(SelectionEvent {
            step: 0,
            group: cfg.n_layers,
            kind: "lm_head".into(),
            rho: Vec::new(),
            gamma: lm_sel.clone(),
            initial: true,
        });
        let lm_adam =
            AdamState::new(&[cfg.d_model, cfg.vocab_sub], hp);
        let lm_full_adam = tc.ablation.fft_output.then(|| {
            AdamState::new(&[cfg.d_model, cfg.vocab], hp)
        });
        // groups = L decoder layers + 1 output-layer group
        let sched = AsyncSchedule::new(
            cfg.n_layers + 1,
            tc.time_slot,
            tc.ablation.synchronous,
        );
        let rewarmer = Rewarmer {
            time_slot: tc.time_slot,
            enabled: !tc.ablation.no_rewarm,
        };
        let mut deltas = BTreeMap::new();
        let mut delta_out = Tensor::zeros(&[0]);
        if pro {
            for kind in &cfg.linear_kinds {
                let kd = cfg.kind(kind);
                deltas.insert(
                    kind.clone(),
                    Tensor::zeros(&[cfg.n_layers, kd.np, kd.mp]),
                );
            }
            delta_out =
                Tensor::zeros(&[cfg.d_model, cfg.vocab_sub]);
        }
        Ok(LosiaDriver {
            pro,
            cfg,
            tc: tc.clone(),
            plans,
            subnets,
            deltas,
            delta_out,
            lm_sel,
            lm_adam,
            lm_full_adam,
            accums: None,
            sl_accums: Vec::new(),
            sched,
            rewarmer,
            warmup_steps: 0, // set by the trainer via set_warmup
            events,
            qcache: BTreeMap::new(),
            pipelined: false,
        })
    }

    /// The trainer passes the global warmup duration T_w (Eq. 8 Cond).
    pub fn set_warmup(&mut self, warmup_steps: usize) {
        self.warmup_steps = warmup_steps;
    }

    fn importance_mode(&self) -> ImportanceMode {
        if self.tc.ablation.gradient_importance {
            ImportanceMode::GradientMagnitude
        } else {
            ImportanceMode::Sensitivity
        }
    }

    /// Upload the full stacked (ρ, γ) index set + γ_out (static) to
    /// every plan replica.
    fn bind_indices(&mut self) -> Result<()> {
        for kind in self.cfg.linear_kinds.clone() {
            let kd = self.cfg.kind(&kind);
            let mut rho =
                Vec::with_capacity(self.cfg.n_layers * kd.np);
            let mut gamma =
                Vec::with_capacity(self.cfg.n_layers * kd.mp);
            for l in 0..self.cfg.n_layers {
                let sel = &self.subnets[l][&kind].sel;
                rho.extend_from_slice(&sel.rho);
                gamma.extend_from_slice(&sel.gamma);
            }
            for plan in &mut self.plans {
                plan.bind_indices(
                    &format!("rho_{kind}"),
                    &[self.cfg.n_layers, kd.np],
                    &rho,
                )?;
                plan.bind_indices(
                    &format!("gamma_{kind}"),
                    &[self.cfg.n_layers, kd.mp],
                    &gamma,
                )?;
            }
        }
        for plan in &mut self.plans {
            plan.bind_indices(
                "gamma_out",
                &[self.cfg.vocab_sub],
                &self.lm_sel,
            )?;
        }
        Ok(())
    }

    /// Upload the full backbone under the quantization policy to every
    /// plan replica, (re)building the quantized cache so later folds
    /// can requantize incrementally instead of re-encoding whole
    /// tensors. Quantization encodes once; replicas share the image.
    fn bind_backbone(&mut self, state: &ModelState) -> Result<()> {
        for (name, t) in &state.params {
            if !self.plans[0].has_input(name) {
                continue;
            }
            if self.plans[0].wants_q8(name) {
                let q = QTensor::quantize(&t.shape, &t.data);
                for plan in &mut self.plans {
                    plan.bind_q8(name, &q)?;
                }
                self.qcache.insert(name.clone(), q);
            } else {
                for plan in &mut self.plans {
                    plan.bind_f32(name, t)?;
                }
            }
        }
        Ok(())
    }

    /// Re-upload one backbone parameter after a host-side fold, on
    /// every plan replica. Quantized mode requantizes only the blocks
    /// covering the folded `(rows, cols)` region of the cached image —
    /// bitwise identical to a full requantize (pinned in
    /// `tests/quant_parity.rs`) at a fraction of the encode cost —
    /// then re-binds it.
    fn rebind_folded(
        &mut self,
        name: &str,
        state: &ModelState,
        rows: &[usize],
        cols: &[usize],
    ) -> Result<()> {
        if self.plans[0].wants_q8(name) {
            let t = state.get(name);
            match self.qcache.get_mut(name) {
                Some(q) => {
                    q.requantize_rows_cols(&t.data, rows, cols);
                }
                None => {
                    self.qcache.insert(
                        name.to_string(),
                        QTensor::quantize(&t.shape, &t.data),
                    );
                }
            }
            let q = &self.qcache[name];
            for plan in &mut self.plans {
                plan.bind_q8(name, q)?;
            }
            Ok(())
        } else {
            let t = state.get(name);
            for plan in &mut self.plans {
                plan.bind_f32(name, t)?;
            }
            Ok(())
        }
    }

    /// Current effective weight of one linear: host W plus the pending
    /// device-frame delta (Pro defers folding until re-localization).
    fn effective_layer(
        &self,
        state: &ModelState,
        kind: &str,
        l: usize,
    ) -> Tensor {
        let mut w = state.layer(kind, l);
        if self.pro {
            let kd = self.cfg.kind(kind);
            let per = kd.np * kd.mp;
            let slice = Tensor::from_vec(
                &[kd.np, kd.mp],
                self.deltas[kind].data[l * per..(l + 1) * per]
                    .to_vec(),
            );
            let st = &self.subnets[l][kind];
            w.scatter_add2(&st.sel.rho, &st.sel.gamma, &slice);
        }
        w
    }

    fn effective_lm_head(&self, state: &ModelState) -> Tensor {
        let mut w = state.get("lm_head").clone();
        if self.pro {
            let rho_all: Vec<usize> =
                (0..self.cfg.d_model).collect();
            w.scatter_add2(&rho_all, &self.lm_sel, &self.delta_out);
        }
        w
    }

    /// Fold a decoder group's pending deltas into host W (old ρ/γ
    /// frame) and clear them.
    fn fold_group(&mut self, state: &mut ModelState, g: usize) {
        for kind in self.cfg.linear_kinds.clone() {
            let kd = self.cfg.kind(&kind);
            let per = kd.np * kd.mp;
            let (rho, gamma) = {
                let st = &self.subnets[g][&kind];
                (st.sel.rho.clone(), st.sel.gamma.clone())
            };
            let delta = self.deltas.get_mut(&kind).unwrap();
            let slice = Tensor::from_vec(
                &[kd.np, kd.mp],
                delta.data[g * per..(g + 1) * per].to_vec(),
            );
            delta.data[g * per..(g + 1) * per]
                .iter_mut()
                .for_each(|x| *x = 0.0);
            let mut w = state.get_mut(&kind).index_axis0(g);
            w.scatter_add2(&rho, &gamma, &slice);
            state.get_mut(&kind).set_axis0(g, &w);
        }
    }

    fn fold_out(&mut self, state: &mut ModelState) {
        let rho_all: Vec<usize> = (0..self.cfg.d_model).collect();
        state.get_mut("lm_head").scatter_add2(
            &rho_all,
            &self.lm_sel,
            &self.delta_out,
        );
        self.delta_out.data.iter_mut().for_each(|x| *x = 0.0);
    }

    /// Ensure accumulators exist for group `g`.
    fn ensure_accums(&mut self, g: usize) {
        let stale = match &self.accums {
            Some((cur, _)) => *cur != g,
            None => true,
        };
        if !stale {
            return;
        }
        let beta = self.tc.ema_beta as f32;
        let mode = self.importance_mode();
        let mut map = BTreeMap::new();
        if g < self.cfg.n_layers {
            for kind in &self.cfg.linear_kinds {
                let kd = self.cfg.kind(kind);
                map.insert(
                    kind.clone(),
                    ImportanceAccum::new(&[kd.n, kd.m], beta, beta, mode),
                );
            }
        } else {
            map.insert(
                "lm_head".into(),
                ImportanceAccum::new(
                    &[self.cfg.d_model, self.cfg.vocab],
                    beta,
                    beta,
                    mode,
                ),
            );
        }
        self.accums = Some((g, map));
    }

    /// Fold a profiled layer's full gradients into the accumulators.
    /// Sensitivity uses the *effective* weights (host W ⊕ pending
    /// device delta) so Pro's deferred folding cannot skew Eq. 3.
    fn accumulate(
        &mut self,
        g: usize,
        state: &ModelState,
        grads: &BTreeMap<String, Tensor>,
    ) {
        self.ensure_accums(g);
        let weights: BTreeMap<String, Tensor> =
            if g < self.cfg.n_layers {
                self.cfg
                    .linear_kinds
                    .clone()
                    .iter()
                    .map(|k| {
                        (k.clone(), self.effective_layer(state, k, g))
                    })
                    .collect()
            } else {
                let mut m = BTreeMap::new();
                m.insert(
                    "lm_head".to_string(),
                    self.effective_lm_head(state),
                );
                m
            };
        let Some((_, accums)) = &mut self.accums else {
            unreachable!()
        };
        for (kind, w) in &weights {
            accums.get_mut(kind).unwrap().update(w, &grads[kind]);
        }
    }

    /// Re-localize every matrix of group `g` (Algorithm 2 lines
    /// 26–34). Pro folds the pending deltas under the *old* selection
    /// first, then re-uploads the mutated statics — the only moment
    /// parameter traffic happens between warmup and finalize.
    fn relocalize(
        &mut self,
        g: usize,
        t: usize,
        state: &mut ModelState,
    ) -> Result<()> {
        let Some((cur, accums)) = self.accums.take() else {
            return Ok(()); // no stats accumulated (e.g. ReLO)
        };
        if cur != g {
            self.accums = Some((cur, accums));
            return Ok(());
        }
        if g < self.cfg.n_layers {
            // capture the outgoing frames first: the fold lands on
            // exactly these (ρ, γ) rows/cols, which is all the
            // quantized re-bind needs to requantize
            let old_sel: Vec<(String, Vec<usize>, Vec<usize>)> = self
                .cfg
                .linear_kinds
                .iter()
                .map(|kind| {
                    let st = &self.subnets[g][kind];
                    (
                        kind.clone(),
                        st.sel.rho.clone(),
                        st.sel.gamma.clone(),
                    )
                })
                .collect();
            if self.pro {
                self.fold_group(state, g);
            }
            for kind in self.cfg.linear_kinds.clone() {
                let kd = self.cfg.kind(&kind);
                let score = accums[&kind].score();
                let sel = localize(&score, kd.np, kd.mp);
                self.events.push(SelectionEvent {
                    step: t,
                    group: g,
                    kind: kind.clone(),
                    rho: sel.rho.clone(),
                    gamma: sel.gamma.clone(),
                    initial: false,
                });
                self.subnets[g].get_mut(&kind).unwrap().relocalize(sel);
            }
            if self.pro {
                for (kind, rho, gamma) in &old_sel {
                    // a stacked [L, n, m] weight flattens to rows of
                    // width m: layer g's folded rows sit at g·n + ρ
                    let kd = self.cfg.kind(kind);
                    let rows: Vec<usize> = rho
                        .iter()
                        .map(|&r| g * kd.n + r)
                        .collect();
                    self.rebind_folded(kind, state, &rows, gamma)?;
                }
                self.bind_indices()?;
            }
        } else {
            let score = accums["lm_head"].score();
            let col_imp = score.col_sums();
            // fold_out lands on the outgoing γ_out columns (every
            // row): capture them before the selection moves
            let old_lm = self.lm_sel.clone();
            if self.pro {
                self.fold_out(state);
            }
            self.lm_sel =
                localize_columns(&col_imp, self.cfg.vocab_sub);
            self.lm_adam.reset();
            self.events.push(SelectionEvent {
                step: t,
                group: g,
                kind: "lm_head".into(),
                rho: Vec::new(),
                gamma: self.lm_sel.clone(),
                initial: false,
            });
            if self.pro {
                let rows: Vec<usize> =
                    (0..self.cfg.d_model).collect();
                self.rebind_folded("lm_head", state, &rows, &old_lm)?;
                for plan in &mut self.plans {
                    plan.bind_indices(
                        "gamma_out",
                        &[self.cfg.vocab_sub],
                        &self.lm_sel,
                    )?;
                }
            }
        }
        Ok(())
    }

    /// Per-group effective LR = base · rewarm factor (Eq. 8).
    fn group_lr(&self, t: usize, g: usize, base: f64) -> f32 {
        let factor = self.rewarmer.factor(
            t,
            self.sched.last_relocalize(t.saturating_sub(1), g),
            self.warmup_steps,
        );
        (base * factor) as f32
    }

    /// Run the fused Pro artifact on one plan replica: returns (loss,
    /// subnet grads in delta-ABI order, probe-layer grad handles by
    /// kind order, lm grad handle). Per-step bindings are the tiny dws
    /// frames, the probe index, and the batch — the backbone stays
    /// device-resident. Only the scalar loss and the subnet-delta
    /// frames are downloaded here; the probe-layer full grads stay
    /// device-side as [`OutputHandle`]s until (unless) the importance
    /// profiler reads them, so the per-step device→host traffic is
    /// subnet-delta-sized — the `downloads_bytes ≪ full-grad bytes`
    /// invariant `tests/output_handles.rs` pins. An associated fn
    /// (not `&mut self`) so the dp shard closure can split-borrow the
    /// plans away from the shared driver fields.
    fn run_pro_on(
        plan: &mut ExecPlan,
        cfg: &ModelCfg,
        deltas: &BTreeMap<String, Tensor>,
        delta_out: &Tensor,
        probe: usize,
        batch: &Batch,
        pipelined: bool,
    ) -> Result<(f64, Vec<Tensor>, Vec<OutputHandle>, OutputHandle)>
    {
        for kind in &cfg.linear_kinds {
            plan.bind_f32(&format!("dws_{kind}"), &deltas[kind])?;
        }
        plan.bind_f32("dws_out", delta_out)?;
        plan.bind_scalar_i32("probe", probe as i32)?;
        if !pipelined {
            plan.bind_batch(batch)?;
        }
        let mut out = plan.run()?;
        let lm_grad = out.pop().expect("probe_lm_head output");
        let kinds = cfg.linear_kinds.len();
        let probe_grads = out.split_off(out.len() - kinds);
        let loss = out.remove(0).into_host()?.data[0] as f64;
        let mut subnet = Vec::with_capacity(out.len());
        for h in out {
            subnet.push(h.into_host()?);
        }
        Ok((loss, subnet, probe_grads, lm_grad))
    }

    /// Run the full-grad artifact on one plan replica and return
    /// (loss, grads by name). The host-gather path consumes every
    /// gradient, so everything downloads.
    fn run_full_on(
        plan: &mut ExecPlan,
        state: &ModelState,
        batch: &Batch,
        pipelined: bool,
    ) -> Result<(f64, BTreeMap<String, Tensor>)> {
        plan.bind_params(state)?;
        if !pipelined {
            plan.bind_batch(batch)?;
        }
        let mut out = plan.run()?.into_iter();
        let loss = out
            .next()
            .expect("loss output")
            .into_host()?
            .data[0] as f64;
        let mut grads = BTreeMap::new();
        for h in out {
            let name = h
                .name()
                .strip_prefix("g_")
                .expect("grad output name")
                .to_string();
            grads.insert(name, h.into_host()?);
        }
        Ok((loss, grads))
    }

    /// Output-layer Adam step in the [d, |γ_out|] frame: advance the
    /// moments, return the (negated) delta to add — shared by the Pro
    /// dws accumulation and the host-gather scatter.
    fn lm_delta(&mut self, g_out: &Tensor, lr: f32) -> Tensor {
        let mut upd = self.lm_adam.update(g_out, lr);
        upd.scale_assign(-1.0);
        upd
    }

    /// Apply the output-layer subnet update (host-gather path).
    fn update_lm(
        &mut self,
        state: &mut ModelState,
        g_out: &Tensor,
        lr: f32,
    ) {
        let upd = self.lm_delta(g_out, lr);
        let rho_all: Vec<usize> = (0..self.cfg.d_model).collect();
        state
            .get_mut("lm_head")
            .scatter_add2(&rho_all, &self.lm_sel, &upd);
    }
}

impl Driver for LosiaDriver {
    fn set_warmup(&mut self, warmup_steps: usize) {
        self.warmup_steps = warmup_steps;
    }

    fn method(&self) -> Method {
        if self.pro {
            Method::LosiaPro
        } else {
            Method::Losia
        }
    }

    fn drain_events(&mut self) -> Vec<SelectionEvent> {
        std::mem::take(&mut self.events)
    }

    fn make_stagers(&mut self) -> Result<Vec<Stager>> {
        let stagers =
            batch_stagers(&self.plans, &self.prefetchable())?;
        self.pipelined = true;
        Ok(stagers)
    }

    fn commit_stager(
        &mut self,
        shard: usize,
        stager: Stager,
    ) -> Result<Stager> {
        self.plans[shard].commit_stager(stager)
    }

    fn prepare(&mut self, state: &mut ModelState) -> Result<()> {
        if self.pro {
            // one-time upload of the frozen backbone + indices
            self.bind_backbone(state)?;
            self.bind_indices()?;
        }
        Ok(())
    }

    fn finalize(&mut self, state: &mut ModelState) -> Result<()> {
        if self.pro {
            // fold every pending subnet delta into the backbone (the
            // paper merges before evaluation / the next task), then
            // refresh the device copy so a reused driver stays
            // coherent
            for g in 0..self.cfg.n_layers {
                self.fold_group(state, g);
            }
            self.fold_out(state);
            self.bind_backbone(state)?;
        }
        Ok(())
    }

    fn trainable_params(&self) -> usize {
        let subnet: usize = self
            .subnets
            .iter()
            .flat_map(|l| l.values())
            .map(|s| s.trainable_params())
            .sum();
        let lm = if self.tc.ablation.fft_output {
            self.cfg.d_model * self.cfg.vocab
        } else {
            self.cfg.d_model * self.cfg.vocab_sub
        };
        subnet + lm
    }

    fn grad_frames_sharded(
        &mut self,
        state: &ModelState,
        batches: &[Batch],
        t: usize,
    ) -> Result<ShardedGrads> {
        if self.pro {
            // probe the currently-profiled decoder layer (the lm_head
            // group reuses slot 0's layer grads but only consumes the
            // lm output). The probe grads come back as device handles
            // and download in `apply_frames` only if the profiler
            // reads them — and only shard 0's payload survives the
            // reduction, so the other shards' probe handles drop
            // undownloaded: cross-shard traffic stays exactly
            // subnet-delta-sized.
            let g = self.sched.profiling_group(t);
            let probe_layer = g.min(self.cfg.n_layers - 1);
            let pipelined = self.pipelined;
            let (plans, cfg, deltas, delta_out) = (
                &mut self.plans,
                &self.cfg,
                &self.deltas,
                &self.delta_out,
            );
            let (shards, worker_nanos) =
                dp::run_sharded(plans, batches, t, |_, plan, batch| {
                    let (loss, outs, pg, lmg) = Self::run_pro_on(
                        plan, cfg, deltas, delta_out, probe_layer,
                        batch, pipelined,
                    )?;
                    let mut frames = Vec::with_capacity(outs.len());
                    for (i, grad) in outs.into_iter().enumerate() {
                        let name = if i < cfg.linear_kinds.len() {
                            format!("dws_{}", cfg.linear_kinds[i])
                        } else {
                            "dws_out".to_string()
                        };
                        frames.push(Frame { name, grad });
                    }
                    Ok(GradFrames {
                        loss,
                        frames,
                        probe: Some(ProbePayload {
                            layer_grads: pg,
                            lm_grad: lmg,
                        }),
                    })
                })?;
            Ok(ShardedGrads { shards, worker_nanos })
        } else {
            let pipelined = self.pipelined;
            let plans = &mut self.plans;
            let (shards, worker_nanos) =
                dp::run_sharded(plans, batches, t, |_, plan, batch| {
                    let (loss, grads) =
                        Self::run_full_on(plan, state, batch, pipelined)?;
                    let frames = grads
                        .into_iter()
                        .map(|(name, grad)| Frame { name, grad })
                        .collect();
                    Ok(GradFrames { loss, frames, probe: None })
                })?;
            Ok(ShardedGrads { shards, worker_nanos })
        }
    }

    fn apply_frames(
        &mut self,
        state: &mut ModelState,
        reduced: GradFrames,
        t: usize,
        lr: f64,
    ) -> Result<f64> {
        let groups = self.sched.groups;
        let profiling = !self.tc.ablation.no_relocalize;

        // ---- reduced gradients -----------------------------------------
        let loss = reduced.loss;
        let mut probe_handles: Option<(Vec<OutputHandle>, OutputHandle)> =
            reduced.probe.map(|p| (p.layer_grads, p.lm_grad));
        let (subnet_grads, full_grads) = if self.pro {
            // Pro frames arrive in delta-ABI order: dws_<kind> stacked
            // [L, np, mp] per kind, then dws_out
            let outs: Vec<Tensor> =
                reduced.frames.into_iter().map(|f| f.grad).collect();
            (Some(outs), None)
        } else {
            let grads: BTreeMap<String, Tensor> = reduced
                .frames
                .into_iter()
                .map(|f| (f.name, f.grad))
                .collect();
            (None, Some(grads))
        };

        // ---- importance profiling --------------------------------------
        if profiling {
            if self.tc.ablation.synchronous {
                // SL: every decoder layer profiles every step
                let grads = full_grads.as_ref().expect("SL needs full");
                for g in 0..self.cfg.n_layers {
                    let per_layer: BTreeMap<String, Tensor> = self
                        .cfg
                        .linear_kinds
                        .iter()
                        .map(|k| {
                            (k.clone(), grads[k].index_axis0(g))
                        })
                        .collect();
                    // ensure_accums keyed per group won't work for SL's
                    // simultaneous groups; SL keeps only layer stats in
                    // a rolling map keyed by group index.
                    self.ensure_accums_sync(g);
                    self.accumulate_sync(g, state, &per_layer);
                }
            } else {
                let g = self.sched.profiling_group(t);
                let action = self.sched.action(t, g);
                if action.profile {
                    let per: BTreeMap<String, Tensor> = if g
                        < self.cfg.n_layers
                    {
                        if let Some(grads) = &full_grads {
                            self.cfg
                                .linear_kinds
                                .iter()
                                .map(|k| {
                                    (k.clone(), grads[k].index_axis0(g))
                                })
                                .collect()
                        } else if let Some((pg, _)) =
                            probe_handles.take()
                        {
                            // the one place Pro moves layer-sized
                            // grads to the host: the probed layer's
                            // slices, in linear-kind ABI order
                            self.cfg
                                .linear_kinds
                                .iter()
                                .cloned()
                                .zip(pg)
                                .map(|(k, h)| Ok((k, h.into_host()?)))
                                .collect::<Result<
                                    BTreeMap<String, Tensor>,
                                >>()?
                        } else {
                            unreachable!()
                        }
                    } else {
                        let lm = if let Some(grads) = &full_grads {
                            grads["lm_head"].clone()
                        } else if let Some((_, lmg)) =
                            probe_handles.take()
                        {
                            lmg.into_host()?
                        } else {
                            unreachable!()
                        };
                        let mut m = BTreeMap::new();
                        m.insert("lm_head".to_string(), lm);
                        m
                    };
                    self.accumulate(g, state, &per);
                }
            }
        }

        // ---- updates ---------------------------------------------------
        match (&subnet_grads, &full_grads) {
            (Some(outs), _) => {
                // Pro: outputs follow delta ABI order: dws_<kind>
                // stacked [L, np, mp], then dws_out. Updates stay in
                // the dws frame — W is not touched until relocalize.
                for (ki, kind) in
                    self.cfg.linear_kinds.clone().iter().enumerate()
                {
                    let stacked = &outs[ki];
                    let kd = self.cfg.kind(kind);
                    let per = kd.np * kd.mp;
                    for l in 0..self.cfg.n_layers {
                        let glr = self.group_lr(t, l, lr);
                        let gsub = stacked.index_axis0(l);
                        let upd = self.subnets[l]
                            .get_mut(kind)
                            .unwrap()
                            .delta_update(&gsub, glr);
                        let delta =
                            self.deltas.get_mut(kind).unwrap();
                        for (i, v) in upd.data.iter().enumerate() {
                            delta.data[l * per + i] += v;
                        }
                    }
                }
                let g_out = &outs[self.cfg.linear_kinds.len()];
                let glr = self.group_lr(t, self.cfg.n_layers, lr);
                let upd = self.lm_delta(g_out, glr);
                self.delta_out.add_assign(&upd);
            }
            (_, Some(grads)) => {
                // LoSiA: gather subnet slices from full gradients
                for kind in self.cfg.linear_kinds.clone() {
                    for l in 0..self.cfg.n_layers {
                        let glr = self.group_lr(t, l, lr);
                        let st =
                            self.subnets[l].get_mut(&kind).unwrap();
                        let gl = grads[&kind].index_axis0(l);
                        let gsub =
                            gl.gather2(&st.sel.rho, &st.sel.gamma);
                        let mut w = state.get_mut(&kind).index_axis0(l);
                        st.apply_update(&mut w, &gsub, glr);
                        state.get_mut(&kind).set_axis0(l, &w);
                    }
                }
                let glr = self.group_lr(t, self.cfg.n_layers, lr);
                if let Some(lm_full) = &mut self.lm_full_adam {
                    // FFTO: dense update of the whole output layer
                    let mut upd =
                        lm_full.update(&grads["lm_head"], glr);
                    upd.scale_assign(-1.0);
                    state.get_mut("lm_head").add_assign(&upd);
                } else {
                    let rho_all: Vec<usize> =
                        (0..self.cfg.d_model).collect();
                    let gsub = grads["lm_head"]
                        .gather2(&rho_all, &self.lm_sel);
                    self.update_lm(state, &gsub, glr);
                }
            }
            _ => unreachable!(),
        }

        // ---- re-localization -------------------------------------------
        if profiling {
            if self.tc.ablation.synchronous {
                if (t + 1) % self.tc.time_slot == 0 {
                    for g in 0..self.cfg.n_layers {
                        self.relocalize_sync(g, t);
                    }
                }
            } else {
                for g in 0..groups {
                    if self.sched.action(t, g).relocalize {
                        self.relocalize(g, t, state)?;
                    }
                }
            }
        }
        Ok(loss)
    }

    fn reduce_set(&self) -> Vec<(String, u64)> {
        if self.pro {
            // exactly the subnet-delta frames — cross-shard
            // communication ∝ subnet size, never the full gradients
            let mut set: Vec<(String, u64)> = self
                .cfg
                .linear_kinds
                .iter()
                .map(|kind| {
                    let kd = self.cfg.kind(kind);
                    let n = self.cfg.n_layers * kd.np * kd.mp;
                    (format!("dws_{kind}"), 4 * n as u64)
                })
                .collect();
            let lm = self.cfg.d_model * self.cfg.vocab_sub;
            set.push(("dws_out".to_string(), 4 * lm as u64));
            set
        } else {
            // the host-gather path reduces the full gradient set
            self.cfg
                .params
                .iter()
                .map(|(name, shape)| {
                    let n: usize = shape.iter().product();
                    (name.clone(), 4 * n as u64)
                })
                .collect()
        }
    }

    fn snapshot(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        let mut w = SectionWriter::new(&mut buf);
        // subnets, layer-major in linear-kind ABI order (the same
        // iteration order restore uses — never BTreeMap order, so the
        // layout is pinned by the config, not the map)
        for layer in &self.subnets {
            for kind in &self.cfg.linear_kinds {
                let st = &layer[kind];
                checkpoint::write_usizes(&mut w, &st.sel.rho)?;
                checkpoint::write_usizes(&mut w, &st.sel.gamma)?;
                checkpoint::write_adam(&mut w, &st.adam)?;
            }
        }
        w.end_section()?;
        // Pro's pending device-frame deltas (empty for host-gather)
        w.u32(self.deltas.len() as u32)?;
        for kind in &self.cfg.linear_kinds {
            if let Some(d) = self.deltas.get(kind) {
                w.str(kind)?;
                checkpoint::write_tensor(&mut w, d)?;
            }
        }
        checkpoint::write_tensor(&mut w, &self.delta_out)?;
        w.end_section()?;
        // output-layer subnet
        checkpoint::write_usizes(&mut w, &self.lm_sel)?;
        checkpoint::write_adam(&mut w, &self.lm_adam)?;
        w.u32(self.lm_full_adam.is_some() as u32)?;
        if let Some(a) = &self.lm_full_adam {
            checkpoint::write_adam(&mut w, a)?;
        }
        w.end_section()?;
        // importance accumulators for the in-flight profiling window
        match &self.accums {
            Some((g, map)) => {
                w.u32(1)?;
                w.u64(*g as u64)?;
                w.u32(map.len() as u32)?;
                for (kind, a) in map {
                    w.str(kind)?;
                    checkpoint::write_accum(&mut w, a)?;
                }
            }
            None => w.u32(0)?,
        }
        w.end_section()?;
        // SL-ablation accumulators (all layers profile simultaneously)
        w.u32(self.sl_accums.len() as u32)?;
        for layer in &self.sl_accums {
            w.u32(layer.len() as u32)?;
            for (kind, a) in layer {
                w.str(kind)?;
                checkpoint::write_accum(&mut w, a)?;
            }
        }
        w.end_section()?;
        drop(w);
        Ok(buf)
    }

    fn restore(
        &mut self,
        blob: &[u8],
        state: &ModelState,
    ) -> Result<()> {
        let mut r = SectionReader::new(
            std::io::Cursor::new(blob),
            "driver snapshot (LoSiA)",
        );
        r.section("subnets");
        for layer in &mut self.subnets {
            for kind in &self.cfg.linear_kinds {
                let st = layer.get_mut(kind).unwrap();
                let rho = checkpoint::read_usizes(&mut r)?;
                let gamma = checkpoint::read_usizes(&mut r)?;
                anyhow::ensure!(
                    rho.len() == st.sel.rho.len()
                        && gamma.len() == st.sel.gamma.len(),
                    "checkpointed subnet for {kind:?} selects \
                     ({}, {}) neurons, this run expects ({}, {}) \
                     (rank-factor mismatch?)",
                    rho.len(),
                    gamma.len(),
                    st.sel.rho.len(),
                    st.sel.gamma.len()
                );
                // install the selection directly — relocalize() would
                // reset the Adam moments we are about to load
                st.sel.rho = rho;
                st.sel.gamma = gamma;
                checkpoint::read_adam_into(&mut r, &mut st.adam)?;
            }
        }
        r.end_section()?;
        r.section("deltas");
        let nd = r.u32()? as usize;
        anyhow::ensure!(
            nd == self.deltas.len(),
            "checkpoint has {nd} delta frames, this run expects {} \
             (losia/losia-pro mismatch?)",
            self.deltas.len()
        );
        for _ in 0..nd {
            let kind = r.str()?;
            let d = checkpoint::read_tensor(&mut r)?;
            let slot = self.deltas.get_mut(&kind).ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint names unknown delta frame {kind:?}"
                )
            })?;
            anyhow::ensure!(
                d.shape == slot.shape,
                "checkpointed delta frame {kind:?} has shape {:?}, \
                 this run expects {:?}",
                d.shape,
                slot.shape
            );
            *slot = d;
        }
        let d_out = checkpoint::read_tensor(&mut r)?;
        anyhow::ensure!(
            d_out.shape == self.delta_out.shape,
            "checkpointed output delta has shape {:?}, this run \
             expects {:?}",
            d_out.shape,
            self.delta_out.shape
        );
        self.delta_out = d_out;
        r.end_section()?;
        r.section("lm");
        let lm_sel = checkpoint::read_usizes(&mut r)?;
        anyhow::ensure!(
            lm_sel.len() == self.lm_sel.len(),
            "checkpointed γ_out selects {} columns, this run expects \
             {}",
            lm_sel.len(),
            self.lm_sel.len()
        );
        self.lm_sel = lm_sel;
        checkpoint::read_adam_into(&mut r, &mut self.lm_adam)?;
        let has_full = r.u32()? != 0;
        anyhow::ensure!(
            has_full == self.lm_full_adam.is_some(),
            "checkpoint and this run disagree on the FFTO ablation \
             (checkpoint: {has_full}, run: {})",
            self.lm_full_adam.is_some()
        );
        if let Some(a) = &mut self.lm_full_adam {
            checkpoint::read_adam_into(&mut r, a)?;
        }
        r.end_section()?;
        r.section("accums");
        self.accums = if r.u32()? != 0 {
            let g = r.u64()? as usize;
            let count = r.u32()? as usize;
            anyhow::ensure!(
                count <= self.cfg.linear_kinds.len() + 1,
                "driver snapshot (LoSiA): implausible accumulator \
                 count {count} (file is corrupt)"
            );
            let mut map = BTreeMap::new();
            for _ in 0..count {
                let kind = r.str()?;
                map.insert(kind, checkpoint::read_accum(&mut r)?);
            }
            Some((g, map))
        } else {
            None
        };
        r.end_section()?;
        r.section("sl_accums");
        let layers = r.u32()? as usize;
        anyhow::ensure!(
            layers == 0 || layers == self.cfg.n_layers,
            "checkpoint has SL accumulators for {layers} layers, this \
             run has {}",
            self.cfg.n_layers
        );
        self.sl_accums = (0..layers)
            .map(|_| {
                let count = r.u32()? as usize;
                let mut map = BTreeMap::new();
                for _ in 0..count {
                    let kind = r.str()?;
                    map.insert(kind, checkpoint::read_accum(&mut r)?);
                }
                Ok(map)
            })
            .collect::<Result<Vec<_>>>()?;
        r.end_section()?;
        // the events queued so far described pre-checkpoint history
        // that the resumed observer stream must not replay
        self.events.clear();
        if self.pro {
            // same static uploads as prepare — against the restored
            // backbone and the just-restored (ρ, γ) selections
            self.bind_backbone(state)?;
            self.bind_indices()?;
        }
        Ok(())
    }
}

// ---- SL-ablation state (all layers profile simultaneously) -----------

impl LosiaDriver {
    fn sync_accums(
        &mut self,
    ) -> &mut Vec<BTreeMap<String, ImportanceAccum>> {
        // lazily boxed in a side field via accums trick is messy; SL
        // keeps its own vector.
        if self.sl_accums.is_empty() {
            let beta = self.tc.ema_beta as f32;
            let mode = self.importance_mode();
            self.sl_accums = (0..self.cfg.n_layers)
                .map(|_| {
                    self.cfg
                        .linear_kinds
                        .iter()
                        .map(|kind| {
                            let kd = self.cfg.kind(kind);
                            (
                                kind.clone(),
                                ImportanceAccum::new(
                                    &[kd.n, kd.m],
                                    beta,
                                    beta,
                                    mode,
                                ),
                            )
                        })
                        .collect()
                })
                .collect();
        }
        &mut self.sl_accums
    }

    fn ensure_accums_sync(&mut self, _g: usize) {
        let _ = self.sync_accums();
    }

    fn accumulate_sync(
        &mut self,
        g: usize,
        state: &ModelState,
        grads: &BTreeMap<String, Tensor>,
    ) {
        let kinds = self.cfg.linear_kinds.clone();
        // split borrow: weights snapshot first
        let weights: BTreeMap<String, Tensor> = kinds
            .iter()
            .map(|k| (k.clone(), state.layer(k, g)))
            .collect();
        let accums = self.sync_accums();
        for kind in &kinds {
            accums[g]
                .get_mut(kind)
                .unwrap()
                .update(&weights[kind], &grads[kind]);
        }
    }

    fn relocalize_sync(&mut self, g: usize, t: usize) {
        if self.sl_accums.is_empty() {
            return;
        }
        for kind in self.cfg.linear_kinds.clone() {
            let kd = self.cfg.kind(&kind);
            let score = self.sl_accums[g][&kind].score();
            let sel = localize(&score, kd.np, kd.mp);
            self.events.push(SelectionEvent {
                step: t,
                group: g,
                kind: kind.clone(),
                rho: sel.rho.clone(),
                gamma: sel.gamma.clone(),
                initial: false,
            });
            self.subnets[g].get_mut(&kind).unwrap().relocalize(sel);
        }
        // reset stats for the next window
        let beta = self.tc.ema_beta as f32;
        let mode = self.importance_mode();
        for kind in self.cfg.linear_kinds.clone() {
            let kd = self.cfg.kind(&kind);
            self.sl_accums[g].insert(
                kind.clone(),
                ImportanceAccum::new(&[kd.n, kd.m], beta, beta, mode),
            );
        }
    }
}
