//! GaLore driver (Zhao et al. 2024): memory-efficient training by
//! low-rank gradient projection.
//!
//! Per linear matrix, the gradient G ∈ R^{n×m} is projected to
//! Pᵀ G ∈ R^{R×m} where P holds the top-R left singular vectors of a
//! recent gradient; Adam runs in the projected space and the update is
//! back-projected: W ← W − P·(adam step). The projector refreshes every
//! `galore_period` steps ("Full Proj" strategy in the paper's setup).
//! The output layer is fully fine-tuned (paper Appendix A.4.1).

use std::collections::BTreeMap;

use anyhow::Result;

use crate::config::{Method, ModelCfg, TrainConfig};
use crate::coordinator::checkpoint;
use crate::coordinator::state::ModelState;
use crate::coordinator::subnet::{AdamParams, AdamState};
use crate::util::durable::{SectionReader, SectionWriter};
use crate::data::Batch;
use crate::methods::{batch_stagers, grads_artifact, Driver};
use crate::runtime::dp::{self, Frame, GradFrames, ShardedGrads};
use crate::runtime::{ExecPlan, Runtime, Stager};
use crate::tensor::svd::left_singular_topk;
use crate::tensor::Tensor;

/// Parameters GaLore never touches — bound statically; everything
/// else (the projected linears and the fully-tuned lm_head) re-uploads
/// each step.
const FROZEN: [&str; 4] = ["embed", "norm1", "norm2", "norm_f"];

pub struct GaloreDriver {
    cfg: ModelCfg,
    /// one replicated plan per data-parallel worker
    plans: Vec<ExecPlan>,
    rank: usize,
    period: usize,
    /// projector per (kind, layer)
    projectors: BTreeMap<(String, usize), Tensor>,
    /// projected-space Adam per (kind, layer)
    adam: BTreeMap<(String, usize), AdamState>,
    /// dense Adam over the output layer
    lm_adam: AdamState,
    hp: AdamParams,
    /// pipelined mode: the trainer commits staged batch uploads, so
    /// the shard closure skips the inline `bind_batch`
    pipelined: bool,
}

impl GaloreDriver {
    pub fn new(rt: &Runtime, tc: &TrainConfig) -> Result<Self> {
        let cfg = rt.cfg.clone();
        let exe =
            rt.load(&grads_artifact("grads_full", tc.use_remat, rt))?;
        let n_plans = dp::plan_count(rt, tc)?;
        let mut plans = Vec::with_capacity(n_plans);
        for _ in 0..n_plans {
            plans.push(ExecPlan::new(exe.clone(), &FROZEN)?);
        }
        let hp = AdamParams {
            beta1: tc.adam_beta1 as f32,
            beta2: tc.adam_beta2 as f32,
            eps: tc.adam_eps as f32,
        };
        let lm_adam =
            AdamState::new(&[cfg.d_model, cfg.vocab], hp);
        Ok(GaloreDriver {
            cfg,
            plans,
            rank: tc.galore_rank,
            period: tc.galore_period.max(1),
            projectors: BTreeMap::new(),
            adam: BTreeMap::new(),
            lm_adam,
            hp,
            pipelined: false,
        })
    }

    fn effective_rank(&self, n: usize) -> usize {
        self.rank.min(n)
    }
}

impl Driver for GaloreDriver {
    fn method(&self) -> Method {
        Method::Galore
    }

    fn trainable_params(&self) -> usize {
        // projected optimizer coordinates + full output layer
        let proj: usize = self
            .cfg
            .linear_kinds
            .iter()
            .map(|kind| {
                let kd = self.cfg.kind(kind);
                self.cfg.n_layers * self.effective_rank(kd.n) * kd.m
            })
            .sum();
        proj + self.cfg.d_model * self.cfg.vocab
    }

    fn prepare(&mut self, state: &mut ModelState) -> Result<()> {
        // frozen parameters upload once per replica and stay
        // device-resident (quantized under LOSIA_QUANT=int8 where the
        // policy allows)
        for plan in &mut self.plans {
            for name in FROZEN {
                plan.bind_param_auto(name, state.get(name))?;
            }
        }
        Ok(())
    }

    fn grad_frames_sharded(
        &mut self,
        state: &ModelState,
        batches: &[Batch],
        t: usize,
    ) -> Result<ShardedGrads> {
        let pipelined = self.pipelined;
        let (plans, cfg) = (&mut self.plans, &self.cfg);
        let (shards, worker_nanos) =
            dp::run_sharded(plans, batches, t, |_, plan, batch| {
                for kind in &cfg.linear_kinds {
                    plan.bind_f32(kind, state.get(kind))?;
                }
                plan.bind_f32("lm_head", state.get("lm_head"))?;
                if !pipelined {
                    plan.bind_batch(batch)?;
                }
                // GaLore projects every trainable gradient host-side,
                // so the linears + lm_head download — that IS the
                // method's traffic (and reduce) cost. Gradients of the
                // frozen set drop undownloaded.
                let mut out = plan.run()?.into_iter();
                let loss = out
                    .next()
                    .expect("loss output")
                    .into_host()?
                    .data[0] as f64;
                let mut frames = Vec::new();
                for h in out {
                    let name = h
                        .name()
                        .strip_prefix("g_")
                        .expect("grad output name");
                    let trained = name == "lm_head"
                        || cfg.linear_kinds.iter().any(|k| k == name);
                    if !trained {
                        continue;
                    }
                    let name = name.to_string();
                    frames.push(Frame { name, grad: h.into_host()? });
                }
                Ok(GradFrames { loss, frames, probe: None })
            })?;
        Ok(ShardedGrads { shards, worker_nanos })
    }

    fn make_stagers(&mut self) -> Result<Vec<Stager>> {
        let stagers =
            batch_stagers(&self.plans, &self.prefetchable())?;
        self.pipelined = true;
        Ok(stagers)
    }

    fn commit_stager(
        &mut self,
        shard: usize,
        stager: Stager,
    ) -> Result<Stager> {
        self.plans[shard].commit_stager(stager)
    }

    fn apply_frames(
        &mut self,
        state: &mut ModelState,
        reduced: GradFrames,
        t: usize,
        lr: f64,
    ) -> Result<f64> {
        let loss = reduced.loss;
        let mut grads = BTreeMap::new();
        for Frame { name, grad } in reduced.frames {
            grads.insert(name, grad);
        }

        for kind in self.cfg.linear_kinds.clone() {
            let kd = self.cfg.kind(&kind);
            let r = self.effective_rank(kd.n);
            for l in 0..self.cfg.n_layers {
                let g = grads[&kind].index_axis0(l);
                let key = (kind.clone(), l);
                // refresh the projector on schedule (and at t = 0)
                if t % self.period == 0
                    || !self.projectors.contains_key(&key)
                {
                    self.projectors
                        .insert(key.clone(), left_singular_topk(&g, r));
                    self.adam
                        .entry(key.clone())
                        .or_insert_with(|| {
                            AdamState::new(&[r, kd.m], self.hp)
                        })
                        .reset();
                }
                let p = &self.projectors[&key];
                let g_proj = p.transpose2().matmul(&g); // [R, m]
                let adam = self.adam.get_mut(&key).unwrap();
                let upd = adam.update(&g_proj, lr as f32); // [R, m]
                let mut back = p.matmul(&upd); // [n, m]
                back.scale_assign(-1.0);
                let mut w = state.get_mut(&kind).index_axis0(l);
                w.add_assign(&back);
                state.get_mut(&kind).set_axis0(l, &w);
            }
        }

        // full fine-tuning of the output layer
        let mut upd = self.lm_adam.update(&grads["lm_head"], lr as f32);
        upd.scale_assign(-1.0);
        state.get_mut("lm_head").add_assign(&upd);
        Ok(loss)
    }

    fn reduce_set(&self) -> Vec<(String, u64)> {
        // full gradients of the projected linears (projection happens
        // host-side *after* the reduction) plus the dense output layer
        let mut set: Vec<(String, u64)> = self
            .cfg
            .linear_kinds
            .iter()
            .map(|kind| {
                let kd = self.cfg.kind(kind);
                let n = self.cfg.n_layers * kd.n * kd.m;
                (kind.clone(), 4 * n as u64)
            })
            .collect();
        let lm = self.cfg.d_model * self.cfg.vocab;
        set.push(("lm_head".to_string(), 4 * lm as u64));
        set
    }

    fn snapshot(&self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        let mut w = SectionWriter::new(&mut buf);
        w.u32(self.projectors.len() as u32)?;
        for ((kind, layer), p) in &self.projectors {
            w.str(kind)?;
            w.u64(*layer as u64)?;
            checkpoint::write_tensor(&mut w, p)?;
        }
        w.end_section()?;
        w.u32(self.adam.len() as u32)?;
        for ((kind, layer), a) in &self.adam {
            w.str(kind)?;
            w.u64(*layer as u64)?;
            checkpoint::write_adam(&mut w, a)?;
        }
        w.end_section()?;
        checkpoint::write_adam(&mut w, &self.lm_adam)?;
        w.end_section()?;
        drop(w);
        Ok(buf)
    }

    fn restore(
        &mut self,
        blob: &[u8],
        state: &ModelState,
    ) -> Result<()> {
        let mut r = SectionReader::new(
            std::io::Cursor::new(blob),
            "driver snapshot (GaLore)",
        );
        r.section("projectors");
        self.projectors.clear();
        let np = r.u32()? as usize;
        for _ in 0..np {
            let kind = r.str()?;
            let layer = r.u64()? as usize;
            let p = checkpoint::read_tensor(&mut r)?;
            self.projectors.insert((kind, layer), p);
        }
        r.end_section()?;
        r.section("adam");
        self.adam.clear();
        let na = r.u32()? as usize;
        for _ in 0..na {
            let kind = r.str()?;
            let layer = r.u64()? as usize;
            let a = checkpoint::read_adam(&mut r, self.hp)?;
            self.adam.insert((kind, layer), a);
        }
        r.end_section()?;
        r.section("lm_adam");
        checkpoint::read_adam_into(&mut r, &mut self.lm_adam)?;
        r.end_section()?;
        // same static rebinding as prepare — the frozen set is pure
        // backbone, untouched by training
        for plan in &mut self.plans {
            for name in FROZEN {
                plan.bind_param_auto(name, state.get(name))?;
            }
        }
        Ok(())
    }
}
