//! Artifact execution: PJRT CPU client + compiled-executable cache.

use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{ArtifactSpec, ModelCfg};
use crate::runtime::host::HostValue;
use crate::tensor::Tensor;

/// A compiled artifact bound to its manifest signature.
pub struct Executable {
    spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
    /// cumulative wall time spent in `execute` (perf accounting)
    pub exec_nanos: std::cell::Cell<u128>,
    pub exec_calls: std::cell::Cell<u64>,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    /// Execute with shape/dtype-checked inputs; returns outputs in
    /// manifest order.
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {:?}: {} inputs given, manifest wants {}",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len()
        );
        let mut literals = Vec::with_capacity(inputs.len());
        for (hv, ispec) in inputs.iter().zip(&self.spec.inputs) {
            hv.check(ispec).with_context(|| {
                format!("artifact {:?}", self.spec.name)
            })?;
            literals.push(hv.to_literal()?);
        }
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?;
        self.exec_nanos
            .set(self.exec_nanos.get() + t0.elapsed().as_nanos());
        self.exec_calls.set(self.exec_calls.get() + 1);
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact {:?}: got {} outputs, manifest wants {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&self.spec.outputs) {
            out.push(HostValue::f32_from_literal(lit, &ospec.shape)?);
        }
        Ok(out)
    }

    /// Mean wall-clock seconds per call so far.
    pub fn mean_exec_secs(&self) -> f64 {
        let calls = self.exec_calls.get().max(1);
        self.exec_nanos.get() as f64 / 1e9 / calls as f64
    }

    /// Clear the execution counters (latency benches isolate methods
    /// sharing one artifact).
    pub fn reset_stats(&self) {
        self.exec_nanos.set(0);
        self.exec_calls.set(0);
    }
}

/// PJRT client + compile cache for one model config.
pub struct Runtime {
    pub cfg: ModelCfg,
    client: xla::PjRtClient,
    cache: Mutex<BTreeMap<String, &'static Executable>>,
}

impl Runtime {
    pub fn new(cfg: ModelCfg) -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime {
            cfg,
            client,
            cache: Mutex::new(BTreeMap::new()),
        })
    }

    /// Load from the default artifacts directory.
    pub fn from_config_name(name: &str) -> Result<Self> {
        let dir = crate::runtime::artifacts_dir();
        let cfg = crate::config::load_manifest(&dir, name)?;
        Self::new(cfg)
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    ///
    /// Executables are leaked intentionally: they live for the process
    /// lifetime (one trainer = one process) and the `xla` crate's
    /// executable type is not reference-counted.
    pub fn load(&self, name: &str) -> Result<&'static Executable> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(name) {
            return Ok(e);
        }
        let spec = self.cfg.try_artifact(name)?.clone();
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            spec.file.to_str().unwrap(),
        )
        .with_context(|| format!("loading {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name:?}"))?;
        eprintln!(
            "[runtime] compiled {}/{} in {:.2}s",
            self.cfg.name,
            name,
            t0.elapsed().as_secs_f64()
        );
        let boxed: &'static Executable = Box::leak(Box::new(Executable {
            spec,
            exe,
            exec_nanos: std::cell::Cell::new(0),
            exec_calls: std::cell::Cell::new(0),
        }));
        cache.insert(name.to_string(), boxed);
        Ok(boxed)
    }
}
