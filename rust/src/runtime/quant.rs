//! Block-quantized frozen-backbone storage: absmax int8 codes with
//! per-block f32 scales.
//!
//! The QLoRA observation applied to this runtime: every PEFT driver
//! keeps the backbone W frozen on the hot path (LoSiA-Pro folds subnet
//! deltas into W only at re-localization, LoRA/GaLore never touch it),
//! so the dominant device-resident bytes and GEMM bandwidth belong to
//! weights that are read-only between rare fold events. Storing them
//! int8 cuts resident memory ~4× (1 code byte + 4/QBLOCK scale bytes
//! per element vs 4) with f32 accumulation in the dequant-fused GEMMs
//! (`kernels::mm_q8` family).
//!
//! ## Storage format
//!
//! [`QTensor`] holds the original shape, one `i8` code per element,
//! and one `f32` scale per [`QBLOCK`]-wide block. Blocks tile the
//! **last axis** and never span rows: for shape `[..., m]` each of the
//! `numel/m` rows carries `ceil(m/QBLOCK)` blocks. Consequences:
//!
//! * slicing a stacked `[L, n, m]` parameter at layer `l` slices both
//!   `codes` and `scales` at aligned offsets (no block straddles the
//!   cut), so the interpreter's per-layer weight views stay zero-copy;
//! * a GEMM loop over `B[k, m]` finds the scale of element `(kk, j)`
//!   at `scales[kk*bpr + j/QBLOCK]` — one lookup per register tile;
//! * a fold that touches rows ρ × columns γ requantizes exactly the
//!   blocks `{(row, c/QBLOCK) : c ∈ γ}` and leaves every other block's
//!   codes bit-identical (pinned by
//!   `tests::requantize_touched_matches_full_requantize`).
//!
//! Per block: `scale = absmax/127`, `code = round(x/scale)` (ties away
//! from zero, clamped to ±127). An all-zero block stores `scale = 0`
//! and round-trips exactly. The round-trip error of any element is
//! bounded by `scale/2` of its block ([`QTensor::block_error_bound`]).
//!
//! ## Opt-in policy
//!
//! Quantization is an opt-in for **static** (device-resident)
//! bindings: `LOSIA_QUANT=int8` in the environment, or
//! [`set_mode`] at runtime (the test/bench hook, mirroring
//! `kernels::set_kernel_threads`). [`quantizable`] names the backbone
//! parameters the policy covers — everything except the RMSNorm gain
//! vectors, which are tiny and precision-sensitive. Per-step bindings
//! always stay f32: a tensor that re-uploads every step has no
//! resident-bytes story and would pay quantization cost per step.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Quantization block width (elements per scale) along the last axis.
pub const QBLOCK: usize = 64;

/// Storage mode for frozen-backbone static bindings.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMode {
    /// f32 everywhere (the default).
    Off,
    /// Block-quantized int8 codes + per-block f32 scales.
    Int8,
}

/// Runtime override: 0 = unset, 1 = Off, 2 = Int8.
static MODE_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

fn env_mode() -> QuantMode {
    static ENV: OnceLock<QuantMode> = OnceLock::new();
    *ENV.get_or_init(|| {
        match std::env::var("LOSIA_QUANT").ok().as_deref() {
            Some("int8") | Some("1") => QuantMode::Int8,
            _ => QuantMode::Off,
        }
    })
}

/// The active mode: a [`set_mode`] override wins, else `LOSIA_QUANT`
/// (`int8` enables), else [`QuantMode::Off`].
pub fn mode() -> QuantMode {
    match MODE_OVERRIDE.load(Ordering::Relaxed) {
        1 => QuantMode::Off,
        2 => QuantMode::Int8,
        _ => env_mode(),
    }
}

/// Override the mode at runtime (`None` clears back to the env var).
/// Process-global, like `kernels::set_kernel_threads` — tests and
/// benches that flip it serialize among themselves.
pub fn set_mode(mode: Option<QuantMode>) {
    let v = match mode {
        None => 0,
        Some(QuantMode::Off) => 1,
        Some(QuantMode::Int8) => 2,
    };
    MODE_OVERRIDE.store(v, Ordering::Relaxed);
}

/// Whether the quantization policy covers a parameter. Backbone
/// matrices (embed, the seven linear kinds, lm_head) quantize; the
/// RMSNorm gain vectors stay f32 — they are a rounding error of the
/// byte budget and multiply every activation element-wise.
pub fn quantizable(name: &str) -> bool {
    !name.starts_with("norm")
}

/// Bytes a shape occupies under int8 block quantization: one code
/// byte per element plus one f32 scale per block. Analytic twin of
/// [`QTensor::byte_len`] for sizing without materializing data.
pub fn quantized_byte_len(shape: &[usize]) -> usize {
    let numel: usize = shape.iter().product();
    let m = shape.last().copied().unwrap_or(1);
    if numel == 0 || m == 0 {
        return 0;
    }
    let rows = numel / m;
    numel + rows * m.div_ceil(QBLOCK) * 4
}

/// A block-quantized tensor: i8 codes + per-block f32 scales. See the
/// module docs for the block layout.
#[derive(Debug, Clone, PartialEq)]
pub struct QTensor {
    pub shape: Vec<usize>,
    pub codes: Vec<i8>,
    pub scales: Vec<f32>,
}

impl QTensor {
    /// Quantize `data` (row-major, `shape.iter().product()` elements).
    pub fn quantize(shape: &[usize], data: &[f32]) -> QTensor {
        let numel: usize = shape.iter().product();
        debug_assert_eq!(data.len(), numel);
        let m = shape.last().copied().unwrap_or(1);
        let mut codes = vec![0i8; numel];
        let mut scales = Vec::new();
        if numel > 0 && m > 0 {
            let rows = numel / m;
            let bpr = m.div_ceil(QBLOCK);
            scales = vec![0.0f32; rows * bpr];
            for r in 0..rows {
                let row = &data[r * m..(r + 1) * m];
                let crow = &mut codes[r * m..(r + 1) * m];
                for b in 0..bpr {
                    let j0 = b * QBLOCK;
                    let jl = QBLOCK.min(m - j0);
                    let span = &row[j0..j0 + jl];
                    let absmax = span
                        .iter()
                        .fold(0.0f32, |acc, &x| acc.max(x.abs()));
                    let scale = absmax / 127.0;
                    scales[r * bpr + b] = scale;
                    if scale > 0.0 {
                        for (c, &x) in
                            crow[j0..j0 + jl].iter_mut().zip(span)
                        {
                            *c = (x / scale)
                                .round()
                                .clamp(-127.0, 127.0)
                                as i8;
                        }
                    }
                }
            }
        }
        QTensor {
            shape: shape.to_vec(),
            codes,
            scales,
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Last-axis length (the blocked axis).
    pub fn row_len(&self) -> usize {
        self.shape.last().copied().unwrap_or(1)
    }

    pub fn rows(&self) -> usize {
        let m = self.row_len();
        if m == 0 {
            0
        } else {
            self.numel() / m
        }
    }

    /// Scales per row: `ceil(row_len / QBLOCK)`.
    pub fn blocks_per_row(&self) -> usize {
        self.row_len().div_ceil(QBLOCK)
    }

    /// Payload bytes device-side: codes (1 B/element) + scales (4 B
    /// per block).
    pub fn byte_len(&self) -> usize {
        self.codes.len() + self.scales.len() * 4
    }

    /// Round-trip error bound of element `(row, col)`: half of its
    /// block scale (absmax quantization rounds to the nearest code).
    pub fn block_error_bound(&self, row: usize, col: usize) -> f32 {
        self.scales[row * self.blocks_per_row() + col / QBLOCK] / 2.0
    }

    /// Dequantize rows `row0..row0+rows` into `out` (f32, row-major).
    pub fn dequantize_rows_into(
        &self,
        row0: usize,
        rows: usize,
        out: &mut [f32],
    ) {
        let m = self.row_len();
        let bpr = self.blocks_per_row();
        debug_assert_eq!(out.len(), rows * m);
        for r in 0..rows {
            let crow = &self.codes[(row0 + r) * m..(row0 + r + 1) * m];
            let srow = &self.scales[(row0 + r) * bpr..];
            for (j, (o, &c)) in
                out[r * m..(r + 1) * m].iter_mut().zip(crow).enumerate()
            {
                *o = c as f32 * srow[j / QBLOCK];
            }
        }
    }

    /// Full dequantization (allocates).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.numel()];
        self.dequantize_rows_into(0, self.rows(), &mut out);
        out
    }

    /// Requantize exactly the blocks covered by `rows × cols` from the
    /// current f32 source `data` (full tensor, row-major). Used by the
    /// LoSiA-Pro fold: after scattering subnet deltas into host W at
    /// (ρ, γ), only `|ρ| · |{γ/QBLOCK}|` blocks per layer recompute —
    /// every untouched block keeps bit-identical codes and scales.
    /// Returns the number of blocks requantized.
    pub fn requantize_rows_cols(
        &mut self,
        data: &[f32],
        rows: &[usize],
        cols: &[usize],
    ) -> usize {
        debug_assert_eq!(data.len(), self.numel());
        let m = self.row_len();
        let bpr = self.blocks_per_row();
        let mut blocks: Vec<usize> =
            cols.iter().map(|c| c / QBLOCK).collect();
        blocks.sort_unstable();
        blocks.dedup();
        let mut touched = 0usize;
        for &r in rows {
            let row = &data[r * m..(r + 1) * m];
            let crow = &mut self.codes[r * m..(r + 1) * m];
            for &b in &blocks {
                let j0 = b * QBLOCK;
                let jl = QBLOCK.min(m - j0);
                let span = &row[j0..j0 + jl];
                let absmax = span
                    .iter()
                    .fold(0.0f32, |acc, &x| acc.max(x.abs()));
                let scale = absmax / 127.0;
                self.scales[r * bpr + b] = scale;
                for (c, &x) in crow[j0..j0 + jl].iter_mut().zip(span) {
                    *c = if scale > 0.0 {
                        (x / scale).round().clamp(-127.0, 127.0) as i8
                    } else {
                        0
                    };
                }
                touched += 1;
            }
        }
        touched
    }

    /// Maximum absolute round-trip error against the f32 source.
    pub fn max_abs_error(&self, data: &[f32]) -> f32 {
        let dq = self.dequantize();
        dq.iter()
            .zip(data)
            .fold(0.0f32, |acc, (&a, &b)| acc.max((a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randn(n: usize, scale: f32, rng: &mut Rng) -> Vec<f32> {
        crate::tensor::Tensor::randn(&[n], scale, rng).data
    }

    #[test]
    fn round_trip_error_is_bounded_per_block() {
        let mut rng = Rng::new(42);
        // 3 rows × 150 cols: last block is 22 wide (non-divisible)
        let (rows, m) = (3usize, 150usize);
        let data = randn(rows * m, 0.3, &mut rng);
        let q = QTensor::quantize(&[rows, m], &data);
        assert_eq!(q.blocks_per_row(), 3);
        assert_eq!(q.scales.len(), rows * 3);
        let dq = q.dequantize();
        for r in 0..rows {
            for j in 0..m {
                let err = (dq[r * m + j] - data[r * m + j]).abs();
                let bound = q.block_error_bound(r, j);
                assert!(
                    err <= bound + f32::EPSILON,
                    "({r},{j}): err {err} > bound {bound}"
                );
            }
        }
    }

    #[test]
    fn all_zero_blocks_round_trip_exactly() {
        let mut data = vec![0.0f32; 2 * 130];
        // one non-zero block in row 1 so mixed rows are covered
        data[130 + 70] = 0.5;
        let q = QTensor::quantize(&[2, 130], &data);
        assert_eq!(q.scales[0], 0.0);
        assert_eq!(q.scales[1], 0.0);
        assert_eq!(q.scales[2], 0.0);
        assert!(q.scales[2 * q.blocks_per_row() + 1] > 0.0);
        let dq = q.dequantize();
        for (i, (&a, &b)) in dq.iter().zip(&data).enumerate() {
            if i == 130 + 70 {
                assert!((a - b).abs() <= q.block_error_bound(1, 70));
            } else {
                assert_eq!(a, 0.0, "element {i} not exactly zero");
            }
        }
    }

    #[test]
    fn extreme_magnitudes_stay_finite_and_bounded() {
        let mut data = vec![1.0e30f32; QBLOCK + 5];
        data[3] = -3.4e38; // near -f32::MAX
        data[QBLOCK + 1] = 1.0e-30; // tiny block absmax
        let q = QTensor::quantize(&[1, QBLOCK + 5], &data);
        let dq = q.dequantize();
        for (j, (&a, &x)) in dq.iter().zip(&data).enumerate() {
            assert!(a.is_finite(), "element {j} not finite");
            assert!((a - x).abs() <= q.block_error_bound(0, j));
        }
    }

    #[test]
    fn byte_len_matches_analytic_and_beats_f32_by_3_5x() {
        let shape = [6usize, 256, 512];
        let numel: usize = shape.iter().product();
        let data = randn(numel, 0.05, &mut Rng::new(7));
        let q = QTensor::quantize(&shape, &data);
        assert_eq!(q.byte_len(), quantized_byte_len(&shape));
        let f32_bytes = numel * 4;
        assert!(
            f32_bytes as f64 / q.byte_len() as f64 >= 3.5,
            "ratio {}",
            f32_bytes as f64 / q.byte_len() as f64
        );
    }

    #[test]
    fn requantize_touched_matches_full_requantize() {
        let mut rng = Rng::new(11);
        let (l, n, m) = (2usize, 8usize, 200usize);
        let mut data = randn(l * n * m, 0.1, &mut rng);
        let mut q = QTensor::quantize(&[l, n, m], &data);
        // mutate a subnet patch of layer 1: rows {2, 5}, cols
        // {0, 63, 64, 199} — touches blocks 0, 1, 3 of each row
        let rows: Vec<usize> = [2usize, 5].iter().map(|r| n + r).collect();
        let cols = [0usize, 63, 64, 199];
        for &r in &rows {
            for &c in &cols {
                data[r * m + c] += 0.7;
            }
        }
        let touched = q.requantize_rows_cols(&data, &rows, &cols);
        assert_eq!(touched, rows.len() * 3);
        let full = QTensor::quantize(&[l, n, m], &data);
        assert_eq!(q, full, "incremental requantize diverged");
    }

    /// Randomized sweep over shapes (including non-divisible last
    /// blocks and degenerate widths), magnitudes, and sparsity: the
    /// per-block error bound holds everywhere, byte accounting
    /// matches the analytic formula, and a random touched-patch
    /// requantize is bitwise the full requantize.
    #[test]
    fn quantize_properties_hold_for_random_shapes() {
        crate::util::proptest::check("q8 round trip", 60, |g| {
            let rows = g.size(1, 12);
            let m = g.size(1, 3 * QBLOCK + 7);
            let scale = [1e-6f32, 0.05, 1.0, 1e4]
                [g.int(0, 3) as usize];
            let mut data = g.normal_vec(rows * m, scale);
            if g.bool() {
                // zero a whole row: all-zero blocks round-trip exact
                let z = g.size(0, rows - 1);
                data[z * m..(z + 1) * m]
                    .iter_mut()
                    .for_each(|x| *x = 0.0);
            }
            let q = QTensor::quantize(&[rows, m], &data);
            assert_eq!(q.byte_len(), quantized_byte_len(&[rows, m]));
            let dq = q.dequantize();
            for r in 0..rows {
                for j in 0..m {
                    let err = (dq[r * m + j] - data[r * m + j]).abs();
                    let bound = q.block_error_bound(r, j);
                    assert!(
                        err <= bound + f32::EPSILON,
                        "({r},{j}): err {err} > bound {bound}"
                    );
                }
            }
            // perturb a random patch, requantize only its rows/cols
            let nr = g.size(1, rows);
            let nc = g.size(1, m.min(8));
            let prows = g.distinct_indices(rows, nr);
            let pcols = g.distinct_indices(m, nc);
            for &r in &prows {
                for &c in &pcols {
                    data[r * m + c] += scale;
                }
            }
            let mut inc = q.clone();
            inc.requantize_rows_cols(&data, &prows, &pcols);
            let full = QTensor::quantize(&[rows, m], &data);
            assert_eq!(inc, full, "incremental requantize diverged");
        });
    }

    #[test]
    fn mode_override_round_trips() {
        // Unit tests share one process, so this test only exercises
        // the Off/clear path (observationally identical to the
        // default for every concurrent test); the Int8 flip is
        // covered by `tests/quant_parity.rs`, which owns its process
        // and serializes through its own lock.
        set_mode(Some(QuantMode::Off));
        assert_eq!(mode(), QuantMode::Off);
        set_mode(None);
        assert!(matches!(mode(), QuantMode::Off | QuantMode::Int8));
    }
}
