//! `RefBackend`: a pure-Rust interpreter for every artifact in the
//! manifest — forward, loss, and hand-derived backward passes over the
//! dense tensor substrate.
//!
//! This is the CI/test backend: it needs no lowered HLO files and no
//! PJRT client, so the full suite (and the `auto` runtime fallback)
//! runs from a bare checkout. Numerics mirror
//! `python/compile/model.py` — RMSNorm/RoPE/SwiGLU constants, causal
//! masking (the fused kernel softmaxes the `0..=i` prefix only, which
//! is bit-identical to the historical `-1e30` fill whose masked tail
//! underflowed to zero — pinned by
//! `kernels::tests::fused_attention_matches_historical_full_row_softmax`),
//! softmax max-subtraction, and the `max(cnt, 1)` loss denominator —
//! and the backward formulas were validated against `jax.grad` of
//! that model (see `tests/backend_parity.rs` for the in-tree
//! tolerance check against the PJRT path).
//!
//! The interpreter dispatches on the artifact base name; `_remat`
//! variants are numerically identical (checkpointing only changes the
//! memory schedule) and share the plain implementation.

// index-heavy kernels: explicit loops ARE the clearest form here
#![allow(clippy::needless_range_loop)]

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::config::{ArtifactSpec, ModelCfg};
use crate::runtime::backend::{
    Backend, DeviceBuffers, DeviceValue, Executor, HostRef,
    StagedBuffers,
};
use crate::runtime::host::HostValue;
use crate::runtime::kernels::{self, add_into, Pool};
use crate::runtime::quant::QBLOCK;
use crate::tensor::Tensor;

const NORM_EPS: f32 = 1e-6;
const ROPE_BASE: f32 = 10000.0;

/// The pure-Rust interpreter backend.
pub struct RefBackend;

impl Backend for RefBackend {
    fn name(&self) -> &'static str {
        "ref"
    }

    fn prepare(
        &self,
        cfg: &ModelCfg,
        spec: &ArtifactSpec,
    ) -> Result<Box<dyn Executor>> {
        // validate the artifact name up front so unknown artifacts
        // fail at load time, like a missing HLO file would
        base_name(&spec.name)?;
        Ok(Box::new(RefExecutor {
            cfg: Arc::new(cfg.clone()),
            spec: Arc::new(spec.clone()),
        }))
    }
}

fn base_name(name: &str) -> Result<&str> {
    let base = name.strip_suffix("_remat").unwrap_or(name);
    match base {
        "fwd_logits" | "fwd_loss" | "fwd_decode" | "grads_full"
        | "grads_probe" | "grads_losia" | "grads_lora"
        | "grads_dora" => Ok(base),
        other => bail!(
            "reference backend: unknown artifact {other:?} \
             (knows fwd_logits, fwd_loss, fwd_decode, grads_full, \
             grads_probe, grads_losia, grads_lora, grads_dora and \
             _remat variants)"
        ),
    }
}

struct RefExecutor {
    cfg: Arc<ModelCfg>,
    spec: Arc<ArtifactSpec>,
}

impl Executor for RefExecutor {
    fn alloc_buffers(&self) -> Box<dyn DeviceBuffers> {
        let slots = (0..self.spec.inputs.len()).map(|_| None).collect();
        Box::new(RefBuffers {
            cfg: Arc::clone(&self.cfg),
            spec: Arc::clone(&self.spec),
            slots,
            donated: vec![false; self.spec.inputs.len()],
            pool: Pool::new(),
            decode: None,
        })
    }
}

/// The interpreter's device-resident output: the computed tensor held
/// backend-side until the handle downloads it (a move, not a copy —
/// the "device" IS host memory here, so laziness costs nothing and
/// the download counters still model the contract traffic).
struct RefValue(Tensor);

impl DeviceValue for RefValue {
    fn download(self: Box<Self>) -> Result<Tensor> {
        Ok(self.0)
    }
}

/// The interpreter's "device": `Arc`'d host-value snapshots per input
/// slot plus a scratch pool reused across `execute()` calls.
///
/// Uploads snapshot the host value at bind time (the static-binding
/// invalidation contract), but a re-upload into a slot of the same
/// shape/dtype overwrites the existing allocation in place instead of
/// reallocating — a static binding therefore costs exactly one
/// allocation for the plan's lifetime, and zero copies per step
/// between mutations.
///
/// Donation (`DeviceBuffers::donate`) marks a slot whose buffer may be
/// reclaimed: after each `execute()` the slot is taken and, when the
/// `Arc` is uniquely held, its f32 storage is recycled into the
/// scratch pool — the next same-shape allocation (typically the
/// matching output, or the re-bound input itself) reuses it instead of
/// growing the heap. Numerics are untouched, so donated and
/// non-donated runs stay bitwise identical.
struct RefBuffers {
    cfg: Arc<ModelCfg>,
    spec: Arc<ArtifactSpec>,
    slots: Vec<Option<Arc<HostValue>>>,
    donated: Vec<bool>,
    pool: Pool,
    /// KV cache for the `fwd_decode` artifact, carried across
    /// `execute()` calls for the lifetime of the owning plan. `None`
    /// for every other artifact and after `clear_state()`.
    decode: Option<DecodeState>,
}

/// Overwrite `slot` in place when the incoming value matches its
/// shape/dtype and the slot is not shared; `false` means the caller
/// must allocate a fresh snapshot.
fn try_reuse_slot(slot: &mut Arc<HostValue>, value: HostRef<'_>) -> bool {
    let Some(hv) = Arc::get_mut(slot) else {
        return false;
    };
    match (hv, value) {
        (HostValue::F32(t), HostRef::F32 { shape, data })
            if t.shape.as_slice() == shape =>
        {
            t.data.copy_from_slice(data);
            true
        }
        (
            HostValue::I32 { shape: s0, data: d0 },
            HostRef::I32 { shape, data },
        ) if s0.as_slice() == shape => {
            d0.copy_from_slice(data);
            true
        }
        (
            HostValue::Q8(q),
            HostRef::Q8 {
                shape,
                codes,
                scales,
            },
        ) if q.shape.as_slice() == shape
            && q.codes.len() == codes.len()
            && q.scales.len() == scales.len() =>
        {
            q.codes.copy_from_slice(codes);
            q.scales.copy_from_slice(scales);
            true
        }
        _ => false,
    }
}

impl DeviceBuffers for RefBuffers {
    fn upload(&mut self, slot: usize, value: HostRef<'_>) -> Result<()> {
        let reused = match &mut self.slots[slot] {
            Some(arc) => try_reuse_slot(arc, value),
            None => false,
        };
        if !reused {
            self.slots[slot] = Some(Arc::new(value.to_host_value()));
        }
        Ok(())
    }

    fn donate(&mut self, slot: usize) -> Result<()> {
        self.donated[slot] = true;
        Ok(())
    }

    fn execute(&mut self) -> Result<Vec<Box<dyn DeviceValue>>> {
        let out = {
            let mut inputs: BTreeMap<&str, &HostValue> =
                BTreeMap::new();
            for (i, spec) in self.spec.inputs.iter().enumerate() {
                let v = self.slots[i].as_ref().ok_or_else(|| {
                    anyhow::anyhow!(
                        "artifact {:?}: input slot {i} ({:?}) was \
                         never uploaded",
                        self.spec.name,
                        spec.name
                    )
                })?;
                inputs.insert(spec.name.as_str(), v.as_ref());
            }
            if base_name(&self.spec.name)? == "fwd_decode" {
                // the decode path threads its plan-resident KV cache
                // through; a failed step drops the cache rather than
                // leave it half-appended
                let r = run_decode(
                    &self.cfg,
                    &self.spec,
                    &inputs,
                    &self.pool,
                    &mut self.decode,
                );
                if r.is_err() {
                    self.decode = None;
                }
                r?
            } else {
                run_artifact(
                    &self.cfg, &self.spec, &inputs, &self.pool,
                )?
            }
        };
        // reclaim donated buffers now that the compute borrow ended
        for (i, donated) in self.donated.iter().enumerate() {
            if !*donated {
                continue;
            }
            if let Some(arc) = self.slots[i].take() {
                if let Ok(HostValue::F32(t)) = Arc::try_unwrap(arc) {
                    self.pool.recycle(t.data);
                }
            }
        }
        Ok(out
            .into_iter()
            .map(|t| Box::new(RefValue(t)) as Box<dyn DeviceValue>)
            .collect())
    }

    fn clear_state(&mut self) {
        self.decode = None;
    }

    fn resident_bytes(&self, slot: usize) -> usize {
        self.slots[slot]
            .as_ref()
            .map(|v| v.byte_len())
            .unwrap_or(0)
    }

    fn alloc_staging(&self) -> Option<Box<dyn StagedBuffers>> {
        Some(Box::new(RefStaged {
            slots: (0..self.spec.inputs.len()).map(|_| None).collect(),
        }))
    }

    fn commit_staged(
        &mut self,
        staged: Box<dyn StagedBuffers>,
        slots: &[usize],
    ) -> Result<Box<dyn StagedBuffers>> {
        let mut st = staged
            .into_any()
            .downcast::<RefStaged>()
            .map_err(|_| {
                anyhow::anyhow!(
                    "reference backend: commit of a foreign staging \
                     set (not allocated by RefBuffers)"
                )
            })?;
        for &i in slots {
            std::mem::swap(&mut self.slots[i], &mut st.slots[i]);
        }
        Ok(st)
    }
}

/// The idle half of a double-buffered [`RefBuffers`]: the same
/// `Arc`'d-snapshot slot layout, filled off-thread by the pipeline's
/// stage worker. `commit_staged` swaps filled slots with the live set
/// (pointer swaps — the copies already happened on the worker), so the
/// displaced storage ping-pongs back for the next step and
/// [`try_reuse_slot`] keeps steady-state staging allocation-free.
struct RefStaged {
    slots: Vec<Option<Arc<HostValue>>>,
}

impl StagedBuffers for RefStaged {
    fn upload(&mut self, slot: usize, value: HostRef<'_>) -> Result<()> {
        let reused = match &mut self.slots[slot] {
            Some(arc) => try_reuse_slot(arc, value),
            None => false,
        };
        if !reused {
            self.slots[slot] = Some(Arc::new(value.to_host_value()));
        }
        Ok(())
    }

    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any> {
        self
    }
}

// ------------------------------------------------------------ dispatch

fn run_artifact(
    cfg: &ModelCfg,
    spec: &ArtifactSpec,
    inputs: &BTreeMap<&str, &HostValue>,
    pool: &Pool,
) -> Result<Vec<Tensor>> {
    let base = base_name(&spec.name)?;
    let model = Model::new(cfg, inputs, base, pool)?;
    let mut out: BTreeMap<String, Tensor> = BTreeMap::new();

    match base {
        "fwd_logits" => {
            let mut fwd = model.forward()?;
            let dm = model.dm;
            let logits = std::mem::take(&mut fwd.logits);
            fwd.recycle(pool);
            out.insert(
                "logits".into(),
                Tensor::from_vec(&[dm.b, dm.s, dm.v], logits),
            );
        }
        "fwd_loss" => {
            let fwd = model.forward()?;
            let (nll, cnt) = model.seq_nll(&fwd.logits)?;
            fwd.recycle(pool);
            let b = model.dm.b;
            out.insert("nll".into(), Tensor::from_vec(&[b], nll));
            out.insert("cnt".into(), Tensor::from_vec(&[b], cnt));
        }
        "grads_full" => {
            let fwd = model.forward()?;
            let (loss, dlogits) = model.loss_and_dlogits(&fwd.logits)?;
            let sinks = model.backward(&fwd, dlogits, true)?;
            fwd.recycle(pool);
            out.insert("loss".into(), scalar(loss));
            for (name, g) in sinks.params.unwrap() {
                out.insert(format!("g_{name}"), g);
            }
        }
        "grads_probe" => {
            let probe = model.probe()?;
            let fwd = model.forward()?;
            let (loss, dlogits) = model.loss_and_dlogits(&fwd.logits)?;
            let sinks = model.backward(&fwd, dlogits, true)?;
            fwd.recycle(pool);
            let params = sinks.params.unwrap();
            out.insert("loss".into(), scalar(loss));
            for kind in &cfg.linear_kinds {
                out.insert(
                    format!("g_{kind}"),
                    params[kind].index_axis0(probe),
                );
            }
            out.insert("g_lm_head".into(), params["lm_head"].clone());
        }
        "grads_losia" => {
            let probe = model.probe()?;
            let fwd = model.forward()?;
            let (loss, dlogits) = model.loss_and_dlogits(&fwd.logits)?;
            let sinks = model.backward(&fwd, dlogits, true)?;
            fwd.recycle(pool);
            let params = sinks.params.unwrap();
            out.insert("loss".into(), scalar(loss));
            for (name, g) in sinks.extras {
                out.insert(format!("g_{name}"), g);
            }
            for kind in &cfg.linear_kinds {
                out.insert(
                    format!("probe_{kind}"),
                    params[kind].index_axis0(probe),
                );
            }
            out.insert(
                "probe_lm_head".into(),
                params["lm_head"].clone(),
            );
        }
        "grads_lora" | "grads_dora" => {
            let fwd = model.forward()?;
            let (loss, dlogits) = model.loss_and_dlogits(&fwd.logits)?;
            let sinks = model.backward(&fwd, dlogits, false)?;
            fwd.recycle(pool);
            out.insert("loss".into(), scalar(loss));
            for (name, g) in sinks.extras {
                out.insert(format!("g_{name}"), g);
            }
        }
        _ => unreachable!("base_name validated"),
    }

    finish_outputs(spec, out)
}

/// Order the produced tensors per the manifest's output list,
/// validating presence and shape — shared by the grid interpreter and
/// the decode path.
fn finish_outputs(
    spec: &ArtifactSpec,
    mut out: BTreeMap<String, Tensor>,
) -> Result<Vec<Tensor>> {
    spec.outputs
        .iter()
        .map(|o| {
            let t = out.remove(&o.name).ok_or_else(|| {
                anyhow::anyhow!(
                    "reference backend: artifact {:?} did not produce \
                     output {:?}",
                    spec.name,
                    o.name
                )
            })?;
            anyhow::ensure!(
                t.shape == o.shape,
                "reference backend: output {:?} has shape {:?}, \
                 manifest wants {:?}",
                o.name,
                t.shape,
                o.shape
            );
            Ok(t)
        })
        .collect()
}

fn scalar(v: f32) -> Tensor {
    Tensor::from_vec(&[], vec![v])
}

// -------------------------------------------- incremental decode state

/// Plan-resident KV cache for `fwd_decode`: per-layer K/V in the
/// unit-major `[B, H, S, Dh]` layout the fused attention units stream
/// (same layout `pack_heads` produces in the grid forward), a per-row
/// fill length, and the RoPE tables (which depend only on `S`/`Dh`, so
/// they are built once per plan instead of once per step). Lives
/// inside [`RefBuffers`] and therefore persists exactly as long as the
/// owning `ExecPlan` — `ExecPlan::clear_state()` (or dropping the
/// plan) releases it.
struct DecodeState {
    /// cached token count per batch row
    lens: Vec<usize>,
    /// per-layer cached keys, unit-major `[B·H·S·Dh]`
    kc: Vec<Vec<f32>>,
    /// per-layer cached values, same layout
    vc: Vec<Vec<f32>>,
    cos: Vec<f32>,
    sin: Vec<f32>,
}

/// One incremental decode step. Each batch row appends `lens[row]` new
/// tokens (packed at the head of its `tokens` row; `reset[row] != 0`
/// clears the row's cache first) and the artifact returns the logits
/// at each row's last appended position — the only row a decoder
/// samples from. Per-token cost is O(prefix) attention plus O(1)
/// linears, against the grid forward's O(prefix) *everything*.
///
/// Bitwise parity with `fwd_logits` over the same prefix
/// (`tests/serve_parity.rs`) holds by construction: the GEMM kernels
/// accumulate each output element k-ascending independent of the row
/// count, RMSNorm/RoPE/SwiGLU are per-row/per-element, and
/// `attn_decode_row` replicates the fused attention's row body against
/// cached K/V rows that are themselves bit-identical by induction.
fn run_decode(
    cfg: &ModelCfg,
    spec: &ArtifactSpec,
    inputs: &BTreeMap<&str, &HostValue>,
    pool: &Pool,
    state: &mut Option<DecodeState>,
) -> Result<Vec<Tensor>> {
    let mut model = Model::new(cfg, inputs, "fwd_decode", pool)?;
    let mode =
        model.i32_in("adapter_mode")?.first().copied().unwrap_or(0);
    model.variant = match mode {
        0 => Variant::Plain,
        1 => Variant::Losia,
        2 => Variant::Lora { dora: false },
        other => bail!(
            "fwd_decode: adapter_mode {other} out of range \
             (0 = plain, 1 = losia, 2 = lora)"
        ),
    };
    let model = model;
    let dm = model.dm;
    let tokens = model.i32_in("tokens")?;
    let lens_in = model.i32_in("lens")?;
    let reset_in = model.i32_in("reset")?;

    let st = state.get_or_insert_with(|| {
        let (cos, sin) = rope_tables(dm.s, dm.dh, pool);
        let unit = dm.b * dm.h * dm.s * dm.dh;
        DecodeState {
            lens: vec![0; dm.b],
            kc: (0..dm.l).map(|_| vec![0.0; unit]).collect(),
            vc: (0..dm.l).map(|_| vec![0.0; unit]).collect(),
            cos,
            sin,
        }
    });

    // per-row control: resets first, then bounds-check the append
    let mut new_lens = vec![0usize; dm.b];
    for bi in 0..dm.b {
        if reset_in[bi] != 0 {
            st.lens[bi] = 0;
        }
        let n = lens_in[bi].max(0) as usize;
        anyhow::ensure!(
            st.lens[bi] + n <= dm.s,
            "fwd_decode: row {bi} would hold {} cached tokens but \
             seq_len is {} (reset the row or shorten the prompt)",
            st.lens[bi] + n,
            dm.s
        );
        new_lens[bi] = n;
    }

    let total: usize = new_lens.iter().sum();
    let mut out: BTreeMap<String, Tensor> = BTreeMap::new();
    if total == 0 {
        // nothing appended anywhere this step: resets (if any) took
        // effect above, logits are defined-zero for inactive rows
        out.insert("logits".into(), Tensor::zeros(&[dm.b, dm.v]));
        return finish_outputs(spec, out);
    }

    // ragged row bookkeeping: the compute grid holds only the new
    // tokens, ordered by batch row then append position
    let mut row_b = Vec::with_capacity(total);
    let mut row_pos = Vec::with_capacity(total);
    let mut row_tok = Vec::with_capacity(total);
    for bi in 0..dm.b {
        for t in 0..new_lens[bi] {
            row_b.push(bi);
            row_pos.push(st.lens[bi] + t);
            row_tok.push(tokens[bi * dm.s + t]);
        }
    }

    let mut x = pool.zeroed(total * dm.d);
    model.gather_w(&mut x, "embed", &row_tok, dm.d, dm.v)?;

    let norm1 = model.f32_in("norm1")?;
    let norm2 = model.f32_in("norm2")?;
    let mut scores = pool.zeroed(dm.s);
    let scale = 1.0 / (dm.dh as f32).sqrt();
    let ua = dm.s * dm.dh;
    for l in 0..dm.l {
        let n1 = &norm1.data[l * dm.d..(l + 1) * dm.d];
        let n2 = &norm2.data[l * dm.d..(l + 1) * dm.d];
        let (h, inv1) = model.rmsnorm_p(&x, n1, total, dm.d);
        pool.recycle(inv1);
        let mut q = model.lin_fwd(l, "wq", &h, total)?;
        let mut k = model.lin_fwd(l, "wk", &h, total)?;
        let v = model.lin_fwd(l, "wv", &h, total)?;
        pool.recycle(h);
        kernels::rope_apply_at(
            &mut q, dm.h, dm.dh, &row_pos, &st.cos, &st.sin,
        );
        kernels::rope_apply_at(
            &mut k, dm.h, dm.dh, &row_pos, &st.cos, &st.sin,
        );

        // append the new K/V rows into the unit-major cache
        for r in 0..total {
            let (bi, pos) = (row_b[r], row_pos[r]);
            for hh in 0..dm.h {
                let u = bi * dm.h + hh;
                let src = r * dm.d + hh * dm.dh;
                let dst = (u * dm.s + pos) * dm.dh;
                st.kc[l][dst..dst + dm.dh]
                    .copy_from_slice(&k[src..src + dm.dh]);
                st.vc[l][dst..dst + dm.dh]
                    .copy_from_slice(&v[src..src + dm.dh]);
            }
        }
        pool.recycle(k);
        pool.recycle(v);

        // O(prefix) attention per new row against the cached prefix,
        // written straight into head-interleaved layout (no unpack)
        let mut att = pool.zeroed(total * dm.d);
        for r in 0..total {
            let (bi, pos) = (row_b[r], row_pos[r]);
            for hh in 0..dm.h {
                let u = bi * dm.h + hh;
                let (a0, q0) =
                    (r * dm.d + hh * dm.dh, r * dm.d + hh * dm.dh);
                kernels::attn_decode_row(
                    &mut att[a0..a0 + dm.dh],
                    &q[q0..q0 + dm.dh],
                    &st.kc[l][u * ua..(u + 1) * ua],
                    &st.vc[l][u * ua..(u + 1) * ua],
                    &mut scores,
                    pos,
                    dm.dh,
                    scale,
                );
            }
        }
        pool.recycle(q);

        let wo_out = model.lin_fwd(l, "wo", &att, total)?;
        pool.recycle(att);
        let mut x_mid = pool.cleared(total * dm.d);
        x_mid.extend_from_slice(&x);
        add_into(&mut x_mid, &wo_out);
        pool.recycle(wo_out);
        pool.recycle(x);

        let (h2, inv2) = model.rmsnorm_p(&x_mid, n2, total, dm.d);
        pool.recycle(inv2);
        let gate = model.lin_fwd(l, "wgate", &h2, total)?;
        let up = model.lin_fwd(l, "wup", &h2, total)?;
        pool.recycle(h2);
        let mut mlp = pool.zeroed(total * cfg.d_ff);
        kernels::silu_mul(&mut mlp, &gate, &up);
        pool.recycle(gate);
        pool.recycle(up);
        let down = model.lin_fwd(l, "wdown", &mlp, total)?;
        pool.recycle(mlp);
        let mut x_new = pool.cleared(total * dm.d);
        x_new.extend_from_slice(&x_mid);
        add_into(&mut x_new, &down);
        pool.recycle(down);
        pool.recycle(x_mid);
        x = x_new;
    }
    pool.recycle(scores);

    // commit the cache lengths only after the whole forward succeeded
    for bi in 0..dm.b {
        st.lens[bi] += new_lens[bi];
    }

    // lm_head only on each active row's LAST appended position — the
    // only logits a decoder consumes
    let active: Vec<usize> =
        (0..dm.b).filter(|&bi| new_lens[bi] > 0).collect();
    let na = active.len();
    let mut offs = vec![0usize; dm.b];
    let mut acc = 0usize;
    for bi in 0..dm.b {
        offs[bi] = acc;
        acc += new_lens[bi];
    }
    let mut xlast = pool.zeroed(na * dm.d);
    for (j, &bi) in active.iter().enumerate() {
        let r = offs[bi] + new_lens[bi] - 1;
        xlast[j * dm.d..(j + 1) * dm.d]
            .copy_from_slice(&x[r * dm.d..(r + 1) * dm.d]);
    }
    pool.recycle(x);
    let norm_f = model.f32_in("norm_f")?;
    let (xn, invf) = model.rmsnorm_p(&xlast, &norm_f.data, na, dm.d);
    pool.recycle(invf);
    pool.recycle(xlast);
    let lm_head = model.weight("lm_head")?;
    let mut lrows = model.mm_w(&xn, lm_head, na, dm.d, dm.v);
    if model.variant == Variant::Losia {
        let vs = cfg.vocab_sub;
        let gamma = model.indices("gamma_out", 0, vs, dm.v)?;
        let dws = model.f32_in("dws_out")?;
        let y = model.mm_p(&xn, &dws.data, na, dm.d, vs);
        scatter_cols(&mut lrows, na, dm.v, &gamma, &y);
        pool.recycle(y);
    }
    pool.recycle(xn);
    let mut logits = vec![0.0f32; dm.b * dm.v];
    for (j, &bi) in active.iter().enumerate() {
        logits[bi * dm.v..(bi + 1) * dm.v]
            .copy_from_slice(&lrows[j * dm.v..(j + 1) * dm.v]);
    }
    pool.recycle(lrows);
    out.insert(
        "logits".into(),
        Tensor::from_vec(&[dm.b, dm.v], logits),
    );
    finish_outputs(spec, out)
}

// ------------------------------------------------------ linear algebra
//
// All hot compute lives in `runtime::kernels` (cache-blocked GEMMs,
// the fused head-parallel attention family, parallel norm/activation/
// loss helpers — every one bitwise-deterministic across thread
// counts); only the small subnet gather/scatter helpers and the RoPE
// tables stay local.

/// Gather columns: out[r, j] = x[r, cols[j]]
fn gather_cols(
    x: &[f32],
    rows: usize,
    width: usize,
    cols: &[usize],
) -> Vec<f32> {
    let mut out = Vec::with_capacity(rows * cols.len());
    for r in 0..rows {
        let row = &x[r * width..(r + 1) * width];
        for &c in cols {
            out.push(row[c]);
        }
    }
    out
}

/// Scatter-add columns: x[r, cols[j]] += v[r, j]
fn scatter_cols(
    x: &mut [f32],
    rows: usize,
    width: usize,
    cols: &[usize],
    v: &[f32],
) {
    for r in 0..rows {
        let row = &mut x[r * width..(r + 1) * width];
        let vrow = &v[r * cols.len()..(r + 1) * cols.len()];
        for (j, &c) in cols.iter().enumerate() {
            row[c] += vrow[j];
        }
    }
}

/// Dequantize `rows` rows of width `m` (blocks tiling the last axis)
/// into `out` — the dense-view fallback for consumers without a fused
/// path (DoRA's elementwise frames). Uses the same expression as the
/// fused kernels, so fallback and fused paths agree bitwise.
fn dequant_rows(
    out: &mut [f32],
    codes: &[i8],
    scales: &[f32],
    rows: usize,
    m: usize,
) {
    let bpr = m.div_ceil(QBLOCK);
    for r in 0..rows {
        let crow = &codes[r * m..(r + 1) * m];
        let srow = &scales[r * bpr..(r + 1) * bpr];
        let orow = &mut out[r * m..(r + 1) * m];
        for (j, (o, &c)) in orow.iter_mut().zip(crow).enumerate() {
            *o = c as f32 * srow[j / QBLOCK];
        }
    }
}

fn rope_tables(s: usize, dh: usize, pool: &Pool) -> (Vec<f32>, Vec<f32>) {
    let half = dh / 2;
    let mut cos = pool.cleared(s * half);
    let mut sin = pool.cleared(s * half);
    for pos in 0..s {
        for e in 0..half {
            let freq =
                ROPE_BASE.powf(-(e as f32) / half as f32);
            let ang = pos as f32 * freq;
            cos.push(ang.cos());
            sin.push(ang.sin());
        }
    }
    (cos, sin)
}

// ----------------------------------------------------------- the model

#[derive(Debug, Clone, Copy)]
struct Dims {
    b: usize,
    s: usize,
    d: usize,
    h: usize,
    dh: usize,
    l: usize,
    v: usize,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Variant {
    Plain,
    Losia,
    Lora { dora: bool },
}

struct LayerCache {
    x_in: Vec<f32>,
    h: Vec<f32>,
    inv1: Vec<f32>,
    /// post-RoPE q/k and v in **unit-major** `[B, H, S, Dh]` layout —
    /// packed once in the forward pass so the head-parallel attention
    /// units stream them contiguously in both directions
    qh: Vec<f32>,
    kh: Vec<f32>,
    vh: Vec<f32>,
    probs: Vec<f32>,
    att: Vec<f32>,
    x_mid: Vec<f32>,
    h2: Vec<f32>,
    inv2: Vec<f32>,
    gate: Vec<f32>,
    up: Vec<f32>,
    mlp: Vec<f32>,
}

struct FwdCache {
    layers: Vec<LayerCache>,
    /// RoPE tables, built once per execution (depend only on S, Dh)
    cos: Vec<f32>,
    sin: Vec<f32>,
    xf: Vec<f32>,
    invf: Vec<f32>,
    xnorm: Vec<f32>,
    logits: Vec<f32>,
}

impl LayerCache {
    fn recycle(self, pool: &Pool) {
        for v in [
            self.x_in, self.h, self.inv1, self.qh, self.kh, self.vh,
            self.probs, self.att, self.x_mid, self.h2, self.inv2,
            self.gate, self.up, self.mlp,
        ] {
            pool.recycle(v);
        }
    }
}

impl FwdCache {
    /// Return every cached activation to the scratch pool so the next
    /// `execute()` on this plan re-uses the allocations.
    fn recycle(self, pool: &Pool) {
        for c in self.layers {
            c.recycle(pool);
        }
        for v in [
            self.cos, self.sin, self.xf, self.invf, self.xnorm,
            self.logits,
        ] {
            pool.recycle(v);
        }
    }
}

struct Sinks {
    params: Option<BTreeMap<String, Tensor>>,
    extras: BTreeMap<String, Tensor>,
}

/// A borrowed weight in whichever storage class it was bound: dense
/// f32, or block-quantized int8 codes + per-block scales (the
/// `static_quantized` class). Consumers dispatch to the matching
/// kernel — the fused q8 GEMMs are bitwise identical to running the
/// f32 GEMM on the dequantization.
#[derive(Clone, Copy)]
enum WRef<'a> {
    Dense(&'a [f32]),
    Q8 { codes: &'a [i8], scales: &'a [f32] },
}

struct Model<'a> {
    cfg: &'a ModelCfg,
    dm: Dims,
    inp: &'a BTreeMap<&'a str, &'a HostValue>,
    variant: Variant,
    pool: &'a Pool,
}

impl<'a> Model<'a> {
    fn new(
        cfg: &'a ModelCfg,
        inp: &'a BTreeMap<&'a str, &'a HostValue>,
        base: &str,
        pool: &'a Pool,
    ) -> Result<Model<'a>> {
        let variant = match base {
            "grads_losia" => Variant::Losia,
            "grads_lora" => Variant::Lora { dora: false },
            "grads_dora" => Variant::Lora { dora: true },
            _ => Variant::Plain,
        };
        let dm = Dims {
            b: cfg.batch,
            s: cfg.seq_len,
            d: cfg.d_model,
            h: cfg.n_heads,
            dh: cfg.d_model / cfg.n_heads,
            l: cfg.n_layers,
            v: cfg.vocab,
        };
        Ok(Model {
            cfg,
            dm,
            inp,
            variant,
            pool,
        })
    }

    // Pool-backed kernel wrappers: outputs come from (and largely
    // return to) the per-plan scratch pool.

    /// `A[n,k] @ B[k,m]` into a pooled buffer.
    fn mm_p(&self, a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = self.pool.zeroed(n * m);
        kernels::mm_into(&mut out, a, b, n, k, m);
        out
    }

    /// `A[k,n]ᵀ @ B[k,m]` into a pooled buffer.
    fn mm_tn_p(&self, a: &[f32], b: &[f32], k: usize, n: usize, m: usize) -> Vec<f32> {
        let mut out = self.pool.zeroed(n * m);
        kernels::mm_tn_into(&mut out, a, b, k, n, m);
        out
    }

    /// `A[n,k] @ B[m,k]ᵀ` into a pooled buffer (transpose scratch
    /// pooled too).
    fn mm_nt_p(&self, a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = self.pool.zeroed(n * m);
        kernels::mm_nt_into_pooled(&mut out, a, b, n, k, m, self.pool);
        out
    }

    /// Attention dims for the kernel layer.
    fn attn_shape(&self) -> kernels::AttnShape {
        kernels::AttnShape {
            b: self.dm.b,
            s: self.dm.s,
            h: self.dm.h,
            dh: self.dm.dh,
        }
    }

    /// Row-parallel RMSNorm forward into pooled buffers: `(y, inv)`.
    fn rmsnorm_p(
        &self,
        x: &[f32],
        w: &[f32],
        rows: usize,
        d: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut y = self.pool.zeroed(rows * d);
        let mut inv = self.pool.zeroed(rows);
        kernels::rmsnorm_fwd(&mut y, &mut inv, x, w, rows, d, NORM_EPS);
        (y, inv)
    }

    /// Tile-parallel RMSNorm backward into pooled buffers: `(dx, dw)`.
    #[allow(clippy::too_many_arguments)]
    fn rmsnorm_bwd_p(
        &self,
        x: &[f32],
        w: &[f32],
        inv: &[f32],
        dy: &[f32],
        rows: usize,
        d: usize,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut dx = self.pool.zeroed(rows * d);
        let mut dw = self.pool.zeroed(d);
        kernels::rmsnorm_bwd(
            &mut dx, &mut dw, x, w, inv, dy, rows, d, self.pool,
        );
        (dx, dw)
    }

    fn f32_in(&self, name: &str) -> Result<&Tensor> {
        self.inp
            .get(name)
            .ok_or_else(|| {
                anyhow::anyhow!("reference backend: missing input {name:?}")
            })?
            .as_f32()
            .with_context(|| format!("input {name:?}"))
    }

    fn i32_in(&self, name: &str) -> Result<&[i32]> {
        match self.inp.get(name) {
            Some(HostValue::I32 { data, .. }) => Ok(data.as_slice()),
            Some(_) => bail!(
                "reference backend: input {name:?} should be i32"
            ),
            None => bail!(
                "reference backend: missing input {name:?}"
            ),
        }
    }

    /// A weight input in whichever storage class it was bound.
    fn weight(&self, name: &str) -> Result<WRef<'_>> {
        match self.inp.get(name) {
            Some(HostValue::F32(t)) => Ok(WRef::Dense(&t.data)),
            Some(HostValue::Q8(q)) => Ok(WRef::Q8 {
                codes: &q.codes,
                scales: &q.scales,
            }),
            Some(_) => bail!(
                "reference backend: input {name:?} should be an f32 \
                 or quantized weight"
            ),
            None => bail!(
                "reference backend: missing input {name:?}"
            ),
        }
    }

    /// Layer slice of a stacked [L, n, m] parameter. Quantization
    /// blocks tile the last axis only, so the slice stays
    /// block-aligned in both storage classes.
    fn layer_weight(&self, kind: &str, l: usize) -> Result<WRef<'_>> {
        let kd = self.cfg.kind(kind);
        let (n, m) = (kd.n, kd.m);
        Ok(match self.weight(kind)? {
            WRef::Dense(d) => {
                WRef::Dense(&d[l * n * m..(l + 1) * n * m])
            }
            WRef::Q8 { codes, scales } => {
                let bpr = m.div_ceil(QBLOCK);
                WRef::Q8 {
                    codes: &codes[l * n * m..(l + 1) * n * m],
                    scales: &scales[l * n * bpr..(l + 1) * n * bpr],
                }
            }
        })
    }

    /// `A[n,k] @ W[k,m]` into a pooled buffer, fused-dequant when the
    /// weight is int8.
    fn mm_w(
        &self,
        a: &[f32],
        w: WRef<'_>,
        n: usize,
        k: usize,
        m: usize,
    ) -> Vec<f32> {
        match w {
            WRef::Dense(b) => self.mm_p(a, b, n, k, m),
            WRef::Q8 { codes, scales } => {
                let mut out = self.pool.zeroed(n * m);
                kernels::mm_q8_into(&mut out, a, codes, scales, n, k, m);
                out
            }
        }
    }

    /// `A[n,k] @ W[m,k]ᵀ` into a pooled buffer, fused-dequant when the
    /// weight is int8.
    fn mm_nt_w(
        &self,
        a: &[f32],
        w: WRef<'_>,
        n: usize,
        k: usize,
        m: usize,
    ) -> Vec<f32> {
        match w {
            WRef::Dense(b) => self.mm_nt_p(a, b, n, k, m),
            WRef::Q8 { codes, scales } => {
                let mut out = self.pool.zeroed(n * m);
                kernels::mm_nt_q8_into_pooled(
                    &mut out, a, codes, scales, n, k, m, self.pool,
                );
                out
            }
        }
    }

    /// Row-gather from a weight table (the embedding lookup), either
    /// storage class.
    fn gather_w(
        &self,
        out: &mut [f32],
        name: &str,
        ids: &[i32],
        d: usize,
        limit: usize,
    ) -> Result<()> {
        match self.weight(name)? {
            WRef::Dense(w) => {
                kernels::gather_rows(out, w, ids, d, limit)
            }
            WRef::Q8 { codes, scales } => {
                kernels::gather_rows_q8(out, codes, scales, ids, d, limit)
            }
        }
        Ok(())
    }

    /// Dense view of a `[rows, m]` weight: borrows it directly when
    /// already f32, dequantizes into pooled scratch (stashed in `buf`
    /// for the caller to recycle) when int8.
    fn as_dense<'b>(
        &self,
        w: WRef<'b>,
        buf: &'b mut Option<Vec<f32>>,
        rows: usize,
        m: usize,
    ) -> &'b [f32] {
        match w {
            WRef::Dense(d) => d,
            WRef::Q8 { codes, scales } => {
                let mut out = self.pool.zeroed(rows * m);
                dequant_rows(&mut out, codes, scales, rows, m);
                buf.insert(out).as_slice()
            }
        }
    }

    fn probe(&self) -> Result<usize> {
        let p = self.i32_in("probe")?[0].max(0) as usize;
        Ok(p.min(self.dm.l - 1))
    }

    fn indices(
        &self,
        name: &str,
        l: usize,
        per_layer: usize,
        limit: usize,
    ) -> Result<Vec<usize>> {
        let data = self.i32_in(name)?;
        Ok(data[l * per_layer..(l + 1) * per_layer]
            .iter()
            .map(|&i| (i.max(0) as usize).min(limit - 1))
            .collect())
    }

    // ------------------------------------------------------- forward

    fn forward(&self) -> Result<FwdCache> {
        let dm = self.dm;
        let rows = dm.b * dm.s;
        let tokens = self.i32_in("tokens")?;

        let mut x = self.pool.zeroed(rows * dm.d);
        self.gather_w(&mut x, "embed", tokens, dm.d, dm.v)?;

        let norm1 = self.f32_in("norm1")?;
        let norm2 = self.f32_in("norm2")?;
        let (cos, sin) = rope_tables(dm.s, dm.dh, self.pool);
        let mut layers = Vec::with_capacity(dm.l);
        for l in 0..dm.l {
            let (c, x_new) = self.block_fwd(
                l,
                x,
                &norm1.data[l * dm.d..(l + 1) * dm.d],
                &norm2.data[l * dm.d..(l + 1) * dm.d],
                (&cos, &sin),
            )?;
            layers.push(c);
            x = x_new;
        }

        let norm_f = self.f32_in("norm_f")?;
        let (xnorm, invf) =
            self.rmsnorm_p(&x, &norm_f.data, rows, dm.d);
        let lm_head = self.weight("lm_head")?;
        let mut logits =
            self.mm_w(&xnorm, lm_head, rows, dm.d, dm.v);
        if self.variant == Variant::Losia {
            let vs = self.cfg.vocab_sub;
            let gamma =
                self.indices("gamma_out", 0, vs, dm.v)?;
            let dws = self.f32_in("dws_out")?;
            let y = self.mm_p(&xnorm, &dws.data, rows, dm.d, vs);
            scatter_cols(&mut logits, rows, dm.v, &gamma, &y);
            self.pool.recycle(y);
        }
        Ok(FwdCache {
            layers,
            cos,
            sin,
            xf: x,
            invf,
            xnorm,
            logits,
        })
    }

    fn block_fwd(
        &self,
        l: usize,
        x: Vec<f32>,
        norm1: &[f32],
        norm2: &[f32],
        rope: (&[f32], &[f32]),
    ) -> Result<(LayerCache, Vec<f32>)> {
        let dm = self.dm;
        let rows = dm.b * dm.s;
        let sh = self.attn_shape();
        let (h, inv1) = self.rmsnorm_p(&x, norm1, rows, dm.d);
        let mut q = self.lin_fwd(l, "wq", &h, rows)?;
        let mut k = self.lin_fwd(l, "wk", &h, rows)?;
        let v = self.lin_fwd(l, "wv", &h, rows)?;

        let (cos, sin) = rope;
        kernels::rope_apply(&mut q, sh, cos, sin, false);
        kernels::rope_apply(&mut k, sh, cos, sin, false);

        // pack q/k/v unit-major once; the head-parallel attention
        // units (forward now, backward later via the cache) stream
        // them contiguously. zeroed() despite being fully overwritten:
        // the parallel row-copy needs initialized storage to split
        // into &mut chunks (safe Rust), and the memset is O(rows·d)
        // against the O(rows·s·dh) attention it feeds.
        let mut qh = self.pool.zeroed(rows * dm.d);
        let mut kh = self.pool.zeroed(rows * dm.d);
        let mut vh = self.pool.zeroed(rows * dm.d);
        kernels::pack_heads(&mut qh, &q, sh);
        kernels::pack_heads(&mut kh, &k, sh);
        kernels::pack_heads(&mut vh, &v, sh);
        self.pool.recycle(q);
        self.pool.recycle(k);
        self.pool.recycle(v);

        let mut att = self.pool.zeroed(rows * dm.d);
        let mut probs = self.pool.zeroed(dm.b * dm.h * dm.s * dm.s);
        kernels::attention_fwd(
            &mut att, &mut probs, &qh, &kh, &vh, sh, self.pool,
        );
        let wo_out = self.lin_fwd(l, "wo", &att, rows)?;
        let mut x_mid = self.pool.cleared(rows * dm.d);
        x_mid.extend_from_slice(&x);
        add_into(&mut x_mid, &wo_out);
        self.pool.recycle(wo_out);

        let (h2, inv2) = self.rmsnorm_p(&x_mid, norm2, rows, dm.d);
        let gate = self.lin_fwd(l, "wgate", &h2, rows)?;
        let up = self.lin_fwd(l, "wup", &h2, rows)?;
        let mut mlp = self.pool.zeroed(rows * self.cfg.d_ff);
        kernels::silu_mul(&mut mlp, &gate, &up);
        let down = self.lin_fwd(l, "wdown", &mlp, rows)?;
        let mut x_new = self.pool.cleared(rows * dm.d);
        x_new.extend_from_slice(&x_mid);
        add_into(&mut x_new, &down);
        self.pool.recycle(down);

        Ok((
            LayerCache {
                x_in: x,
                h,
                inv1,
                qh,
                kh,
                vh,
                probs,
                att,
                x_mid,
                h2,
                inv2,
                gate,
                up,
                mlp,
            },
            x_new,
        ))
    }

    // ------------------------------------------------------- linears

    fn lin_fwd(
        &self,
        l: usize,
        kind: &str,
        x: &[f32],
        rows: usize,
    ) -> Result<Vec<f32>> {
        let kd = self.cfg.kind(kind);
        let w = self.layer_weight(kind, l)?;
        match self.variant {
            Variant::Plain => Ok(self.mm_w(x, w, rows, kd.n, kd.m)),
            Variant::Losia => {
                let mut y = self.mm_w(x, w, rows, kd.n, kd.m);
                let rho = self.indices(
                    &format!("rho_{kind}"),
                    l,
                    kd.np,
                    kd.n,
                )?;
                let gamma = self.indices(
                    &format!("gamma_{kind}"),
                    l,
                    kd.mp,
                    kd.m,
                )?;
                let dws_t = self.f32_in(&format!("dws_{kind}"))?;
                let dws = &dws_t.data
                    [l * kd.np * kd.mp..(l + 1) * kd.np * kd.mp];
                let xs = gather_cols(x, rows, kd.n, &rho);
                let ys = self.mm_p(&xs, dws, rows, kd.np, kd.mp);
                scatter_cols(&mut y, rows, kd.m, &gamma, &ys);
                self.pool.recycle(ys);
                Ok(y)
            }
            Variant::Lora { dora } => {
                let r = self.cfg.lora_rank;
                let scale = (self.cfg.lora_alpha
                    / self.cfg.lora_rank as f64)
                    as f32;
                let la_t = self.f32_in(&format!("la_{kind}"))?;
                let lb_t = self.f32_in(&format!("lb_{kind}"))?;
                let la =
                    &la_t.data[l * kd.n * r..(l + 1) * kd.n * r];
                let lb =
                    &lb_t.data[l * r * kd.m..(l + 1) * r * kd.m];
                if !dora {
                    let mut y = self.mm_w(x, w, rows, kd.n, kd.m);
                    let xa = self.mm_p(x, la, rows, kd.n, r);
                    let mut yl = self.mm_p(&xa, lb, rows, r, kd.m);
                    for v in yl.iter_mut() {
                        *v *= scale;
                    }
                    add_into(&mut y, &yl);
                    self.pool.recycle(xa);
                    self.pool.recycle(yl);
                    Ok(y)
                } else {
                    let mut wdq = None;
                    let wd = self.as_dense(w, &mut wdq, kd.n, kd.m);
                    let (wp, cn, weff) =
                        self.dora_frames(l, kind, wd, la, lb, scale)?;
                    let y = self.mm_p(x, &weff, rows, kd.n, kd.m);
                    self.pool.recycle(wp);
                    self.pool.recycle(cn);
                    self.pool.recycle(weff);
                    if let Some(v) = wdq {
                        self.pool.recycle(v);
                    }
                    Ok(y)
                }
            }
        }
    }

    /// DoRA frames shared by forward and backward: `wp = W + s·A·B`,
    /// per-column norms `cn = √(Σ wp² + 1e-8)`, and the effective
    /// weight `weff = wp · mag/cn`.
    #[allow(clippy::type_complexity)]
    fn dora_frames(
        &self,
        l: usize,
        kind: &str,
        w: &[f32],
        la: &[f32],
        lb: &[f32],
        scale: f32,
    ) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let kd = self.cfg.kind(kind);
        let r = self.cfg.lora_rank;
        let mag_t = self.f32_in(&format!("mag_{kind}"))?;
        let mag = &mag_t.data[l * kd.m..(l + 1) * kd.m];
        let mut wp = self.mm_p(la, lb, kd.n, r, kd.m);
        for (i, v) in wp.iter_mut().enumerate() {
            *v = w[i] + scale * *v;
        }
        let mut cn = self.pool.zeroed(kd.m);
        for i in 0..kd.n {
            for j in 0..kd.m {
                let v = wp[i * kd.m + j];
                cn[j] += v * v;
            }
        }
        for c in cn.iter_mut() {
            *c = (*c + 1e-8).sqrt();
        }
        let mut weff = self.pool.cleared(kd.n * kd.m);
        weff.extend_from_slice(&wp);
        for i in 0..kd.n {
            for j in 0..kd.m {
                weff[i * kd.m + j] *= mag[j] / cn[j];
            }
        }
        Ok((wp, cn, weff))
    }

    /// Backward through one linear: returns dx, accumulates gradients.
    #[allow(clippy::too_many_arguments)]
    fn lin_bwd(
        &self,
        l: usize,
        kind: &str,
        x: &[f32],
        rows: usize,
        dy: &[f32],
        sinks: &mut Sinks,
    ) -> Result<Vec<f32>> {
        let kd = self.cfg.kind(kind);
        let w = self.layer_weight(kind, l)?;
        if let Some(params) = &mut sinks.params {
            let g = self.mm_tn_p(x, dy, rows, kd.n, kd.m);
            let dst = params.get_mut(kind).unwrap();
            add_into(
                &mut dst.data
                    [l * kd.n * kd.m..(l + 1) * kd.n * kd.m],
                &g,
            );
            self.pool.recycle(g);
        }
        match self.variant {
            Variant::Plain => {
                Ok(self.mm_nt_w(dy, w, rows, kd.m, kd.n))
            }
            Variant::Losia => {
                let rho = self.indices(
                    &format!("rho_{kind}"),
                    l,
                    kd.np,
                    kd.n,
                )?;
                let gamma = self.indices(
                    &format!("gamma_{kind}"),
                    l,
                    kd.mp,
                    kd.m,
                )?;
                let dws_t = self.f32_in(&format!("dws_{kind}"))?;
                let dws = &dws_t.data
                    [l * kd.np * kd.mp..(l + 1) * kd.np * kd.mp];
                let xs = gather_cols(x, rows, kd.n, &rho);
                let dys = gather_cols(dy, rows, kd.m, &gamma);
                // Eq. 9: the factorized subnet gradient
                let gsub =
                    self.mm_tn_p(&xs, &dys, rows, kd.np, kd.mp);
                let dst = sinks
                    .extras
                    .get_mut(&format!("dws_{kind}"))
                    .unwrap();
                add_into(
                    &mut dst.data
                        [l * kd.np * kd.mp..(l + 1) * kd.np * kd.mp],
                    &gsub,
                );
                self.pool.recycle(gsub);
                let mut dx = self.mm_nt_w(dy, w, rows, kd.m, kd.n);
                let dxs =
                    self.mm_nt_p(&dys, dws, rows, kd.mp, kd.np);
                scatter_cols(&mut dx, rows, kd.n, &rho, &dxs);
                self.pool.recycle(dxs);
                Ok(dx)
            }
            Variant::Lora { dora } => {
                let r = self.cfg.lora_rank;
                let scale = (self.cfg.lora_alpha
                    / self.cfg.lora_rank as f64)
                    as f32;
                let la_t = self.f32_in(&format!("la_{kind}"))?;
                let lb_t = self.f32_in(&format!("lb_{kind}"))?;
                let la =
                    &la_t.data[l * kd.n * r..(l + 1) * kd.n * r];
                let lb =
                    &lb_t.data[l * r * kd.m..(l + 1) * r * kd.m];
                if !dora {
                    let dyb = self.mm_nt_p(dy, lb, rows, kd.m, r);
                    let mut gla =
                        self.mm_tn_p(x, &dyb, rows, kd.n, r);
                    for v in gla.iter_mut() {
                        *v *= scale;
                    }
                    let xa = self.mm_p(x, la, rows, kd.n, r);
                    let mut glb =
                        self.mm_tn_p(&xa, dy, rows, r, kd.m);
                    for v in glb.iter_mut() {
                        *v *= scale;
                    }
                    self.sink_adapter(sinks, "la", kind, l, &gla);
                    self.sink_adapter(sinks, "lb", kind, l, &glb);
                    let mut dx =
                        self.mm_nt_w(dy, w, rows, kd.m, kd.n);
                    let mut dxl =
                        self.mm_nt_p(&dyb, la, rows, r, kd.n);
                    for v in dxl.iter_mut() {
                        *v *= scale;
                    }
                    add_into(&mut dx, &dxl);
                    for v in [dyb, gla, xa, glb, dxl] {
                        self.pool.recycle(v);
                    }
                    Ok(dx)
                } else {
                    let mag_t =
                        self.f32_in(&format!("mag_{kind}"))?;
                    let mag = &mag_t.data[l * kd.m..(l + 1) * kd.m];
                    let mut wdq = None;
                    let wd = self.as_dense(w, &mut wdq, kd.n, kd.m);
                    let (wp, cn, weff) =
                        self.dora_frames(l, kind, wd, la, lb, scale)?;
                    let dweff =
                        self.mm_tn_p(x, dy, rows, kd.n, kd.m);
                    // col_j = Σ_i dweff·wp ; dmag_j = col_j / cn_j
                    let mut col = vec![0.0f32; kd.m];
                    for i in 0..kd.n {
                        for j in 0..kd.m {
                            col[j] += dweff[i * kd.m + j]
                                * wp[i * kd.m + j];
                        }
                    }
                    let gmag: Vec<f32> = (0..kd.m)
                        .map(|j| col[j] / cn[j])
                        .collect();
                    // dwp = dweff·(mag/cn) − wp·col·mag/cn³
                    let mut dwp = self.pool.zeroed(kd.n * kd.m);
                    for j in 0..kd.m {
                        let sden = mag[j] / cn[j];
                        let corr =
                            col[j] * mag[j] / (cn[j] * cn[j] * cn[j]);
                        for i in 0..kd.n {
                            dwp[i * kd.m + j] = dweff[i * kd.m + j]
                                * sden
                                - wp[i * kd.m + j] * corr;
                        }
                    }
                    let mut gla =
                        self.mm_nt_p(&dwp, lb, kd.n, kd.m, r);
                    for v in gla.iter_mut() {
                        *v *= scale;
                    }
                    let mut glb =
                        self.mm_tn_p(la, &dwp, kd.n, r, kd.m);
                    for v in glb.iter_mut() {
                        *v *= scale;
                    }
                    self.sink_adapter(sinks, "la", kind, l, &gla);
                    self.sink_adapter(sinks, "lb", kind, l, &glb);
                    self.sink_adapter(sinks, "mag", kind, l, &gmag);
                    let dx =
                        self.mm_nt_p(dy, &weff, rows, kd.m, kd.n);
                    for v in [wp, cn, weff, dweff, dwp, gla, glb] {
                        self.pool.recycle(v);
                    }
                    if let Some(v) = wdq {
                        self.pool.recycle(v);
                    }
                    Ok(dx)
                }
            }
        }
    }

    fn sink_adapter(
        &self,
        sinks: &mut Sinks,
        group: &str,
        kind: &str,
        l: usize,
        g: &[f32],
    ) {
        let dst = sinks
            .extras
            .get_mut(&format!("{group}_{kind}"))
            .unwrap();
        let per = g.len();
        add_into(&mut dst.data[l * per..(l + 1) * per], g);
    }

    // -------------------------------------------------------- losses

    /// Per-sequence (summed NLL, token count) — the `fwd_loss` ABI,
    /// sequence-parallel in the kernel layer.
    fn seq_nll(
        &self,
        logits: &[f32],
    ) -> Result<(Vec<f32>, Vec<f32>)> {
        let dm = self.dm;
        let targets = self.i32_in("targets")?;
        let mask = self.f32_in("mask")?;
        let mut nll = vec![0.0f32; dm.b];
        let mut cnt = vec![0.0f32; dm.b];
        kernels::seq_nll(
            &mut nll, &mut cnt, logits, targets, &mask.data, dm.b,
            dm.s, dm.v,
        );
        Ok((nll, cnt))
    }

    /// Mean masked loss and its logits cotangent, tile-parallel in
    /// the kernel layer.
    fn loss_and_dlogits(
        &self,
        logits: &[f32],
    ) -> Result<(f32, Vec<f32>)> {
        let dm = self.dm;
        let rows = dm.b * dm.s;
        let targets = self.i32_in("targets")?;
        let mask = self.f32_in("mask")?;
        let total: f32 = mask.data.iter().sum();
        let c = total.max(1.0);
        let mut dl = self.pool.zeroed(rows * dm.v);
        let loss = kernels::ce_loss(
            &mut dl, logits, targets, &mask.data, rows, dm.v, c,
            self.pool,
        );
        Ok((loss, dl))
    }

    // ------------------------------------------------------ backward

    fn backward(
        &self,
        fwd: &FwdCache,
        dlogits: Vec<f32>,
        want_params: bool,
    ) -> Result<Sinks> {
        let dm = self.dm;
        let rows = dm.b * dm.s;
        let mut sinks = Sinks {
            params: want_params.then(|| {
                self.cfg
                    .params
                    .iter()
                    .map(|(n, s)| (n.clone(), Tensor::zeros(s)))
                    .collect()
            }),
            extras: BTreeMap::new(),
        };
        match self.variant {
            Variant::Losia => {
                for kind in &self.cfg.linear_kinds {
                    let kd = self.cfg.kind(kind);
                    sinks.extras.insert(
                        format!("dws_{kind}"),
                        Tensor::zeros(&[dm.l, kd.np, kd.mp]),
                    );
                }
                sinks.extras.insert(
                    "dws_out".into(),
                    Tensor::zeros(&[dm.d, self.cfg.vocab_sub]),
                );
            }
            Variant::Lora { dora } => {
                let r = self.cfg.lora_rank;
                for kind in &self.cfg.linear_kinds {
                    let kd = self.cfg.kind(kind);
                    sinks.extras.insert(
                        format!("la_{kind}"),
                        Tensor::zeros(&[dm.l, kd.n, r]),
                    );
                    sinks.extras.insert(
                        format!("lb_{kind}"),
                        Tensor::zeros(&[dm.l, r, kd.m]),
                    );
                    if dora {
                        sinks.extras.insert(
                            format!("mag_{kind}"),
                            Tensor::zeros(&[dm.l, kd.m]),
                        );
                    }
                }
            }
            Variant::Plain => {}
        }

        // lm_head (+ output-layer subnet delta)
        let lm_head = self.weight("lm_head")?;
        if let Some(params) = &mut sinks.params {
            let g =
                self.mm_tn_p(&fwd.xnorm, &dlogits, rows, dm.d, dm.v);
            add_into(&mut params.get_mut("lm_head").unwrap().data, &g);
            self.pool.recycle(g);
        }
        let mut dxnorm =
            self.mm_nt_w(&dlogits, lm_head, rows, dm.v, dm.d);
        if self.variant == Variant::Losia {
            let vs = self.cfg.vocab_sub;
            let gamma = self.indices("gamma_out", 0, vs, dm.v)?;
            let dls = gather_cols(&dlogits, rows, dm.v, &gamma);
            let g = self.mm_tn_p(&fwd.xnorm, &dls, rows, dm.d, vs);
            add_into(
                &mut sinks.extras.get_mut("dws_out").unwrap().data,
                &g,
            );
            self.pool.recycle(g);
            let dws = self.f32_in("dws_out")?;
            let dxd = self.mm_nt_p(&dls, &dws.data, rows, vs, dm.d);
            add_into(&mut dxnorm, &dxd);
            self.pool.recycle(dxd);
        }
        self.pool.recycle(dlogits);

        let norm_f = self.f32_in("norm_f")?;
        let (mut dx, dnf) = self.rmsnorm_bwd_p(
            &fwd.xf,
            &norm_f.data,
            &fwd.invf,
            &dxnorm,
            rows,
            dm.d,
        );
        self.pool.recycle(dxnorm);
        if let Some(params) = &mut sinks.params {
            add_into(&mut params.get_mut("norm_f").unwrap().data, &dnf);
        }
        self.pool.recycle(dnf);

        let norm1 = self.f32_in("norm1")?;
        let norm2 = self.f32_in("norm2")?;
        for l in (0..dm.l).rev() {
            let c = &fwd.layers[l];
            // x = x_mid + down(mlp)
            let dmlp =
                self.lin_bwd(l, "wdown", &c.mlp, rows, &dx, &mut sinks)?;
            let mut dx_mid = dx;
            let ff = self.cfg.d_ff;
            let mut dgate = self.pool.zeroed(rows * ff);
            let mut dup = self.pool.zeroed(rows * ff);
            kernels::dsilu_mul(
                &mut dgate, &mut dup, &dmlp, &c.gate, &c.up,
            );
            self.pool.recycle(dmlp);
            let mut dh2 =
                self.lin_bwd(l, "wup", &c.h2, rows, &dup, &mut sinks)?;
            let dh2b = self
                .lin_bwd(l, "wgate", &c.h2, rows, &dgate, &mut sinks)?;
            add_into(&mut dh2, &dh2b);
            self.pool.recycle(dh2b);
            self.pool.recycle(dgate);
            self.pool.recycle(dup);
            let (dxm, dn2) = self.rmsnorm_bwd_p(
                &c.x_mid,
                &norm2.data[l * dm.d..(l + 1) * dm.d],
                &c.inv2,
                &dh2,
                rows,
                dm.d,
            );
            self.pool.recycle(dh2);
            add_into(&mut dx_mid, &dxm);
            self.pool.recycle(dxm);
            if let Some(params) = &mut sinks.params {
                add_into(
                    &mut params.get_mut("norm2").unwrap().data
                        [l * dm.d..(l + 1) * dm.d],
                    &dn2,
                );
            }
            self.pool.recycle(dn2);
            // x_mid = x_in + wo(att)
            let datt = self
                .lin_bwd(l, "wo", &c.att, rows, &dx_mid, &mut sinks)?;
            let mut dx_in = dx_mid;
            let sh = self.attn_shape();
            let mut dq = self.pool.zeroed(rows * dm.d);
            let mut dk = self.pool.zeroed(rows * dm.d);
            let mut dv = self.pool.zeroed(rows * dm.d);
            kernels::attention_bwd(
                &mut dq, &mut dk, &mut dv, &datt, &c.probs, &c.qh,
                &c.kh, &c.vh, sh, self.pool,
            );
            self.pool.recycle(datt);
            kernels::rope_apply(&mut dq, sh, &fwd.cos, &fwd.sin, true);
            kernels::rope_apply(&mut dk, sh, &fwd.cos, &fwd.sin, true);
            let mut dhp =
                self.lin_bwd(l, "wq", &c.h, rows, &dq, &mut sinks)?;
            let dhk =
                self.lin_bwd(l, "wk", &c.h, rows, &dk, &mut sinks)?;
            add_into(&mut dhp, &dhk);
            let dhv =
                self.lin_bwd(l, "wv", &c.h, rows, &dv, &mut sinks)?;
            add_into(&mut dhp, &dhv);
            for v in [dq, dk, dv, dhk, dhv] {
                self.pool.recycle(v);
            }
            let (dxi, dn1) = self.rmsnorm_bwd_p(
                &c.x_in,
                &norm1.data[l * dm.d..(l + 1) * dm.d],
                &c.inv1,
                &dhp,
                rows,
                dm.d,
            );
            self.pool.recycle(dhp);
            add_into(&mut dx_in, &dxi);
            self.pool.recycle(dxi);
            if let Some(params) = &mut sinks.params {
                add_into(
                    &mut params.get_mut("norm1").unwrap().data
                        [l * dm.d..(l + 1) * dm.d],
                    &dn1,
                );
            }
            self.pool.recycle(dn1);
            dx = dx_in;
        }

        if let Some(params) = &mut sinks.params {
            let tokens = self.i32_in("tokens")?;
            let de = params.get_mut("embed").unwrap();
            for r in 0..rows {
                let t = (tokens[r].max(0) as usize).min(dm.v - 1);
                add_into(
                    &mut de.data[t * dm.d..(t + 1) * dm.d],
                    &dx[r * dm.d..(r + 1) * dm.d],
                );
            }
        }
        self.pool.recycle(dx);
        Ok(sinks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Runtime;
    use crate::util::rng::Rng;

    fn rt() -> Runtime {
        let dir = crate::runtime::artifacts_dir();
        let cfg = crate::config::resolve_config(&dir, "tiny").unwrap();
        Runtime::with_backend(cfg, Box::new(RefBackend))
    }

    fn inputs_for(
        rt: &Runtime,
        name: &str,
        seed: u64,
    ) -> Vec<HostValue> {
        let spec = rt.cfg.artifact(name).clone();
        let mut rng = Rng::new(seed);
        spec.inputs
            .iter()
            .map(|i| match i.dtype {
                crate::config::Dtype::F32 => {
                    if i.name == "mask" || i.name.starts_with("norm") {
                        HostValue::F32(Tensor::ones(&i.shape))
                    } else {
                        HostValue::F32(Tensor::randn(
                            &i.shape, 0.05, &mut rng,
                        ))
                    }
                }
                crate::config::Dtype::I32 => {
                    let n: usize = i.shape.iter().product();
                    let data: Vec<usize> =
                        (0..n).map(|_| rng.below(4)).collect();
                    HostValue::from_indices(&i.shape, &data)
                }
            })
            .collect()
    }

    #[test]
    fn fwd_logits_shape_and_finiteness() {
        let rt = rt();
        let exe = rt.load("fwd_logits").unwrap();
        let out = exe.run(&inputs_for(&rt, "fwd_logits", 0)).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(
            out[0].shape,
            vec![rt.cfg.batch, rt.cfg.seq_len, rt.cfg.vocab]
        );
        assert!(out[0].data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn grads_full_loss_positive_and_grads_nonzero() {
        let rt = rt();
        let exe = rt.load("grads_full").unwrap();
        let out = exe.run(&inputs_for(&rt, "grads_full", 1)).unwrap();
        let loss = out[0].data[0];
        assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
        assert!(out[1].frob_norm() > 0.0, "embed grad is zero");
    }

    #[test]
    fn zero_mask_gives_zero_loss_and_grads() {
        let rt = rt();
        let exe = rt.load("grads_full").unwrap();
        let mut inputs = inputs_for(&rt, "grads_full", 2);
        let mask_idx = exe
            .spec()
            .inputs
            .iter()
            .position(|i| i.name == "mask")
            .unwrap();
        inputs[mask_idx] = HostValue::F32(Tensor::zeros(&[
            rt.cfg.batch,
            rt.cfg.seq_len,
        ]));
        let out = exe.run(&inputs).unwrap();
        assert_eq!(out[0].data[0], 0.0);
        for g in &out[1..] {
            assert!(g.data.iter().all(|&v| v == 0.0));
        }
    }

    #[test]
    fn static_bindings_cost_zero_per_step_copies() {
        // The device-residency contract: with every parameter bound
        // statically, N training-shaped steps move only the batch —
        // zero static re-uploads (and so zero parameter deep copies)
        // between mutations. Also pins that pooled scratch reuse
        // cannot contaminate results: every step must reproduce the
        // first step's outputs bitwise.
        use crate::coordinator::state::ModelState;
        use crate::data::Batch;
        use crate::runtime::ExecPlan;

        let rt = rt();
        let exe = rt.load("fwd_loss").unwrap();
        let param_names: Vec<&str> = rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut plan =
            ExecPlan::new(std::sync::Arc::clone(&exe), &param_names)
                .unwrap();
        let mut rng = Rng::new(9);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let (b, s) = (rt.cfg.batch, rt.cfg.seq_len);
        let batch = Batch {
            tokens: (0..b * s).map(|i| (i % 7) as i32).collect(),
            targets: (0..b * s).map(|i| (i % 5) as i32).collect(),
            mask: vec![1.0; b * s],
            batch: b,
            seq: s,
        };
        plan.bind_params(&state).unwrap();
        plan.bind_batch(&batch).unwrap();
        let first = plan.run_host().unwrap();

        let s0 = exe.stats();
        for _ in 0..4 {
            plan.bind_batch(&batch).unwrap();
            let out = plan.run_host().unwrap();
            for (a, b) in first.iter().zip(&out) {
                assert_eq!(
                    a.data, b.data,
                    "pooled scratch contaminated a later step"
                );
            }
        }
        let d = exe.stats().delta_since(&s0);
        assert_eq!(d.calls, 4);
        assert_eq!(d.static_uploads, 0, "static params were re-copied");
        assert_eq!(d.step_uploads, 3 * 4, "tokens/targets/mask only");
    }

    #[test]
    fn long_lived_plan_matches_one_shot_run() {
        // Scratch-pool reuse (ExecPlan) vs fresh buffers every call
        // (Executable::run) must agree bitwise on the same inputs.
        let rt = rt();
        let exe = rt.load("grads_full").unwrap();
        let inputs = inputs_for(&rt, "grads_full", 11);
        let one_shot = exe.run(&inputs).unwrap();

        let mut plan =
            crate::runtime::ExecPlan::new(exe, &[]).unwrap();
        let specs = plan.spec().inputs.clone();
        for _ in 0..2 {
            for (spec, hv) in specs.iter().zip(&inputs) {
                plan.bind(&spec.name, hv.into()).unwrap();
            }
            let out = plan.run_host().unwrap();
            for (a, b) in one_shot.iter().zip(&out) {
                assert_eq!(a.shape, b.shape);
                assert_eq!(a.data, b.data, "plan diverged from run()");
            }
        }
    }

    #[test]
    fn donated_plan_matches_undonated_bitwise() {
        // Donation only changes where allocations come from — every
        // output must stay bit-identical to an undonated plan, and
        // the donated slot must invalidate after each run.
        let rt = rt();
        let exe = rt.load("grads_full").unwrap();
        let inputs = inputs_for(&rt, "grads_full", 21);
        let specs = exe.spec().inputs.clone();
        let statics: Vec<&str> = specs
            .iter()
            .filter(|s| s.dtype == crate::config::Dtype::F32)
            .map(|s| s.name.as_str())
            .collect();

        let mut plain =
            crate::runtime::ExecPlan::new(Arc::clone(&exe), &statics)
                .unwrap();
        let mut donor =
            crate::runtime::ExecPlan::new(Arc::clone(&exe), &statics)
                .unwrap();
        // donate every f32 parameter that has a same-shape gradient
        // output (mask has none and is rejected — skip it)
        let mut donated = 0;
        for s in &statics {
            if donor.donate(s).is_ok() {
                donated += 1;
            }
        }
        assert!(donated >= 2, "donated only {donated} inputs");

        for round in 0..2 {
            for (spec, hv) in specs.iter().zip(&inputs) {
                plain.bind(&spec.name, hv.into()).unwrap();
                donor.bind(&spec.name, hv.into()).unwrap();
            }
            let a = plain.run_host().unwrap();
            let b = donor.run_host().unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert_eq!(x.shape, y.shape);
                let same = x
                    .data
                    .iter()
                    .zip(&y.data)
                    .all(|(p, q)| p.to_bits() == q.to_bits());
                assert!(same, "round {round}: donation changed bits");
            }
        }

        // stale re-run: donated statics were consumed, plain's not.
        // Re-bind only the per-step inputs (tokens/targets) on both.
        for (spec, hv) in specs.iter().zip(&inputs) {
            if spec.dtype != crate::config::Dtype::F32 {
                plain.bind(&spec.name, hv.into()).unwrap();
                donor.bind(&spec.name, hv.into()).unwrap();
            }
        }
        plain.run().unwrap();
        let err = donor.run().unwrap_err();
        assert!(
            format!("{err:#}").contains("embed"),
            "stale donated slot should list unbound inputs: {err:#}"
        );
    }

    #[test]
    fn remat_variant_matches_plain() {
        let rt = rt();
        let a = rt.load("grads_full").unwrap();
        let b = rt.load("grads_full_remat").unwrap();
        let inputs = inputs_for(&rt, "grads_full", 3);
        let oa = a.run(&inputs).unwrap();
        let ob = b.run(&inputs).unwrap();
        assert_eq!(oa[0].data, ob[0].data);
    }

    #[test]
    fn losia_grads_respect_the_selection() {
        // g_dws must equal the (rho, gamma) slice of the full probe
        // gradient for the probed layer (Eq. 9 consistency).
        let rt = rt();
        let exe = rt.load("grads_losia").unwrap();
        let spec = exe.spec().clone();
        let mut rng = Rng::new(4);
        let mut inputs = Vec::new();
        for i in &spec.inputs {
            inputs.push(match i.dtype {
                crate::config::Dtype::F32 => {
                    if i.name == "mask" || i.name.starts_with("norm") {
                        HostValue::F32(Tensor::ones(&i.shape))
                    } else if i.name.starts_with("dws") {
                        HostValue::F32(Tensor::zeros(&i.shape))
                    } else {
                        HostValue::F32(Tensor::randn(
                            &i.shape, 0.05, &mut rng,
                        ))
                    }
                }
                crate::config::Dtype::I32 => {
                    if i.name == "probe" {
                        HostValue::scalar_i32(0)
                    } else if i.name == "tokens" || i.name == "targets"
                    {
                        let n: usize = i.shape.iter().product();
                        let data: Vec<usize> =
                            (0..n).map(|_| rng.below(4)).collect();
                        HostValue::from_indices(&i.shape, &data)
                    } else {
                        // distinct selection indices per layer row
                        let per = *i.shape.last().unwrap();
                        let rows: usize =
                            i.shape.iter().product::<usize>() / per;
                        let limit = if i.name == "gamma_out" {
                            rt.cfg.vocab
                        } else {
                            let kind = i
                                .name
                                .splitn(2, '_')
                                .nth(1)
                                .unwrap();
                            let kd = rt.cfg.kind(kind);
                            if i.name.starts_with("rho") {
                                kd.n
                            } else {
                                kd.m
                            }
                        };
                        let mut data = Vec::new();
                        for _ in 0..rows {
                            data.extend(
                                rng.choose_distinct(limit, per),
                            );
                        }
                        HostValue::from_indices(&i.shape, &data)
                    }
                }
            });
        }
        let out = exe.run(&inputs).unwrap();
        let by_name: BTreeMap<&str, &Tensor> = spec
            .outputs
            .iter()
            .zip(&out)
            .map(|(s, t)| (s.name.as_str(), t))
            .collect();
        let rho_wq = match &inputs[spec
            .inputs
            .iter()
            .position(|i| i.name == "rho_wq")
            .unwrap()]
        {
            HostValue::I32 { data, .. } => data.clone(),
            _ => unreachable!(),
        };
        let gamma_wq = match &inputs[spec
            .inputs
            .iter()
            .position(|i| i.name == "gamma_wq")
            .unwrap()]
        {
            HostValue::I32 { data, .. } => data.clone(),
            _ => unreachable!(),
        };
        let kd = rt.cfg.kind("wq");
        let rho: Vec<usize> =
            rho_wq[..kd.np].iter().map(|&i| i as usize).collect();
        let gamma: Vec<usize> =
            gamma_wq[..kd.mp].iter().map(|&i| i as usize).collect();
        let probe_full = by_name["probe_wq"];
        let want = probe_full.gather2(&rho, &gamma);
        let got = by_name["g_dws_wq"].index_axis0(0);
        for (a, b) in got.data.iter().zip(&want.data) {
            assert!(
                (a - b).abs() <= 1e-5 * b.abs().max(1.0),
                "factorized grad diverges from gathered: {a} vs {b}"
            );
        }
    }
}
