//! Deterministic data-parallel engine: shard-replicated [`ExecPlan`]s
//! with a fixed-order tree reduction over per-shard gradient frames.
//!
//! ## The determinism contract, one level up
//!
//! `runtime::kernels` keeps every kernel bitwise identical across
//! thread counts by fixing the work decomposition by *unit* and
//! folding reduction partials in a constant order. This module
//! promotes that property to a whole training run:
//!
//! * **Shards define the numerics, workers don't.** A run is split
//!   into `shards` (S) logical sub-batches per step — S is the
//!   analogue of the kernels' constant reduction-tile height. The
//!   `workers` (W) knob only says how many OS threads execute those
//!   shards concurrently (each worker owns one replicated plan and a
//!   contiguous shard block); it never appears in any arithmetic.
//! * **Fixed-order tree reduction.** Per-shard gradient frames are
//!   combined in pairwise rounds over ascending shard index —
//!   `(0+1), (2+3), …`, then the same over the survivors — so the
//!   fold shape depends only on S. Gradients are then averaged with
//!   one `× 1/S` pass (skipped entirely at S = 1 so the single-shard
//!   path is bit-for-bit the legacy step).
//! * **Thread-budget split.** Each worker runs its shards under
//!   [`kernels::with_thread_budget`]`(kernel_threads() / W)`, so W
//!   workers share the one process-wide budget instead of
//!   oversubscribing W × B threads (the same budget-is-spent-once
//!   rule as the kernels' nested-worker guard).
//!
//! Consequently `workers = 1` and `workers = N` produce identical
//! bits for the same `shards` — the `tests/kernel_parity.rs` property
//! promoted to whole-run, pinned end-to-end by `tests/dp_parity.rs`.
//!
//! ## Who reduces what
//!
//! Drivers expose their reducible set as named [`Frame`]s (see
//! `methods::Driver::grad_frames_sharded`). LoSiA-Pro contributes
//! only the subnet-delta-sized `dws_*` frames — cross-worker traffic
//! ∝ subnet size, the PR 4 download invariant made a communication
//! invariant — while LoRA ships adapter grads and GaLore/FFT/LoSiA
//! ship their full trainable gradient sets. Importance-probe outputs
//! ride along as undownloaded [`OutputHandle`]s and are **not**
//! reduced: the profiler consumes shard 0's probe only (worker-count
//! invariant, since shard 0's sub-batch is fixed by S).
//!
//! ## Composition with the step pipeline
//!
//! [`crate::runtime::pipeline`] stages step N+1's batch uploads while
//! step N executes. With dp on, the pipeline requires `shards ==
//! workers` (checked by `PipelineConfig::validate`): each plan then
//! runs exactly one shard per step, so one staged buffer set per plan
//! covers the whole step. With W < S a plan runs several shards
//! sequentially, re-binding its per-step slots between runs, and only
//! the first could be pre-staged (block-prefix staging is a possible
//! follow-up). The pipeline's stage threads draw from the same
//! process-wide kernel budget this module divides: the trainer wraps
//! the pipelined loop in `with_thread_budget(kernel_threads() −
//! prefetch_threads)`, and because each worker's
//! `kernel_threads() / W` split is computed on the training thread,
//! the dp workers see the reduced budget automatically.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::TrainConfig;
use crate::data::Batch;
use crate::runtime::backend::{ExecPlan, OutputHandle, Runtime};
use crate::runtime::kernels;
use crate::tensor::Tensor;
use crate::util::error::TrainError;
use crate::util::faultpoint;

/// Resolved data-parallel configuration for one run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DpConfig {
    /// Physical executor threads (each owns one replicated plan).
    /// Never affects numerics; clamped to `shards`.
    pub workers: usize,
    /// Logical sub-batches per step — the numerics knob. The final
    /// state is a pure function of `(seed, shards)`, not `workers`.
    pub shards: usize,
}

impl DpConfig {
    /// Resolve from the train config with env fallbacks: an explicit
    /// `TrainConfig` setting (the `SessionBuilder` knobs) wins, else
    /// `LOSIA_DP_WORKERS` / `LOSIA_DP_SHARDS`, else 1. Setting
    /// workers without shards defaults `shards = workers` (the
    /// common "just use N cores" case); workers are clamped to the
    /// shard count so no worker ever sits empty.
    pub fn resolve(tc: &TrainConfig) -> DpConfig {
        let env = |key: &str| {
            std::env::var(key)
                .ok()
                .and_then(|s| s.parse::<usize>().ok())
                .filter(|&n| n >= 1)
        };
        let workers = if tc.dp_workers != 1 {
            tc.dp_workers.max(1)
        } else {
            env("LOSIA_DP_WORKERS").unwrap_or(1)
        };
        let shards = if tc.dp_shards != 1 {
            tc.dp_shards.max(1)
        } else {
            env("LOSIA_DP_SHARDS").unwrap_or(workers)
        };
        DpConfig {
            workers: workers.min(shards).max(1),
            shards: shards.max(1),
        }
    }

    /// Whether the trainer should run the sharded loop at all.
    pub fn enabled(&self) -> bool {
        self.shards > 1
    }

    /// Kernel threads each worker may use: the process budget split
    /// evenly, floored at 1.
    pub fn worker_thread_budget(&self) -> usize {
        (kernels::kernel_threads() / self.workers.max(1)).max(1)
    }
}

/// Validated plan-replica count for a driver: the resolved worker
/// count, with parallel replication gated to the reference backend
/// (PJRT buffer thread-safety is untested — same policy as Q8
/// binds being ref-only).
pub fn plan_count(rt: &Runtime, tc: &TrainConfig) -> Result<usize> {
    let dp = DpConfig::resolve(tc);
    ensure!(
        dp.workers <= 1 || rt.backend_name() == "ref",
        "dp: workers={} requires the reference backend \
         (LOSIA_BACKEND=ref); backend `{}` plans are not replicated \
         across threads. Run with workers=1 (shards still apply).",
        dp.workers,
        rt.backend_name()
    );
    Ok(dp.workers.max(1))
}

/// One named gradient/delta tensor contributed to the reduction.
#[derive(Debug, Clone)]
pub struct Frame {
    pub name: String,
    pub grad: Tensor,
}

/// Device-resident importance-probe outputs (LoSiA-Pro): full-layer
/// gradient handles that stay on device unless the profiler reads
/// them. Never reduced — shard 0's payload is the one consumed.
pub struct ProbePayload {
    /// probed layer's grads, linear-kind ABI order
    pub layer_grads: Vec<OutputHandle>,
    /// full lm_head grad
    pub lm_grad: OutputHandle,
}

/// One shard's reducible step output.
pub struct GradFrames {
    pub loss: f64,
    pub frames: Vec<Frame>,
    pub probe: Option<ProbePayload>,
}

/// All shards' outputs for one step, plus per-worker busy time.
pub struct ShardedGrads {
    pub shards: Vec<GradFrames>,
    /// wall nanos each worker spent on its shard block (length = the
    /// worker count actually used this step)
    pub worker_nanos: Vec<u64>,
}

/// Fold `shards` into one averaged [`GradFrames`] with the fixed
/// pairwise-rounds tree; returns the reduced frames and the byte size
/// of one shard's frame set (== the cross-worker traffic each worker
/// contributes per step).
///
/// Round 1 combines `(0+1), (2+3), …` in ascending shard order; each
/// later round does the same over the survivors (an odd tail carries
/// over unchanged). The fold shape is a function of `shards.len()`
/// alone, so the result is bitwise independent of how many workers
/// produced the inputs. After folding, losses and gradients are
/// scaled by `1/S` (f64 resp. f32) — skipped at S = 1 so a
/// single-shard reduce is an exact pass-through of the legacy step.
/// The probe payload is taken from shard 0; other shards' handles
/// drop undownloaded (zero bytes moved).
pub fn reduce(shards: Vec<GradFrames>) -> Result<(GradFrames, u64)> {
    ensure!(!shards.is_empty(), "dp: reduce of zero shards");
    let n = shards.len();
    let frame_bytes: u64 = shards[0]
        .frames
        .iter()
        .map(|f| f.grad.len() as u64 * 4)
        .sum();
    for (i, s) in shards.iter().enumerate().skip(1) {
        ensure!(
            s.frames.len() == shards[0].frames.len(),
            "dp: shard {i} produced {} frames, shard 0 produced {}",
            s.frames.len(),
            shards[0].frames.len()
        );
        for (a, b) in shards[0].frames.iter().zip(&s.frames) {
            ensure!(
                a.name == b.name && a.grad.shape == b.grad.shape,
                "dp: shard {i} frame `{}` {:?} does not match \
                 shard 0 frame `{}` {:?}",
                b.name,
                b.grad.shape,
                a.name,
                a.grad.shape
            );
        }
    }
    let mut items = shards;
    let probe = items[0].probe.take();
    while items.len() > 1 {
        let mut next = Vec::with_capacity(items.len().div_ceil(2));
        let mut it = items.into_iter();
        while let Some(mut a) = it.next() {
            if let Some(b) = it.next() {
                a.loss += b.loss;
                for (fa, fb) in a.frames.iter_mut().zip(b.frames) {
                    fa.grad.add_assign(&fb.grad);
                }
            }
            next.push(a);
        }
        items = next;
    }
    let mut red = items.pop().expect("non-empty reduce");
    if n > 1 {
        red.loss /= n as f64;
        let inv = 1.0f32 / n as f32;
        for f in &mut red.frames {
            f.grad.scale_assign(inv);
        }
    }
    red.probe = probe;
    Ok((red, frame_bytes))
}

/// Run `f(shard_index, plan, batch)` for every shard, fanning
/// contiguous shard blocks out across the replicated `plans`.
///
/// Worker `w` of `W` owns `plans[w]` and shards
/// `[S·w/W, S·(w+1)/W)` — an even contiguous split — and executes
/// them **sequentially** on its plan under a
/// [`kernels::with_thread_budget`] cap of `kernel_threads() / W`.
/// With one plan (or one shard) everything runs inline on the
/// calling thread with no cap. Results come back in shard order
/// either way; since `f`'s output is a pure function of
/// `(shard index, bindings)`, the worker count is invisible in them.
///
/// `t` is the 0-based training step — it arms the `dp-worker` fault
/// site and labels contained panics. A panic inside `f` (on any
/// worker) is caught after every worker finished its block and joined,
/// then surfaced as [`TrainError::WorkerPanic`] — no thread leaks, no
/// poisoned state, and the other workers' shards complete normally.
pub fn run_sharded<T, F>(
    plans: &mut [ExecPlan],
    batches: &[Batch],
    t: usize,
    f: F,
) -> Result<(Vec<T>, Vec<u64>)>
where
    T: Send,
    F: Fn(usize, &mut ExecPlan, &Batch) -> Result<T> + Sync,
{
    ensure!(!plans.is_empty(), "dp: no plans to run");
    let s = batches.len();
    let w = plans.len().min(s).max(1);
    if w <= 1 {
        let t0 = Instant::now();
        let mut out = Vec::with_capacity(s);
        for (i, b) in batches.iter().enumerate() {
            faultpoint::hit("dp-worker", t)?;
            let r = catch_unwind(AssertUnwindSafe(|| {
                f(i, &mut plans[0], b)
            }))
            .map_err(|_| TrainError::WorkerPanic {
                site: "dp-worker".into(),
            })?;
            out.push(r?);
        }
        return Ok((out, vec![t0.elapsed().as_nanos() as u64]));
    }
    let budget = (kernels::kernel_threads() / w).max(1);
    let mut results: Vec<Option<Result<T>>> =
        (0..s).map(|_| None).collect();
    let mut nanos = vec![0u64; w];
    let mut panicked = vec![false; w];
    std::thread::scope(|scope| {
        let mut plans_rest: &mut [ExecPlan] = plans;
        let mut res_rest: &mut [Option<Result<T>>] = &mut results;
        let mut nanos_rest: &mut [u64] = &mut nanos;
        let mut panic_rest: &mut [bool] = &mut panicked;
        for wi in 0..w {
            let lo = s * wi / w;
            let hi = s * (wi + 1) / w;
            let (plan, pr) =
                plans_rest.split_first_mut().expect("plan per worker");
            plans_rest = pr;
            let (chunk, rr) = res_rest.split_at_mut(hi - lo);
            res_rest = rr;
            let (busy, nr) =
                nanos_rest.split_first_mut().expect("slot per worker");
            nanos_rest = nr;
            let (poisoned, xr) =
                panic_rest.split_first_mut().expect("flag per worker");
            panic_rest = xr;
            let fref = &f;
            scope.spawn(move || {
                let t0 = Instant::now();
                // contain panics inside the worker so the scope joins
                // every thread normally and the training thread can
                // surface one typed error instead of re-panicking
                let caught = catch_unwind(AssertUnwindSafe(|| {
                    kernels::with_thread_budget(budget, || {
                        for (k, slot) in chunk.iter_mut().enumerate() {
                            let i = lo + k;
                            *slot =
                                Some(faultpoint::hit("dp-worker", t).and_then(
                                    |()| fref(i, plan, &batches[i]),
                                ));
                        }
                    });
                }));
                *poisoned = caught.is_err();
                *busy = t0.elapsed().as_nanos() as u64;
            });
        }
    });
    if panicked.iter().any(|&p| p) {
        return Err(TrainError::WorkerPanic {
            site: "dp-worker".into(),
        }
        .into());
    }
    let mut out = Vec::with_capacity(s);
    for r in results {
        out.push(r.expect("worker filled every slot")?);
    }
    Ok((out, nanos))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frames(loss: f64, vals: &[f32]) -> GradFrames {
        GradFrames {
            loss,
            frames: vec![Frame {
                name: "g".into(),
                grad: Tensor::from_vec(&[vals.len()], vals.to_vec()),
            }],
            probe: None,
        }
    }

    #[test]
    fn single_shard_reduce_is_exact_passthrough() {
        // no 1/S scale at S = 1 — bits in == bits out, including a
        // loss whose ×1.0 round trip we refuse to rely on
        let vals = [1.000001f32, -0.25, 3.5e-8];
        let (red, bytes) = reduce(vec![frames(0.625, &vals)]).unwrap();
        assert_eq!(red.loss.to_bits(), 0.625f64.to_bits());
        for (a, b) in red.frames[0].grad.data.iter().zip(&vals) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert_eq!(bytes, 12);
    }

    #[test]
    fn reduce_is_pairwise_rounds_not_sequential() {
        // values chosen so ((a+b)+(c+d)) != (((a+b)+c)+d) in f32:
        // the tree must fold (0+1) and (2+3) first
        let a = 1.0e8f32;
        let b = -1.0e8f32;
        let c = 1.0f32;
        let d = 3.0e-8f32;
        let (red, _) = reduce(vec![
            frames(0.0, &[a]),
            frames(0.0, &[b]),
            frames(0.0, &[c]),
            frames(0.0, &[d]),
        ])
        .unwrap();
        let tree = ((a + b) + (c + d)) * (1.0 / 4.0);
        let seq = ((a + b) + c + d) * (1.0 / 4.0);
        assert_ne!(tree.to_bits(), seq.to_bits(), "bad test values");
        assert_eq!(red.frames[0].grad.data[0].to_bits(), tree.to_bits());
    }

    #[test]
    fn reduce_averages_loss_and_handles_odd_tails() {
        let (red, _) = reduce(vec![
            frames(1.0, &[3.0]),
            frames(2.0, &[6.0]),
            frames(6.0, &[9.0]),
        ])
        .unwrap();
        // pairwise: (1+2), carry 6 → (3+6) → /3
        assert_eq!(red.loss, 3.0);
        assert_eq!(red.frames[0].grad.data[0], 6.0);
    }

    #[test]
    fn reduce_rejects_mismatched_frames() {
        let a = frames(0.0, &[1.0, 2.0]);
        let b = frames(0.0, &[1.0]);
        assert!(reduce(vec![a, b]).is_err());
        let a = frames(0.0, &[1.0]);
        let mut b = frames(0.0, &[1.0]);
        b.frames[0].name = "other".into();
        assert!(reduce(vec![a, b]).is_err());
    }

    #[test]
    fn resolve_defaults_clamps_and_reads_builder() {
        use crate::config::TrainConfig;
        let tc = TrainConfig::default();
        let dp = DpConfig::resolve(&tc);
        // default: no dp (env vars are not set in the test harness)
        if std::env::var("LOSIA_DP_WORKERS").is_err()
            && std::env::var("LOSIA_DP_SHARDS").is_err()
        {
            assert_eq!(dp, DpConfig { workers: 1, shards: 1 });
        }
        // workers alone defaults shards = workers
        let tc = TrainConfig {
            dp_workers: 4,
            ..TrainConfig::default()
        };
        let dp = DpConfig::resolve(&tc);
        assert_eq!(dp.workers, 4);
        assert_eq!(dp.shards, 4);
        assert!(dp.enabled());
        // workers clamp to shards
        let tc = TrainConfig {
            dp_workers: 4,
            dp_shards: 2,
            ..TrainConfig::default()
        };
        let dp = DpConfig::resolve(&tc);
        assert_eq!(dp, DpConfig { workers: 2, shards: 2 });
        // shards without workers: serial but sharded numerics
        let tc = TrainConfig {
            dp_shards: 3,
            ..TrainConfig::default()
        };
        let dp = DpConfig::resolve(&tc);
        assert_eq!(dp, DpConfig { workers: 1, shards: 3 });
        assert!(dp.enabled());
    }
}
