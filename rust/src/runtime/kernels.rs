//! Shared compute kernels for the reference backend: cache-blocked,
//! row-parallel matrix multiplies plus a scratch-buffer pool.
//!
//! ## Determinism contract
//!
//! Every kernel accumulates each output element in ascending-`k`
//! order, exactly like the historical naive interpreter loops, and
//! parallelism only partitions **output rows** across threads — chunk
//! boundaries never change the per-element accumulation order. Parallel
//! output is therefore bitwise identical to serial output (pinned by
//! `serial_and_parallel_agree_bitwise` below), which is what lets
//! `tests/backend_parity.rs` keep its tolerances while the thread count
//! varies between machines.
//!
//! One deliberate divergence from the old loops: they skipped
//! `a == 0.0` terms, these kernels always multiply. For finite
//! operands that can only flip the sign of an exactly-zero result
//! (`±0`, invisible to `==` and to tolerance checks); a zero weight
//! against a non-finite activation now propagates NaN where the skip
//! hid it — which is the honest IEEE answer.
//!
//! ## Threading
//!
//! The worker count defaults to `std::thread::available_parallelism`
//! and can be overridden with `LOSIA_KERNEL_THREADS` (`1` forces
//! serial). Small products (< [`PAR_MIN_MACS`] multiply-accumulates)
//! always run serial so the tiny-config test suite is not taxed with
//! spawn overhead. Workers are scoped `std::thread` spawns by default;
//! with the optional `rayon` cargo feature the same row chunks are
//! dispatched onto the rayon global pool instead (identical results —
//! chunking, not scheduling, determines the numerics).
//!
//! ## Scratch reuse
//!
//! [`Pool`] recycles the interpreter's large `f32` temporaries across
//! `execute()` calls: each `RefBackend` buffer set owns one pool, so a
//! training step re-uses the previous step's activation/gradient
//! buffers instead of re-allocating them (see
//! `runtime/README.md` § kernels).

// index-heavy kernels: explicit loops ARE the clearest form here
#![allow(clippy::needless_range_loop)]

use std::cell::RefCell;
use std::sync::OnceLock;

/// Minimum multiply-accumulate count before a kernel fans out to
/// threads; below this, spawn overhead dominates the work.
pub const PAR_MIN_MACS: usize = 1 << 18;

/// Row-tile height: output rows computed together so one loaded `b`
/// row feeds several accumulator rows.
const RT: usize = 4;

/// Column-tile width: per-tile accumulators live in registers/L1
/// across the whole `k` loop instead of re-reading the output row.
const JT: usize = 16;

/// Worker-thread count for the row-parallel kernels: the
/// `LOSIA_KERNEL_THREADS` env var when set (minimum 1), else
/// `available_parallelism`. Cached for the process lifetime.
pub fn kernel_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("LOSIA_KERNEL_THREADS")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .map(|n| n.max(1))
            .unwrap_or_else(|| {
                std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
            })
    })
}

fn effective_threads(requested: usize, rows: usize, macs: usize) -> usize {
    if requested <= 1 || macs < PAR_MIN_MACS {
        return 1;
    }
    requested.min(rows).max(1)
}

/// Split `out` into contiguous row chunks and run `body(row0, chunk)`
/// on each, across `threads` workers. `body` must compute a row from
/// `(row index, inputs)` alone, so the chunking is invisible in the
/// output.
fn for_row_chunks<F>(
    threads: usize,
    out: &mut [f32],
    rows: usize,
    row_len: usize,
    body: &F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_len);
    if threads <= 1 || rows <= 1 {
        body(0, out);
        return;
    }
    let per = rows.div_ceil(threads);
    #[cfg(feature = "rayon")]
    rayon::scope(|s| {
        for (ci, chunk) in out.chunks_mut(per * row_len).enumerate() {
            s.spawn(move |_| body(ci * per, chunk));
        }
    });
    #[cfg(not(feature = "rayon"))]
    std::thread::scope(|s| {
        for (ci, chunk) in out.chunks_mut(per * row_len).enumerate() {
            s.spawn(move || body(ci * per, chunk));
        }
    });
}

// ------------------------------------------------------------- kernels

/// `out[n,m] += A[n,k] @ B[k,m]` with the configured thread count.
pub fn mm_into(out: &mut [f32], a: &[f32], b: &[f32], n: usize, k: usize, m: usize) {
    mm_into_threads(kernel_threads(), out, a, b, n, k, m);
}

/// Allocating convenience wrapper over [`mm_into`].
pub fn mm(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_into(&mut out, a, b, n, k, m);
    out
}

/// [`mm_into`] with an explicit worker count (`1` = serial); the
/// determinism tests and the kernel microbench drive this directly.
pub fn mm_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        let rows = chunk.len() / m;
        mm_chunk(chunk, &a[row0 * k..(row0 + rows) * k], b, k, m);
    });
}

/// `out[n,m] += A[k,n]ᵀ @ B[k,m]` (contraction over rows of both).
pub fn mm_tn_into(out: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize, m: usize) {
    mm_tn_into_threads(kernel_threads(), out, a, b, k, n, m);
}

/// Allocating convenience wrapper over [`mm_tn_into`].
pub fn mm_tn(a: &[f32], b: &[f32], k: usize, n: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_tn_into(&mut out, a, b, k, n, m);
    out
}

/// [`mm_tn_into`] with an explicit worker count.
pub fn mm_tn_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    m: usize,
) {
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(b.len(), k * m);
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        mm_tn_chunk(chunk, row0, a, b, n, k, m);
    });
}

/// `out[n,m] += A[n,k] @ B[m,k]ᵀ` (contraction over columns of both).
pub fn mm_nt_into(out: &mut [f32], a: &[f32], b: &[f32], n: usize, k: usize, m: usize) {
    mm_nt_into_threads(kernel_threads(), out, a, b, n, k, m);
}

/// Allocating convenience wrapper over [`mm_nt_into`].
pub fn mm_nt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_nt_into(&mut out, a, b, n, k, m);
    out
}

/// [`mm_nt_into`] with an explicit worker count. `B` is transposed
/// once up front (O(km), amortized against O(nkm) compute) so the
/// inner loops stream both operands contiguously and vectorize.
pub fn mm_nt_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    mm_nt_impl(threads, out, a, b, n, k, m, None);
}

/// [`mm_nt_into`] drawing the transpose scratch from `pool` (and
/// returning it) instead of allocating per call — the interpreter's
/// hot backward path calls this once per linear per step.
pub fn mm_nt_into_pooled(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    pool: &Pool,
) {
    mm_nt_impl(kernel_threads(), out, a, b, n, k, m, Some(pool));
}

#[allow(clippy::too_many_arguments)]
fn mm_nt_impl(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    pool: Option<&Pool>,
) {
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), m * k);
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let mut bt = match pool {
        Some(p) => p.zeroed(b.len()),
        None => vec![0.0f32; b.len()],
    };
    transpose_into(&mut bt, b, m, k);
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        let rows = chunk.len() / m;
        mm_chunk(chunk, &a[row0 * k..(row0 + rows) * k], &bt, k, m);
    });
    if let Some(p) = pool {
        p.recycle(bt);
    }
}

/// `out[cols,rows] = xᵀ` for row-major `x[rows,cols]`.
fn transpose_into(out: &mut [f32], x: &[f32], rows: usize, cols: usize) {
    debug_assert_eq!(out.len(), rows * cols);
    for i in 0..rows {
        let xrow = &x[i * cols..(i + 1) * cols];
        for (j, &v) in xrow.iter().enumerate() {
            out[j * rows + i] = v;
        }
    }
}

/// Register-tiled `chunk[rows,m] += A[rows,k] @ B[k,m]` where `a` is
/// already offset to the chunk's first row. Per output element the
/// accumulation runs `k` ascending — identical to a naive axpy loop.
fn mm_chunk(out: &mut [f32], a: &[f32], b: &[f32], k: usize, m: usize) {
    let rows = out.len() / m;
    debug_assert_eq!(a.len(), rows * k);
    let mut i0 = 0usize;
    while i0 < rows {
        let il = RT.min(rows - i0);
        let mut j0 = 0usize;
        while j0 < m {
            let jl = JT.min(m - j0);
            let mut acc = [[0.0f32; JT]; RT];
            for kk in 0..k {
                let brow = &b[kk * m + j0..kk * m + j0 + jl];
                for r in 0..il {
                    let av = a[(i0 + r) * k + kk];
                    for (x, &bv) in acc[r].iter_mut().zip(brow) {
                        *x += av * bv;
                    }
                }
            }
            for r in 0..il {
                let off = (i0 + r) * m + j0;
                let orow = &mut out[off..off + jl];
                for (o, &x) in orow.iter_mut().zip(&acc[r][..jl]) {
                    *o += x;
                }
            }
            j0 += jl;
        }
        i0 += il;
    }
}

/// Tiled transposed-A chunk: `out` rows are columns `row0..` of
/// `a[k,n]`. Accumulation per element runs `k` ascending, matching the
/// historical `mm_tn` loop nest.
fn mm_tn_chunk(
    out: &mut [f32],
    row0: usize,
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    let rows = out.len() / m;
    let mut i0 = 0usize;
    while i0 < rows {
        let il = RT.min(rows - i0);
        let mut j0 = 0usize;
        while j0 < m {
            let jl = JT.min(m - j0);
            let mut acc = [[0.0f32; JT]; RT];
            for kk in 0..k {
                let brow = &b[kk * m + j0..kk * m + j0 + jl];
                let arow = &a[kk * n..(kk + 1) * n];
                for r in 0..il {
                    let av = arow[row0 + i0 + r];
                    for (x, &bv) in acc[r].iter_mut().zip(brow) {
                        *x += av * bv;
                    }
                }
            }
            for r in 0..il {
                let off = (i0 + r) * m + j0;
                let orow = &mut out[off..off + jl];
                for (o, &x) in orow.iter_mut().zip(&acc[r][..jl]) {
                    *o += x;
                }
            }
            j0 += jl;
        }
        i0 += il;
    }
}

// ---------------------------------------------------------------- pool

/// Retain at most this many free buffers; beyond it, returned buffers
/// are simply dropped (bounds memory held by an idle plan). One
/// `grads_*` execute recycles ~100 backward temporaries *before* the
/// forward cache (~60 buffers, including the only attention-probs-
/// sized allocations) comes back at the end of the dispatch — the cap
/// must exceed their sum or the largest buffers are the ones dropped
/// every step.
const POOL_MAX_BUFS: usize = 256;

/// Scratch-buffer pool: recycles large `f32` temporaries across
/// interpreter `execute()` calls. `RefBackend` device buffers own one
/// pool per plan, so step N+1's forward pass reuses step N's
/// activation and gradient allocations.
///
/// Interior mutability (`RefCell`) lets the interpreter draw scratch
/// while its inputs are immutably borrowed from the same buffer set;
/// the pool is intentionally `!Sync` — worker threads only ever see
/// `&[f32]` / `&mut [f32]` slices of buffers the caller drew.
#[derive(Default)]
pub struct Pool {
    free: RefCell<Vec<Vec<f32>>>,
}

impl Pool {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zeroed buffer of exactly `len` elements, reusing the
    /// best-fitting retained allocation when one is large enough.
    pub fn zeroed(&self, len: usize) -> Vec<f32> {
        let mut v = self.cleared(len);
        v.resize(len, 0.0);
        v
    }

    /// An **empty** buffer (len 0) with capacity ≥ `capacity`, reusing
    /// a retained allocation without paying [`Pool::zeroed`]'s fill —
    /// for targets that are fully overwritten via
    /// `extend_from_slice`/`push`.
    pub fn cleared(&self, capacity: usize) -> Vec<f32> {
        let mut free = self.free.borrow_mut();
        let mut best: Option<(usize, usize)> = None;
        for (i, b) in free.iter().enumerate() {
            let c = b.capacity();
            let better = match best {
                Some((_, bc)) => c < bc,
                None => true,
            };
            if c >= capacity && better {
                best = Some((i, c));
            }
        }
        match best {
            Some((i, _)) => {
                let mut v = free.swap_remove(i);
                v.clear();
                v
            }
            None => Vec::with_capacity(capacity),
        }
    }

    /// Return a buffer for later reuse (no-op for empty allocations or
    /// once [`POOL_MAX_BUFS`] buffers are already retained).
    pub fn recycle(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut free = self.free.borrow_mut();
        if free.len() < POOL_MAX_BUFS {
            free.push(v);
        }
    }

    /// Number of currently retained free buffers (test hook).
    pub fn retained(&self) -> usize {
        self.free.borrow().len()
    }
}

// ---------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// The historical interpreter loops, kept verbatim (including the
    /// `av == 0.0` skip) as the numeric reference. The blocked kernels
    /// drop that skip — for finite operands the only possible
    /// divergence is the sign of an exactly-zero result (`±0`), which
    /// `to_bits` equality on zero-free random data cannot hit; with
    /// non-finite operands (`0 × ∞`) results can genuinely differ,
    /// and that corner is documented, not pinned.
    fn naive_mm(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * m..(i + 1) * m];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * m..(kk + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    fn naive_mm_tn(a: &[f32], b: &[f32], k: usize, n: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for r in 0..k {
            let arow = &a[r * n..(r + 1) * n];
            let brow = &b[r * m..(r + 1) * m];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[i * m..(i + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    fn naive_mm_nt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * m..(i + 1) * m];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                *o += acc;
            }
        }
        out
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.normal_vec(n, 1.0)
    }

    fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: element {i} differs ({x} vs {y})"
            );
        }
    }

    #[test]
    fn blocked_kernels_match_naive_loops_bitwise() {
        // ragged shapes exercise every RT/JT tail path
        for &(n, k, m) in
            &[(1, 1, 1), (5, 7, 9), (33, 17, 40), (64, 32, 64)]
        {
            let a = randv(n * k, 1);
            let b = randv(k * m, 2);
            let bt = randv(m * k, 3);
            let at = randv(k * n, 4);

            let mut got = vec![0.0f32; n * m];
            mm_into_threads(1, &mut got, &a, &b, n, k, m);
            assert_bitwise_eq(&got, &naive_mm(&a, &b, n, k, m), "mm");

            let mut got = vec![0.0f32; n * m];
            mm_tn_into_threads(1, &mut got, &at, &b, k, n, m);
            assert_bitwise_eq(
                &got,
                &naive_mm_tn(&at, &b, k, n, m),
                "mm_tn",
            );

            let mut got = vec![0.0f32; n * m];
            mm_nt_into_threads(1, &mut got, &a, &bt, n, k, m);
            assert_bitwise_eq(
                &got,
                &naive_mm_nt(&a, &bt, n, k, m),
                "mm_nt",
            );
        }
    }

    #[test]
    fn serial_and_parallel_agree_bitwise() {
        // n*k*m must clear PAR_MIN_MACS so the threaded path engages;
        // ragged dims keep the tile tails honest under chunking.
        let (n, k, m) = (97, 64, 49);
        assert!(n * k * m >= PAR_MIN_MACS);
        let a = randv(n * k, 10);
        let b = randv(k * m, 11);
        let at = randv(k * n, 12);
        let bt = randv(m * k, 13);
        for threads in [2, 3, 8] {
            let mut serial = vec![0.0f32; n * m];
            mm_into_threads(1, &mut serial, &a, &b, n, k, m);
            let mut par = vec![0.0f32; n * m];
            mm_into_threads(threads, &mut par, &a, &b, n, k, m);
            assert_bitwise_eq(&serial, &par, "mm par");

            let mut serial = vec![0.0f32; n * m];
            mm_tn_into_threads(1, &mut serial, &at, &b, k, n, m);
            let mut par = vec![0.0f32; n * m];
            mm_tn_into_threads(threads, &mut par, &at, &b, k, n, m);
            assert_bitwise_eq(&serial, &par, "mm_tn par");

            let mut serial = vec![0.0f32; n * m];
            mm_nt_into_threads(1, &mut serial, &a, &bt, n, k, m);
            let mut par = vec![0.0f32; n * m];
            mm_nt_into_threads(threads, &mut par, &a, &bt, n, k, m);
            assert_bitwise_eq(&serial, &par, "mm_nt par");
        }
    }

    #[test]
    fn mm_matches_tensor_matmul() {
        use crate::tensor::Tensor;
        let (n, k, m) = (6, 5, 4);
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[n, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, m], 1.0, &mut rng);
        let want = a.matmul(&b);
        let got = mm(&a.data, &b.data, n, k, m);
        for (x, y) in got.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn into_variants_accumulate() {
        // `+=` semantics: pre-seeded output keeps its contribution
        let (n, k, m) = (3, 2, 3);
        let a = randv(n * k, 20);
        let b = randv(k * m, 21);
        let base = randv(n * m, 22);
        let mut out = base.clone();
        mm_into_threads(1, &mut out, &a, &b, n, k, m);
        let plain = naive_mm(&a, &b, n, k, m);
        for i in 0..n * m {
            assert_eq!(
                out[i].to_bits(),
                (base[i] + plain[i]).to_bits()
            );
        }
    }

    #[test]
    fn pool_recycles_and_zeroes() {
        let pool = Pool::new();
        let mut v = pool.zeroed(64);
        v.iter_mut().for_each(|x| *x = 7.0);
        pool.recycle(v);
        assert_eq!(pool.retained(), 1);
        let v2 = pool.zeroed(32);
        assert!(v2.iter().all(|&x| x == 0.0), "reused buffer not zeroed");
        assert!(v2.capacity() >= 64, "did not reuse the retained buffer");
        assert_eq!(pool.retained(), 0);
        // too-small buffers are left retained, fresh alloc happens
        pool.recycle(v2);
        let big = pool.zeroed(1024);
        assert_eq!(big.len(), 1024);
        assert_eq!(pool.retained(), 1);
    }

    #[test]
    fn kernel_threads_is_at_least_one() {
        assert!(kernel_threads() >= 1);
    }
}
