//! Shared compute kernels for the reference backend: cache-blocked,
//! row-parallel matrix multiplies, a fused head-parallel attention
//! family, parallel elementwise/reduction helpers, and a
//! scratch-buffer pool.
//!
//! ## Determinism contract
//!
//! Every kernel fixes its work partitioning by **unit** (an output
//! row, a `(batch, head)` attention unit, a constant-size reduction
//! tile) — never by scheduler decision. Parallelism only distributes
//! those units across threads; the per-element accumulation order is
//! identical at every thread count, so parallel output is bitwise
//! identical to serial output (pinned by the `*_agree_bitwise` tests
//! below and `tests/kernel_parity.rs`). Cross-unit reductions
//! (`rmsnorm_bwd`'s `dw`, the cross-entropy loss scalar) accumulate
//! into per-tile partials of constant [`REDUCE_ROWS`] height and are
//! folded serially in ascending tile order — again independent of the
//! thread count.
//!
//! One deliberate divergence from the historical interpreter loops:
//! they skipped `a == 0.0` terms in the GEMMs, these kernels always
//! multiply. For finite operands that can only flip the sign of an
//! exactly-zero result (`±0`, invisible to `==` and to tolerance
//! checks); a zero weight against a non-finite activation now
//! propagates NaN where the skip hid it — which is the honest IEEE
//! answer.
//!
//! ## Threading
//!
//! All kernels share a single thread budget: [`kernel_threads`]
//! (override with `LOSIA_KERNEL_THREADS`, or at runtime through
//! [`set_kernel_threads`] — the bench/test hook). Small problems
//! (< [`PAR_MIN_MACS`] multiply-accumulates for compute kernels,
//! < [`PAR_MIN_ELEMS`] elements for memory-bound maps) always run
//! serial so the tiny-config test suite is not taxed with spawn
//! overhead. Workers are scoped `std::thread` spawns by default; with
//! the optional `rayon` cargo feature the same chunks are dispatched
//! onto the rayon global pool instead (identical results — chunking,
//! not scheduling, determines the numerics).
//!
//! **Nested-oversubscription guard:** every worker thread is marked
//! (thread-local flag) for its job's duration, and
//! [`effective_threads`]/[`effective_map_threads`] return 1 on a
//! marked thread. A kernel invoked from inside another kernel's
//! worker therefore runs serial instead of multiplying the thread
//! count — the budget is spent once, at the outermost fan-out.
//!
//! ## Scratch ownership
//!
//! [`Pool`] recycles the interpreter's large `f32` temporaries across
//! `execute()` calls. The pool is intentionally `!Sync` and is only
//! ever touched by the orchestrating thread: kernels that need
//! per-worker scratch (the attention family's score/dprob rows) draw
//! **one** buffer of `threads × row` length before fanning out and
//! hand each worker a disjoint `&mut` slice of it. Worker bodies see
//! plain slices, never the pool.

// index-heavy kernels: explicit loops ARE the clearest form here
#![allow(clippy::needless_range_loop)]
// boxed-job vectors for the fan-out plumbing read clearer inline
#![allow(clippy::type_complexity)]

use std::cell::{Cell, RefCell};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::runtime::quant::QBLOCK;

/// Minimum multiply-accumulate count before a compute kernel fans out
/// to threads; below this, spawn overhead dominates the work.
pub const PAR_MIN_MACS: usize = 1 << 18;

/// Minimum element count before a memory-bound map/copy kernel fans
/// out to threads.
pub const PAR_MIN_ELEMS: usize = 1 << 16;

/// Fixed reduction-tile height (rows per partial) for cross-row
/// reductions. Constant — NOT derived from the thread count — so the
/// partial-sum association (and therefore every bit of the result) is
/// identical no matter how many workers run.
const REDUCE_ROWS: usize = 32;

/// Row-tile height: output rows computed together so one loaded `b`
/// row feeds several accumulator rows.
const RT: usize = 4;

/// Column-tile width: per-tile accumulators live in registers/L1
/// across the whole `k` loop instead of re-reading the output row.
const JT: usize = 16;

// ------------------------------------------------------- thread budget

/// Runtime override installed by [`set_kernel_threads`]; 0 = unset.
static THREAD_OVERRIDE: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True while this thread is executing a kernel worker job — the
    /// nested-oversubscription guard reads it.
    static IN_KERNEL_WORKER: Cell<bool> = const { Cell::new(false) };

    /// Per-thread cap on the kernel-thread budget (0 = uncapped).
    /// Installed by [`with_thread_budget`] so the dp engine can split
    /// one process-wide budget across its worker threads.
    static THREAD_BUDGET: Cell<usize> = const { Cell::new(0) };
}

fn in_worker() -> bool {
    IN_KERNEL_WORKER.with(|f| f.get())
}

/// RAII marker: the current thread is a kernel worker until drop.
struct WorkerGuard;

impl WorkerGuard {
    fn enter() -> Self {
        IN_KERNEL_WORKER.with(|f| f.set(true));
        WorkerGuard
    }
}

impl Drop for WorkerGuard {
    fn drop(&mut self) {
        IN_KERNEL_WORKER.with(|f| f.set(false));
    }
}

/// Worker-thread count for the parallel kernels: the
/// [`set_kernel_threads`] override when installed, else the
/// `LOSIA_KERNEL_THREADS` env var when set (minimum 1), else
/// `available_parallelism`. The env-derived value is cached for the
/// process lifetime; the override can change at any time.
pub fn kernel_threads() -> usize {
    let base = {
        let o = THREAD_OVERRIDE.load(Ordering::Relaxed);
        if o > 0 {
            o
        } else {
            static N: OnceLock<usize> = OnceLock::new();
            *N.get_or_init(|| {
                std::env::var("LOSIA_KERNEL_THREADS")
                    .ok()
                    .and_then(|s| s.parse::<usize>().ok())
                    .map(|n| n.max(1))
                    .unwrap_or_else(|| {
                        std::thread::available_parallelism()
                            .map(|n| n.get())
                            .unwrap_or(1)
                    })
            })
        }
    };
    let cap = THREAD_BUDGET.with(|b| b.get());
    if cap > 0 {
        base.min(cap)
    } else {
        base
    }
}

/// Run `f` with this thread's kernel budget capped at `n` (minimum 1),
/// restoring the previous cap afterwards. The dp engine wraps each
/// worker in this so `W` workers share one process-wide budget
/// (`kernel_threads() / W` each) instead of oversubscribing `W × B`
/// threads. Thread count never affects kernel numerics (the
/// determinism contract above), so capping is invisible in results.
pub fn with_thread_budget<R>(n: usize, f: impl FnOnce() -> R) -> R {
    let prev = THREAD_BUDGET.with(|b| b.replace(n.max(1)));
    struct Restore(usize);
    impl Drop for Restore {
        fn drop(&mut self) {
            THREAD_BUDGET.with(|b| b.set(self.0));
        }
    }
    let _restore = Restore(prev);
    f()
}

/// Install (or with `0`, clear) a process-wide thread-count override —
/// the hook the kernel microbench and the serial-vs-parallel parity
/// tests use to drive one interpreter at several thread counts.
/// Results are bitwise identical at every setting, so flipping it
/// mid-run can change performance but never numerics.
pub fn set_kernel_threads(n: usize) {
    THREAD_OVERRIDE.store(n, Ordering::Relaxed);
}

/// Thread count a compute kernel should actually use: 1 inside
/// another kernel's worker (the nested guard), 1 under the
/// [`PAR_MIN_MACS`] floor, else `requested` capped by `units`.
fn effective_threads(requested: usize, units: usize, macs: usize) -> usize {
    if in_worker() || requested <= 1 || macs < PAR_MIN_MACS {
        return 1;
    }
    requested.min(units).max(1)
}

/// [`effective_threads`] with the memory-bound [`PAR_MIN_ELEMS`]
/// floor, for maps/copies.
fn effective_map_threads(
    requested: usize,
    units: usize,
    elems: usize,
) -> usize {
    if in_worker() || requested <= 1 || elems < PAR_MIN_ELEMS {
        return 1;
    }
    requested.min(units).max(1)
}

// ------------------------------------------------------------- fan-out

/// Run `jobs` across at most `threads` workers: job `i` goes to
/// worker `i % threads` (a static assignment — but since every job
/// computes its outputs from its unit index alone, the assignment is
/// invisible in the results). With one worker (or one job) everything
/// runs inline on the calling thread. Worker threads are marked so
/// nested kernel calls inside a job run serial.
fn fanout_strided<'a>(
    threads: usize,
    jobs: Vec<Box<dyn FnOnce() + Send + 'a>>,
) {
    if threads <= 1 || jobs.len() <= 1 {
        for j in jobs {
            j();
        }
        return;
    }
    let t = threads.min(jobs.len());
    let mut buckets: Vec<Vec<Box<dyn FnOnce() + Send + 'a>>> =
        (0..t).map(|_| Vec::new()).collect();
    for (i, j) in jobs.into_iter().enumerate() {
        buckets[i % t].push(j);
    }
    #[cfg(feature = "rayon")]
    rayon::scope(|s| {
        for bucket in buckets {
            s.spawn(move |_| {
                let _g = WorkerGuard::enter();
                for j in bucket {
                    j();
                }
            });
        }
    });
    #[cfg(not(feature = "rayon"))]
    std::thread::scope(|s| {
        for bucket in buckets {
            s.spawn(move || {
                let _g = WorkerGuard::enter();
                for j in bucket {
                    j();
                }
            });
        }
    });
}

/// Split `out` into contiguous row chunks and run `body(row0, chunk)`
/// on each, across `threads` workers. `body` must compute a row from
/// `(row index, inputs)` alone, so the chunking is invisible in the
/// output.
fn for_row_chunks<F>(
    threads: usize,
    out: &mut [f32],
    rows: usize,
    row_len: usize,
    body: &F,
) where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), rows * row_len);
    if threads <= 1 || rows <= 1 {
        body(0, out);
        return;
    }
    let per = rows.div_ceil(threads);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out
        .chunks_mut(per * row_len)
        .enumerate()
        .map(|(ci, chunk)| {
            Box::new(move || body(ci * per, chunk))
                as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    fanout_strided(threads, jobs);
}

/// [`for_row_chunks`] for kernels with two output buffers sharing the
/// same row structure (`len_a`/`len_b` elements per row): both are
/// chunked at the same row boundaries and handed to `body(row0,
/// chunk_a, chunk_b)` together.
fn for_row_chunks2<F>(
    threads: usize,
    out_a: &mut [f32],
    len_a: usize,
    out_b: &mut [f32],
    len_b: usize,
    rows: usize,
    body: &F,
) where
    F: Fn(usize, &mut [f32], &mut [f32]) + Sync,
{
    debug_assert_eq!(out_a.len(), rows * len_a);
    debug_assert_eq!(out_b.len(), rows * len_b);
    if threads <= 1 || rows <= 1 {
        body(0, out_a, out_b);
        return;
    }
    let per = rows.div_ceil(threads);
    let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = out_a
        .chunks_mut(per * len_a)
        .zip(out_b.chunks_mut(per * len_b))
        .enumerate()
        .map(|(ci, (ca, cb))| {
            Box::new(move || body(ci * per, ca, cb))
                as Box<dyn FnOnce() + Send + '_>
        })
        .collect();
    fanout_strided(threads, jobs);
}

// ------------------------------------------------------------- kernels

/// `out[n,m] += A[n,k] @ B[k,m]` with the configured thread count.
pub fn mm_into(out: &mut [f32], a: &[f32], b: &[f32], n: usize, k: usize, m: usize) {
    mm_into_threads(kernel_threads(), out, a, b, n, k, m);
}

/// Allocating convenience wrapper over [`mm_into`].
pub fn mm(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_into(&mut out, a, b, n, k, m);
    out
}

/// [`mm_into`] with an explicit worker count (`1` = serial); the
/// determinism tests and the kernel microbench drive this directly.
pub fn mm_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), k * m);
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        let rows = chunk.len() / m;
        mm_chunk(chunk, &a[row0 * k..(row0 + rows) * k], b, k, m);
    });
}

/// `out[n,m] += A[k,n]ᵀ @ B[k,m]` (contraction over rows of both).
pub fn mm_tn_into(out: &mut [f32], a: &[f32], b: &[f32], k: usize, n: usize, m: usize) {
    mm_tn_into_threads(kernel_threads(), out, a, b, k, n, m);
}

/// Allocating convenience wrapper over [`mm_tn_into`].
pub fn mm_tn(a: &[f32], b: &[f32], k: usize, n: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_tn_into(&mut out, a, b, k, n, m);
    out
}

/// [`mm_tn_into`] with an explicit worker count.
pub fn mm_tn_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    m: usize,
) {
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(b.len(), k * m);
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        mm_tn_chunk(chunk, row0, a, b, n, k, m);
    });
}

/// `out[n,m] += A[n,k] @ B[m,k]ᵀ` (contraction over columns of both).
pub fn mm_nt_into(out: &mut [f32], a: &[f32], b: &[f32], n: usize, k: usize, m: usize) {
    mm_nt_into_threads(kernel_threads(), out, a, b, n, k, m);
}

/// Allocating convenience wrapper over [`mm_nt_into`].
pub fn mm_nt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_nt_into(&mut out, a, b, n, k, m);
    out
}

/// [`mm_nt_into`] with an explicit worker count. `B` is transposed
/// once up front (O(km), amortized against O(nkm) compute) so the
/// inner loops stream both operands contiguously and vectorize.
pub fn mm_nt_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    mm_nt_impl(threads, out, a, b, n, k, m, None);
}

/// [`mm_nt_into`] drawing the transpose scratch from `pool` (and
/// returning it) instead of allocating per call — the interpreter's
/// hot backward path calls this once per linear per step.
pub fn mm_nt_into_pooled(
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    pool: &Pool,
) {
    mm_nt_impl(kernel_threads(), out, a, b, n, k, m, Some(pool));
}

#[allow(clippy::too_many_arguments)]
fn mm_nt_impl(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
    pool: Option<&Pool>,
) {
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(b.len(), m * k);
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let mut bt = match pool {
        Some(p) => p.zeroed(b.len()),
        None => vec![0.0f32; b.len()],
    };
    transpose_into(&mut bt, b, m, k);
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        let rows = chunk.len() / m;
        mm_chunk(chunk, &a[row0 * k..(row0 + rows) * k], &bt, k, m);
    });
    if let Some(p) = pool {
        p.recycle(bt);
    }
}

/// `out[cols,rows] = xᵀ` for row-major `x[rows,cols]`.
fn transpose_into(out: &mut [f32], x: &[f32], rows: usize, cols: usize) {
    debug_assert_eq!(out.len(), rows * cols);
    for i in 0..rows {
        let xrow = &x[i * cols..(i + 1) * cols];
        for (j, &v) in xrow.iter().enumerate() {
            out[j * rows + i] = v;
        }
    }
}

/// Register-tiled `chunk[rows,m] += A[rows,k] @ B[k,m]` where `a` is
/// already offset to the chunk's first row. Per output element the
/// accumulation runs `k` ascending — identical to a naive axpy loop.
fn mm_chunk(out: &mut [f32], a: &[f32], b: &[f32], k: usize, m: usize) {
    let rows = out.len() / m;
    debug_assert_eq!(a.len(), rows * k);
    let mut i0 = 0usize;
    while i0 < rows {
        let il = RT.min(rows - i0);
        let mut j0 = 0usize;
        while j0 < m {
            let jl = JT.min(m - j0);
            let mut acc = [[0.0f32; JT]; RT];
            for kk in 0..k {
                let brow = &b[kk * m + j0..kk * m + j0 + jl];
                for r in 0..il {
                    let av = a[(i0 + r) * k + kk];
                    for (x, &bv) in acc[r].iter_mut().zip(brow) {
                        *x += av * bv;
                    }
                }
            }
            for r in 0..il {
                let off = (i0 + r) * m + j0;
                let orow = &mut out[off..off + jl];
                for (o, &x) in orow.iter_mut().zip(&acc[r][..jl]) {
                    *o += x;
                }
            }
            j0 += jl;
        }
        i0 += il;
    }
}

/// Tiled transposed-A chunk: `out` rows are columns `row0..` of
/// `a[k,n]`. Accumulation per element runs `k` ascending, matching the
/// historical `mm_tn` loop nest.
fn mm_tn_chunk(
    out: &mut [f32],
    row0: usize,
    a: &[f32],
    b: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    let rows = out.len() / m;
    let mut i0 = 0usize;
    while i0 < rows {
        let il = RT.min(rows - i0);
        let mut j0 = 0usize;
        while j0 < m {
            let jl = JT.min(m - j0);
            let mut acc = [[0.0f32; JT]; RT];
            for kk in 0..k {
                let brow = &b[kk * m + j0..kk * m + j0 + jl];
                let arow = &a[kk * n..(kk + 1) * n];
                for r in 0..il {
                    let av = arow[row0 + i0 + r];
                    for (x, &bv) in acc[r].iter_mut().zip(brow) {
                        *x += av * bv;
                    }
                }
            }
            for r in 0..il {
                let off = (i0 + r) * m + j0;
                let orow = &mut out[off..off + jl];
                for (o, &x) in orow.iter_mut().zip(&acc[r][..jl]) {
                    *o += x;
                }
            }
            j0 += jl;
        }
        i0 += il;
    }
}

// ------------------------------------------- dequant-fused q8 GEMMs
//
// The same blocked loops with the weight operand stored as int8 codes
// + per-block f32 scales (`runtime::quant` layout: blocks tile the
// last axis, scale of element `(kk, j)` at `scales[kk*bpr +
// j/QBLOCK]`). Each register tile dequantizes its ≤ JT-wide B row
// into a stack buffer and then accumulates in f32 exactly like the
// f32 kernels — same chunking, same k-ascending per-element order —
// so two properties hold for free:
//
// 1. serial and parallel results are bitwise identical (the
//    determinism contract above, extended to q8 by
//    `tests/kernel_parity.rs` at LOSIA_KERNEL_THREADS=1/4), and
// 2. `mm_q8(a, q)` is bitwise identical to `mm(a, q.dequantize())`
//    (pinned by `q8_gemms_match_dequantized_f32_bitwise` below) —
//    quantization error lives entirely in the stored codes, never in
//    the contraction.

/// `out[n,m] += A[n,k] @ dequant(Bq)[k,m]` where `Bq` is `[k, m]`
/// int8 codes + per-block scales.
pub fn mm_q8_into(
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    mm_q8_into_threads(kernel_threads(), out, a, bcodes, bscales, n, k, m);
}

/// Allocating convenience wrapper over [`mm_q8_into`].
pub fn mm_q8(
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_q8_into(&mut out, a, bcodes, bscales, n, k, m);
    out
}

/// [`mm_q8_into`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn mm_q8_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(bcodes.len(), k * m);
    debug_assert_eq!(bscales.len(), k * m.div_ceil(QBLOCK));
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        let rows = chunk.len() / m;
        mm_chunk_q8(
            chunk,
            &a[row0 * k..(row0 + rows) * k],
            bcodes,
            bscales,
            k,
            m,
        );
    });
}

/// `out[n,m] += A[k,n]ᵀ @ dequant(Bq)[k,m]`.
pub fn mm_tn_q8_into(
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    k: usize,
    n: usize,
    m: usize,
) {
    mm_tn_q8_into_threads(kernel_threads(), out, a, bcodes, bscales, k, n, m);
}

/// Allocating convenience wrapper over [`mm_tn_q8_into`].
pub fn mm_tn_q8(
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    k: usize,
    n: usize,
    m: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_tn_q8_into(&mut out, a, bcodes, bscales, k, n, m);
    out
}

/// [`mm_tn_q8_into`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn mm_tn_q8_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    k: usize,
    n: usize,
    m: usize,
) {
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), k * n);
    debug_assert_eq!(bcodes.len(), k * m);
    debug_assert_eq!(bscales.len(), k * m.div_ceil(QBLOCK));
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        mm_tn_chunk_q8(chunk, row0, a, bcodes, bscales, n, k, m);
    });
}

/// `out[n,m] += A[n,k] @ dequant(Bq)[m,k]ᵀ` where `Bq` is `[m, k]`
/// (blocks along `k`). Like [`mm_nt_into_threads`], `B` is
/// dequant-transposed once up front (O(km), amortized against O(nkm)
/// compute); the contraction then reuses the f32 [`mm_chunk`], so the
/// determinism and dequant-equivalence properties carry over.
pub fn mm_nt_q8_into(
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    mm_nt_q8_impl(kernel_threads(), out, a, bcodes, bscales, n, k, m, None);
}

/// Allocating convenience wrapper over [`mm_nt_q8_into`].
pub fn mm_nt_q8(
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
) -> Vec<f32> {
    let mut out = vec![0.0f32; n * m];
    mm_nt_q8_into(&mut out, a, bcodes, bscales, n, k, m);
    out
}

/// [`mm_nt_q8_into`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn mm_nt_q8_into_threads(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    mm_nt_q8_impl(threads, out, a, bcodes, bscales, n, k, m, None);
}

/// [`mm_nt_q8_into`] drawing the dequant-transpose scratch from
/// `pool` — the interpreter's backward path (`dx = dy · Wᵀ` against a
/// quantized frozen W) calls this once per linear per step.
#[allow(clippy::too_many_arguments)]
pub fn mm_nt_q8_into_pooled(
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
    pool: &Pool,
) {
    mm_nt_q8_impl(
        kernel_threads(),
        out,
        a,
        bcodes,
        bscales,
        n,
        k,
        m,
        Some(pool),
    );
}

#[allow(clippy::too_many_arguments)]
fn mm_nt_q8_impl(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
    pool: Option<&Pool>,
) {
    let bpr = k.div_ceil(QBLOCK);
    debug_assert_eq!(out.len(), n * m);
    debug_assert_eq!(a.len(), n * k);
    debug_assert_eq!(bcodes.len(), m * k);
    debug_assert_eq!(bscales.len(), m * bpr);
    if n == 0 || m == 0 {
        return; // empty output; avoid the rows = len/m division
    }
    let mut bt = match pool {
        Some(p) => p.zeroed(bcodes.len()),
        None => vec![0.0f32; bcodes.len()],
    };
    // fused dequant-transpose: bt[j, i] = codes[i, j] · scale(i, j)
    for i in 0..m {
        let crow = &bcodes[i * k..(i + 1) * k];
        let srow = &bscales[i * bpr..];
        for (j, &c) in crow.iter().enumerate() {
            bt[j * m + i] =
                c as f32 * srow[j / QBLOCK];
        }
    }
    let t = effective_threads(threads, n, n * k * m);
    for_row_chunks(t, out, n, m, &|row0, chunk| {
        let rows = chunk.len() / m;
        mm_chunk(chunk, &a[row0 * k..(row0 + rows) * k], &bt, k, m);
    });
    if let Some(p) = pool {
        p.recycle(bt);
    }
}

/// [`mm_chunk`] with a quantized `B`: each `kk` iteration dequantizes
/// its ≤ JT-wide `B` row tile into a stack buffer, then accumulates
/// exactly as the f32 kernel does. One scale lookup per element; a
/// tile spans at most two QBLOCK blocks (JT ≤ QBLOCK).
fn mm_chunk_q8(
    out: &mut [f32],
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    k: usize,
    m: usize,
) {
    let bpr = m.div_ceil(QBLOCK);
    let rows = out.len() / m;
    debug_assert_eq!(a.len(), rows * k);
    let mut i0 = 0usize;
    while i0 < rows {
        let il = RT.min(rows - i0);
        let mut j0 = 0usize;
        while j0 < m {
            let jl = JT.min(m - j0);
            let mut acc = [[0.0f32; JT]; RT];
            for kk in 0..k {
                let brow = &bcodes[kk * m + j0..kk * m + j0 + jl];
                let srow = &bscales[kk * bpr..];
                let mut bdq = [0.0f32; JT];
                for (j, (x, &c)) in
                    bdq.iter_mut().zip(brow).enumerate()
                {
                    *x = c as f32
                        * srow[(j0 + j) / QBLOCK];
                }
                for r in 0..il {
                    let av = a[(i0 + r) * k + kk];
                    for (x, &bv) in
                        acc[r].iter_mut().zip(&bdq[..jl])
                    {
                        *x += av * bv;
                    }
                }
            }
            for r in 0..il {
                let off = (i0 + r) * m + j0;
                let orow = &mut out[off..off + jl];
                for (o, &x) in orow.iter_mut().zip(&acc[r][..jl]) {
                    *o += x;
                }
            }
            j0 += jl;
        }
        i0 += il;
    }
}

/// [`mm_tn_chunk`] with a quantized `B` (same per-tile dequant as
/// [`mm_chunk_q8`], transposed-A access).
#[allow(clippy::too_many_arguments)]
fn mm_tn_chunk_q8(
    out: &mut [f32],
    row0: usize,
    a: &[f32],
    bcodes: &[i8],
    bscales: &[f32],
    n: usize,
    k: usize,
    m: usize,
) {
    let bpr = m.div_ceil(QBLOCK);
    let rows = out.len() / m;
    let mut i0 = 0usize;
    while i0 < rows {
        let il = RT.min(rows - i0);
        let mut j0 = 0usize;
        while j0 < m {
            let jl = JT.min(m - j0);
            let mut acc = [[0.0f32; JT]; RT];
            for kk in 0..k {
                let brow = &bcodes[kk * m + j0..kk * m + j0 + jl];
                let srow = &bscales[kk * bpr..];
                let arow = &a[kk * n..(kk + 1) * n];
                let mut bdq = [0.0f32; JT];
                for (j, (x, &c)) in
                    bdq.iter_mut().zip(brow).enumerate()
                {
                    *x = c as f32
                        * srow[(j0 + j) / QBLOCK];
                }
                for r in 0..il {
                    let av = arow[row0 + i0 + r];
                    for (x, &bv) in
                        acc[r].iter_mut().zip(&bdq[..jl])
                    {
                        *x += av * bv;
                    }
                }
            }
            for r in 0..il {
                let off = (i0 + r) * m + j0;
                let orow = &mut out[off..off + jl];
                for (o, &x) in orow.iter_mut().zip(&acc[r][..jl]) {
                    *o += x;
                }
            }
            j0 += jl;
        }
        i0 += il;
    }
}

/// [`gather_rows`] against a quantized table (`[limit, d]` codes +
/// scales): each selected row dequantizes straight into its output
/// slot. Pure per-row copies — deterministic under any partition.
pub fn gather_rows_q8(
    out: &mut [f32],
    codes: &[i8],
    scales: &[f32],
    ids: &[i32],
    d: usize,
    limit: usize,
) {
    let bpr = d.div_ceil(QBLOCK);
    let rows = ids.len();
    debug_assert_eq!(out.len(), rows * d);
    debug_assert!(limit * d <= codes.len());
    let t = effective_map_threads(kernel_threads(), rows, rows * d);
    for_row_chunks(t, out, rows, d, &|row0, chunk| {
        for (r, orow) in chunk.chunks_mut(d).enumerate() {
            let id = (ids[row0 + r].max(0) as usize).min(limit - 1);
            let crow = &codes[id * d..(id + 1) * d];
            let srow = &scales[id * bpr..];
            for (j, (o, &c)) in
                orow.iter_mut().zip(crow).enumerate()
            {
                *o = c as f32 * srow[j / QBLOCK];
            }
        }
    });
}

// ------------------------------------------------- elementwise kernels

/// `dst[i] += src[i]`, partitioned across threads (the residual adds
/// and gradient accumulations of the interpreter).
pub fn add_into(dst: &mut [f32], src: &[f32]) {
    add_into_threads(kernel_threads(), dst, src);
}

/// [`add_into`] with an explicit worker count.
pub fn add_into_threads(threads: usize, dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    let n = dst.len();
    let t = effective_map_threads(threads, n, n);
    for_row_chunks(t, dst, n, 1, &|row0, chunk| {
        for (d, &s) in chunk.iter_mut().zip(&src[row0..row0 + chunk.len()]) {
            *d += s;
        }
    });
}

/// `out[i] = f(a[i], b[i])`, output rows partitioned across threads.
/// `f` must be pure — it may run on any worker for any index.
pub fn map2_rows<F>(out: &mut [f32], a: &[f32], b: &[f32], f: &F)
where
    F: Fn(f32, f32) -> f32 + Sync,
{
    map2_rows_threads(kernel_threads(), out, a, b, f);
}

/// [`map2_rows`] with an explicit worker count.
pub fn map2_rows_threads<F>(
    threads: usize,
    out: &mut [f32],
    a: &[f32],
    b: &[f32],
    f: &F,
) where
    F: Fn(f32, f32) -> f32 + Sync,
{
    debug_assert_eq!(out.len(), a.len());
    debug_assert_eq!(out.len(), b.len());
    let n = out.len();
    let t = effective_map_threads(threads, n, n);
    for_row_chunks(t, out, n, 1, &|row0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let g = row0 + i;
            *o = f(a[g], b[g]);
        }
    });
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn dsilu(x: f32) -> f32 {
    let sg = 1.0 / (1.0 + (-x).exp());
    sg * (1.0 + x * (1.0 - sg))
}

/// SwiGLU forward fusion: `out[i] = silu(gate[i]) * up[i]`.
pub fn silu_mul(out: &mut [f32], gate: &[f32], up: &[f32]) {
    silu_mul_threads(kernel_threads(), out, gate, up);
}

/// [`silu_mul`] with an explicit worker count.
pub fn silu_mul_threads(
    threads: usize,
    out: &mut [f32],
    gate: &[f32],
    up: &[f32],
) {
    map2_rows_threads(threads, out, gate, up, &|g, u| silu(g) * u);
}

/// SwiGLU backward fusion: `dgate[i] = dmlp·up·silu'(gate)`,
/// `dup[i] = dmlp·silu(gate)` in one pass.
pub fn dsilu_mul(
    dgate: &mut [f32],
    dup: &mut [f32],
    dmlp: &[f32],
    gate: &[f32],
    up: &[f32],
) {
    dsilu_mul_threads(kernel_threads(), dgate, dup, dmlp, gate, up);
}

/// [`dsilu_mul`] with an explicit worker count.
pub fn dsilu_mul_threads(
    threads: usize,
    dgate: &mut [f32],
    dup: &mut [f32],
    dmlp: &[f32],
    gate: &[f32],
    up: &[f32],
) {
    debug_assert_eq!(dgate.len(), dmlp.len());
    debug_assert_eq!(dup.len(), dmlp.len());
    debug_assert_eq!(gate.len(), dmlp.len());
    debug_assert_eq!(up.len(), dmlp.len());
    let n = dmlp.len();
    let t = effective_map_threads(threads, n, n);
    for_row_chunks2(t, dgate, 1, dup, 1, n, &|row0, cg, cu| {
        for i in 0..cg.len() {
            let g = row0 + i;
            cg[i] = dmlp[g] * up[g] * dsilu(gate[g]);
            cu[i] = dmlp[g] * silu(gate[g]);
        }
    });
}

/// Row gather: `out[r] = table[clamp(ids[r])]` for `d`-wide rows —
/// the embedding lookup, parallel over output rows.
pub fn gather_rows(
    out: &mut [f32],
    table: &[f32],
    ids: &[i32],
    d: usize,
    limit: usize,
) {
    gather_rows_threads(kernel_threads(), out, table, ids, d, limit);
}

/// [`gather_rows`] with an explicit worker count.
pub fn gather_rows_threads(
    threads: usize,
    out: &mut [f32],
    table: &[f32],
    ids: &[i32],
    d: usize,
    limit: usize,
) {
    let rows = ids.len();
    debug_assert_eq!(out.len(), rows * d);
    debug_assert!(limit * d <= table.len());
    let t = effective_map_threads(threads, rows, rows * d);
    for_row_chunks(t, out, rows, d, &|row0, chunk| {
        for (r, orow) in chunk.chunks_mut(d).enumerate() {
            let id = (ids[row0 + r].max(0) as usize).min(limit - 1);
            orow.copy_from_slice(&table[id * d..(id + 1) * d]);
        }
    });
}

// --------------------------------------------------- norm / rope / loss

/// RMSNorm forward over `rows` rows of width `d`:
/// `y = x · inv(x) · w`, `inv[r] = 1/√(mean(x²) + eps)` cached for the
/// backward pass. Rows are partitioned across threads.
pub fn rmsnorm_fwd(
    y: &mut [f32],
    inv: &mut [f32],
    x: &[f32],
    w: &[f32],
    rows: usize,
    d: usize,
    eps: f32,
) {
    rmsnorm_fwd_threads(kernel_threads(), y, inv, x, w, rows, d, eps);
}

/// [`rmsnorm_fwd`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_fwd_threads(
    threads: usize,
    y: &mut [f32],
    inv: &mut [f32],
    x: &[f32],
    w: &[f32],
    rows: usize,
    d: usize,
    eps: f32,
) {
    debug_assert_eq!(y.len(), rows * d);
    debug_assert_eq!(inv.len(), rows);
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(w.len(), d);
    let t = effective_map_threads(threads, rows, rows * d * 2);
    for_row_chunks2(t, y, d, inv, 1, rows, &|row0, yc, ic| {
        for (r, yr) in yc.chunks_mut(d).enumerate() {
            let row = row0 + r;
            let xr = &x[row * d..(row + 1) * d];
            let mean: f32 =
                xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let iv = 1.0 / (mean + eps).sqrt();
            ic[r] = iv;
            for i in 0..d {
                yr[i] = xr[i] * iv * w[i];
            }
        }
    });
}

/// RMSNorm backward:
/// `dx_i = inv·w_i·dy_i − inv³/d · x_i · Σ_j dy_j·w_j·x_j`,
/// `dw_i += Σ_r dy·x·inv`. `dx` rows are computed tile-parallel; the
/// cross-row `dw` reduction goes through fixed [`REDUCE_ROWS`]-high
/// per-tile partials folded serially in tile order, so the result is
/// bitwise independent of the thread count. `dw` is accumulated into
/// (callers pass a zeroed buffer for plain assignment).
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_bwd(
    dx: &mut [f32],
    dw: &mut [f32],
    x: &[f32],
    w: &[f32],
    inv: &[f32],
    dy: &[f32],
    rows: usize,
    d: usize,
    pool: &Pool,
) {
    rmsnorm_bwd_threads(
        kernel_threads(),
        dx,
        dw,
        x,
        w,
        inv,
        dy,
        rows,
        d,
        pool,
    );
}

/// [`rmsnorm_bwd`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn rmsnorm_bwd_threads(
    threads: usize,
    dx: &mut [f32],
    dw: &mut [f32],
    x: &[f32],
    w: &[f32],
    inv: &[f32],
    dy: &[f32],
    rows: usize,
    d: usize,
    pool: &Pool,
) {
    debug_assert_eq!(dx.len(), rows * d);
    debug_assert_eq!(dw.len(), d);
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(w.len(), d);
    debug_assert_eq!(inv.len(), rows);
    debug_assert_eq!(dy.len(), rows * d);
    if rows == 0 {
        return;
    }
    let tiles = rows.div_ceil(REDUCE_ROWS);
    let mut partials = pool.zeroed(tiles * d);
    let t = effective_threads(threads, tiles, rows * d * 3);
    {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = dx
            .chunks_mut(REDUCE_ROWS * d)
            .zip(partials.chunks_mut(d))
            .enumerate()
            .map(|(ti, (dxt, pt))| {
                Box::new(move || {
                    let row0 = ti * REDUCE_ROWS;
                    for (r, dxr) in dxt.chunks_mut(d).enumerate() {
                        let row = row0 + r;
                        let xr = &x[row * d..(row + 1) * d];
                        let dyr = &dy[row * d..(row + 1) * d];
                        let iv = inv[row];
                        let mut s = 0.0f32;
                        for i in 0..d {
                            s += dyr[i] * w[i] * xr[i];
                        }
                        let c = iv * iv * iv / d as f32 * s;
                        for i in 0..d {
                            dxr[i] = iv * w[i] * dyr[i] - c * xr[i];
                            pt[i] += dyr[i] * xr[i] * iv;
                        }
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        fanout_strided(t, jobs);
    }
    // fold tile partials serially, ascending — thread-count invariant
    for ti in 0..tiles {
        let pt = &partials[ti * d..(ti + 1) * d];
        for i in 0..d {
            dw[i] += pt[i];
        }
    }
    pool.recycle(partials);
}

/// Apply RoPE in place over `[B, S, H, Dh]` (flat `[BS·D]`), rows
/// partitioned across threads. `inverse` applies the transposed
/// rotation (the backward pass). `cos`/`sin` are `[S, Dh/2]` tables.
pub fn rope_apply(
    x: &mut [f32],
    sh: AttnShape,
    cos: &[f32],
    sin: &[f32],
    inverse: bool,
) {
    rope_apply_threads(kernel_threads(), x, sh, cos, sin, inverse);
}

/// [`rope_apply`] with an explicit worker count.
pub fn rope_apply_threads(
    threads: usize,
    x: &mut [f32],
    sh: AttnShape,
    cos: &[f32],
    sin: &[f32],
    inverse: bool,
) {
    let d = sh.h * sh.dh;
    let rows = sh.b * sh.s;
    let half = sh.dh / 2;
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(cos.len(), sh.s * half);
    debug_assert_eq!(sin.len(), sh.s * half);
    let t = effective_map_threads(threads, rows, rows * d * 2);
    for_row_chunks(t, x, rows, d, &|row0, chunk| {
        for (r, xrow) in chunk.chunks_mut(d).enumerate() {
            let pos = (row0 + r) % sh.s;
            for hh in 0..sh.h {
                let base = hh * sh.dh;
                for e in 0..half {
                    let c = cos[pos * half + e];
                    let s = sin[pos * half + e];
                    let x1 = xrow[base + e];
                    let x2 = xrow[base + half + e];
                    let (n1, n2) = if inverse {
                        (x1 * c + x2 * s, -x1 * s + x2 * c)
                    } else {
                        (x1 * c - x2 * s, x1 * s + x2 * c)
                    };
                    xrow[base + e] = n1;
                    xrow[base + half + e] = n2;
                }
            }
        }
    });
}

fn log_softmax_at(row: &[f32], t: usize) -> f32 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let mut z = 0.0f32;
    for &v in row {
        z += (v - mx).exp();
    }
    row[t] - mx - z.ln()
}

/// Per-sequence summed NLL and mask count (the `fwd_loss` ABI):
/// `nll[b] = Σ_s −log_softmax(logits[b,s])[target]·mask`,
/// `cnt[b] = Σ_s mask`. Sequences are partitioned across threads;
/// within a sequence the per-position accumulation order is fixed.
#[allow(clippy::too_many_arguments)]
pub fn seq_nll(
    nll: &mut [f32],
    cnt: &mut [f32],
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    v: usize,
) {
    seq_nll_threads(
        kernel_threads(),
        nll,
        cnt,
        logits,
        targets,
        mask,
        b,
        s,
        v,
    );
}

/// [`seq_nll`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn seq_nll_threads(
    threads: usize,
    nll: &mut [f32],
    cnt: &mut [f32],
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    v: usize,
) {
    debug_assert_eq!(nll.len(), b);
    debug_assert_eq!(cnt.len(), b);
    debug_assert_eq!(logits.len(), b * s * v);
    debug_assert_eq!(targets.len(), b * s);
    debug_assert_eq!(mask.len(), b * s);
    let t = effective_threads(threads, b, b * s * v);
    for_row_chunks2(t, nll, 1, cnt, 1, b, &|b0, nc, cc| {
        for bi in 0..nc.len() {
            let bb = b0 + bi;
            for ss in 0..s {
                let r = bb * s + ss;
                let m = mask[r];
                cc[bi] += m;
                if m == 0.0 {
                    continue;
                }
                let row = &logits[r * v..(r + 1) * v];
                let tgt = (targets[r].max(0) as usize).min(v - 1);
                nc[bi] -= log_softmax_at(row, tgt) * m;
            }
        }
    });
}

/// Masked-mean cross-entropy loss and its logits cotangent:
/// fills `dl[rows,v]` (must be zeroed — masked rows stay zero) and
/// returns the scalar loss. Rows are processed in fixed
/// [`REDUCE_ROWS`]-high tiles whose partial losses fold serially in
/// tile order, so the scalar is bitwise thread-count invariant.
/// `c` is the mask-sum denominator (`total.max(1.0)`).
#[allow(clippy::too_many_arguments)]
pub fn ce_loss(
    dl: &mut [f32],
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    v: usize,
    c: f32,
    pool: &Pool,
) -> f32 {
    ce_loss_threads(
        kernel_threads(),
        dl,
        logits,
        targets,
        mask,
        rows,
        v,
        c,
        pool,
    )
}

/// [`ce_loss`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn ce_loss_threads(
    threads: usize,
    dl: &mut [f32],
    logits: &[f32],
    targets: &[i32],
    mask: &[f32],
    rows: usize,
    v: usize,
    c: f32,
    pool: &Pool,
) -> f32 {
    debug_assert_eq!(dl.len(), rows * v);
    debug_assert_eq!(logits.len(), rows * v);
    debug_assert_eq!(targets.len(), rows);
    debug_assert_eq!(mask.len(), rows);
    if rows == 0 {
        return 0.0;
    }
    let tiles = rows.div_ceil(REDUCE_ROWS);
    let mut partials = pool.zeroed(tiles);
    let t = effective_threads(threads, tiles, rows * v * 3);
    {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = dl
            .chunks_mut(REDUCE_ROWS * v)
            .zip(partials.chunks_mut(1))
            .enumerate()
            .map(|(ti, (dlt, pt))| {
                Box::new(move || {
                    let row0 = ti * REDUCE_ROWS;
                    for (r, drow) in dlt.chunks_mut(v).enumerate() {
                        let row = row0 + r;
                        let m = mask[row];
                        if m == 0.0 {
                            continue;
                        }
                        let lrow =
                            &logits[row * v..(row + 1) * v];
                        let tgt = (targets[row].max(0) as usize)
                            .min(v - 1);
                        let mx = lrow
                            .iter()
                            .cloned()
                            .fold(f32::NEG_INFINITY, f32::max);
                        let mut z = 0.0f32;
                        for &x in lrow {
                            z += (x - mx).exp();
                        }
                        pt[0] -= (lrow[tgt] - mx - z.ln()) * m / c;
                        for (j, &x) in lrow.iter().enumerate() {
                            drow[j] = (x - mx).exp() / z * m / c;
                        }
                        drow[tgt] -= m / c;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        fanout_strided(t, jobs);
    }
    let mut loss = 0.0f32;
    for ti in 0..tiles {
        loss += partials[ti];
    }
    pool.recycle(partials);
    loss
}

// ---------------------------------------------------- fused attention

/// Shape of one attention invocation. `d_model = h · dh`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnShape {
    /// batch
    pub b: usize,
    /// sequence length
    pub s: usize,
    /// heads
    pub h: usize,
    /// head dim
    pub dh: usize,
}

impl AttnShape {
    fn d(&self) -> usize {
        self.h * self.dh
    }

    fn units(&self) -> usize {
        self.b * self.h
    }
}

/// Repack `[B, S, H, Dh]` (head-interleaved, how the QKV projections
/// produce it) into `[B, H, S, Dh]` (unit-major, how the attention
/// units consume it). Every destination row is one contiguous read of
/// the source, so this is a parallel row copy.
pub fn pack_heads(dst: &mut [f32], src: &[f32], sh: AttnShape) {
    pack_heads_threads(kernel_threads(), dst, src, sh);
}

/// [`pack_heads`] with an explicit worker count.
pub fn pack_heads_threads(
    threads: usize,
    dst: &mut [f32],
    src: &[f32],
    sh: AttnShape,
) {
    let rows = sh.b * sh.h * sh.s;
    debug_assert_eq!(dst.len(), rows * sh.dh);
    debug_assert_eq!(src.len(), rows * sh.dh);
    let t = effective_map_threads(threads, rows, rows * sh.dh);
    for_row_chunks(t, dst, rows, sh.dh, &|row0, chunk| {
        for (r, drow) in chunk.chunks_mut(sh.dh).enumerate() {
            let idx = row0 + r; // (b, h, pos) row of dst
            let pos = idx % sh.s;
            let bh = idx / sh.s;
            let hh = bh % sh.h;
            let bb = bh / sh.h;
            let off = ((bb * sh.s + pos) * sh.h + hh) * sh.dh;
            drow.copy_from_slice(&src[off..off + sh.dh]);
        }
    });
}

/// Inverse of [`pack_heads`]: `[B, H, S, Dh]` → `[B, S, H, Dh]`.
pub fn unpack_heads(dst: &mut [f32], src: &[f32], sh: AttnShape) {
    unpack_heads_threads(kernel_threads(), dst, src, sh);
}

/// [`unpack_heads`] with an explicit worker count.
pub fn unpack_heads_threads(
    threads: usize,
    dst: &mut [f32],
    src: &[f32],
    sh: AttnShape,
) {
    let rows = sh.b * sh.s * sh.h;
    debug_assert_eq!(dst.len(), rows * sh.dh);
    debug_assert_eq!(src.len(), rows * sh.dh);
    let t = effective_map_threads(threads, rows, rows * sh.dh);
    for_row_chunks(t, dst, rows, sh.dh, &|row0, chunk| {
        for (r, drow) in chunk.chunks_mut(sh.dh).enumerate() {
            let idx = row0 + r; // (b, pos, h) row of dst
            let hh = idx % sh.h;
            let bp = idx / sh.h;
            let pos = bp % sh.s;
            let bb = bp / sh.s;
            let off = ((bb * sh.h + hh) * sh.s + pos) * sh.dh;
            drow.copy_from_slice(&src[off..off + sh.dh]);
        }
    });
}

/// One `(batch, head)` unit of causal attention, fused per query row:
/// scores over the causal prefix `0..=i` only (the masked tail of a
/// probability row is exactly `+0.0` — identical bits to the
/// historical full-row mask/exp, which underflowed the tail to zero),
/// max-subtracted softmax, then the probs·V contraction. All slices
/// are `[s, ·]` unit-major; `att` and `probs` must be zeroed;
/// `scores` is an `s`-length scratch row.
#[allow(clippy::too_many_arguments)]
fn attn_fwd_unit(
    att: &mut [f32],
    probs: &mut [f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    scores: &mut [f32],
    s: usize,
    dh: usize,
    scale: f32,
) {
    for i in 0..s {
        let qrow = &q[i * dh..(i + 1) * dh];
        let mut mx = f32::NEG_INFINITY;
        for j in 0..=i {
            let krow = &k[j * dh..(j + 1) * dh];
            let mut acc = 0.0f32;
            for e in 0..dh {
                acc += qrow[e] * krow[e];
            }
            let sc = acc * scale;
            scores[j] = sc;
            mx = mx.max(sc);
        }
        let mut z = 0.0f32;
        for j in 0..=i {
            let e = (scores[j] - mx).exp();
            scores[j] = e;
            z += e;
        }
        let prow = &mut probs[i * s..(i + 1) * s];
        let arow = &mut att[i * dh..(i + 1) * dh];
        for j in 0..=i {
            let p = scores[j] / z;
            prow[j] = p;
            if p == 0.0 {
                continue;
            }
            let vrow = &v[j * dh..(j + 1) * dh];
            for e in 0..dh {
                arow[e] += p * vrow[e];
            }
        }
    }
}

/// One `(batch, head)` unit of the attention backward pass:
/// `dprobs = datt·Vᵀ`, the softmax Jacobian contraction, then `dq`/
/// `dk` rank-1 updates — all over the causal prefix. Slices unit-major
/// `[s, ·]`; `dq`/`dk`/`dv` must be zeroed; `dprobs` is scratch.
#[allow(clippy::too_many_arguments)]
fn attn_bwd_unit(
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    datt: &[f32],
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    dprobs: &mut [f32],
    s: usize,
    dh: usize,
    scale: f32,
) {
    for i in 0..s {
        let prow = &probs[i * s..(i + 1) * s];
        let darow = &datt[i * dh..(i + 1) * dh];
        // dprobs_j = Σ_e datt·v ; dv_j += p·datt
        for j in 0..=i {
            let vrow = &v[j * dh..(j + 1) * dh];
            let mut acc = 0.0f32;
            for e in 0..dh {
                acc += darow[e] * vrow[e];
            }
            dprobs[j] = acc;
            let p = prow[j];
            if p != 0.0 {
                let dvrow = &mut dv[j * dh..(j + 1) * dh];
                for e in 0..dh {
                    dvrow[e] += p * darow[e];
                }
            }
        }
        // softmax backward (masked entries have p = 0)
        let mut inner = 0.0f32;
        for j in 0..=i {
            inner += prow[j] * dprobs[j];
        }
        let qrow = &q[i * dh..(i + 1) * dh];
        let dqrow = &mut dq[i * dh..(i + 1) * dh];
        for j in 0..=i {
            let ds = prow[j] * (dprobs[j] - inner) * scale;
            if ds == 0.0 {
                continue;
            }
            let krow = &k[j * dh..(j + 1) * dh];
            let dkrow = &mut dk[j * dh..(j + 1) * dh];
            for e in 0..dh {
                dqrow[e] += ds * krow[e];
                dkrow[e] += ds * qrow[e];
            }
        }
    }
}

/// Fused causal attention forward, parallel over `(batch, head)`
/// units. Inputs `q`/`k`/`v` are **unit-major** `[B, H, S, Dh]` (see
/// [`pack_heads`]); outputs are the head-interleaved context
/// `att[B, S, H·Dh]` (fully overwritten) and the probability tensor
/// `probs[B, H, S, S]` (must be zeroed — the causal tail stays `+0`).
/// Each unit is computed by exactly one worker with its own score
/// scratch row, so the result is bitwise thread-count invariant.
pub fn attention_fwd(
    att: &mut [f32],
    probs: &mut [f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: AttnShape,
    pool: &Pool,
) {
    attention_fwd_threads(kernel_threads(), att, probs, q, k, v, sh, pool);
}

/// [`attention_fwd`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn attention_fwd_threads(
    threads: usize,
    att: &mut [f32],
    probs: &mut [f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: AttnShape,
    pool: &Pool,
) {
    let (s, dh, d) = (sh.s, sh.dh, sh.d());
    let units = sh.units();
    let (ua, up) = (s * dh, s * s);
    debug_assert_eq!(att.len(), sh.b * s * d);
    debug_assert_eq!(probs.len(), units * up);
    debug_assert_eq!(q.len(), sh.b * s * d);
    debug_assert_eq!(k.len(), sh.b * s * d);
    debug_assert_eq!(v.len(), sh.b * s * d);
    if units == 0 || s == 0 {
        return;
    }
    let scale = 1.0 / (dh as f32).sqrt();
    let mut attu = pool.zeroed(units * ua);
    let t = effective_threads(threads, units, units * up * dh);
    let per = units.div_ceil(t);
    let mut scratch = pool.zeroed(t * s);
    {
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = probs
            .chunks_mut(per * up)
            .zip(attu.chunks_mut(per * ua))
            .zip(scratch.chunks_mut(s))
            .enumerate()
            .map(|(ci, ((pch, ach), scr))| {
                Box::new(move || {
                    let n = pch.len() / up;
                    for i in 0..n {
                        let u = ci * per + i;
                        attn_fwd_unit(
                            &mut ach[i * ua..(i + 1) * ua],
                            &mut pch[i * up..(i + 1) * up],
                            &q[u * ua..(u + 1) * ua],
                            &k[u * ua..(u + 1) * ua],
                            &v[u * ua..(u + 1) * ua],
                            scr,
                            s,
                            dh,
                            scale,
                        );
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        fanout_strided(t, jobs);
    }
    pool.recycle(scratch);
    unpack_heads_threads(threads, att, &attu, sh);
    pool.recycle(attu);
}

/// One query row of incremental-decode attention for a single
/// `(batch, head)` unit: the new token at absolute position `i`,
/// scored against the cached prefix rows `0..=i` of `k`/`v`. This is
/// the row body of [`attn_fwd_unit`] verbatim (same dot-product
/// accumulation order, same max-subtracted softmax over the causal
/// prefix, same skip of underflowed probabilities) minus the `probs`
/// residual no decode consumer needs — so a token decoded against the
/// KV cache is bitwise identical to the same row of a full-grid
/// [`attention_fwd`] (`tests/serve_parity.rs` pins this). `att` is
/// the `dh`-wide output row (must be zeroed), `q` the new query row,
/// `k`/`v` the unit's `[s, dh]` cache slices, `scores` a scratch row
/// of at least `i + 1` entries.
pub fn attn_decode_row(
    att: &mut [f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    scores: &mut [f32],
    i: usize,
    dh: usize,
    scale: f32,
) {
    debug_assert_eq!(att.len(), dh);
    debug_assert_eq!(q.len(), dh);
    debug_assert!((i + 1) * dh <= k.len());
    debug_assert!((i + 1) * dh <= v.len());
    debug_assert!(i < scores.len());
    let mut mx = f32::NEG_INFINITY;
    for j in 0..=i {
        let krow = &k[j * dh..(j + 1) * dh];
        let mut acc = 0.0f32;
        for e in 0..dh {
            acc += q[e] * krow[e];
        }
        let sc = acc * scale;
        scores[j] = sc;
        mx = mx.max(sc);
    }
    let mut z = 0.0f32;
    for j in 0..=i {
        let e = (scores[j] - mx).exp();
        scores[j] = e;
        z += e;
    }
    for j in 0..=i {
        let p = scores[j] / z;
        if p == 0.0 {
            continue;
        }
        let vrow = &v[j * dh..(j + 1) * dh];
        for e in 0..dh {
            att[e] += p * vrow[e];
        }
    }
}

/// Apply RoPE to head-interleaved rows (`[rows, H·Dh]`) at explicit
/// absolute positions `pos[r]` — the incremental-decode variant of
/// [`rope_apply`], whose grid form derives each row's position from
/// its index inside the `[B, S]` grid. The per-element rotation is
/// the same expression, so a decode row at position `p` matches row
/// `p` of the full-grid application bitwise. `cos`/`sin` are
/// `[S, Dh/2]` tables covering every referenced position.
pub fn rope_apply_at(
    x: &mut [f32],
    h: usize,
    dh: usize,
    pos: &[usize],
    cos: &[f32],
    sin: &[f32],
) {
    let d = h * dh;
    let rows = pos.len();
    let half = dh / 2;
    debug_assert_eq!(x.len(), rows * d);
    debug_assert_eq!(cos.len(), sin.len());
    let t =
        effective_map_threads(kernel_threads(), rows, rows * d * 2);
    for_row_chunks(t, x, rows, d, &|row0, chunk| {
        for (r, xrow) in chunk.chunks_mut(d).enumerate() {
            let p = pos[row0 + r];
            debug_assert!((p + 1) * half <= cos.len());
            for hh in 0..h {
                let base = hh * dh;
                for e in 0..half {
                    let c = cos[p * half + e];
                    let s = sin[p * half + e];
                    let x1 = xrow[base + e];
                    let x2 = xrow[base + half + e];
                    let (n1, n2) =
                        (x1 * c - x2 * s, x1 * s + x2 * c);
                    xrow[base + e] = n1;
                    xrow[base + half + e] = n2;
                }
            }
        }
    });
}

/// Fused causal attention backward, parallel over `(batch, head)`
/// units. `datt` is the head-interleaved upstream cotangent
/// `[B, S, H·Dh]` (packed unit-major internally); `probs`/`q`/`k`/`v`
/// are the unit-major forward residuals; outputs `dq`/`dk`/`dv` come
/// back head-interleaved `[B, S, H·Dh]` (fully overwritten), **before**
/// any RoPE inverse — the caller applies that. Bitwise thread-count
/// invariant for the same reason as the forward.
#[allow(clippy::too_many_arguments)]
pub fn attention_bwd(
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    datt: &[f32],
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: AttnShape,
    pool: &Pool,
) {
    attention_bwd_threads(
        kernel_threads(),
        dq,
        dk,
        dv,
        datt,
        probs,
        q,
        k,
        v,
        sh,
        pool,
    );
}

/// [`attention_bwd`] with an explicit worker count.
#[allow(clippy::too_many_arguments)]
pub fn attention_bwd_threads(
    threads: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
    datt: &[f32],
    probs: &[f32],
    q: &[f32],
    k: &[f32],
    v: &[f32],
    sh: AttnShape,
    pool: &Pool,
) {
    let (s, dh, d) = (sh.s, sh.dh, sh.d());
    let units = sh.units();
    let (ua, up) = (s * dh, s * s);
    let n = sh.b * s * d;
    debug_assert_eq!(dq.len(), n);
    debug_assert_eq!(dk.len(), n);
    debug_assert_eq!(dv.len(), n);
    debug_assert_eq!(datt.len(), n);
    debug_assert_eq!(probs.len(), units * up);
    debug_assert_eq!(q.len(), n);
    debug_assert_eq!(k.len(), n);
    debug_assert_eq!(v.len(), n);
    if units == 0 || s == 0 {
        return;
    }
    let scale = 1.0 / (dh as f32).sqrt();
    let mut dah = pool.zeroed(n);
    pack_heads_threads(threads, &mut dah, datt, sh);
    let mut dqu = pool.zeroed(n);
    let mut dku = pool.zeroed(n);
    let mut dvu = pool.zeroed(n);
    let t = effective_threads(threads, units, units * up * dh);
    let per = units.div_ceil(t);
    let mut scratch = pool.zeroed(t * s);
    {
        let dah = &dah;
        let jobs: Vec<Box<dyn FnOnce() + Send + '_>> = dqu
            .chunks_mut(per * ua)
            .zip(dku.chunks_mut(per * ua))
            .zip(dvu.chunks_mut(per * ua))
            .zip(scratch.chunks_mut(s))
            .enumerate()
            .map(|(ci, (((qch, kch), vch), scr))| {
                Box::new(move || {
                    let nu = qch.len() / ua;
                    for i in 0..nu {
                        let u = ci * per + i;
                        attn_bwd_unit(
                            &mut qch[i * ua..(i + 1) * ua],
                            &mut kch[i * ua..(i + 1) * ua],
                            &mut vch[i * ua..(i + 1) * ua],
                            &dah[u * ua..(u + 1) * ua],
                            &probs[u * up..(u + 1) * up],
                            &q[u * ua..(u + 1) * ua],
                            &k[u * ua..(u + 1) * ua],
                            &v[u * ua..(u + 1) * ua],
                            scr,
                            s,
                            dh,
                            scale,
                        );
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        fanout_strided(t, jobs);
    }
    pool.recycle(scratch);
    unpack_heads_threads(threads, dq, &dqu, sh);
    unpack_heads_threads(threads, dk, &dku, sh);
    unpack_heads_threads(threads, dv, &dvu, sh);
    pool.recycle(dah);
    pool.recycle(dqu);
    pool.recycle(dku);
    pool.recycle(dvu);
}

// ---------------------------------------------------------------- pool

/// Retain at most this many free buffers; beyond it, returned buffers
/// are simply dropped (bounds memory held by an idle plan). One
/// `grads_*` execute recycles the backward temporaries (~100, plus
/// the attention family's per-layer pack/unpack intermediates and the
/// norm `dw` partials since PR 5) *before* the forward cache (~60
/// buffers, including the only attention-probs-sized allocations)
/// comes back at the end of the dispatch — the cap must exceed their
/// sum even on the 12-layer `gpt90m` config, or the largest buffers
/// are the ones dropped every step.
const POOL_MAX_BUFS: usize = 512;

/// Scratch-buffer pool: recycles large `f32` temporaries across
/// interpreter `execute()` calls. `RefBackend` device buffers own one
/// pool per plan, so a training step re-uses the previous step's
/// activation and gradient buffers instead of re-allocating them.
///
/// Interior mutability (`RefCell`) lets the interpreter draw scratch
/// while its inputs are immutably borrowed from the same buffer set;
/// the pool is intentionally `!Sync` — only the orchestrating thread
/// touches it. Kernels that need per-worker scratch draw one
/// `threads × row` buffer up front and hand each worker a disjoint
/// `&mut` slice (see the module docs § scratch ownership).
#[derive(Default)]
pub struct Pool {
    free: RefCell<Vec<Vec<f32>>>,
}

impl Pool {
    pub fn new() -> Self {
        Self::default()
    }

    /// A zeroed buffer of exactly `len` elements, reusing the
    /// best-fitting retained allocation when one is large enough.
    pub fn zeroed(&self, len: usize) -> Vec<f32> {
        let mut v = self.cleared(len);
        v.resize(len, 0.0);
        v
    }

    /// An **empty** buffer (len 0) with capacity ≥ `capacity`, reusing
    /// a retained allocation without paying [`Pool::zeroed`]'s fill —
    /// for targets that are fully overwritten via
    /// `extend_from_slice`/`push`.
    pub fn cleared(&self, capacity: usize) -> Vec<f32> {
        let mut free = self.free.borrow_mut();
        let mut best: Option<(usize, usize)> = None;
        for (i, b) in free.iter().enumerate() {
            let c = b.capacity();
            let better = match best {
                Some((_, bc)) => c < bc,
                None => true,
            };
            if c >= capacity && better {
                best = Some((i, c));
            }
        }
        match best {
            Some((i, _)) => {
                let mut v = free.swap_remove(i);
                v.clear();
                v
            }
            None => Vec::with_capacity(capacity),
        }
    }

    /// Return a buffer for later reuse (no-op for empty allocations or
    /// once [`POOL_MAX_BUFS`] buffers are already retained).
    pub fn recycle(&self, v: Vec<f32>) {
        if v.capacity() == 0 {
            return;
        }
        let mut free = self.free.borrow_mut();
        if free.len() < POOL_MAX_BUFS {
            free.push(v);
        }
    }

    /// Number of currently retained free buffers (test hook).
    pub fn retained(&self) -> usize {
        self.free.borrow().len()
    }
}

// ---------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn thread_budget_caps_and_restores() {
        // the cap is thread-local, scoped, and floored at 1; it never
        // raises the budget above the process-wide setting
        set_kernel_threads(4);
        assert_eq!(kernel_threads(), 4);
        with_thread_budget(2, || {
            assert_eq!(kernel_threads(), 2);
            with_thread_budget(8, || assert_eq!(kernel_threads(), 4));
            with_thread_budget(0, || assert_eq!(kernel_threads(), 1));
            assert_eq!(kernel_threads(), 2);
        });
        assert_eq!(kernel_threads(), 4);
        // other threads are unaffected while a cap is active
        with_thread_budget(1, || {
            let other = std::thread::spawn(kernel_threads)
                .join()
                .unwrap();
            assert_eq!(other, 4);
        });
        set_kernel_threads(0);
    }

    #[test]
    fn thread_budget_restores_after_panic() {
        // the cap is restored by an RAII guard, so a panicking worker
        // (a kernel assert, a poisoned driver) cannot leak a clamped
        // budget into subsequent steps on this thread — the pipeline
        // and dp engines both rely on this
        let before = THREAD_BUDGET.with(|b| b.get());
        let unwound = std::panic::catch_unwind(
            std::panic::AssertUnwindSafe(|| {
                with_thread_budget(1, || {
                    assert_eq!(kernel_threads(), 1);
                    panic!("worker died mid-kernel");
                })
            }),
        );
        assert!(unwound.is_err(), "the closure must have panicked");
        assert_eq!(
            THREAD_BUDGET.with(|b| b.get()),
            before,
            "a panic inside the scope must not leak the clamped budget"
        );
    }

    /// The historical interpreter loops, kept verbatim (including the
    /// `av == 0.0` skip) as the numeric reference. The blocked kernels
    /// drop that skip — for finite operands the only possible
    /// divergence is the sign of an exactly-zero result (`±0`), which
    /// `to_bits` equality on zero-free random data cannot hit; with
    /// non-finite operands (`0 × ∞`) results can genuinely differ,
    /// and that corner is documented, not pinned.
    fn naive_mm(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * m..(i + 1) * m];
            for (kk, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[kk * m..(kk + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    fn naive_mm_tn(a: &[f32], b: &[f32], k: usize, n: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for r in 0..k {
            let arow = &a[r * n..(r + 1) * n];
            let brow = &b[r * m..(r + 1) * m];
            for (i, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[i * m..(i + 1) * m];
                for j in 0..m {
                    orow[j] += av * brow[j];
                }
            }
        }
        out
    }

    fn naive_mm_nt(a: &[f32], b: &[f32], n: usize, k: usize, m: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; n * m];
        for i in 0..n {
            let arow = &a[i * k..(i + 1) * k];
            let orow = &mut out[i * m..(i + 1) * m];
            for (j, o) in orow.iter_mut().enumerate() {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for kk in 0..k {
                    acc += arow[kk] * brow[kk];
                }
                *o += acc;
            }
        }
        out
    }

    fn randv(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        rng.normal_vec(n, 1.0)
    }

    fn assert_bitwise_eq(a: &[f32], b: &[f32], what: &str) {
        assert_eq!(a.len(), b.len(), "{what}: length");
        for (i, (x, y)) in a.iter().zip(b).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: element {i} differs ({x} vs {y})"
            );
        }
    }

    #[test]
    fn blocked_kernels_match_naive_loops_bitwise() {
        // ragged shapes exercise every RT/JT tail path
        for &(n, k, m) in
            &[(1, 1, 1), (5, 7, 9), (33, 17, 40), (64, 32, 64)]
        {
            let a = randv(n * k, 1);
            let b = randv(k * m, 2);
            let bt = randv(m * k, 3);
            let at = randv(k * n, 4);

            let mut got = vec![0.0f32; n * m];
            mm_into_threads(1, &mut got, &a, &b, n, k, m);
            assert_bitwise_eq(&got, &naive_mm(&a, &b, n, k, m), "mm");

            let mut got = vec![0.0f32; n * m];
            mm_tn_into_threads(1, &mut got, &at, &b, k, n, m);
            assert_bitwise_eq(
                &got,
                &naive_mm_tn(&at, &b, k, n, m),
                "mm_tn",
            );

            let mut got = vec![0.0f32; n * m];
            mm_nt_into_threads(1, &mut got, &a, &bt, n, k, m);
            assert_bitwise_eq(
                &got,
                &naive_mm_nt(&a, &bt, n, k, m),
                "mm_nt",
            );
        }
    }

    #[test]
    fn serial_and_parallel_agree_bitwise() {
        // n*k*m must clear PAR_MIN_MACS so the threaded path engages;
        // ragged dims keep the tile tails honest under chunking.
        let (n, k, m) = (97, 64, 49);
        assert!(n * k * m >= PAR_MIN_MACS);
        let a = randv(n * k, 10);
        let b = randv(k * m, 11);
        let at = randv(k * n, 12);
        let bt = randv(m * k, 13);
        for threads in [2, 3, 8] {
            let mut serial = vec![0.0f32; n * m];
            mm_into_threads(1, &mut serial, &a, &b, n, k, m);
            let mut par = vec![0.0f32; n * m];
            mm_into_threads(threads, &mut par, &a, &b, n, k, m);
            assert_bitwise_eq(&serial, &par, "mm par");

            let mut serial = vec![0.0f32; n * m];
            mm_tn_into_threads(1, &mut serial, &at, &b, k, n, m);
            let mut par = vec![0.0f32; n * m];
            mm_tn_into_threads(threads, &mut par, &at, &b, k, n, m);
            assert_bitwise_eq(&serial, &par, "mm_tn par");

            let mut serial = vec![0.0f32; n * m];
            mm_nt_into_threads(1, &mut serial, &a, &bt, n, k, m);
            let mut par = vec![0.0f32; n * m];
            mm_nt_into_threads(threads, &mut par, &a, &bt, n, k, m);
            assert_bitwise_eq(&serial, &par, "mm_nt par");
        }
    }

    #[test]
    fn mm_matches_tensor_matmul() {
        use crate::tensor::Tensor;
        let (n, k, m) = (6, 5, 4);
        let mut rng = Rng::new(9);
        let a = Tensor::randn(&[n, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, m], 1.0, &mut rng);
        let want = a.matmul(&b);
        let got = mm(&a.data, &b.data, n, k, m);
        for (x, y) in got.iter().zip(&want.data) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn into_variants_accumulate() {
        // `+=` semantics: pre-seeded output keeps its contribution
        let (n, k, m) = (3, 2, 3);
        let a = randv(n * k, 20);
        let b = randv(k * m, 21);
        let base = randv(n * m, 22);
        let mut out = base.clone();
        mm_into_threads(1, &mut out, &a, &b, n, k, m);
        let plain = naive_mm(&a, &b, n, k, m);
        for i in 0..n * m {
            assert_eq!(
                out[i].to_bits(),
                (base[i] + plain[i]).to_bits()
            );
        }
    }

    // ------------------------------------------------ q8 GEMM parity

    /// The dequant-fused contract: `mm_*_q8` over (codes, scales) is
    /// bitwise the plain f32 kernel over the dequantized matrix — the
    /// per-tile dequant expression is the same `code · scale` product
    /// in the same k-ascending order.
    #[test]
    fn q8_gemms_match_dequantized_dense_bitwise() {
        use crate::runtime::quant::QTensor;
        // ragged shapes: partial RT/JT tiles AND a ragged last quant
        // block (k, m not multiples of QBLOCK)
        for &(n, k, m) in
            &[(1, 1, 1), (5, 7, 9), (33, 17, 40), (13, 70, 67)]
        {
            let a = randv(n * k, 50);
            let at = randv(k * n, 51);

            let qb = QTensor::quantize(&[k, m], &randv(k * m, 52));
            let dqb = qb.dequantize();
            let mut got = vec![0.0f32; n * m];
            mm_q8_into_threads(
                1, &mut got, &a, &qb.codes, &qb.scales, n, k, m,
            );
            let mut want = vec![0.0f32; n * m];
            mm_into_threads(1, &mut want, &a, &dqb, n, k, m);
            assert_bitwise_eq(&got, &want, "mm_q8");

            let mut got = vec![0.0f32; n * m];
            mm_tn_q8_into_threads(
                1, &mut got, &at, &qb.codes, &qb.scales, k, n, m,
            );
            let mut want = vec![0.0f32; n * m];
            mm_tn_into_threads(1, &mut want, &at, &dqb, k, n, m);
            assert_bitwise_eq(&got, &want, "mm_tn_q8");

            let qbt = QTensor::quantize(&[m, k], &randv(m * k, 53));
            let dqbt = qbt.dequantize();
            let mut got = vec![0.0f32; n * m];
            mm_nt_q8_into_threads(
                1, &mut got, &a, &qbt.codes, &qbt.scales, n, k, m,
            );
            let mut want = vec![0.0f32; n * m];
            mm_nt_into_threads(1, &mut want, &a, &dqbt, n, k, m);
            assert_bitwise_eq(&got, &want, "mm_nt_q8");
        }
    }

    #[test]
    fn q8_gemms_serial_parallel_agree_bitwise() {
        use crate::runtime::quant::QTensor;
        let (n, k, m) = (97, 70, 49);
        assert!(n * k * m >= PAR_MIN_MACS);
        let a = randv(n * k, 60);
        let at = randv(k * n, 61);
        let qb = QTensor::quantize(&[k, m], &randv(k * m, 62));
        let qbt = QTensor::quantize(&[m, k], &randv(m * k, 63));
        for threads in [2, 3, 8] {
            let mut serial = vec![0.0f32; n * m];
            mm_q8_into_threads(
                1, &mut serial, &a, &qb.codes, &qb.scales, n, k, m,
            );
            let mut par = vec![0.0f32; n * m];
            mm_q8_into_threads(
                threads, &mut par, &a, &qb.codes, &qb.scales, n, k, m,
            );
            assert_bitwise_eq(&serial, &par, "mm_q8 par");

            let mut serial = vec![0.0f32; n * m];
            mm_tn_q8_into_threads(
                1, &mut serial, &at, &qb.codes, &qb.scales, k, n, m,
            );
            let mut par = vec![0.0f32; n * m];
            mm_tn_q8_into_threads(
                threads, &mut par, &at, &qb.codes, &qb.scales, k, n, m,
            );
            assert_bitwise_eq(&serial, &par, "mm_tn_q8 par");

            let mut serial = vec![0.0f32; n * m];
            mm_nt_q8_into_threads(
                1, &mut serial, &a, &qbt.codes, &qbt.scales, n, k, m,
            );
            let mut par = vec![0.0f32; n * m];
            mm_nt_q8_into_threads(
                threads, &mut par, &a, &qbt.codes, &qbt.scales, n, k,
                m,
            );
            assert_bitwise_eq(&serial, &par, "mm_nt_q8 par");
        }
    }

    #[test]
    fn gather_rows_q8_matches_dense_gather_bitwise() {
        use crate::runtime::quant::QTensor;
        // ragged row width (blocks of 64 → 70 leaves a 6-wide tail)
        let (v, d) = (19, 70);
        let q = QTensor::quantize(&[v, d], &randv(v * d, 70));
        let dq = q.dequantize();
        let ids = [0i32, 7, 18, 3, 3, -1, 25];
        let mut got = vec![0.0f32; ids.len() * d];
        gather_rows_q8(&mut got, &q.codes, &q.scales, &ids, d, v);
        let mut want = vec![0.0f32; ids.len() * d];
        gather_rows(&mut want, &dq, &ids, d, v);
        assert_bitwise_eq(&got, &want, "gather_rows_q8");
    }

    // ------------------------------------------- elementwise parity

    #[test]
    fn elementwise_kernels_serial_parallel_agree_bitwise() {
        // big enough to clear PAR_MIN_ELEMS; ragged so chunk tails
        // are exercised
        let n = (1 << 16) + 37;
        let a = randv(n, 30);
        let b = randv(n, 31);
        for threads in [2, 5] {
            let mut s = a.clone();
            add_into_threads(1, &mut s, &b);
            let mut p = a.clone();
            add_into_threads(threads, &mut p, &b);
            assert_bitwise_eq(&s, &p, "add_into");

            let mut s = vec![0.0f32; n];
            silu_mul_threads(1, &mut s, &a, &b);
            let mut p = vec![0.0f32; n];
            silu_mul_threads(threads, &mut p, &a, &b);
            assert_bitwise_eq(&s, &p, "silu_mul");

            let mut sg = vec![0.0f32; n];
            let mut su = vec![0.0f32; n];
            dsilu_mul_threads(1, &mut sg, &mut su, &a, &a, &b);
            let mut pg = vec![0.0f32; n];
            let mut pu = vec![0.0f32; n];
            dsilu_mul_threads(threads, &mut pg, &mut pu, &a, &a, &b);
            assert_bitwise_eq(&sg, &pg, "dsilu_mul dgate");
            assert_bitwise_eq(&su, &pu, "dsilu_mul dup");
        }
    }

    #[test]
    fn rmsnorm_serial_parallel_agree_bitwise() {
        // ragged row count and width; rows*d*3 clears PAR_MIN_MACS so
        // the tiled backward genuinely fans out
        let (rows, d) = (403, 257);
        assert!(rows * d * 3 >= PAR_MIN_MACS);
        let x = randv(rows * d, 40);
        let w = randv(d, 41);
        let dy = randv(rows * d, 42);
        let pool = Pool::new();

        let mut ys = vec![0.0f32; rows * d];
        let mut invs = vec![0.0f32; rows];
        rmsnorm_fwd_threads(1, &mut ys, &mut invs, &x, &w, rows, d, 1e-6);
        for threads in [2, 4] {
            let mut yp = vec![0.0f32; rows * d];
            let mut invp = vec![0.0f32; rows];
            rmsnorm_fwd_threads(
                threads, &mut yp, &mut invp, &x, &w, rows, d, 1e-6,
            );
            assert_bitwise_eq(&ys, &yp, "rmsnorm_fwd y");
            assert_bitwise_eq(&invs, &invp, "rmsnorm_fwd inv");
        }

        let mut dxs = vec![0.0f32; rows * d];
        let mut dws = vec![0.0f32; d];
        rmsnorm_bwd_threads(
            1, &mut dxs, &mut dws, &x, &w, &invs, &dy, rows, d, &pool,
        );
        for threads in [2, 4] {
            let mut dxp = vec![0.0f32; rows * d];
            let mut dwp = vec![0.0f32; d];
            rmsnorm_bwd_threads(
                threads, &mut dxp, &mut dwp, &x, &w, &invs, &dy, rows,
                d, &pool,
            );
            assert_bitwise_eq(&dxs, &dxp, "rmsnorm_bwd dx");
            assert_bitwise_eq(&dws, &dwp, "rmsnorm_bwd dw");
        }
    }

    #[test]
    fn rope_serial_parallel_agree_bitwise_and_inverts() {
        let sh = AttnShape { b: 4, s: 97, h: 6, dh: 18 };
        let d = sh.h * sh.dh;
        let n = sh.b * sh.s * d;
        assert!(n * 2 >= PAR_MIN_ELEMS, "too small to engage threads");
        let half = sh.dh / 2;
        let mut cos = Vec::new();
        let mut sin = Vec::new();
        for pos in 0..sh.s {
            for e in 0..half {
                let ang = pos as f32
                    * 10000f32.powf(-(e as f32) / half as f32);
                cos.push(ang.cos());
                sin.push(ang.sin());
            }
        }
        let x0 = randv(n, 50);
        let mut s = x0.clone();
        rope_apply_threads(1, &mut s, sh, &cos, &sin, false);
        for threads in [2, 3] {
            let mut p = x0.clone();
            rope_apply_threads(threads, &mut p, sh, &cos, &sin, false);
            assert_bitwise_eq(&s, &p, "rope");
        }
        // inverse rotation undoes the forward within float tolerance
        let mut back = s.clone();
        rope_apply_threads(2, &mut back, sh, &cos, &sin, true);
        for (a, b) in back.iter().zip(&x0) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn loss_kernels_serial_parallel_agree_bitwise() {
        // b*s*v clears PAR_MIN_MACS so seq_nll (and a fortiori
        // ce_loss) genuinely fans out; ragged everywhere
        let (b, s, v) = (6, 111, 401);
        let rows = b * s;
        assert!(b * s * v >= PAR_MIN_MACS);
        let logits = randv(rows * v, 60);
        let mut rng = Rng::new(61);
        let targets: Vec<i32> =
            (0..rows).map(|_| rng.below(v) as i32).collect();
        // mix of masked and unmasked positions
        let mask: Vec<f32> = (0..rows)
            .map(|i| if i % 7 == 0 { 0.0 } else { 1.0 })
            .collect();
        let c = mask.iter().sum::<f32>().max(1.0);
        let pool = Pool::new();

        let mut dls = vec![0.0f32; rows * v];
        let ls = ce_loss_threads(
            1, &mut dls, &logits, &targets, &mask, rows, v, c, &pool,
        );
        for threads in [2, 4] {
            let mut dlp = vec![0.0f32; rows * v];
            let lp = ce_loss_threads(
                threads, &mut dlp, &logits, &targets, &mask, rows, v,
                c, &pool,
            );
            assert_eq!(ls.to_bits(), lp.to_bits(), "ce_loss scalar");
            assert_bitwise_eq(&dls, &dlp, "ce_loss dl");
        }

        let mut nlls = vec![0.0f32; b];
        let mut cnts = vec![0.0f32; b];
        seq_nll_threads(
            1, &mut nlls, &mut cnts, &logits, &targets, &mask, b, s, v,
        );
        for threads in [2, 3] {
            let mut nllp = vec![0.0f32; b];
            let mut cntp = vec![0.0f32; b];
            seq_nll_threads(
                threads, &mut nllp, &mut cntp, &logits, &targets,
                &mask, b, s, v,
            );
            assert_bitwise_eq(&nlls, &nllp, "seq_nll nll");
            assert_bitwise_eq(&cnts, &cntp, "seq_nll cnt");
        }
    }

    // --------------------------------------------- attention parity

    /// The historical serial attention forward (full-row mask fill,
    /// full-row exp) over head-interleaved `[B, S, H, Dh]` operands —
    /// the reference the fused causal-prefix kernel must match
    /// bitwise on the probability tensor. A frozen fossil with a
    /// verbatim twin in `benches/kernels_micro.rs` (the perf
    /// baseline); keep both byte-identical and never "improve"
    /// either.
    fn naive_attention_fwd(
        qr: &[f32],
        kr: &[f32],
        v4: &[f32],
        sh: AttnShape,
    ) -> (Vec<f32>, Vec<f32>) {
        let (b, s, h, dh) = (sh.b, sh.s, sh.h, sh.dh);
        let scale = 1.0 / (dh as f32).sqrt();
        let mut probs = vec![0.0f32; b * h * s * s];
        let mut att = vec![0.0f32; b * s * h * dh];
        let mut scores = vec![0.0f32; s];
        let at =
            |bb: usize, pos: usize, hh: usize| ((bb * s + pos) * h + hh) * dh;
        for bb in 0..b {
            for hh in 0..h {
                for i in 0..s {
                    let prow_off = ((bb * h + hh) * s + i) * s;
                    scores.fill(-1e30);
                    let qrow = &qr[at(bb, i, hh)..at(bb, i, hh) + dh];
                    for (j, sc) in
                        scores.iter_mut().enumerate().take(i + 1)
                    {
                        let krow =
                            &kr[at(bb, j, hh)..at(bb, j, hh) + dh];
                        let mut acc = 0.0f32;
                        for e in 0..dh {
                            acc += qrow[e] * krow[e];
                        }
                        *sc = acc * scale;
                    }
                    let mx = scores
                        .iter()
                        .cloned()
                        .fold(f32::NEG_INFINITY, f32::max);
                    let mut z = 0.0f32;
                    for sc in scores.iter_mut() {
                        *sc = (*sc - mx).exp();
                        z += *sc;
                    }
                    let prow = &mut probs[prow_off..prow_off + s];
                    for (j, &e) in scores.iter().enumerate() {
                        prow[j] = e / z;
                    }
                    let arow = at(bb, i, hh);
                    for (j, &p) in prow.iter().enumerate().take(i + 1)
                    {
                        if p == 0.0 {
                            continue;
                        }
                        let vrow =
                            &v4[at(bb, j, hh)..at(bb, j, hh) + dh];
                        for e in 0..dh {
                            att[arow + e] += p * vrow[e];
                        }
                    }
                }
            }
        }
        (att, probs)
    }

    #[test]
    fn fused_attention_matches_historical_full_row_softmax() {
        // The causal-prefix fix must be invisible: identical probs
        // (bitwise) and identical context to the historical kernel
        // that filled and exponentiated the masked tail.
        for sh in [
            AttnShape { b: 1, s: 1, h: 1, dh: 4 },
            AttnShape { b: 2, s: 7, h: 3, dh: 6 },
            AttnShape { b: 2, s: 33, h: 2, dh: 20 },
        ] {
            let n = sh.b * sh.s * sh.h * sh.dh;
            let qr = randv(n, 70);
            let kr = randv(n, 71);
            let v4 = randv(n, 72);
            let (want_att, want_probs) =
                naive_attention_fwd(&qr, &kr, &v4, sh);

            let pool = Pool::new();
            let mut qh = vec![0.0f32; n];
            let mut kh = vec![0.0f32; n];
            let mut vh = vec![0.0f32; n];
            pack_heads_threads(1, &mut qh, &qr, sh);
            pack_heads_threads(1, &mut kh, &kr, sh);
            pack_heads_threads(1, &mut vh, &v4, sh);
            let mut att = vec![0.0f32; n];
            let mut probs =
                vec![0.0f32; sh.b * sh.h * sh.s * sh.s];
            attention_fwd_threads(
                1, &mut att, &mut probs, &qh, &kh, &vh, sh, &pool,
            );
            assert_bitwise_eq(&probs, &want_probs, "causal probs");
            assert_bitwise_eq(&att, &want_att, "causal att");
        }
    }

    #[test]
    fn attention_serial_parallel_agree_bitwise() {
        // units * s * s * dh clears PAR_MIN_MACS; ragged s and dh
        let sh = AttnShape { b: 2, s: 57, h: 4, dh: 36 };
        assert!(
            sh.b * sh.h * sh.s * sh.s * sh.dh >= PAR_MIN_MACS,
            "shape too small to engage threads"
        );
        let n = sh.b * sh.s * sh.h * sh.dh;
        let q = randv(n, 80);
        let k = randv(n, 81);
        let v = randv(n, 82);
        let datt = randv(n, 83);
        let pool = Pool::new();

        let mut att_s = vec![0.0f32; n];
        let mut probs_s = vec![0.0f32; sh.b * sh.h * sh.s * sh.s];
        attention_fwd_threads(
            1, &mut att_s, &mut probs_s, &q, &k, &v, sh, &pool,
        );
        let mut dq_s = vec![0.0f32; n];
        let mut dk_s = vec![0.0f32; n];
        let mut dv_s = vec![0.0f32; n];
        attention_bwd_threads(
            1, &mut dq_s, &mut dk_s, &mut dv_s, &datt, &probs_s, &q,
            &k, &v, sh, &pool,
        );

        for threads in [2, 3, 8] {
            let mut att_p = vec![0.0f32; n];
            let mut probs_p =
                vec![0.0f32; sh.b * sh.h * sh.s * sh.s];
            attention_fwd_threads(
                threads, &mut att_p, &mut probs_p, &q, &k, &v, sh,
                &pool,
            );
            assert_bitwise_eq(&att_s, &att_p, "attention_fwd att");
            assert_bitwise_eq(
                &probs_s,
                &probs_p,
                "attention_fwd probs",
            );

            let mut dq_p = vec![0.0f32; n];
            let mut dk_p = vec![0.0f32; n];
            let mut dv_p = vec![0.0f32; n];
            attention_bwd_threads(
                threads, &mut dq_p, &mut dk_p, &mut dv_p, &datt,
                &probs_s, &q, &k, &v, sh, &pool,
            );
            assert_bitwise_eq(&dq_s, &dq_p, "attention_bwd dq");
            assert_bitwise_eq(&dk_s, &dk_p, "attention_bwd dk");
            assert_bitwise_eq(&dv_s, &dv_p, "attention_bwd dv");
        }
    }

    #[test]
    fn pack_unpack_heads_roundtrip() {
        let sh = AttnShape { b: 2, s: 5, h: 3, dh: 7 };
        let n = sh.b * sh.s * sh.h * sh.dh;
        let x = randv(n, 90);
        let mut packed = vec![0.0f32; n];
        pack_heads_threads(2, &mut packed, &x, sh);
        let mut back = vec![0.0f32; n];
        unpack_heads_threads(2, &mut back, &packed, sh);
        assert_bitwise_eq(&x, &back, "pack/unpack roundtrip");
        // spot-check the layout: dst[b=1,h=2,pos=3] == src[b=1,pos=3,h=2]
        let src_off = ((sh.s + 3) * sh.h + 2) * sh.dh;
        let dst_off = ((sh.h + 2) * sh.s + 3) * sh.dh;
        assert_eq!(
            packed[dst_off].to_bits(),
            x[src_off].to_bits()
        );
    }

    // ------------------------------------------------- thread budget

    #[test]
    fn workers_are_marked_for_nested_serialization() {
        // any kernel called from inside a worker must see an
        // effective thread count of 1 — the oversubscription guard
        let jobs: Vec<Box<dyn FnOnce() + Send>> = (0..2)
            .map(|_| {
                Box::new(|| {
                    assert!(in_worker(), "worker flag not set");
                    assert_eq!(
                        effective_threads(8, 100, usize::MAX),
                        1,
                        "nested kernel would fan out"
                    );
                    assert_eq!(
                        effective_map_threads(8, 100, usize::MAX),
                        1
                    );
                }) as Box<dyn FnOnce() + Send>
            })
            .collect();
        fanout_strided(2, jobs);
        assert!(!in_worker(), "orchestrator inherited the flag");
    }

    #[test]
    fn pool_recycles_and_zeroes() {
        let pool = Pool::new();
        let mut v = pool.zeroed(64);
        v.iter_mut().for_each(|x| *x = 7.0);
        pool.recycle(v);
        assert_eq!(pool.retained(), 1);
        let v2 = pool.zeroed(32);
        assert!(v2.iter().all(|&x| x == 0.0), "reused buffer not zeroed");
        assert!(v2.capacity() >= 64, "did not reuse the retained buffer");
        assert_eq!(pool.retained(), 0);
        // too-small buffers are left retained, fresh alloc happens
        pool.recycle(v2);
        let big = pool.zeroed(1024);
        assert_eq!(big.len(), 1024);
        assert_eq!(pool.retained(), 1);
    }

    #[test]
    fn kernel_threads_is_at_least_one() {
        assert!(kernel_threads() >= 1);
    }
}
