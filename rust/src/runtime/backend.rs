//! Backend-abstracted execution: the [`Backend`] trait, device-resident
//! input buffers, typed [`ExecPlan`]s, and the [`Runtime`] cache.
//!
//! The old execution model cloned every parameter tensor into a
//! `BTreeMap<String, HostValue>` each step, re-converted each entry to
//! a backend literal on every call, and copied every output back to
//! host — even for frozen backbone weights that never change between
//! relocalizations. The redesigned model splits that into:
//!
//! * [`Backend`] — compiles/interprets one artifact ([`Executor`]) and
//!   allocates its input storage ([`DeviceBuffers`]). Two backends
//!   exist: the PJRT/XLA path ([`crate::runtime::PjrtBackend`]) and a
//!   pure-Rust interpreter ([`crate::runtime::RefBackend`]) that needs
//!   no lowered artifacts.
//! * [`ExecPlan`] — a typed plan over one executable. Inputs are
//!   resolved by manifest name at bind time and marked **static**
//!   (uploaded once, re-uploaded only when the caller mutates them —
//!   e.g. on LoSiA relocalization or a LoRA merge) or **per-step**
//!   (batch tensors, subnet deltas). Static buffers persist across
//!   `run()` calls; per-step bindings are cleared after every run so a
//!   stale batch is an error instead of silent training on old data.
//! * [`OutputHandle`] — a device-resident output of one `run()`.
//!   Nothing crosses back to the host until the caller asks
//!   ([`OutputHandle::host`] / [`OutputHandle::into_host`]), so a
//!   driver that only consumes the subnet-delta outputs never pays
//!   for full-size gradients it would immediately discard.
//! * [`ExecStats`] — atomic per-artifact counters (calls, wall time,
//!   static/per-step upload counts, and the download split: how many
//!   outputs were materialised host-side and how many bytes moved)
//!   surfaced through the observer event stream
//!   ([`crate::session::observer::ExecEvent`]).
//!
//! ## The static-binding invalidation contract
//!
//! A static binding reflects the host value **at bind time**. Mutating
//! the host tensor afterwards does NOT propagate: callers must re-bind
//! the input, and `ExecStats::static_uploads` counts exactly those
//! re-binds. Drivers rely on this to make the per-step hot path
//! upload-free for frozen parameters; the unit tests in this module
//! pin the contract (a stale static binding keeps executing the old
//! value — the "silently train on old weights" bug is caught by
//! asserting upload counts, not by guesswork).
//!
//! ## Buffer donation
//!
//! [`ExecPlan::donate`] marks a static input as *donated* (classic XLA
//! input/output aliasing): the backend may reclaim or alias the
//! buffer's storage while producing a same-shape output, so e.g. a
//! relocalization's folded-`W` re-upload reuses the old backbone slot
//! instead of allocating next to it. Donation is advisory on the
//! backend side (a backend that cannot alias simply drops the buffer)
//! but binding semantics are uniform: a donated slot is **consumed by
//! `run()`** like a per-step binding, so executing again without
//! re-binding it is a loud error rather than silent reuse of
//! reclaimed storage.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{ArtifactSpec, Dtype, ModelCfg, TensorSpec};
use crate::coordinator::state::ModelState;
use crate::data::Batch;
use crate::runtime::host::HostValue;
use crate::runtime::quant::{self, QTensor};
use crate::tensor::Tensor;

// ------------------------------------------------------------- bindings

/// Who re-binds an input slot: `Static` survives across `run()` calls,
/// `PerStep` must be re-bound before every call.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BindingKind {
    Static,
    PerStep,
}

/// A borrowed host tensor crossing into a backend — the upload-side
/// twin of [`HostValue`], without the allocation. `Q8` is the
/// `static_quantized` storage class: block-quantized int8 codes plus
/// per-block f32 scales standing in for an f32 manifest input.
#[derive(Debug, Clone, Copy)]
pub enum HostRef<'a> {
    F32 { shape: &'a [usize], data: &'a [f32] },
    I32 { shape: &'a [usize], data: &'a [i32] },
    Q8 {
        shape: &'a [usize],
        codes: &'a [i8],
        scales: &'a [f32],
    },
}

impl<'a> HostRef<'a> {
    pub fn tensor(t: &'a Tensor) -> Self {
        HostRef::F32 {
            shape: &t.shape,
            data: &t.data,
        }
    }

    pub fn quantized(q: &'a QTensor) -> Self {
        HostRef::Q8 {
            shape: &q.shape,
            codes: &q.codes,
            scales: &q.scales,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostRef::F32 { shape, .. } => shape,
            HostRef::I32 { shape, .. } => shape,
            HostRef::Q8 { shape, .. } => shape,
        }
    }

    /// The *logical* dtype — a quantized ref reports `F32` because it
    /// stands in for an f32 manifest input; int8 is a storage detail.
    pub fn dtype(&self) -> Dtype {
        match self {
            HostRef::F32 { .. } => Dtype::F32,
            HostRef::I32 { .. } => Dtype::I32,
            HostRef::Q8 { .. } => Dtype::F32,
        }
    }

    /// Validate against a manifest input spec (shape + dtype).
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        anyhow::ensure!(
            self.shape() == spec.shape.as_slice(),
            "input {:?}: shape {:?} != manifest {:?}",
            spec.name,
            self.shape(),
            spec.shape
        );
        anyhow::ensure!(
            self.dtype() == spec.dtype,
            "input {:?}: dtype {:?} != manifest {:?}",
            spec.name,
            self.dtype(),
            spec.dtype
        );
        Ok(())
    }

    /// Owned copy (the reference backend's "device" representation).
    pub fn to_host_value(&self) -> HostValue {
        match self {
            HostRef::F32 { shape, data } => HostValue::F32(
                Tensor::from_vec(shape, data.to_vec()),
            ),
            HostRef::I32 { shape, data } => HostValue::I32 {
                shape: shape.to_vec(),
                data: data.to_vec(),
            },
            HostRef::Q8 {
                shape,
                codes,
                scales,
            } => HostValue::Q8(QTensor {
                shape: shape.to_vec(),
                codes: codes.to_vec(),
                scales: scales.to_vec(),
            }),
        }
    }
}

impl<'a> From<&'a HostValue> for HostRef<'a> {
    fn from(v: &'a HostValue) -> Self {
        match v {
            HostValue::F32(t) => HostRef::tensor(t),
            HostValue::I32 { shape, data } => HostRef::I32 {
                shape,
                data,
            },
            HostValue::Q8(q) => HostRef::quantized(q),
        }
    }
}

// ---------------------------------------------------------------- stats

/// Cumulative per-artifact execution counters. Atomics (not `Cell`) so
/// executables can be shared via `Arc` across plans and observers.
/// Wall time is split by phase — `upload_nanos` (host→device binds),
/// `nanos` (execute), `download_nanos` (device→host materialisation) —
/// so "the win is in the execute phase, not hidden in transfers" is a
/// measurable statement.
#[derive(Debug, Default)]
pub struct ExecStats {
    calls: AtomicU64,
    nanos: AtomicU64,
    upload_nanos: AtomicU64,
    download_nanos: AtomicU64,
    overlap_nanos: AtomicU64,
    static_uploads: AtomicU64,
    step_uploads: AtomicU64,
    downloads: AtomicU64,
    download_bytes: AtomicU64,
}

impl ExecStats {
    pub fn snapshot(&self) -> ExecSnapshot {
        ExecSnapshot {
            calls: self.calls.load(Ordering::Relaxed),
            nanos: self.nanos.load(Ordering::Relaxed),
            upload_nanos: self.upload_nanos.load(Ordering::Relaxed),
            download_nanos: self
                .download_nanos
                .load(Ordering::Relaxed),
            overlap_nanos: self.overlap_nanos.load(Ordering::Relaxed),
            static_uploads: self.static_uploads.load(Ordering::Relaxed),
            step_uploads: self.step_uploads.load(Ordering::Relaxed),
            downloads: self.downloads.load(Ordering::Relaxed),
            download_bytes: self
                .download_bytes
                .load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.calls.store(0, Ordering::Relaxed);
        self.nanos.store(0, Ordering::Relaxed);
        self.upload_nanos.store(0, Ordering::Relaxed);
        self.download_nanos.store(0, Ordering::Relaxed);
        self.overlap_nanos.store(0, Ordering::Relaxed);
        self.static_uploads.store(0, Ordering::Relaxed);
        self.step_uploads.store(0, Ordering::Relaxed);
        self.downloads.store(0, Ordering::Relaxed);
        self.download_bytes.store(0, Ordering::Relaxed);
    }

    fn record_exec(&self, nanos: u64) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    fn record_download(&self, bytes: u64, nanos: u64) {
        self.downloads.fetch_add(1, Ordering::Relaxed);
        self.download_bytes.fetch_add(bytes, Ordering::Relaxed);
        self.download_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    fn record_upload(&self, kind: BindingKind, nanos: u64) {
        self.upload_nanos.fetch_add(nanos, Ordering::Relaxed);
        match kind {
            BindingKind::Static => {
                self.static_uploads.fetch_add(1, Ordering::Relaxed)
            }
            BindingKind::PerStep => {
                self.step_uploads.fetch_add(1, Ordering::Relaxed)
            }
        };
    }

    /// A per-step upload performed off the critical path (staged into
    /// an idle buffer set while execute runs). Counts as a step upload
    /// — the sync and pipelined paths move identical copies — but its
    /// wall time lands in `overlap_nanos`, not `upload_nanos`, so
    /// `upload_secs()` stays "exposed transfer time".
    fn record_staged_upload(&self, nanos: u64) {
        self.overlap_nanos.fetch_add(nanos, Ordering::Relaxed);
        self.step_uploads.fetch_add(1, Ordering::Relaxed);
    }
}

/// A point-in-time copy of [`ExecStats`], also used for deltas.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ExecSnapshot {
    pub calls: u64,
    /// wall time inside `execute()` (the compute phase)
    pub nanos: u64,
    /// wall time inside `upload()` (host→device binds, both kinds)
    /// that was *exposed* — paid on the training thread's critical
    /// path. Staged (overlapped) binds land in `overlap_nanos`.
    pub upload_nanos: u64,
    /// wall time materialising outputs host-side
    pub download_nanos: u64,
    /// wall time of per-step uploads hidden behind execute by the
    /// step pipeline's double-buffered staging (0 on the sync path)
    pub overlap_nanos: u64,
    pub static_uploads: u64,
    pub step_uploads: u64,
    /// outputs materialised host-side (lazy `OutputHandle` downloads)
    pub downloads: u64,
    /// device→host bytes those downloads moved
    pub download_bytes: u64,
}

impl ExecSnapshot {
    /// Counter movement since `prev` (saturating, so a reset between
    /// snapshots reads as zero instead of wrapping).
    pub fn delta_since(&self, prev: &ExecSnapshot) -> ExecSnapshot {
        ExecSnapshot {
            calls: self.calls.saturating_sub(prev.calls),
            nanos: self.nanos.saturating_sub(prev.nanos),
            upload_nanos: self
                .upload_nanos
                .saturating_sub(prev.upload_nanos),
            download_nanos: self
                .download_nanos
                .saturating_sub(prev.download_nanos),
            overlap_nanos: self
                .overlap_nanos
                .saturating_sub(prev.overlap_nanos),
            static_uploads: self
                .static_uploads
                .saturating_sub(prev.static_uploads),
            step_uploads: self
                .step_uploads
                .saturating_sub(prev.step_uploads),
            downloads: self.downloads.saturating_sub(prev.downloads),
            download_bytes: self
                .download_bytes
                .saturating_sub(prev.download_bytes),
        }
    }

    /// Execute-phase wall time (the historical meaning — transfer
    /// phases are reported separately).
    pub fn total_secs(&self) -> f64 {
        self.nanos as f64 / 1e9
    }

    pub fn mean_secs(&self) -> f64 {
        self.total_secs() / self.calls.max(1) as f64
    }

    /// Host→device bind-phase wall time.
    pub fn upload_secs(&self) -> f64 {
        self.upload_nanos as f64 / 1e9
    }

    /// Device→host download-phase wall time.
    pub fn download_secs(&self) -> f64 {
        self.download_nanos as f64 / 1e9
    }

    /// Wall time of per-step binds the pipeline hid behind execute.
    pub fn overlap_secs(&self) -> f64 {
        self.overlap_nanos as f64 / 1e9
    }
}

// --------------------------------------------------------------- traits

/// One device-resident output value. Downloading consumes it — the
/// single device→host copy happens here (or never, if the caller
/// drops the handle without asking).
///
/// `Send` so [`OutputHandle`]s (and the plans that produce them) can
/// move between the dp engine's worker threads.
pub trait DeviceValue: Send {
    fn download(self: Box<Self>) -> Result<Tensor>;
}

/// Backend-owned input storage for one executable — the "device
/// buffers". Slot indices follow the artifact manifest input order.
///
/// `Send` so an [`ExecPlan`] (which owns its buffers exclusively) can
/// be driven from a dp worker thread; buffers are never *shared*
/// across threads, so `Sync` is not required.
pub trait DeviceBuffers: Send {
    /// Copy one host value into input slot `slot`.
    fn upload(&mut self, slot: usize, value: HostRef<'_>) -> Result<()>;

    /// Mark input slot `slot` as donated: `execute` may reclaim or
    /// alias its storage for an output. Advisory — the default no-op
    /// keeps copy semantics — but the slot is invalidated by the plan
    /// after every `run()` either way, so callers observe identical
    /// binding behaviour on every backend.
    fn donate(&mut self, _slot: usize) -> Result<()> {
        Ok(())
    }

    /// Execute over the uploaded inputs; device-resident outputs in
    /// manifest order.
    fn execute(&mut self) -> Result<Vec<Box<dyn DeviceValue>>>;

    /// Resident payload bytes currently held in input slot `slot` (0
    /// if unbound). Backends that cannot introspect their storage may
    /// keep the default; the reference backend reports exact sizes,
    /// which is what the quantization benches and `losia info` read.
    fn resident_bytes(&self, _slot: usize) -> usize {
        0
    }

    /// Drop any backend state the plan carries **between** `execute()`
    /// calls beyond the input slots themselves (e.g. the reference
    /// backend's decode KV cache). Default no-op: most artifacts are
    /// pure functions of their bindings.
    fn clear_state(&mut self) {}

    /// Allocate a detached staging set sized like these buffers, or
    /// `None` when the backend has no staged-upload support — the
    /// step pipeline is gated off for such backends, exactly like
    /// `dp::plan_count` gates worker replication.
    fn alloc_staging(&self) -> Option<Box<dyn StagedBuffers>> {
        None
    }

    /// Swap the listed `slots` from a filled staging set into the live
    /// buffers (O(1) per slot — pointer swaps, no copies) and hand the
    /// displaced storage back as the next staging set. Only called on
    /// staging sets this backend allocated via [`Self::alloc_staging`].
    fn commit_staged(
        &mut self,
        _staged: Box<dyn StagedBuffers>,
        _slots: &[usize],
    ) -> Result<Box<dyn StagedBuffers>> {
        anyhow::bail!(
            "backend does not support staged (double-buffered) uploads"
        )
    }
}

/// The idle half of a double-buffered plan: a detached, `Send` set of
/// per-step input slots that a pipeline worker fills while the live
/// buffers execute. [`ExecPlan::commit_stager`] swaps the filled slots
/// in and returns the displaced storage, so two sets ping-pong with
/// zero steady-state allocation.
pub trait StagedBuffers: Send {
    /// Copy one host value into staging slot `slot` (manifest index).
    fn upload(&mut self, slot: usize, value: HostRef<'_>) -> Result<()>;

    /// Concrete-type escape hatch so the owning backend can downcast
    /// its own staging set back at commit time.
    fn into_any(self: Box<Self>) -> Box<dyn std::any::Any>;
}

/// One compiled (PJRT) or interpreted (reference) artifact.
///
/// `Send + Sync` because [`Executable`]s are shared via `Arc` across
/// every plan replica — including replicas owned by different dp
/// worker threads — and only ever used through `&self`.
pub trait Executor: Send + Sync {
    fn alloc_buffers(&self) -> Box<dyn DeviceBuffers>;
}

/// A family of executors sharing one device/client.
pub trait Backend {
    fn name(&self) -> &'static str;

    /// Compile or otherwise prepare one artifact for execution.
    fn prepare(
        &self,
        cfg: &ModelCfg,
        spec: &ArtifactSpec,
    ) -> Result<Box<dyn Executor>>;
}

// ----------------------------------------------------------- executable

/// An artifact bound to its manifest signature, shareable via `Arc`
/// (droppable — no more `Box::leak` — and stats are atomic).
pub struct Executable {
    spec: ArtifactSpec,
    backend: &'static str,
    exec: Box<dyn Executor>,
    stats: ExecStats,
}

impl Executable {
    pub fn spec(&self) -> &ArtifactSpec {
        &self.spec
    }

    pub fn backend(&self) -> &'static str {
        self.backend
    }

    /// Cumulative counters. For per-stage isolation diff snapshots
    /// (`ExecSnapshot::delta_since`) instead of resetting — the
    /// trainer's exec tracker is continuously diffing these.
    pub fn stats(&self) -> ExecSnapshot {
        self.stats.snapshot()
    }

    /// One-shot execution with positional, shape/dtype-checked inputs
    /// in manifest order. Allocates fresh buffers per call and
    /// downloads every output eagerly — use an [`ExecPlan`] on hot
    /// paths, where [`OutputHandle`]s keep untouched outputs
    /// device-side.
    pub fn run(&self, inputs: &[HostValue]) -> Result<Vec<Tensor>> {
        anyhow::ensure!(
            inputs.len() == self.spec.inputs.len(),
            "artifact {:?}: {} inputs given, manifest wants {} ({})",
            self.spec.name,
            inputs.len(),
            self.spec.inputs.len(),
            self.spec.signature()
        );
        let mut bufs = self.exec.alloc_buffers();
        for (i, (hv, ispec)) in
            inputs.iter().zip(&self.spec.inputs).enumerate()
        {
            let r = HostRef::from(hv);
            r.check(ispec).with_context(|| {
                format!(
                    "artifact {:?} ({})",
                    self.spec.name,
                    self.spec.signature()
                )
            })?;
            let t0 = Instant::now();
            bufs.upload(i, r)?;
            self.stats.record_upload(
                BindingKind::PerStep,
                t0.elapsed().as_nanos() as u64,
            );
        }
        let t0 = Instant::now();
        let out = bufs.execute()?;
        self.stats.record_exec(t0.elapsed().as_nanos() as u64);
        self.check_output_count(out.len())?;
        out.into_iter()
            .enumerate()
            .map(|(i, v)| self.download_output(i, v))
            .collect()
    }

    fn check_output_count(&self, got: usize) -> Result<()> {
        anyhow::ensure!(
            got == self.spec.outputs.len(),
            "artifact {:?}: got {} outputs, manifest wants {}",
            self.spec.name,
            got,
            self.spec.outputs.len()
        );
        Ok(())
    }

    /// Materialise output `index` host-side, validating its manifest
    /// shape and recording the download split.
    fn download_output(
        &self,
        index: usize,
        value: Box<dyn DeviceValue>,
    ) -> Result<Tensor> {
        let ospec = &self.spec.outputs[index];
        let t0 = Instant::now();
        let t = value.download().with_context(|| {
            format!(
                "artifact {:?}: downloading output {:?}",
                self.spec.name, ospec.name
            )
        })?;
        let nanos = t0.elapsed().as_nanos() as u64;
        anyhow::ensure!(
            t.shape == ospec.shape,
            "artifact {:?}: output {:?} has shape {:?}, manifest \
             wants {:?}",
            self.spec.name,
            ospec.name,
            t.shape,
            ospec.shape
        );
        self.stats
            .record_download(t.data.len() as u64 * 4, nanos);
        Ok(t)
    }
}

// -------------------------------------------------------- output handle

/// A device-resident output of one [`ExecPlan::run`]. The tensor stays
/// backend-side until [`OutputHandle::host`] / [`OutputHandle::into_host`]
/// downloads it (once — later calls reuse the cached copy); dropping an
/// undownloaded handle moves zero bytes. `ExecStats`' download
/// counters record exactly the handles that crossed back, which is
/// what makes "the LoSiA-Pro hot path downloads only subnet-delta-sized
/// outputs" an assertable invariant rather than a hope.
pub struct OutputHandle {
    exe: Arc<Executable>,
    index: usize,
    value: Option<Box<dyn DeviceValue>>,
    host: Option<Tensor>,
}

impl OutputHandle {
    /// Manifest output name.
    pub fn name(&self) -> &str {
        &self.exe.spec().outputs[self.index].name
    }

    /// Manifest output shape (known without downloading).
    pub fn shape(&self) -> &[usize] {
        &self.exe.spec().outputs[self.index].shape
    }

    /// Size of the host copy this handle would download.
    pub fn byte_len(&self) -> u64 {
        self.shape().iter().product::<usize>() as u64 * 4
    }

    pub fn is_downloaded(&self) -> bool {
        self.host.is_some()
    }

    fn download(&mut self) -> Result<()> {
        if self.host.is_some() {
            return Ok(());
        }
        let value = self.value.take().ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {:?}: output {:?} was already consumed",
                self.exe.spec().name,
                self.exe.spec().outputs[self.index].name,
            )
        })?;
        let t = self.exe.download_output(self.index, value)?;
        self.host = Some(t);
        Ok(())
    }

    /// Borrow the host copy, downloading it on first access.
    pub fn host(&mut self) -> Result<&Tensor> {
        self.download()?;
        Ok(self.host.as_ref().expect("downloaded above"))
    }

    /// Take the host copy, downloading it if it never crossed yet.
    pub fn into_host(mut self) -> Result<Tensor> {
        self.download()?;
        Ok(self.host.take().expect("downloaded above"))
    }
}

// ------------------------------------------------------------ exec plan

/// A typed execution plan: named bindings against one executable's
/// manifest, with static inputs held device-side across steps.
pub struct ExecPlan {
    exe: Arc<Executable>,
    bufs: Box<dyn DeviceBuffers>,
    index: BTreeMap<String, usize>,
    kinds: Vec<BindingKind>,
    bound: Vec<bool>,
    donated: Vec<bool>,
}

impl ExecPlan {
    /// Build a plan, declaring which manifest inputs are static. Every
    /// name must exist in the manifest — ABI drift fails at plan-build
    /// time with the full signature, not mid-step.
    pub fn new(
        exe: Arc<Executable>,
        static_inputs: &[&str],
    ) -> Result<ExecPlan> {
        let spec = exe.spec();
        let index: BTreeMap<String, usize> = spec
            .inputs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.clone(), i))
            .collect();
        let mut kinds = vec![BindingKind::PerStep; spec.inputs.len()];
        for name in static_inputs {
            let i = *index.get(*name).ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact {:?}: static binding {:?} is not a \
                     manifest input ({})",
                    spec.name,
                    name,
                    spec.signature()
                )
            })?;
            kinds[i] = BindingKind::Static;
        }
        let bound = vec![false; spec.inputs.len()];
        let donated = vec![false; spec.inputs.len()];
        let bufs = exe.exec.alloc_buffers();
        Ok(ExecPlan {
            exe,
            bufs,
            index,
            kinds,
            bound,
            donated,
        })
    }

    pub fn executable(&self) -> &Arc<Executable> {
        &self.exe
    }

    pub fn spec(&self) -> &ArtifactSpec {
        self.exe.spec()
    }

    pub fn has_input(&self, name: &str) -> bool {
        self.index.contains_key(name)
    }

    pub fn is_static(&self, name: &str) -> bool {
        self.index
            .get(name)
            .map(|&i| self.kinds[i] == BindingKind::Static)
            .unwrap_or(false)
    }

    pub fn is_bound(&self, name: &str) -> bool {
        self.index
            .get(name)
            .map(|&i| self.bound[i])
            .unwrap_or(false)
    }

    pub fn is_donated(&self, name: &str) -> bool {
        self.index
            .get(name)
            .map(|&i| self.donated[i])
            .unwrap_or(false)
    }

    /// Donate a static input's buffer to the backend: every `run()`
    /// may reclaim or alias its storage into a same-shape output, and
    /// consumes the binding (the caller must re-bind before the next
    /// run — reclaimed storage is never silently re-read). The input
    /// must be a static f32 binding with at least one same-shape
    /// output to alias into; both are checked at donate time against
    /// the manifest, not mid-step.
    pub fn donate(&mut self, name: &str) -> Result<()> {
        let spec = self.exe.spec();
        let i = *self.index.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {:?}: no input named {:?} to donate ({})",
                spec.name,
                name,
                spec.signature()
            )
        })?;
        anyhow::ensure!(
            self.kinds[i] == BindingKind::Static,
            "artifact {:?}: input {:?} is per-step — only static \
             buffers can be donated ({})",
            spec.name,
            name,
            spec.signature()
        );
        let ispec = &spec.inputs[i];
        anyhow::ensure!(
            ispec.dtype == Dtype::F32
                && spec
                    .outputs
                    .iter()
                    .any(|o| o.shape == ispec.shape),
            "artifact {:?}: input {:?} ({:?} {:?}) matches no output \
             buffer to alias into ({})",
            spec.name,
            name,
            ispec.dtype,
            ispec.shape,
            spec.signature()
        );
        self.donated[i] = true;
        self.bufs.donate(i)
    }

    /// Drop any cross-step backend state this plan carries (a decode
    /// plan's KV cache). Bindings are untouched: statics stay bound,
    /// per-step slots still follow the consume-on-run contract.
    pub fn clear_state(&mut self) {
        self.bufs.clear_state();
    }

    /// Upload one named input. Static slots persist until re-bound;
    /// per-step slots are consumed by the next [`ExecPlan::run`].
    pub fn bind(&mut self, name: &str, value: HostRef<'_>) -> Result<()> {
        let spec = self.exe.spec();
        let i = *self.index.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {:?}: no input named {:?} ({})",
                spec.name,
                name,
                spec.signature()
            )
        })?;
        value.check(&spec.inputs[i]).with_context(|| {
            format!(
                "artifact {:?} ({})",
                spec.name,
                spec.signature()
            )
        })?;
        let t0 = Instant::now();
        self.bufs.upload(i, value)?;
        self.exe.stats.record_upload(
            self.kinds[i],
            t0.elapsed().as_nanos() as u64,
        );
        self.bound[i] = true;
        Ok(())
    }

    pub fn bind_f32(&mut self, name: &str, t: &Tensor) -> Result<()> {
        self.bind(name, HostRef::tensor(t))
    }

    /// Bind a block-quantized int8 value into a **static** slot (the
    /// `static_quantized` binding class). Per-step inputs change every
    /// call, so quantizing them would pay the encode cost for no
    /// resident-byte win — that's rejected here, loudly.
    pub fn bind_q8(&mut self, name: &str, q: &QTensor) -> Result<()> {
        anyhow::ensure!(
            self.is_static(name),
            "artifact {:?}: input {:?} is per-step — quantized \
             bindings are static-only ({})",
            self.exe.spec().name,
            name,
            self.exe.spec().signature()
        );
        self.bind(name, HostRef::quantized(q))
    }

    /// Bind one parameter under the session quantization policy: a
    /// static, quantizable binding is encoded to int8 when
    /// `LOSIA_QUANT=int8` (or [`quant::set_mode`]) is active;
    /// everything else stays dense f32.
    pub fn bind_param_auto(
        &mut self,
        name: &str,
        t: &Tensor,
    ) -> Result<()> {
        if self.wants_q8(name) {
            self.bind_q8(name, &QTensor::quantize(&t.shape, &t.data))
        } else {
            self.bind_f32(name, t)
        }
    }

    /// Does the current quantization policy store `name` as int8 in
    /// this plan? (Static + quantizable + mode is `Int8`.)
    pub fn wants_q8(&self, name: &str) -> bool {
        quant::mode() == quant::QuantMode::Int8
            && self.is_static(name)
            && quant::quantizable(name)
    }

    /// Resident payload bytes currently bound in `name`'s slot (0 if
    /// unknown or unbound).
    pub fn binding_bytes(&self, name: &str) -> usize {
        self.index
            .get(name)
            .map(|&i| self.bufs.resident_bytes(i))
            .unwrap_or(0)
    }

    /// Total resident payload bytes across the plan's **static**
    /// slots — the backbone memory footprint a quantized run shrinks.
    pub fn static_resident_bytes(&self) -> usize {
        self.kinds
            .iter()
            .enumerate()
            .filter(|(_, k)| **k == BindingKind::Static)
            .map(|(i, _)| self.bufs.resident_bytes(i))
            .sum()
    }

    pub fn bind_i32(
        &mut self,
        name: &str,
        shape: &[usize],
        data: &[i32],
    ) -> Result<()> {
        self.bind(name, HostRef::I32 { shape, data })
    }

    pub fn bind_scalar_i32(&mut self, name: &str, v: i32) -> Result<()> {
        let data = [v];
        self.bind(
            name,
            HostRef::I32 {
                shape: &[],
                data: &data,
            },
        )
    }

    /// Index-vector upload (ρ/γ selections) in ABI i32 form.
    pub fn bind_indices(
        &mut self,
        name: &str,
        shape: &[usize],
        idx: &[usize],
    ) -> Result<()> {
        let data: Vec<i32> = idx.iter().map(|&i| i as i32).collect();
        self.bind_i32(name, shape, &data)
    }

    /// Bind every model parameter the manifest declares, by name.
    /// Each goes through the quantization policy
    /// ([`ExecPlan::bind_param_auto`]): with `LOSIA_QUANT=int8`,
    /// static quantizable parameters land device-side as int8.
    pub fn bind_params(&mut self, state: &ModelState) -> Result<()> {
        for (name, t) in &state.params {
            if self.has_input(name) {
                self.bind_param_auto(name, t)?;
            }
        }
        Ok(())
    }

    /// Bind the batch inputs the manifest declares (`tokens`, and
    /// `targets`/`mask` where present — `fwd_logits` takes neither).
    pub fn bind_batch(&mut self, batch: &Batch) -> Result<()> {
        let shape = [batch.batch, batch.seq];
        self.bind_i32("tokens", &shape, &batch.tokens)?;
        if self.has_input("targets") {
            self.bind_i32("targets", &shape, &batch.targets)?;
        }
        if self.has_input("mask") {
            self.bind(
                "mask",
                HostRef::F32 {
                    shape: &shape,
                    data: &batch.mask,
                },
            )?;
        }
        Ok(())
    }

    /// Execute. Every input must be bound; per-step bindings (and
    /// donated statics, whose storage the backend may have reclaimed)
    /// are cleared afterwards so the next run demands fresh ones.
    /// Outputs come back as device-resident [`OutputHandle`]s — only
    /// what the caller downloads crosses to the host.
    pub fn run(&mut self) -> Result<Vec<OutputHandle>> {
        let spec = self.exe.spec();
        let unbound: Vec<&str> = spec
            .inputs
            .iter()
            .enumerate()
            .filter(|(i, _)| !self.bound[*i])
            .map(|(_, s)| s.name.as_str())
            .collect();
        anyhow::ensure!(
            unbound.is_empty(),
            "artifact {:?}: unbound inputs {:?} ({})",
            spec.name,
            unbound,
            spec.signature()
        );
        let t0 = Instant::now();
        let out = self.bufs.execute()?;
        self.exe
            .stats
            .record_exec(t0.elapsed().as_nanos() as u64);
        for (i, kind) in self.kinds.iter().enumerate() {
            if *kind == BindingKind::PerStep || self.donated[i] {
                self.bound[i] = false;
            }
        }
        self.exe.check_output_count(out.len())?;
        Ok(out
            .into_iter()
            .enumerate()
            .map(|(index, value)| OutputHandle {
                exe: Arc::clone(&self.exe),
                index,
                value: Some(value),
                host: None,
            })
            .collect())
    }

    /// Execute and download every output — the convenience path for
    /// callers that genuinely consume the full output set (full-grad
    /// drivers, the gradient-structure benches).
    pub fn run_host(&mut self) -> Result<Vec<Tensor>> {
        self.run()?
            .into_iter()
            .map(OutputHandle::into_host)
            .collect()
    }

    /// Build a [`Stager`] over the named **per-step** inputs: the idle
    /// half of a double buffer that a pipeline worker fills for step
    /// N+1 while this plan executes step N. Errors if any name is
    /// unknown or static (statics persist — staging them would be a
    /// correctness bug, not an optimisation), and if the backend has
    /// no staging support (the pipeline is ref-only, like dp workers).
    pub fn make_stager(&self, names: &[&str]) -> Result<Stager> {
        let spec = self.exe.spec();
        let mut slots = Vec::with_capacity(names.len());
        for name in names {
            let i = *self.index.get(*name).ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact {:?}: no input named {:?} to stage ({})",
                    spec.name,
                    name,
                    spec.signature()
                )
            })?;
            anyhow::ensure!(
                self.kinds[i] == BindingKind::PerStep,
                "artifact {:?}: input {:?} is static — only per-step \
                 bindings are prefetchable ({})",
                spec.name,
                name,
                spec.signature()
            );
            slots.push(i);
        }
        let inner = self.bufs.alloc_staging().ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {:?}: backend {:?} does not support staged \
                 uploads — run with the pipeline off",
                spec.name,
                self.exe.backend()
            )
        })?;
        Ok(Stager {
            exe: Arc::clone(&self.exe),
            inner,
            slots,
            staged: vec![false; names.len()],
            bytes: 0,
        })
    }

    /// Swap a filled [`Stager`]'s slots into this plan (O(1) pointer
    /// swaps — the copies already happened off-thread) and return the
    /// displaced storage as the next staging set. Only slots the
    /// stager actually staged are swapped and marked bound; the rest
    /// keep whatever the plan held.
    pub fn commit_stager(&mut self, mut s: Stager) -> Result<Stager> {
        anyhow::ensure!(
            Arc::ptr_eq(&s.exe, &self.exe),
            "stager for artifact {:?} committed into a plan for {:?}",
            s.exe.spec().name,
            self.exe.spec().name
        );
        let filled: Vec<usize> = s
            .slots
            .iter()
            .zip(&s.staged)
            .filter(|(_, staged)| **staged)
            .map(|(&i, _)| i)
            .collect();
        s.inner = self.bufs.commit_staged(s.inner, &filled)?;
        for &i in &filled {
            self.bound[i] = true;
        }
        for f in &mut s.staged {
            *f = false;
        }
        s.bytes = 0;
        Ok(s)
    }
}

// ---------------------------------------------------------------- stager

/// The detached half of a double-buffered [`ExecPlan`]: per-step input
/// slots a pipeline worker fills off the training thread while the
/// live buffers execute. Binds are validated against the manifest
/// exactly like [`ExecPlan::bind`], but their wall time is recorded as
/// *overlapped* ([`ExecSnapshot::overlap_secs`]) rather than exposed.
/// `Send` (no `Sync` needed — one worker owns it at a time).
pub struct Stager {
    exe: Arc<Executable>,
    inner: Box<dyn StagedBuffers>,
    /// manifest slot indices this stager may bind (all per-step)
    slots: Vec<usize>,
    /// parallel to `slots`: staged since the last commit?
    staged: Vec<bool>,
    /// payload bytes staged since the last commit
    bytes: u64,
}

impl Stager {
    /// Manifest names this stager covers, in slot order.
    pub fn names(&self) -> Vec<&str> {
        self.slots
            .iter()
            .map(|&i| self.exe.spec().inputs[i].name.as_str())
            .collect()
    }

    pub fn covers(&self, name: &str) -> bool {
        self.slots
            .iter()
            .any(|&i| self.exe.spec().inputs[i].name == name)
    }

    /// Payload bytes staged since the last commit.
    pub fn staged_bytes(&self) -> u64 {
        self.bytes
    }

    /// Stage one named input into the idle buffer set.
    pub fn bind(&mut self, name: &str, value: HostRef<'_>) -> Result<()> {
        let spec = self.exe.spec();
        let pos = self
            .slots
            .iter()
            .position(|&i| spec.inputs[i].name == name)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact {:?}: input {:?} is not covered by this \
                     stager (prefetchable: {:?})",
                    spec.name,
                    name,
                    self.names()
                )
            })?;
        let i = self.slots[pos];
        value.check(&spec.inputs[i]).with_context(|| {
            format!(
                "artifact {:?} ({})",
                spec.name,
                spec.signature()
            )
        })?;
        let t0 = Instant::now();
        self.inner.upload(i, value)?;
        self.exe
            .stats
            .record_staged_upload(t0.elapsed().as_nanos() as u64);
        self.staged[pos] = true;
        self.bytes += spec.inputs[i].numel() as u64 * 4;
        Ok(())
    }

    pub fn bind_f32(&mut self, name: &str, t: &Tensor) -> Result<()> {
        self.bind(name, HostRef::tensor(t))
    }

    pub fn bind_i32(
        &mut self,
        name: &str,
        shape: &[usize],
        data: &[i32],
    ) -> Result<()> {
        self.bind(name, HostRef::I32 { shape, data })
    }

    /// Stage the batch inputs this stager covers (`tokens`, plus
    /// `targets`/`mask` when the artifact takes them) — the staging
    /// mirror of [`ExecPlan::bind_batch`].
    pub fn bind_batch(&mut self, batch: &Batch) -> Result<()> {
        let shape = [batch.batch, batch.seq];
        self.bind_i32("tokens", &shape, &batch.tokens)?;
        if self.covers("targets") {
            self.bind_i32("targets", &shape, &batch.targets)?;
        }
        if self.covers("mask") {
            self.bind(
                "mask",
                HostRef::F32 {
                    shape: &shape,
                    data: &batch.mask,
                },
            )?;
        }
        Ok(())
    }
}

// -------------------------------------------------------------- runtime

/// Which backend `Runtime::from_config_name` should build, from the
/// `LOSIA_BACKEND` env var (`ref`, `pjrt`, or `auto`/unset).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendChoice {
    Auto,
    Reference,
    Pjrt,
}

pub fn backend_choice() -> BackendChoice {
    match std::env::var("LOSIA_BACKEND")
        .unwrap_or_default()
        .to_ascii_lowercase()
        .as_str()
    {
        "" | "auto" => BackendChoice::Auto,
        "ref" | "reference" => BackendChoice::Reference,
        "pjrt" | "xla" => BackendChoice::Pjrt,
        other => {
            eprintln!(
                "[runtime] unknown LOSIA_BACKEND={other:?} \
                 (expected ref|pjrt|auto); using auto"
            );
            BackendChoice::Auto
        }
    }
}

/// Backend handle + per-config compiled-executable cache.
pub struct Runtime {
    pub cfg: ModelCfg,
    backend: Box<dyn Backend>,
    cache: Mutex<BTreeMap<String, Arc<Executable>>>,
}

impl Runtime {
    /// PJRT runtime over an already-loaded config (back-compat entry).
    pub fn new(cfg: ModelCfg) -> Result<Self> {
        Ok(Self::with_backend(
            cfg,
            Box::new(crate::runtime::PjrtBackend::new()?),
        ))
    }

    pub fn with_backend(
        cfg: ModelCfg,
        backend: Box<dyn Backend>,
    ) -> Self {
        Runtime {
            cfg,
            backend,
            cache: Mutex::new(BTreeMap::new()),
        }
    }

    /// Load from the default artifacts directory, honouring
    /// `LOSIA_BACKEND`. In `auto` mode the PJRT/XLA path is used when
    /// lowered artifacts exist and the pure-Rust reference backend
    /// (with built-in config shapes) otherwise, so tests and CI run
    /// without `make artifacts`.
    pub fn from_config_name(name: &str) -> Result<Self> {
        let dir = crate::runtime::artifacts_dir();
        Self::from_config_dir(&dir, name)
    }

    pub fn from_config_dir(dir: &Path, name: &str) -> Result<Self> {
        match backend_choice() {
            BackendChoice::Reference => {
                let cfg = crate::config::resolve_config(dir, name)?;
                Ok(Self::with_backend(
                    cfg,
                    Box::new(crate::runtime::RefBackend),
                ))
            }
            BackendChoice::Pjrt => {
                let cfg = crate::config::load_manifest(dir, name)?;
                Self::new(cfg)
            }
            BackendChoice::Auto => {
                if dir.join("manifest.json").exists() {
                    let cfg = crate::config::load_manifest(dir, name)?;
                    Self::new(cfg)
                } else {
                    eprintln!(
                        "[runtime] no artifact manifest under {}; \
                         using the pure-Rust reference backend \
                         (run `make artifacts` + LOSIA_BACKEND=pjrt \
                         for the XLA path)",
                        dir.display()
                    );
                    let cfg =
                        crate::config::builtin_config(name, dir)?;
                    Ok(Self::with_backend(
                        cfg,
                        Box::new(crate::runtime::RefBackend),
                    ))
                }
            }
        }
    }

    /// Prepare (or fetch from cache) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Arc<Executable>> {
        let mut cache = self.cache.lock().unwrap();
        if let Some(e) = cache.get(name) {
            return Ok(Arc::clone(e));
        }
        let spec = self.cfg.try_artifact(name)?.clone();
        let exec = self.backend.prepare(&self.cfg, &spec)?;
        let exe = Arc::new(Executable {
            spec,
            backend: self.backend.name(),
            exec,
            stats: ExecStats::default(),
        });
        cache.insert(name.to_string(), Arc::clone(&exe));
        Ok(exe)
    }

    /// Active backend's name (`"ref"` / `"pjrt"`) — the dp engine
    /// gates parallel plan replication on this, and the step pipeline
    /// gates staged uploads the same way.
    pub fn backend_name(&self) -> &'static str {
        self.backend.name()
    }

    /// Cumulative exec stats for every artifact touched so far.
    pub fn exec_snapshots(&self) -> Vec<(String, ExecSnapshot)> {
        self.cache
            .lock()
            .unwrap()
            .iter()
            .map(|(k, e)| (k.clone(), e.stats.snapshot()))
            .collect()
    }
}

// ---------------------------------------------------------------- tests

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::RefBackend;
    use crate::util::rng::Rng;

    fn ref_runtime() -> Runtime {
        let dir = crate::runtime::artifacts_dir();
        let cfg = crate::config::resolve_config(&dir, "tiny")
            .expect("tiny config");
        Runtime::with_backend(cfg, Box::new(RefBackend))
    }

    fn bind_all(
        plan: &mut ExecPlan,
        state: &ModelState,
        batch: &Batch,
    ) {
        plan.bind_params(state).unwrap();
        plan.bind_batch(batch).unwrap();
    }

    fn tiny_batch(rt: &Runtime) -> Batch {
        let (b, s) = (rt.cfg.batch, rt.cfg.seq_len);
        Batch {
            tokens: (0..b * s).map(|i| (i % 7) as i32).collect(),
            targets: (0..b * s).map(|i| (i % 5) as i32).collect(),
            mask: vec![1.0; b * s],
            batch: b,
            seq: s,
        }
    }

    #[test]
    fn unknown_static_name_fails_with_signature() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let err = ExecPlan::new(exe, &["not-an-input"]).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("not-an-input"), "{msg}");
        assert!(msg.contains("tokens"), "{msg}");
    }

    #[test]
    fn run_requires_every_binding_and_lists_missing() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let mut plan = ExecPlan::new(exe, &[]).unwrap();
        let mut rng = Rng::new(0);
        let state = ModelState::init(&rt.cfg, &mut rng);
        plan.bind_params(&state).unwrap();
        let err = plan.run().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("unbound"), "{msg}");
        assert!(msg.contains("tokens"), "{msg}");
    }

    #[test]
    fn per_step_bindings_are_consumed_by_run() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let param_names: Vec<&str> = rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut plan = ExecPlan::new(exe, &param_names).unwrap();
        let mut rng = Rng::new(1);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let batch = tiny_batch(&rt);
        bind_all(&mut plan, &state, &batch);
        plan.run().unwrap();
        // statics persist, the batch does not
        assert!(plan.is_bound("embed"));
        assert!(!plan.is_bound("tokens"));
        let err = plan.run().unwrap_err();
        assert!(format!("{err:#}").contains("tokens"));
        plan.bind_batch(&batch).unwrap();
        plan.run().unwrap();
    }

    #[test]
    fn stale_static_binding_keeps_old_value_until_rebound() {
        // The invalidation contract: mutating host state does NOT
        // reach the device until the caller re-binds. A driver that
        // forgot to re-bind would silently train on old weights —
        // this test pins the semantics the drivers build on.
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let param_names: Vec<&str> = rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut plan =
            ExecPlan::new(Arc::clone(&exe), &param_names).unwrap();
        let mut rng = Rng::new(2);
        let mut state = ModelState::init(&rt.cfg, &mut rng);
        let batch = tiny_batch(&rt);
        bind_all(&mut plan, &state, &batch);
        let before = plan.run_host().unwrap();

        // mutate the host lm_head; device copy must be unaffected
        state.get_mut("lm_head").scale_assign(0.0);
        plan.bind_batch(&batch).unwrap();
        let stale = plan.run_host().unwrap();
        assert_eq!(before[0].data, stale[0].data, "static was re-read");

        let s0 = exe.stats();
        plan.bind_f32("lm_head", state.get("lm_head")).unwrap();
        let d = exe.stats().delta_since(&s0);
        assert_eq!(d.static_uploads, 1);
        assert_eq!(d.step_uploads, 0);
        plan.bind_batch(&batch).unwrap();
        let fresh = plan.run_host().unwrap();
        assert_ne!(
            before[0].data, fresh[0].data,
            "re-bound static had no effect"
        );
    }

    #[test]
    fn upload_counters_split_static_and_per_step() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let mut plan =
            ExecPlan::new(Arc::clone(&exe), &["embed"]).unwrap();
        let mut rng = Rng::new(3);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let batch = tiny_batch(&rt);
        let s0 = exe.stats();
        bind_all(&mut plan, &state, &batch);
        plan.run().unwrap();
        let d = exe.stats().delta_since(&s0);
        assert_eq!(d.calls, 1);
        assert_eq!(d.static_uploads, 1, "embed only");
        // 11 remaining params + tokens/targets/mask
        assert_eq!(d.step_uploads, 14, "{d:?}");

        // steady state: rebind only the per-step inputs — zero static
        // traffic
        let s1 = exe.stats();
        for (n, t) in &state.params {
            if n != "embed" {
                plan.bind_f32(n, t).unwrap();
            }
        }
        plan.bind_batch(&batch).unwrap();
        plan.run().unwrap();
        let d = exe.stats().delta_since(&s1);
        assert_eq!(d.static_uploads, 0);
        assert_eq!(d.step_uploads, 14);
    }

    #[test]
    fn staged_batch_commit_matches_direct_bind_bitwise() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let param_names: Vec<&str> = rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut direct =
            ExecPlan::new(Arc::clone(&exe), &param_names).unwrap();
        let mut staged =
            ExecPlan::new(Arc::clone(&exe), &param_names).unwrap();
        let mut rng = Rng::new(7);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let batch = tiny_batch(&rt);
        direct.bind_params(&state).unwrap();
        staged.bind_params(&state).unwrap();

        direct.bind_batch(&batch).unwrap();
        let want = direct.run_host().unwrap();

        let mut stager = staged
            .make_stager(&["tokens", "targets", "mask"])
            .unwrap();
        let s0 = exe.stats();
        stager.bind_batch(&batch).unwrap();
        let d = exe.stats().delta_since(&s0);
        assert_eq!(d.step_uploads, 3, "staged binds are step uploads");
        assert_eq!(
            d.upload_nanos, 0,
            "staged binds must not count as exposed upload time"
        );
        assert!(stager.staged_bytes() > 0);

        let mut stager = staged.commit_stager(stager).unwrap();
        let got = staged.run_host().unwrap();
        for (w, g) in want.iter().zip(&got) {
            let wb: Vec<u32> =
                w.data.iter().map(|v| v.to_bits()).collect();
            let gb: Vec<u32> =
                g.data.iter().map(|v| v.to_bits()).collect();
            assert_eq!(wb, gb, "staged run diverged bitwise");
        }

        // ping-pong: the displaced set comes back empty and is
        // immediately reusable for the next step's staging
        assert_eq!(stager.staged_bytes(), 0);
        stager.bind_batch(&batch).unwrap();
        staged.commit_stager(stager).unwrap();
        direct.bind_batch(&batch).unwrap();
        let want2 = direct.run_host().unwrap();
        let got2 = staged.run_host().unwrap();
        let wb: Vec<u32> =
            want2[0].data.iter().map(|v| v.to_bits()).collect();
        let gb: Vec<u32> =
            got2[0].data.iter().map(|v| v.to_bits()).collect();
        assert_eq!(wb, gb, "second staged step diverged bitwise");
    }

    #[test]
    fn stager_rejects_static_unknown_and_uncovered_inputs() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let plan =
            ExecPlan::new(Arc::clone(&exe), &["embed"]).unwrap();
        let err = plan.make_stager(&["embed"]).unwrap_err();
        assert!(format!("{err:#}").contains("static"));
        let err = plan.make_stager(&["nope"]).unwrap_err();
        assert!(format!("{err:#}").contains("nope"));

        let mut stager = plan.make_stager(&["tokens"]).unwrap();
        let batch = tiny_batch(&rt);
        let shape = [batch.batch, batch.seq];
        let err = stager
            .bind_i32("targets", &shape, &batch.targets)
            .unwrap_err();
        assert!(format!("{err:#}").contains("not covered"));
    }

    #[test]
    fn commit_swaps_only_staged_slots_and_checks_the_executable() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let param_names: Vec<&str> = rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut plan =
            ExecPlan::new(Arc::clone(&exe), &param_names).unwrap();
        let mut rng = Rng::new(8);
        let state = ModelState::init(&rt.cfg, &mut rng);
        plan.bind_params(&state).unwrap();
        let batch = tiny_batch(&rt);
        let mut stager = plan
            .make_stager(&["tokens", "targets", "mask"])
            .unwrap();
        let shape = [batch.batch, batch.seq];
        stager.bind_i32("tokens", &shape, &batch.tokens).unwrap();
        plan.commit_stager(stager).unwrap();
        assert!(plan.is_bound("tokens"));
        assert!(!plan.is_bound("targets"), "unstaged slot got bound");
        let err = plan.run().unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("targets"), "{msg}");
        assert!(msg.contains("mask"), "{msg}");

        // a stager belongs to its executable — cross-plan commits of
        // a different artifact's stager are rejected loudly
        let other = rt.load("grads_full").unwrap();
        let other_plan = ExecPlan::new(other, &[]).unwrap();
        let foreign =
            other_plan.make_stager(&["tokens"]).unwrap();
        let err = plan.commit_stager(foreign).unwrap_err();
        assert!(format!("{err:#}").contains("grads_full"));
    }

    #[test]
    fn shape_mismatch_names_artifact_and_signature() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let mut plan = ExecPlan::new(exe, &[]).unwrap();
        let bad = Tensor::zeros(&[3, 3]);
        let err = plan.bind_f32("embed", &bad).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("fwd_loss"), "{msg}");
        assert!(msg.contains("shape"), "{msg}");
        assert!(msg.contains("inputs:"), "{msg}");
    }

    #[test]
    fn undownloaded_outputs_move_zero_bytes() {
        // The download-on-demand contract: run() itself records no
        // download traffic; each handle pays exactly once on first
        // host access, cached afterwards.
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let mut plan = ExecPlan::new(Arc::clone(&exe), &[]).unwrap();
        let mut rng = Rng::new(5);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let batch = tiny_batch(&rt);
        bind_all(&mut plan, &state, &batch);

        let s0 = exe.stats();
        let mut out = plan.run().unwrap();
        let d = exe.stats().delta_since(&s0);
        assert_eq!(d.calls, 1);
        assert_eq!(d.downloads, 0, "run() downloaded eagerly");
        assert_eq!(d.download_bytes, 0);

        // fwd_loss outputs: nll [B], cnt [B] — download only nll
        assert_eq!(out[0].name(), "nll");
        assert!(!out[0].is_downloaded());
        let nll_bytes = out[0].byte_len();
        out[0].host().unwrap();
        out[0].host().unwrap(); // cached: no second download
        let d = exe.stats().delta_since(&s0);
        assert_eq!(d.downloads, 1);
        assert_eq!(d.download_bytes, nll_bytes);

        // dropping the never-touched cnt handle moves nothing
        drop(out);
        let d = exe.stats().delta_since(&s0);
        assert_eq!(d.downloads, 1);
    }

    #[test]
    fn one_shot_run_downloads_everything() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let mut rng = Rng::new(6);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let batch = tiny_batch(&rt);
        let inputs: Vec<HostValue> = exe
            .spec()
            .inputs
            .iter()
            .map(|i| match i.name.as_str() {
                "tokens" => HostValue::I32 {
                    shape: i.shape.clone(),
                    data: batch.tokens.clone(),
                },
                "targets" => HostValue::I32 {
                    shape: i.shape.clone(),
                    data: batch.targets.clone(),
                },
                "mask" => HostValue::F32(Tensor::from_vec(
                    &i.shape,
                    batch.mask.clone(),
                )),
                name => {
                    HostValue::F32(state.get(name).clone())
                }
            })
            .collect();
        let s0 = exe.stats();
        let out = exe.run(&inputs).unwrap();
        let d = exe.stats().delta_since(&s0);
        assert_eq!(d.downloads, out.len() as u64);
        let bytes: u64 =
            out.iter().map(|t| t.data.len() as u64 * 4).sum();
        assert_eq!(d.download_bytes, bytes);
    }

    #[test]
    fn donation_rejects_per_step_unknown_and_unaliasable_inputs() {
        let rt = ref_runtime();
        let exe = rt.load("grads_full").unwrap();
        let mut plan =
            ExecPlan::new(Arc::clone(&exe), &["embed"]).unwrap();

        let err = plan.donate("nope").unwrap_err();
        assert!(format!("{err:#}").contains("nope"));

        // tokens is per-step (and i32 — no output to alias into)
        let err = plan.donate("tokens").unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("per-step") || msg.contains("static"), "{msg}");

        // lm_head was not declared static on this plan
        let err = plan.donate("lm_head").unwrap_err();
        assert!(format!("{err:#}").contains("static"));

        // embed is static and grads_full emits g_embed of equal shape
        plan.donate("embed").unwrap();
        assert!(plan.is_donated("embed"));
        assert!(!plan.is_donated("lm_head"));
    }

    #[test]
    fn donated_static_is_consumed_by_run() {
        let rt = ref_runtime();
        let exe = rt.load("grads_full").unwrap();
        let param_names: Vec<&str> = rt
            .cfg
            .params
            .iter()
            .map(|(n, _)| n.as_str())
            .collect();
        let mut plan =
            ExecPlan::new(Arc::clone(&exe), &param_names).unwrap();
        plan.donate("embed").unwrap();
        let mut rng = Rng::new(7);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let batch = tiny_batch(&rt);
        bind_all(&mut plan, &state, &batch);
        plan.run().unwrap();
        assert!(
            !plan.is_bound("embed"),
            "donated static survived run()"
        );
        plan.bind_batch(&batch).unwrap();
        let err = plan.run().unwrap_err();
        assert!(
            format!("{err:#}").contains("embed"),
            "stale donated slot did not error by name"
        );
        // re-binding re-arms the donation for the next run
        plan.bind_f32("embed", state.get("embed")).unwrap();
        plan.bind_batch(&batch).unwrap();
        plan.run().unwrap();
        assert!(!plan.is_bound("embed"));
    }

    #[test]
    fn bind_q8_rejects_per_step_slots() {
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let mut plan = ExecPlan::new(exe, &[]).unwrap();
        let mut rng = Rng::new(8);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let embed = state.get("embed");
        let q = QTensor::quantize(&embed.shape, &embed.data);
        let err = plan.bind_q8("embed", &q).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("static-only"), "{msg}");
    }

    #[test]
    fn quantized_static_matches_dequantized_dense_bitwise() {
        // The kernel contract: running with a q8-bound static is
        // bitwise identical to running dense on its dequantization
        // (the fused kernels dequantize with the same expression).
        // Also pins the resident-byte accounting both ways.
        let rt = ref_runtime();
        let exe = rt.load("fwd_loss").unwrap();
        let mut rng = Rng::new(9);
        let state = ModelState::init(&rt.cfg, &mut rng);
        let batch = tiny_batch(&rt);
        let embed = state.get("embed");
        let q = QTensor::quantize(&embed.shape, &embed.data);

        let mut qplan =
            ExecPlan::new(Arc::clone(&exe), &["embed"]).unwrap();
        qplan.bind_q8("embed", &q).unwrap();
        assert_eq!(qplan.binding_bytes("embed"), q.byte_len());
        assert_eq!(qplan.static_resident_bytes(), q.byte_len());
        for (n, t) in &state.params {
            if n != "embed" {
                qplan.bind_f32(n, t).unwrap();
            }
        }
        qplan.bind_batch(&batch).unwrap();
        let q_out = qplan.run_host().unwrap();

        let mut dplan =
            ExecPlan::new(Arc::clone(&exe), &["embed"]).unwrap();
        let dq =
            Tensor::from_vec(&embed.shape, q.dequantize());
        dplan.bind_f32("embed", &dq).unwrap();
        assert_eq!(
            dplan.binding_bytes("embed"),
            dq.data.len() * 4,
            "dense resident bytes"
        );
        for (n, t) in &state.params {
            if n != "embed" {
                dplan.bind_f32(n, t).unwrap();
            }
        }
        dplan.bind_batch(&batch).unwrap();
        let d_out = dplan.run_host().unwrap();

        for (a, b) in q_out.iter().zip(&d_out) {
            let ab: Vec<u32> =
                a.data.iter().map(|x| x.to_bits()).collect();
            let bb: Vec<u32> =
                b.data.iter().map(|x| x.to_bits()).collect();
            assert_eq!(ab, bb, "q8 static diverged from dequant");
        }
        assert!(
            q.byte_len() * 3 < embed.data.len() * 4,
            "quantized embed should be well under 1/3 of f32"
        );
    }
}
