//! The software-pipelined step loop: double-buffered per-step uploads
//! plus bounded batch prefetch, bitwise identical to the synchronous
//! loop.
//!
//! ## What overlaps, and why only that
//!
//! A step is `upload → execute → download → apply`. Which uploads can
//! legally run early is a data-dependency fact, not a tuning choice:
//!
//! * The **batch grid** (`tokens`/`targets`/`mask`) for step N+1
//!   depends on nothing produced by step N — prefetchable.
//! * The LoSiA-Pro `dws_*` frames, adapter tensors, the probe index,
//!   and every download are produced or consumed by `apply_frames(N)`
//!   — step-dependent, so they stay on the critical path and their
//!   wall time stays *exposed* in `ExecStats`.
//!
//! Drivers declare the split via `Driver::prefetchable`; today that is
//! exactly the batch grid for every method.
//!
//! ## Buffer ownership and handoff
//!
//! Two worker threads feed the training thread:
//!
//! 1. the **pack worker** ([`BatchPrefetcher`]) owns the intact
//!    `Batcher` state machines and packs step groups into a
//!    depth-bounded queue;
//! 2. the **stage worker** ([`StepPipeline`]) receives an idle staging
//!    set (one [`Stager`] per plan replica) from the free queue,
//!    copies the next group's batches into it off-thread, and sends
//!    the filled set to the training thread.
//!
//! The training thread commits each filled stager
//! ([`crate::runtime::ExecPlan::commit_stager`] — O(1) pointer swaps),
//! recycles the displaced storage back to the free queue, and runs the
//! step. A set is owned by exactly one thread at every instant; the
//! channels are the handoff points, so there is no shared mutable
//! buffer anywhere.
//!
//! ## Determinism argument
//!
//! The pipeline moves *copies*, never *arithmetic*: batch packing
//! draws from the same `Batcher` state machines in the same order
//! (pinned by `data::batcher` tests), staged uploads place the same
//! bytes in the same slots the inline `bind_batch` would, and every
//! kernel still runs on the training thread (or its dp workers) in
//! the same sequence. Thread budgets change wall-clock only — the
//! kernel layer is bitwise thread-count-invariant. Hence pipelined
//! and synchronous runs are bitwise identical, pinned end-to-end by
//! `tests/pipeline_parity.rs`.
//!
//! ## Interaction with dp and donation
//!
//! The pipeline composes with `dp::run_sharded` under one constraint:
//! `shards == workers`. A plan that executes several shards per step
//! re-binds its per-step slots *between* runs inside the gradient
//! phase, so only one shard per plan can be staged ahead; staging
//! block prefixes for W < S is a possible follow-up. Donation is
//! unaffected: donated slots are static, stagers cover per-step slots
//! only, and the swap preserves the live set's donated storage.
//! Like dp worker replication (and Q8 binds), staged uploads are
//! gated to the reference backend.

use std::sync::mpsc;
use std::time::Instant;

use anyhow::{ensure, Result};

use crate::config::TrainConfig;
use crate::data::{Batch, BatchPrefetcher};
use crate::runtime::backend::{Runtime, Stager};
use crate::runtime::dp::DpConfig;
use crate::runtime::kernels;
use crate::util::error::TrainError;
use crate::util::faultpoint;

/// Resolved pipeline configuration for one run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PipelineConfig {
    pub enabled: bool,
    /// Step groups the pack/stage workers may run ahead of the
    /// training thread (≥ 1).
    pub queue_depth: usize,
}

impl PipelineConfig {
    /// Resolve from the train config with env fallbacks: an explicit
    /// `TrainConfig::pipeline` (the `--pipeline` / builder knob) wins,
    /// else `LOSIA_PIPELINE` (`on`/`1`/`true` to enable), else off.
    /// Queue depth comes from `LOSIA_PIPELINE_DEPTH` (default 2 — one
    /// set staging while one is live is already full overlap; deeper
    /// queues only buy slack against jitter).
    pub fn resolve(tc: &TrainConfig) -> PipelineConfig {
        let enabled = match tc.pipeline {
            Some(on) => on,
            None => match std::env::var("LOSIA_PIPELINE")
                .unwrap_or_default()
                .to_ascii_lowercase()
                .as_str()
            {
                "1" | "on" | "true" | "yes" => true,
                "" | "0" | "off" | "false" | "no" => false,
                other => {
                    crate::util::warn::warn(format!(
                        "unknown LOSIA_PIPELINE={other:?} (expected \
                         on|off); pipeline stays off"
                    ));
                    false
                }
            },
        };
        let queue_depth = std::env::var("LOSIA_PIPELINE_DEPTH")
            .ok()
            .and_then(|s| s.parse::<usize>().ok())
            .filter(|&n| n >= 1)
            .unwrap_or(2);
        PipelineConfig {
            enabled,
            queue_depth,
        }
    }

    /// Check this config against the runtime and dp layout — the
    /// pipeline's analogue of [`crate::runtime::dp::plan_count`]'s
    /// backend gate. No-op when disabled.
    pub fn validate(&self, rt: &Runtime, dp: &DpConfig) -> Result<()> {
        if !self.enabled {
            return Ok(());
        }
        ensure!(
            rt.backend_name() == "ref",
            "pipeline: staged uploads require the reference backend \
             (LOSIA_BACKEND=ref); backend `{}` has no double-buffer \
             support. Run with --pipeline off.",
            rt.backend_name()
        );
        ensure!(
            dp.shards == dp.workers,
            "pipeline: shards ({}) must equal workers ({}) — a plan \
             executing several shards per step re-binds its per-step \
             slots between runs, so only one shard per plan can be \
             staged ahead. Use --workers {} or --pipeline off.",
            dp.shards,
            dp.workers,
            dp.shards
        );
        Ok(())
    }

    /// Worker threads the pipeline adds: the pack worker and the
    /// stage worker. 0 when disabled.
    pub fn prefetch_threads(&self) -> usize {
        if self.enabled {
            2
        } else {
            0
        }
    }

    /// Kernel threads left to the training loop once the pipeline
    /// workers took their share of the process-wide budget (floored
    /// at 1) — the same budget-is-spent-once rule dp workers follow.
    pub fn main_thread_budget(&self) -> usize {
        kernels::kernel_threads()
            .saturating_sub(self.prefetch_threads())
            .max(1)
    }
}

/// One staged step group crossing from the stage worker: the packed
/// batches (shard order), the filled stagers (plan order, 1:1 with
/// batches), and the staged payload bytes.
type FullMsg = Result<(Vec<Batch>, Vec<Stager>, u64)>;

/// The training thread's handle on the two pipeline workers. See the
/// module docs for the ownership/handoff contract.
pub struct StepPipeline {
    full_rx: Option<mpsc::Receiver<FullMsg>>,
    free_tx: Option<mpsc::Sender<Vec<Stager>>>,
    worker: Option<std::thread::JoinHandle<()>>,
    last_stall_nanos: u64,
    queue_depth: usize,
}

impl StepPipeline {
    /// Start the stage worker over a running [`BatchPrefetcher`] and
    /// `queue_depth` idle staging sets (each one [`Stager`] per plan
    /// replica, from `Driver::make_stagers`).
    pub fn new(
        prefetch: BatchPrefetcher,
        sets: Vec<Vec<Stager>>,
    ) -> Result<StepPipeline> {
        ensure!(!sets.is_empty(), "pipeline: need ≥ 1 staging set");
        let shards = sets[0].len();
        ensure!(shards >= 1, "pipeline: empty staging set");
        for s in &sets {
            ensure!(
                s.len() == shards,
                "pipeline: ragged staging sets ({} vs {shards})",
                s.len()
            );
        }
        let depth = sets.len();
        let (free_tx, free_rx) = mpsc::channel::<Vec<Stager>>();
        let (full_tx, full_rx) = mpsc::sync_channel::<FullMsg>(depth);
        for set in sets {
            free_tx.send(set).expect("free queue open at startup");
        }
        let worker = std::thread::Builder::new()
            .name("losia-stage".into())
            .spawn(move || {
                let mut prefetch = prefetch;
                // staging is memcpy, not compute, but the worker still
                // pins a 1-thread kernel budget so nothing reached
                // from here could ever oversubscribe the process
                kernels::with_thread_budget(1, || {
                    stage_loop(&mut prefetch, &free_rx, &full_tx)
                });
            })?;
        Ok(StepPipeline {
            full_rx: Some(full_rx),
            free_tx: Some(free_tx),
            worker: Some(worker),
            last_stall_nanos: 0,
            queue_depth: depth,
        })
    }

    pub fn queue_depth(&self) -> usize {
        self.queue_depth
    }

    /// The next step's staged group. Blocks when the workers fell
    /// behind; that blocked time is the step's exposed stall
    /// ([`Self::last_stall_nanos`]).
    ///
    /// When the stage worker died, the thread is joined here and a
    /// panic is surfaced as [`TrainError::WorkerPanic`] — typed, with
    /// no leaked thread, rather than a hang or an opaque recv error.
    pub fn next(&mut self) -> Result<(Vec<Batch>, Vec<Stager>, u64)> {
        let rx = self
            .full_rx
            .as_ref()
            .expect("full queue lives until drop");
        let t0 = Instant::now();
        let msg = match rx.recv() {
            Ok(m) => m,
            Err(_) => {
                // the worker is gone; join it to learn whether it
                // panicked or exited after sending its own error
                let panicked = self
                    .worker
                    .take()
                    .map(|h| h.join().is_err())
                    .unwrap_or(false);
                if panicked {
                    return Err(TrainError::WorkerPanic {
                        site: "stage-worker".into(),
                    }
                    .into());
                }
                return Err(anyhow::anyhow!(
                    "pipeline: stage worker exited without a result"
                ));
            }
        };
        self.last_stall_nanos = t0.elapsed().as_nanos() as u64;
        msg
    }

    /// Wall time [`Self::next`] last spent blocked on the queue.
    pub fn last_stall_nanos(&self) -> u64 {
        self.last_stall_nanos
    }

    /// Hand a displaced staging set back for re-staging (the
    /// ping-pong return edge).
    pub fn recycle(&mut self, set: Vec<Stager>) {
        if let Some(tx) = &self.free_tx {
            // a send error means the worker already exited; the next
            // `next()` call surfaces its error
            let _ = tx.send(set);
        }
    }
}

impl Drop for StepPipeline {
    fn drop(&mut self) {
        // close both queues first: a worker blocked on the free queue
        // sees recv fail, one blocked on a full queue sees send fail —
        // either way it exits and the join cannot deadlock
        self.free_tx.take();
        self.full_rx.take();
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

fn stage_loop(
    prefetch: &mut BatchPrefetcher,
    free_rx: &mpsc::Receiver<Vec<Stager>>,
    full_tx: &mpsc::SyncSender<FullMsg>,
) {
    // 0-based index of the group being staged, counted from this
    // run's first step — the step the `stage-worker` fault site arms
    // against (a resumed run counts from its resume point)
    let mut group_idx = 0usize;
    while prefetch.remaining() > 0 {
        if let Err(e) = faultpoint::hit("stage-worker", group_idx) {
            let _ = full_tx.send(Err(e));
            return;
        }
        group_idx += 1;
        // take the group first: the pack worker keeps packing ahead
        // even while every staging set is in flight
        let group = match prefetch.next_group() {
            Ok(g) => g,
            Err(e) => {
                let _ = full_tx.send(Err(e));
                return;
            }
        };
        let Ok(mut set) = free_rx.recv() else {
            return; // training thread dropped the pipeline
        };
        if set.len() != group.len() {
            let _ = full_tx.send(Err(anyhow::anyhow!(
                "pipeline: {} stagers for {} shard batches",
                set.len(),
                group.len()
            )));
            return;
        }
        let mut bind_err = None;
        for (stager, batch) in set.iter_mut().zip(&group) {
            if let Err(e) = stager.bind_batch(batch) {
                bind_err = Some(e);
                break;
            }
        }
        if let Some(e) = bind_err {
            let _ = full_tx.send(Err(e));
            return;
        }
        let bytes = set.iter().map(Stager::staged_bytes).sum();
        if full_tx.send(Ok((group, set, bytes))).is_err() {
            return;
        }
    }
}
