//! Backend-abstracted runtime: load artifacts and execute them through
//! a pluggable [`Backend`].
//!
//! Two backends ship in-tree (see `src/runtime/README.md` for the
//! architecture notes):
//!
//! * [`PjrtBackend`] — the PJRT/XLA path over AOT-compiled HLO-text
//!   artifacts (see `python/compile/aot.py`);
//!   `xla::HloModuleProto::from_text_file` reassigns instruction ids
//!   so jax ≥ 0.5 modules round-trip into xla_extension 0.5.1 cleanly.
//! * [`RefBackend`] — a pure-Rust interpreter over the dense tensor
//!   ops, used by tests/CI (no lowered artifacts required) and as the
//!   automatic fallback when no manifest is present.
//!
//! Selection: `LOSIA_BACKEND=ref|pjrt|auto` (default `auto`).
//!
//! The reference interpreter's matrix multiplies live in [`kernels`]:
//! cache-blocked, row-parallel (`LOSIA_KERNEL_THREADS`), and bitwise
//! deterministic regardless of thread count.

pub mod backend;
pub mod dp;
pub mod host;
pub mod kernels;
pub mod pipeline;
pub mod pjrt;
pub mod quant;
pub mod reference;

pub use backend::{
    backend_choice, Backend, BackendChoice, BindingKind, DeviceBuffers,
    DeviceValue, ExecPlan, ExecSnapshot, ExecStats, Executable,
    Executor, HostRef, OutputHandle, Runtime, StagedBuffers, Stager,
};
pub use dp::{DpConfig, Frame, GradFrames, ProbePayload, ShardedGrads};
pub use pipeline::{PipelineConfig, StepPipeline};
pub use host::HostValue;
pub use pjrt::PjrtBackend;
pub use quant::{QTensor, QuantMode};
pub use reference::RefBackend;

use std::path::PathBuf;

/// Locate `artifacts/` relative to the crate root, overridable with
/// `LOSIA_ARTIFACTS`. Tests, benches, and examples all resolve through
/// this so they work from any working directory under the repo.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("LOSIA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.join("artifacts")
}
