//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! The interchange format is HLO *text* (see `python/compile/aot.py`);
//! `xla::HloModuleProto::from_text_file` reassigns instruction ids so
//! jax ≥ 0.5 modules round-trip into xla_extension 0.5.1 cleanly.

pub mod exec;
pub mod host;

pub use exec::{Executable, Runtime};
pub use host::HostValue;

use std::path::PathBuf;

/// Locate `artifacts/` relative to the crate root, overridable with
/// `LOSIA_ARTIFACTS`. Tests, benches, and examples all resolve through
/// this so they work from any working directory under the repo.
pub fn artifacts_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("LOSIA_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let manifest = PathBuf::from(env!("CARGO_MANIFEST_DIR"));
    manifest.join("artifacts")
}
