//! PJRT/XLA backend: compiles AOT-lowered HLO-text artifacts with a
//! PJRT CPU client and executes them.
//!
//! Input slots hold `xla::Literal`s — the host→device conversion
//! happens once per [`crate::runtime::ExecPlan::bind`], so static
//! bindings (frozen parameters) cost nothing on the per-step path.
//! Outputs stay as literals until an
//! [`crate::runtime::OutputHandle`] downloads them: the
//! literal→`Tensor` element copy is the device→host transfer this
//! backend defers, so an untouched output (a full-size gradient the
//! driver discards) never materialises host-side.
//!
//! Donation: PJRT input aliasing is fixed at compile time by the HLO
//! module, which `aot.py` does not emit — so `donate` here only drops
//! the donated literal after a successful execute (reclaiming its
//! memory) instead of aliasing. Binding semantics match the reference
//! backend exactly: a donated slot is consumed by every run.

use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::config::{ArtifactSpec, ModelCfg};
use crate::runtime::backend::{
    Backend, DeviceBuffers, DeviceValue, Executor, HostRef,
};
use crate::runtime::host::HostValue;
use crate::tensor::Tensor;

/// The PJRT CPU client shared by every executor it prepares.
pub struct PjrtBackend {
    client: xla::PjRtClient,
}

impl PjrtBackend {
    pub fn new() -> Result<Self> {
        let client =
            xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtBackend { client })
    }
}

impl Backend for PjrtBackend {
    fn name(&self) -> &'static str {
        "pjrt"
    }

    fn prepare(
        &self,
        cfg: &ModelCfg,
        spec: &ArtifactSpec,
    ) -> Result<Box<dyn Executor>> {
        let t0 = Instant::now();
        let path = spec.file.to_str().ok_or_else(|| {
            anyhow::anyhow!(
                "artifact {:?}: non-UTF-8 artifact path {:?} ({})",
                spec.name,
                spec.file,
                spec.signature()
            )
        })?;
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("loading {}", spec.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {:?}", spec.name))?;
        eprintln!(
            "[runtime] compiled {}/{} in {:.2}s",
            cfg.name,
            spec.name,
            t0.elapsed().as_secs_f64()
        );
        Ok(Box::new(PjrtExecutor {
            exe: Arc::new(exe),
            spec: Arc::new(spec.clone()),
        }))
    }
}

struct PjrtExecutor {
    exe: Arc<xla::PjRtLoadedExecutable>,
    spec: Arc<ArtifactSpec>,
}

// SAFETY: the `Executor` supertraits require Send + Sync.
// `PjRtLoadedExecutable` wraps a heap-owned C++ object whose
// `Execute` entry point PJRT documents as thread-safe, and this
// executor only ever reads it through `&self`; `ArtifactSpec` is
// plain data. Note the dp engine never actually drives PJRT plans
// from multiple threads — `dp::plan_count` gates parallel replication
// to the reference backend — so cross-thread use here is limited to
// moving an executor between threads, which the C++ object (no
// thread-affine state) supports.
unsafe impl Send for PjrtExecutor {}
unsafe impl Sync for PjrtExecutor {}

impl Executor for PjrtExecutor {
    fn alloc_buffers(&self) -> Box<dyn DeviceBuffers> {
        let slots =
            (0..self.spec.inputs.len()).map(|_| None).collect();
        Box::new(PjrtBuffers {
            exe: Arc::clone(&self.exe),
            spec: Arc::clone(&self.spec),
            slots,
            donated: vec![false; self.spec.inputs.len()],
        })
    }
}

struct PjrtBuffers {
    exe: Arc<xla::PjRtLoadedExecutable>,
    spec: Arc<ArtifactSpec>,
    slots: Vec<Option<xla::Literal>>,
    donated: Vec<bool>,
}

// SAFETY: the `DeviceBuffers` supertrait requires Send. `Literal` is
// heap-owned host memory with no thread affinity, and a buffer set is
// owned exclusively by one plan (never shared), so moving it between
// threads is sound.
unsafe impl Send for PjrtBuffers {}

/// One output literal, converted to a host `Tensor` only on download.
struct PjrtValue {
    lit: xla::Literal,
    shape: Vec<usize>,
}

// SAFETY: as for PjrtBuffers — an owned heap literal, moved not
// shared.
unsafe impl Send for PjrtValue {}

impl DeviceValue for PjrtValue {
    fn download(self: Box<Self>) -> Result<Tensor> {
        HostValue::f32_from_literal(&self.lit, &self.shape)
    }
}

fn to_literal(value: HostRef<'_>) -> Result<xla::Literal> {
    let dims: Vec<i64> =
        value.shape().iter().map(|&d| d as i64).collect();
    let lit = match value {
        HostRef::F32 { data, .. } => {
            xla::Literal::vec1(data).reshape(&dims)?
        }
        HostRef::I32 { data, .. } => {
            xla::Literal::vec1(data).reshape(&dims)?
        }
        HostRef::Q8 { shape, .. } => anyhow::bail!(
            "quantized (int8) bindings are not supported by the pjrt \
             backend yet — shape {shape:?} would need an int8 literal \
             and dequant-fused HLO; run with LOSIA_BACKEND=ref or \
             unset LOSIA_QUANT"
        ),
    };
    Ok(lit)
}

impl DeviceBuffers for PjrtBuffers {
    fn upload(&mut self, slot: usize, value: HostRef<'_>) -> Result<()> {
        self.slots[slot] = Some(to_literal(value)?);
        Ok(())
    }

    fn donate(&mut self, slot: usize) -> Result<()> {
        self.donated[slot] = true;
        Ok(())
    }

    fn execute(&mut self) -> Result<Vec<Box<dyn DeviceValue>>> {
        let mut literals = Vec::with_capacity(self.slots.len());
        for (i, slot) in self.slots.iter_mut().enumerate() {
            literals.push(slot.take().ok_or_else(|| {
                anyhow::anyhow!(
                    "artifact {:?}: input slot {i} ({:?}) was never \
                     uploaded",
                    self.spec.name,
                    self.spec.inputs[i].name
                )
            })?);
        }
        let run = self.exe.execute::<xla::Literal>(&literals);
        // Return the literals to their slots before error handling so
        // static bindings survive a failed execute. Donated slots are
        // consumed on success — their literals drop here, reclaiming
        // the storage the caller promised not to re-read.
        let ok = run.is_ok();
        for ((slot, donated), lit) in self
            .slots
            .iter_mut()
            .zip(&self.donated)
            .zip(literals)
        {
            if !(ok && *donated) {
                *slot = Some(lit);
            }
        }
        let result = run?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: always a tuple.
        let parts = result.to_tuple()?;
        anyhow::ensure!(
            parts.len() == self.spec.outputs.len(),
            "artifact {:?}: got {} outputs, manifest wants {}",
            self.spec.name,
            parts.len(),
            self.spec.outputs.len()
        );
        Ok(parts
            .into_iter()
            .zip(&self.spec.outputs)
            .map(|(lit, ospec)| {
                Box::new(PjrtValue {
                    lit,
                    shape: ospec.shape.clone(),
                }) as Box<dyn DeviceValue>
            })
            .collect())
    }
}
