//! Host-side values crossing the PJRT boundary.
//!
//! Artifacts take a flat list of tensors (f32 or i32) in manifest
//! order; [`HostValue`] is the typed wrapper that converts to/from
//! `xla::Literal` and validates shapes against the manifest spec.

use anyhow::{bail, Result};

use crate::config::{Dtype, TensorSpec};
use crate::runtime::quant::QTensor;
use crate::tensor::Tensor;

/// A host tensor: f32 (weights/activations), i32 (token ids, subnet
/// indices, probe selectors), or a block-quantized int8 weight
/// ([`QTensor`] — the `static_quantized` storage class for frozen
/// backbones; logically still an f32 tensor, checked against f32
/// manifest specs).
#[derive(Debug, Clone)]
pub enum HostValue {
    F32(Tensor),
    I32 { shape: Vec<usize>, data: Vec<i32> },
    Q8(QTensor),
}

impl HostValue {
    pub fn scalar_i32(v: i32) -> Self {
        HostValue::I32 {
            shape: vec![],
            data: vec![v],
        }
    }

    pub fn from_indices(shape: &[usize], idx: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), idx.len());
        HostValue::I32 {
            shape: shape.to_vec(),
            data: idx.iter().map(|&i| i as i32).collect(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32(t) => &t.shape,
            HostValue::I32 { shape, .. } => shape,
            HostValue::Q8(q) => &q.shape,
        }
    }

    /// The *logical* dtype: a quantized value reports `F32` (it
    /// stands in for an f32 manifest input; the int8 codes are a
    /// storage detail). Use [`Self::byte_len`] for the storage story.
    pub fn dtype(&self) -> Dtype {
        match self {
            HostValue::F32(_) => Dtype::F32,
            HostValue::I32 { .. } => Dtype::I32,
            HostValue::Q8(_) => Dtype::F32,
        }
    }

    /// Resident payload bytes of this value as stored: 4 B/element
    /// for f32/i32, codes + per-block scales for quantized.
    pub fn byte_len(&self) -> usize {
        match self {
            HostValue::F32(t) => t.data.len() * 4,
            HostValue::I32 { data, .. } => data.len() * 4,
            HostValue::Q8(q) => q.byte_len(),
        }
    }

    /// Borrow the f32 tensor; dtype mismatch is a typed error naming
    /// the actual shape/dtype instead of a panic mid-step.
    pub fn as_f32(&self) -> Result<&Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            HostValue::I32 { shape, .. } => bail!(
                "expected an f32 value, got i32 with shape {shape:?}"
            ),
            HostValue::Q8(q) => bail!(
                "expected a dense f32 value, got a block-quantized \
                 int8 tensor with shape {:?} (this consumer has no \
                 dequant-fused path)",
                q.shape
            ),
        }
    }

    /// Take the f32 tensor by value (same contract as [`Self::as_f32`]).
    pub fn into_f32(self) -> Result<Tensor> {
        match self {
            HostValue::F32(t) => Ok(t),
            HostValue::I32 { shape, .. } => bail!(
                "expected an f32 value, got i32 with shape {shape:?}"
            ),
            HostValue::Q8(q) => bail!(
                "expected a dense f32 value, got a block-quantized \
                 int8 tensor with shape {:?} (this consumer has no \
                 dequant-fused path)",
                q.shape
            ),
        }
    }

    /// Borrow the quantized payload; storage-class mismatch is a
    /// typed error.
    pub fn as_q8(&self) -> Result<&QTensor> {
        match self {
            HostValue::Q8(q) => Ok(q),
            other => bail!(
                "expected a block-quantized int8 value, got {:?} with \
                 shape {:?}",
                other.dtype(),
                other.shape()
            ),
        }
    }

    /// Borrow the i32 payload; dtype mismatch is a typed error.
    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostValue::I32 { data, .. } => Ok(data),
            HostValue::F32(t) => bail!(
                "expected an i32 value, got f32 with shape {:?}",
                t.shape
            ),
            HostValue::Q8(q) => bail!(
                "expected an i32 value, got a block-quantized int8 \
                 tensor with shape {:?}",
                q.shape
            ),
        }
    }

    /// Validate against a manifest spec (shape + dtype). One
    /// implementation shared with the borrowed upload path
    /// ([`crate::runtime::HostRef::check`]); plan-level binds wrap the
    /// error with the artifact's full manifest signature.
    pub fn check(&self, spec: &TensorSpec) -> Result<()> {
        crate::runtime::HostRef::from(self).check(spec)
    }

    /// Read an f32 literal back into a [`Tensor`] with the given shape.
    pub fn f32_from_literal(
        lit: &xla::Literal,
        shape: &[usize],
    ) -> Result<Tensor> {
        let data = lit.to_vec::<f32>()?;
        if data.len() != shape.iter().product::<usize>() {
            bail!(
                "literal has {} elements, expected shape {:?}",
                data.len(),
                shape
            );
        }
        Ok(Tensor::from_vec(shape, data))
    }
}

impl From<Tensor> for HostValue {
    fn from(t: Tensor) -> Self {
        HostValue::F32(t)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_check_catches_mismatch() {
        let spec = TensorSpec {
            name: "x".into(),
            shape: vec![2, 3],
            dtype: Dtype::F32,
        };
        let good = HostValue::F32(Tensor::zeros(&[2, 3]));
        assert!(good.check(&spec).is_ok());
        let bad_shape = HostValue::F32(Tensor::zeros(&[3, 2]));
        assert!(bad_shape.check(&spec).is_err());
        let bad_dtype = HostValue::from_indices(&[2, 3], &[0; 6]);
        assert!(bad_dtype.check(&spec).is_err());
    }

    #[test]
    fn typed_accessors_return_errors_not_panics() {
        let f = HostValue::F32(Tensor::zeros(&[2]));
        assert!(f.as_f32().is_ok());
        assert!(f.as_i32().is_err());
        let i = HostValue::scalar_i32(3);
        assert!(i.as_i32().is_ok());
        let err = i.as_f32().unwrap_err().to_string();
        assert!(err.contains("i32"), "{err}");
        assert!(i.into_f32().is_err());
    }

    #[test]
    fn index_conversion() {
        let hv = HostValue::from_indices(&[4], &[1, 2, 3, 4]);
        match &hv {
            HostValue::I32 { data, .. } => {
                assert_eq!(data, &vec![1, 2, 3, 4])
            }
            _ => panic!(),
        }
    }
}
