//! Eval harness integration: PPL option scoring, generation, and the
//! untrained-model chance-level sanity checks.

use losia::coordinator::state::ModelState;
use losia::data::commonsense::suite;
use losia::data::domain::{KvFacts, ModMath};
use losia::data::{gen_eval_set, Task};
use losia::eval::generate::Generator;
use losia::eval::{pass_at_k, ppl_accuracy, ppl_accuracy_by_category};
use losia::runtime::Runtime;
use losia::util::rng::Rng;

fn fresh(rt: &Runtime, seed: u64) -> ModelState {
    let mut rng = Rng::new(seed);
    ModelState::init(&rt.cfg, &mut rng)
}

#[test]
fn untrained_model_scores_near_chance_on_10way() {
    let rt = Runtime::from_config_name("tiny").unwrap();
    let state = fresh(&rt, 0);
    let items = gen_eval_set(&ModMath, 120, 3);
    let acc = ppl_accuracy(&rt, &state, &items).unwrap();
    // 10 options → chance 10%; untrained should sit well below 40%
    assert!(acc < 40.0, "suspiciously high untrained acc {acc}");
    assert!(acc >= 0.0);
}

#[test]
fn category_breakdown_sums_consistently() {
    let rt = Runtime::from_config_name("tiny").unwrap();
    let state = fresh(&rt, 1);
    let kv = KvFacts::new(16, 4, 5);
    let items = gen_eval_set(&kv, 80, 4);
    let by_cat =
        ppl_accuracy_by_category(&rt, &state, &items).unwrap();
    assert!(by_cat.contains_key("__all__"));
    // overall accuracy must lie within [min, max] of categories
    let cats: Vec<f64> = by_cat
        .iter()
        .filter(|(k, _)| *k != "__all__")
        .map(|(_, v)| *v)
        .collect();
    assert!(!cats.is_empty());
    let lo = cats.iter().cloned().fold(f64::INFINITY, f64::min);
    let hi = cats.iter().cloned().fold(0.0f64, f64::max);
    let all = by_cat["__all__"];
    assert!(all >= lo - 1e-9 && all <= hi + 1e-9);
}

#[test]
fn nan_nll_scores_as_incorrect_instead_of_panicking() {
    // Regression: a divergent run (NaN weights → NaN NLL for every
    // option) used to panic the whole eval pass inside a
    // `partial_cmp().unwrap()` min-by. It must now complete and score
    // every item as incorrect.
    let rt = Runtime::from_config_name("tiny").unwrap();
    let mut state = fresh(&rt, 6);
    state.get_mut("lm_head").data.fill(f32::NAN);
    let items = gen_eval_set(&ModMath, 16, 5);
    let acc = ppl_accuracy(&rt, &state, &items).unwrap();
    assert_eq!(acc, 0.0, "all-NaN options cannot be correct");
    let by_cat =
        ppl_accuracy_by_category(&rt, &state, &items).unwrap();
    assert_eq!(by_cat["__all__"], 0.0);
}

#[test]
fn generator_emits_tokens_within_vocab() {
    let rt = Runtime::from_config_name("tiny").unwrap();
    let state = fresh(&rt, 2);
    let mut gen = Generator::new(&rt, &state).unwrap();
    let mut rng = Rng::new(0);
    let prompts = vec![vec![5u32, 15, 6, 3]; 2];
    let outs = gen.generate(&prompts, 4, 0.0, &mut rng).unwrap();
    assert_eq!(outs.len(), 2);
    for o in &outs {
        assert!(o.len() <= 4);
        assert!(o.iter().all(|&t| (t as usize) < rt.cfg.vocab));
    }
    // greedy decoding is deterministic
    let outs2 = gen.generate(&prompts, 4, 0.0, &mut rng).unwrap();
    assert_eq!(outs, outs2);
}

#[test]
fn sampling_respects_temperature_diversity() {
    let rt = Runtime::from_config_name("tiny").unwrap();
    let state = fresh(&rt, 3);
    let mut gen = Generator::new(&rt, &state).unwrap();
    let mut rng = Rng::new(7);
    let prompt = vec![vec![5u32, 15, 6, 3]; 4];
    // high temperature across 4 parallel samples: expect ≥ 2 distinct
    let outs = gen.generate(&prompt, 3, 2.0, &mut rng).unwrap();
    let distinct: std::collections::BTreeSet<_> =
        outs.iter().collect();
    assert!(distinct.len() >= 2, "temperature produced no diversity");
}

#[test]
fn pass_at_k_is_monotone_in_k() {
    let rt = Runtime::from_config_name("tiny").unwrap();
    let state = fresh(&rt, 4);
    let items = gen_eval_set(&ModMath, 12, 9);
    let p1 = pass_at_k(&rt, &state, &items, 1, 0.8, 5).unwrap();
    let p4 = pass_at_k(&rt, &state, &items, 4, 0.8, 5).unwrap();
    assert!(p4 >= p1 - 1e-9, "pass@4 {p4} < pass@1 {p1}");
}

#[test]
fn commonsense_suite_is_scoreable() {
    let rt = Runtime::from_config_name("tiny").unwrap();
    let state = fresh(&rt, 5);
    for task in suite().iter().take(3) {
        let items = gen_eval_set(task.as_ref(), 24, 11);
        let acc = ppl_accuracy(&rt, &state, &items).unwrap();
        assert!((0.0..=100.0).contains(&acc), "{}", task.name());
    }
}
