//! Backend parity: the pure-Rust `RefBackend` must reproduce the
//! PJRT/XLA path within f32 tolerance — per-artifact outputs and
//! end-to-end training steps for every method.
//!
//! These tests self-skip when no lowered artifacts are present (the
//! RefBackend-only CI lane); the XLA lane runs them for real.

use losia::config::{Method, TrainConfig};
use losia::coordinator::state::ModelState;
use losia::runtime::{
    artifacts_dir, HostValue, PjrtBackend, RefBackend, Runtime,
};
use losia::session::Session;
use losia::tensor::Tensor;
use losia::util::rng::Rng;

/// Both runtimes over the SAME manifest config, or None when the XLA
/// side is unavailable in this checkout.
fn runtimes() -> Option<(Runtime, Runtime)> {
    let dir = artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("[parity] no artifacts — skipping");
        return None;
    }
    let cfg = losia::config::load_manifest(&dir, "tiny").ok()?;
    let pjrt = match PjrtBackend::new() {
        Ok(b) => Runtime::with_backend(cfg.clone(), Box::new(b)),
        Err(e) => {
            eprintln!("[parity] no PJRT client ({e}) — skipping");
            return None;
        }
    };
    let reff = Runtime::with_backend(cfg, Box::new(RefBackend));
    Some((pjrt, reff))
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn artifact_inputs(rt: &Runtime, name: &str, seed: u64) -> Vec<HostValue> {
    let spec = rt.cfg.artifact(name).clone();
    let mut rng = Rng::new(seed);
    spec.inputs
        .iter()
        .map(|i| match i.dtype {
            losia::config::Dtype::F32 => {
                if i.name == "mask" || i.name.starts_with("norm") {
                    HostValue::F32(Tensor::ones(&i.shape))
                } else {
                    HostValue::F32(Tensor::randn(&i.shape, 0.05, &mut rng))
                }
            }
            losia::config::Dtype::I32 => {
                let n: usize = i.shape.iter().product();
                let data: Vec<usize> =
                    (0..n).map(|_| rng.below(4)).collect();
                HostValue::from_indices(&i.shape, &data)
            }
        })
        .collect()
}

#[test]
fn artifact_outputs_match_across_backends() {
    let Some((pjrt, reff)) = runtimes() else { return };
    for name in
        ["fwd_logits", "fwd_loss", "grads_full", "grads_probe"]
    {
        let inputs = artifact_inputs(&pjrt, name, 11);
        let a = pjrt.load(name).unwrap().run(&inputs).unwrap();
        let b = reff.load(name).unwrap().run(&inputs).unwrap();
        assert_eq!(a.len(), b.len(), "{name}: output arity");
        for (i, (ta, tb)) in a.iter().zip(&b).enumerate() {
            assert_eq!(ta.shape, tb.shape, "{name}[{i}]: shape");
            let scale = ta
                .data
                .iter()
                .map(|v| v.abs())
                .fold(0.0f32, f32::max)
                .max(1.0);
            let diff = max_abs_diff(&ta.data, &tb.data);
            assert!(
                diff <= 2e-3 * scale,
                "{name} output {i} ({:?}): max diff {diff} vs \
                 scale {scale}",
                pjrt.cfg.artifact(name).outputs[i].name
            );
        }
    }
}

fn train_on(
    rt: &Runtime,
    method: Method,
    steps: usize,
) -> (ModelState, Vec<(usize, f64)>) {
    let tc = TrainConfig {
        method,
        steps,
        lr: 2e-3,
        time_slot: 2, // force a relocalization inside 6 steps
        seed: 13,
        ..TrainConfig::default()
    };
    let mut s = Session::builder()
        .runtime(rt)
        .train_config(tc)
        .task("modmath")
        .train_n(128)
        .model_seed(13)
        .data_seed(13)
        .batcher_seed(13)
        .build()
        .unwrap();
    let report = s.train().unwrap();
    (s.into_state(), report.loss_curve)
}

#[test]
fn every_method_trains_identically_on_both_backends() {
    let Some((pjrt, reff)) = runtimes() else { return };
    for method in [
        Method::Fft,
        Method::Lora,
        Method::Pissa,
        Method::Dora,
        Method::Galore,
        Method::Losia,
        Method::LosiaPro,
    ] {
        let steps = 6;
        let (sa, la) = train_on(&pjrt, method, steps);
        let (sb, lb) = train_on(&reff, method, steps);
        assert_eq!(la.len(), lb.len(), "{}", method.name());
        for ((_, a), (_, b)) in la.iter().zip(&lb) {
            assert!(
                (a - b).abs() < 5e-3,
                "{}: loss diverged {a} vs {b}",
                method.name()
            );
        }
        let mut worst = 0.0f32;
        for ((_, ta), (_, tb)) in sa.params.iter().zip(&sb.params) {
            worst = worst.max(max_abs_diff(&ta.data, &tb.data));
        }
        assert!(
            worst < 5e-3,
            "{}: weights diverged by {worst}",
            method.name()
        );
    }
}
