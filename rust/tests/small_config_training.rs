//! Training integration on the `small` builtin config through the
//! pure-Rust reference backend.
//!
//! The naive single-threaded interpreter could only afford `tiny`
//! here; the blocked/row-parallel kernel layer
//! (`runtime::kernels`) makes `small` cheap enough for the
//! no-artifact CI lane. The runtime is built from the builtin config
//! zoo directly, so these tests behave identically whether or not
//! lowered artifacts are present.

use losia::config::Method;
use losia::runtime::{RefBackend, Runtime};
use losia::session::Session;

fn small_ref_runtime() -> Runtime {
    let dir = losia::runtime::artifacts_dir();
    let cfg = losia::config::builtin_config("small", &dir)
        .expect("small builtin config");
    Runtime::with_backend(cfg, Box::new(RefBackend))
}

#[test]
fn losia_pro_trains_on_small_config() {
    let rt = small_ref_runtime();
    assert_eq!(rt.cfg.d_model, 128, "small config shape");
    let mut session = Session::builder()
        .runtime(&rt)
        .method(Method::LosiaPro)
        .task("modmath")
        .steps(6)
        .time_slot(3)
        .lr(1e-3)
        .train_n(64)
        .eval_n(0)
        .build()
        .unwrap();
    let report = session.train().unwrap();
    let first = report.first_loss.expect("first loss");
    let last = report.final_loss.expect("final loss");
    assert!(first.is_finite() && first > 0.0, "first loss {first}");
    assert!(last.is_finite() && last > 0.0, "final loss {last}");
    assert!(
        last < first * 1.5,
        "loss exploded on small config: {first} → {last}"
    );
}

#[test]
fn lora_trains_and_evals_on_small_config() {
    let rt = small_ref_runtime();
    let mut session = Session::builder()
        .runtime(&rt)
        .method(Method::Lora)
        .task("modmath")
        .steps(4)
        .lr(1e-3)
        .train_n(64)
        .eval_n(8)
        .build()
        .unwrap();
    let report = session.train().unwrap();
    assert!(report.final_loss.expect("final loss").is_finite());
    let acc = report.ppl_acc_post.expect("post-train ppl accuracy");
    assert!((0.0..=100.0).contains(&acc), "acc {acc}");
}
