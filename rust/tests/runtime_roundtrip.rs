//! Integration: load the tiny config on whichever backend the
//! environment selects (PJRT with artifacts, the reference
//! interpreter without), execute fwd/grads, check numerics.

use losia::config::Dtype;
use losia::runtime::{HostValue, Runtime};
use losia::tensor::Tensor;
use losia::util::rng::Rng;

fn init_inputs(rt: &Runtime, name: &str, rng: &mut Rng) -> Vec<HostValue> {
    let spec = rt.cfg.artifact(name).clone();
    spec.inputs
        .iter()
        .map(|i| match i.dtype {
            Dtype::F32 => {
                if i.name == "mask" {
                    HostValue::F32(Tensor::ones(&i.shape))
                } else if i.name.starts_with("norm") {
                    HostValue::F32(Tensor::ones(&i.shape))
                } else {
                    HostValue::F32(Tensor::randn(&i.shape, 0.05, rng))
                }
            }
            Dtype::I32 => {
                let n: usize = i.shape.iter().product();
                let data: Vec<usize> =
                    (0..n).map(|_| rng.below(4)).collect();
                HostValue::from_indices(&i.shape, &data)
            }
        })
        .collect()
}

#[test]
fn fwd_logits_shape_and_finiteness() {
    let rt = Runtime::from_config_name("tiny").unwrap();
    let exe = rt.load("fwd_logits").unwrap();
    let mut rng = Rng::new(0);
    let inputs = init_inputs(&rt, "fwd_logits", &mut rng);
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(
        out[0].shape,
        vec![rt.cfg.batch, rt.cfg.seq_len, rt.cfg.vocab]
    );
    assert!(out[0].data.iter().all(|v| v.is_finite()));
}

#[test]
fn executables_outlive_the_runtime() {
    // `Runtime::load` hands out `Arc<Executable>` (no more leaked
    // statics): an executable keeps working after its runtime drops.
    let rt = Runtime::from_config_name("tiny").unwrap();
    let exe = rt.load("fwd_logits").unwrap();
    let mut rng = Rng::new(7);
    let inputs = init_inputs(&rt, "fwd_logits", &mut rng);
    drop(rt);
    let out = exe.run(&inputs).unwrap();
    assert_eq!(out.len(), 1);
    let stats = exe.stats();
    assert_eq!(stats.calls, 1);
    assert!(stats.step_uploads > 0);
}

#[test]
fn grads_full_loss_positive() {
    let rt = Runtime::from_config_name("tiny").unwrap();
    let exe = rt.load("grads_full").unwrap();
    let mut rng = Rng::new(1);
    let inputs = init_inputs(&rt, "grads_full", &mut rng);
    let out = exe.run(&inputs).unwrap();
    let loss = out[0].data[0];
    assert!(loss.is_finite() && loss > 0.0, "loss={loss}");
    // gradient of embed should be non-zero
    assert!(out[1].frob_norm() > 0.0);
}
