//! The fault matrix: every named `LOSIA_FAULT` site is armed in turn
//! and the run must fail the way the contract in `runtime/README.md`
//! promises — typed errors, contained worker panics, and a checkpoint
//! directory that always holds a loadable record.
//!
//! The recovery half is covered too: after each simulated crash the
//! same configuration is re-run with `--resume` and must finish
//! **bitwise identical** to a run that never crashed (torn bytes,
//! leftover `.tmp` files, and skipped checkpoints included).
//!
//! `LOSIA_FAULT` is process-global, so every test here serializes on
//! one lock — including the ones that never arm a fault, which would
//! otherwise train under a neighbour's armed site.

use std::path::{Path, PathBuf};
use std::sync::Mutex;

use losia::config::Method;
use losia::coordinator::checkpoint;
use losia::coordinator::state::ModelState;
use losia::runtime::{RefBackend, Runtime};
use losia::session::{RunReport, Session};
use losia::util::error::TrainError;
use losia::util::{durable, faultpoint};

static FAULT_LOCK: Mutex<()> = Mutex::new(());

/// Arms a fault spec for the duration of a scope; disarms on drop so
/// a failed assertion cannot leak the spec into the next test.
struct Arm;
impl Arm {
    fn set(spec: &str) -> Arm {
        std::env::set_var(faultpoint::ENV, spec);
        Arm
    }
}
impl Drop for Arm {
    fn drop(&mut self) {
        std::env::remove_var(faultpoint::ENV);
    }
}

fn small_ref_runtime() -> Runtime {
    let dir = losia::runtime::artifacts_dir();
    let cfg = losia::config::builtin_config("small", &dir)
        .expect("small builtin config");
    Runtime::with_backend(cfg, Box::new(RefBackend))
}

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "losia_crash_{tag}_{}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    dir
}

struct RunSpec<'a> {
    method: Method,
    workers: usize,
    shards: usize,
    pipeline: bool,
    steps: usize,
    ckpt: Option<(&'a Path, usize, usize, bool)>,
}

impl Default for RunSpec<'_> {
    fn default() -> Self {
        RunSpec {
            method: Method::LosiaPro,
            workers: 1,
            shards: 2,
            pipeline: false,
            steps: 6,
            ckpt: None,
        }
    }
}

fn run(spec: RunSpec<'_>) -> anyhow::Result<(RunReport, ModelState)> {
    let rt = small_ref_runtime();
    let mut b = Session::builder()
        .runtime(&rt)
        .method(spec.method)
        .task("modmath")
        .steps(spec.steps)
        .time_slot(3)
        .lr(1e-3)
        .train_n(64)
        .eval_n(0)
        .workers(spec.workers)
        .dp_shards(spec.shards)
        .pipeline(spec.pipeline);
    if let Some((dir, every, keep, resume)) = spec.ckpt {
        b = b
            .checkpoint_every(every)
            .checkpoint_dir(dir)
            .checkpoint_keep(keep)
            .resume(resume);
    }
    let mut session = b.build()?;
    let report = session.train()?;
    Ok((report, session.into_state()))
}

fn assert_states_bitwise_eq(a: &ModelState, b: &ModelState, what: &str) {
    assert_eq!(a.params.len(), b.params.len(), "{what}: param count");
    for ((na, ta), (nb, tb)) in a.params.iter().zip(&b.params) {
        assert_eq!(na, nb, "{what}: param order");
        for (ei, (x, y)) in ta.data.iter().zip(&tb.data).enumerate() {
            assert_eq!(
                x.to_bits(),
                y.to_bits(),
                "{what}: {na}[{ei}] differs ({x} vs {y}) — recovery \
                 changed the numerics"
            );
        }
    }
}

fn fault_injected(err: &anyhow::Error, want_site: &str) {
    match err.downcast_ref::<TrainError>() {
        Some(TrainError::FaultInjected { site, .. }) => {
            assert_eq!(site, want_site)
        }
        other => panic!(
            "expected FaultInjected at {want_site}, got {other:?} \
             ({err:#})"
        ),
    }
}

fn worker_panic(err: &anyhow::Error, want_site: &str) {
    match err.downcast_ref::<TrainError>() {
        Some(TrainError::WorkerPanic { site }) => assert!(
            site.contains(want_site),
            "panic contained at {site:?}, expected {want_site:?}"
        ),
        other => panic!(
            "expected WorkerPanic at {want_site}, got {other:?} \
             ({err:#})"
        ),
    }
}

fn tmp_files(dir: &Path) -> Vec<PathBuf> {
    std::fs::read_dir(dir)
        .map(|rd| {
            rd.flatten()
                .map(|e| e.path())
                .filter(|p| durable::is_tmp(p))
                .collect()
        })
        .unwrap_or_default()
}

/// A crash during the step-4 save (the write errors before any byte
/// lands) aborts the run with the typed fault; the step-2 record
/// survives and a `--resume` run finishes on the uninterrupted bits.
#[test]
fn failed_save_aborts_and_prior_checkpoint_resumes_bitwise() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (_, base) = run(RunSpec::default()).unwrap();
    let dir = tmp_dir("save_error");
    let err = {
        let _arm = Arm::set("save@4:error");
        run(RunSpec {
            ckpt: Some((&dir, 2, 4, false)),
            ..RunSpec::default()
        })
        .unwrap_err()
    };
    fault_injected(&err, "save");
    let steps: Vec<usize> =
        checkpoint::list(&dir).into_iter().map(|(s, _)| s).collect();
    assert_eq!(steps, [2], "only the step-2 record survives the crash");
    let (report, state) = run(RunSpec {
        ckpt: Some((&dir, 2, 4, true)),
        ..RunSpec::default()
    })
    .unwrap();
    let ck = report.checkpoint.as_ref().expect("checkpoint block");
    assert_eq!(ck.resume_step, Some(2), "resumed from the survivor");
    assert_states_bitwise_eq(&base, &state, "save-error recovery");
    std::fs::remove_dir_all(&dir).ok();
}

/// A `partial` save tears the `.tmp` mid-write and never renames: the
/// destination path must not exist, the torn `.tmp` is left behind,
/// readers skip it, and the resumed run's rotation sweeps it away.
#[test]
fn partial_save_never_tears_the_destination() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (_, base) = run(RunSpec::default()).unwrap();
    let dir = tmp_dir("save_partial");
    let err = {
        let _arm = Arm::set("save@4:partial");
        run(RunSpec {
            ckpt: Some((&dir, 2, 4, false)),
            ..RunSpec::default()
        })
        .unwrap_err()
    };
    fault_injected(&err, "save");
    assert!(
        !checkpoint::checkpoint_path(&dir, 4).exists(),
        "the torn write must never reach the destination path"
    );
    assert!(
        !tmp_files(&dir).is_empty(),
        "the crash leaves its torn .tmp behind"
    );
    let rt = small_ref_runtime();
    let (ck, path) = checkpoint::load_latest(&dir, &rt.cfg)
        .unwrap()
        .expect("step-2 record still loads");
    assert_eq!(ck.step, 2, "newest loadable record: {}", path.display());
    drop(rt);
    let (_, state) = run(RunSpec {
        ckpt: Some((&dir, 2, 4, true)),
        ..RunSpec::default()
    })
    .unwrap();
    assert_states_bitwise_eq(&base, &state, "partial-save recovery");
    assert!(
        tmp_files(&dir).is_empty(),
        "rotation sweeps the torn .tmp once writes succeed again"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// Flipping bytes in the newest record (a torn disk, not a torn
/// write) must not strand the run: `load_latest` skips the corrupt
/// file with a warning and resumes from the previous one.
#[test]
fn corrupt_newest_checkpoint_falls_back_to_previous() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (_, base) = run(RunSpec::default()).unwrap();
    let dir = tmp_dir("corrupt");
    run(RunSpec {
        steps: 4,
        ckpt: Some((&dir, 2, 4, false)),
        ..RunSpec::default()
    })
    .unwrap();
    // truncate the step-4 record mid-payload
    let victim = checkpoint::checkpoint_path(&dir, 4);
    let len = std::fs::metadata(&victim).unwrap().len();
    let f = std::fs::OpenOptions::new()
        .write(true)
        .open(&victim)
        .unwrap();
    f.set_len(len / 2).unwrap();
    drop(f);
    let (report, state) = run(RunSpec {
        ckpt: Some((&dir, 2, 4, true)),
        ..RunSpec::default()
    })
    .unwrap();
    let ck = report.checkpoint.as_ref().expect("checkpoint block");
    assert_eq!(
        ck.resume_step,
        Some(2),
        "resume skipped the corrupt step-4 record"
    );
    assert_states_bitwise_eq(&base, &state, "corrupt-record recovery");
    std::fs::remove_dir_all(&dir).ok();
}

/// CRC corruption (same length, flipped byte) is caught too — the
/// loader reports a typed mismatch and `load_latest` falls through.
#[test]
fn bitflipped_checkpoint_is_rejected_by_crc() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = tmp_dir("bitflip");
    run(RunSpec {
        steps: 2,
        ckpt: Some((&dir, 2, 4, false)),
        ..RunSpec::default()
    })
    .unwrap();
    let victim = checkpoint::checkpoint_path(&dir, 2);
    let mut bytes = std::fs::read(&victim).unwrap();
    let mid = bytes.len() / 2;
    bytes[mid] ^= 0xA5;
    std::fs::write(&victim, &bytes).unwrap();
    let rt = small_ref_runtime();
    let err = checkpoint::TrainCheckpoint::load(&victim, &rt.cfg)
        .expect_err("flipped byte must not load");
    let msg = format!("{err:#}");
    assert!(
        matches!(
            err.downcast_ref::<TrainError>(),
            Some(
                TrainError::CrcMismatch { .. }
                    | TrainError::Truncated { .. }
            )
        ),
        "typed corruption error, got: {msg}"
    );
    assert!(
        checkpoint::load_latest(&dir, &rt.cfg).unwrap().is_none(),
        "no loadable record remains"
    );
    std::fs::remove_dir_all(&dir).ok();
}

/// `--resume` against an empty directory is a warning, not an error:
/// the run starts fresh and still matches the uninterrupted bits.
#[test]
fn resume_with_no_checkpoints_starts_fresh() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (_, base) = run(RunSpec::default()).unwrap();
    let dir = tmp_dir("fresh");
    let cap = losia::util::warn::capture();
    let (report, state) = run(RunSpec {
        ckpt: Some((&dir, 2, 4, true)),
        ..RunSpec::default()
    })
    .unwrap();
    let warnings = cap.drain();
    assert!(
        warnings.iter().any(|w| w.contains("starting fresh")),
        "fresh start is surfaced as a warning: {warnings:?}"
    );
    let ck = report.checkpoint.as_ref().expect("checkpoint block");
    assert_eq!(ck.resume_step, None, "nothing to resume from");
    assert_eq!(ck.writes, 3, "steps 2, 4, 6 write");
    assert_states_bitwise_eq(&base, &state, "fresh-start fallback");
    std::fs::remove_dir_all(&dir).ok();
}

/// Rotation: `keep = 2` with a checkpoint every step leaves exactly
/// the two newest records on disk.
#[test]
fn rotation_keeps_only_the_newest_records() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let dir = tmp_dir("rotate");
    run(RunSpec {
        ckpt: Some((&dir, 1, 2, false)),
        ..RunSpec::default()
    })
    .unwrap();
    let steps: Vec<usize> =
        checkpoint::list(&dir).into_iter().map(|(s, _)| s).collect();
    assert_eq!(steps, [5, 6], "keep=2 retains the two newest");
    std::fs::remove_dir_all(&dir).ok();
}

/// A dp worker that panics mid-step is joined and surfaced as a typed
/// [`TrainError::WorkerPanic`] — the test completing at all proves
/// nothing hangs on a dead sibling's channel.
#[test]
fn dp_worker_panic_is_contained() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _arm = Arm::set("dp-worker@3:panic");
    let err = run(RunSpec {
        workers: 2,
        ..RunSpec::default()
    })
    .unwrap_err();
    worker_panic(&err, "dp-worker");
}

/// An injected reduce failure surfaces as the typed fault with the
/// step it fired at.
#[test]
fn reduce_fault_surfaces_typed() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _arm = Arm::set("reduce@3:error");
    let err = run(RunSpec {
        workers: 2,
        ..RunSpec::default()
    })
    .unwrap_err();
    match err.downcast_ref::<TrainError>() {
        Some(TrainError::FaultInjected { site, step }) => {
            assert_eq!(site, "reduce");
            assert_eq!(*step, 3);
        }
        other => panic!("wrong variant: {other:?} ({err:#})"),
    }
}

/// A pipeline stage worker that panics while staging is contained —
/// the training thread gets the typed error instead of deadlocking on
/// a staging handoff that will never arrive.
#[test]
fn stage_worker_panic_is_contained() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _arm = Arm::set("stage-worker@*:panic");
    let err = run(RunSpec {
        workers: 2,
        pipeline: true,
        ..RunSpec::default()
    })
    .unwrap_err();
    worker_panic(&err, "stage-worker");
}

/// Same containment for the async batch prefetcher.
#[test]
fn prefetch_worker_panic_is_contained() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let _arm = Arm::set("prefetch-worker@*:panic");
    let err = run(RunSpec {
        workers: 2,
        pipeline: true,
        ..RunSpec::default()
    })
    .unwrap_err();
    worker_panic(&err, "prefetch-worker");
}

/// End-to-end kill/recover drill across *both* loop shapes: crash the
/// pipelined run at the step-4 save, resume synchronously (and the
/// other way round) — the checkpoint format owes nothing to the loop
/// that wrote it.
#[test]
fn resume_crosses_loop_shapes() {
    let _guard = FAULT_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let (_, base) = run(RunSpec::default()).unwrap();
    for (crash_pipe, resume_pipe) in [(true, false), (false, true)] {
        let dir = tmp_dir(&format!("cross_{crash_pipe}"));
        let err = {
            let _arm = Arm::set("save@4:error");
            run(RunSpec {
                workers: 2,
                pipeline: crash_pipe,
                ckpt: Some((&dir, 2, 4, false)),
                ..RunSpec::default()
            })
            .unwrap_err()
        };
        fault_injected(&err, "save");
        let (report, state) = run(RunSpec {
            workers: 2,
            pipeline: resume_pipe,
            ckpt: Some((&dir, 2, 4, true)),
            ..RunSpec::default()
        })
        .unwrap();
        let what = format!(
            "crash in {} loop, resume in {} loop",
            if crash_pipe { "pipelined" } else { "sync" },
            if resume_pipe { "pipelined" } else { "sync" },
        );
        assert_eq!(
            report.checkpoint.as_ref().unwrap().resume_step,
            Some(2),
            "{what}"
        );
        assert_states_bitwise_eq(&base, &state, &what);
        std::fs::remove_dir_all(&dir).ok();
    }
}
